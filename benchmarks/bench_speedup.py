"""Paper Fig. 10: performance/energy vs MCU and classic CGRA.

For each (workload x dataset group): cycle-model times for MCU (64MHz),
op-centric CGRA (100MHz), and the FLIP simulator (100MHz); reports
speedups and MTEPS (Table 5 row). Energy uses the paper's power numbers
(MCU 0.78mW core-only, CGRA 17mW, FLIP 26mW).
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit
from repro.core import PROGRAMS, baselines, compile_mapping, simulate
from repro.graphs import make_dataset

POWER_MW = {"mcu": 0.78, "cgra": 17.0, "flip": 26.0}


def run(groups=("SRN", "LRN", "Tree", "Syn"), algos=("bfs", "sssp", "wcc"),
        graphs_per_group: int = 3, sources_per_graph: int = 3,
        effort: int = 1, **kwargs):
    rng = np.random.default_rng(0)
    results = {}
    skip = kwargs.get("skip", ())
    for grp in groups:
        for algo in algos:
            if (grp, algo) in skip:
                emit(f"fig10_{grp}_{algo}", 0.0, "skipped_in_fast_mode")
                continue
            t_mcu, t_cgra, t_flip, edges = [], [], [], []
            for gi, g in enumerate(make_dataset(grp, graphs_per_group)):
                mapping = compile_mapping(g, effort=effort, seed=gi,
                                          program=PROGRAMS[algo])
                srcs = [0] if grp == "Tree" else rng.integers(
                    0, g.n, sources_per_graph)
                for src in srcs:
                    src = int(src)
                    r = simulate(mapping, PROGRAMS[algo], src=src)
                    t_flip.append(r.cycles / mapping.arch.freq_mhz)
                    t_mcu.append(baselines.mcu_cycles(algo, g, src).time_us)
                    t_cgra.append(baselines.cgra_cycles(algo, g,
                                                        src).time_us)
                    edges.append(g.m)
            s_mcu = np.mean(np.asarray(t_mcu) / np.asarray(t_flip))
            s_cgra = np.mean(np.asarray(t_cgra) / np.asarray(t_flip))
            mteps = np.mean(np.asarray(edges) / np.asarray(t_flip))
            e_mcu = np.mean(np.asarray(t_mcu)) * POWER_MW["mcu"]
            e_flip = np.mean(np.asarray(t_flip)) * POWER_MW["flip"]
            results[(grp, algo)] = (s_mcu, s_cgra, mteps, e_flip / e_mcu)
            emit(f"fig10_{grp}_{algo}", float(np.mean(t_flip)),
                 f"speedup_vs_mcu={s_mcu:.1f}x "
                 f"speedup_vs_cgra={s_cgra:.1f}x flip_mteps={mteps:.0f} "
                 f"energy_vs_mcu={e_flip / e_mcu:.2f}")
    return results


def main():
    run()


if __name__ == "__main__":
    main()
