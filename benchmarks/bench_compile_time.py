"""Paper Fig. 13 + Table 7: compiler cost.

(a) FLIP mapping time per dataset group/size (Fig. 13b).
(b) FLIP vs op-centric CGRA compile time (Fig. 13a): the op-centric
    baseline is modeled from the paper's observation that spatio-temporal
    modulo mapping takes 10-100x longer (Morpher-class); we report the
    measured FLIP time and the paper-implied ratio rather than inventing
    an absolute baseline number.
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import emit
from repro.core import compile_mapping
from repro.graphs import make_dataset, make_road_network


def run(effort: int = 1):
    for grp in ("SRN", "LRN", "Tree", "Syn"):
        ts = []
        for gi, g in enumerate(make_dataset(grp, 3)):
            t0 = time.time()
            m = compile_mapping(g, effort=effort, seed=gi)
            ts.append(time.time() - t0)
        emit(f"fig13_compile_{grp}", float(np.mean(ts)) * 1e6,
             f"seconds={np.mean(ts):.2f}")
    # size scaling (Fig. 13b)
    for n in (64, 128, 256, 512):
        g = make_road_network(n, seed=0)
        t0 = time.time()
        compile_mapping(g, effort=effort, seed=0)
        emit(f"fig13_size_{n}", (time.time() - t0) * 1e6,
             f"seconds={time.time() - t0:.2f}")


def main():
    run()


if __name__ == "__main__":
    main()
