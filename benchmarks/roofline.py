"""Roofline report: aggregates experiments/dryrun/*.json into the
EXPERIMENTS.md tables (§Dry-run, §Roofline)."""
from __future__ import annotations

import glob
import json
import os

from benchmarks.common import emit


def load_cells(directory: str = "experiments/dryrun"):
    cells = []
    for path in sorted(glob.glob(os.path.join(directory, "*.json"))):
        with open(path) as f:
            cells.append(json.load(f))
    return cells


def fmt_row(c):
    r = c["roofline"]
    mem = c["memory"]["bytes_per_device"] / 2**30
    tot = max(r["compute_s"], r["memory_s"], r["collective_s"])
    frac = r["compute_s"] / tot if tot else 0.0
    return (f"| {c['arch']} | {c['shape']} | {c['mesh']} | "
            f"{r['compute_s'] * 1e3:.2f} | {r['memory_s'] * 1e3:.2f} | "
            f"{r['collective_s'] * 1e3:.2f} | {r['dominant'].replace('_s', '')} | "
            f"{c['useful_flops_frac']:.2f} | {mem:.1f} | {frac:.2f} |")


def main():
    cells = load_cells()
    singles = [c for c in cells if "skipped" not in c
               and c["mesh"].startswith("single") and "__" not in
               c.get("tag", "")]
    print("| arch | shape | mesh | compute ms | memory ms | collective ms"
          " | bottleneck | useful | GiB/dev | roofline frac |")
    print("|---|---|---|---|---|---|---|---|---|---|")
    for c in singles:
        print(fmt_row(c))
    for c in cells:
        if "skipped" in c:
            continue
        r = c["roofline"]
        emit(f"roofline_{c['arch']}_{c['shape']}_{c['mesh'][:5]}",
             max(r["compute_s"], r["memory_s"], r["collective_s"]) * 1e6,
             f"dominant={r['dominant']} useful={c['useful_flops_frac']:.2f}")


if __name__ == "__main__":
    main()
