"""Benchmark harness entry point: one function per paper table/figure.
Prints ``name,us_per_call,derived`` CSV rows.

  Fig. 10 (speedup/energy)      -> bench_speedup
  Fig. 11 + Fig. 4 (parallelism)-> bench_parallelism
  Fig. 12 + Sec 5.2.5 (scaling) -> bench_scaling
  Fig. 13 + Table 7 (compiler)  -> bench_compile_time
  Table 8 (mapping quality)     -> bench_mapping_quality
  kernels                       -> bench_kernels
  §Roofline (from dry-run JSON) -> roofline

Fast mode (default) uses reduced graph counts; FULL=1 uses paper-scale
counts (100 graphs/group).

Every invocation appends a run record to BENCH_all.json, and the kernel
section always appends its rows to BENCH_kernels.json (written from
`bench_kernels.main`'s finally-block, so a mid-bench failure still
records the partial run).
"""
from __future__ import annotations

import os
import sys
import traceback


def main() -> None:
    from benchmarks import (bench_speedup, bench_parallelism,
                            bench_scaling, bench_compile_time,
                            bench_mapping_quality, bench_kernels,
                            bench_serving, bench_traffic_replay,
                            bench_features, bench_incremental,
                            bench_frontier_density,
                            bench_telemetry_overhead, bench_autotune)
    fast = bool(os.environ.get("BENCH_FAST"))
    calls = [
        (bench_speedup, dict(graphs_per_group=1, sources_per_graph=1,
                             effort=0, skip=(("Syn", "wcc"),))
            if fast else {}),
        (bench_parallelism, dict(graphs_per_group=1, sources=2, effort=0,
                                 skip=(("Syn", "wcc"), ("Syn", "sssp")))
            if fast else {}),
        (bench_scaling, {}),
        (bench_compile_time, {}),
        (bench_mapping_quality, dict(graphs_per_group=1, sources=1)
            if fast else {}),
        (bench_kernels, {}),
        # overhead gate disabled here (inf): the aggregate run records
        # the ratio; the dedicated CI job enforces the <=1.05 bound
        (bench_serving, dict(max_overhead=float("inf"))),
        # speedup gate disabled here (0): recorded only; the
        # serving-replay-smoke CI job enforces the >=1.5x bound
        (bench_traffic_replay, dict(min_speedup=0.0)),
        # kwargs are explicit (the dispatch below only routes to run()
        # on a non-empty kwargs dict); fast honors BENCH_FAST
        (bench_features, dict(fast=fast)),
        (bench_incremental, dict(fast=fast)),
        (bench_frontier_density, dict(fast=fast)),
        # overhead gate disabled here (inf): recorded only; the
        # telemetry-overhead CI job enforces the bound
        (bench_telemetry_overhead, dict(max_ratio=float("inf"))),
        # tuned-vs-default/worst gates disabled here (0): recorded
        # only; the autotune-smoke CI job enforces the bounds
        (bench_autotune, dict(min_vs_default=0.0, min_vs_worst=0.0)),
    ]
    for m, kw in calls:
        try:
            if kw and hasattr(m, "run"):
                m.run(**kw)
            else:
                m.main()
        except Exception:
            print(f"[bench] {m.__name__} FAILED", file=sys.stderr)
            traceback.print_exc()
    # roofline table only if dry-run results exist
    try:
        from benchmarks import roofline
        if roofline.load_cells():
            roofline.main()
    except Exception:
        traceback.print_exc()
    from benchmarks.common import write_json
    write_json("all")


if __name__ == "__main__":
    main()
