"""Shared helpers for the benchmark harness."""
from __future__ import annotations

import time


def emit(name: str, us_per_call: float, derived: str = ""):
    """CSV row: name, us_per_call, derived."""
    print(f"{name},{us_per_call:.3f},{derived}")


def timed(fn, *args, repeats: int = 1, **kw):
    t0 = time.time()
    out = None
    for _ in range(repeats):
        out = fn(*args, **kw)
    return out, (time.time() - t0) * 1e6 / repeats
