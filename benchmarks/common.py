"""Shared helpers for the benchmark harness.

Every `emit` prints the historical ``name,us_per_call,derived`` CSV row
AND records it in an in-process results list; `write_json(tag)` appends
the rows collected so far as one run record to ``BENCH_<tag>.json``
(under ``$BENCH_OUT`` if set, else the cwd). The file is append-safe --
each invocation adds a ``{"ts", "meta", "rows"}`` entry to the ``runs``
list instead of overwriting history -- so repo-root files and CI
artifacts accumulate the perf trajectory across runs, and ``meta``
(`run_meta()`: git SHA, hostname, jax version, device kind) keeps every
recorded row attributable to the code and machine that produced it.
"""
from __future__ import annotations

import functools
import json
import os
import platform
import socket
import subprocess
import sys
import time

RESULTS: list[dict] = []


@functools.lru_cache(maxsize=1)
def run_meta() -> dict:
    """Provenance stamped into every recorded run: git SHA, hostname,
    platform, python/jax versions, and the JAX device kind. Every probe
    is best-effort -- a bench run must never fail on metadata."""
    meta = {
        "hostname": socket.gethostname(),
        "platform": platform.platform(),
        "python": sys.version.split()[0],
    }
    try:
        meta["git_sha"] = subprocess.run(
            ["git", "rev-parse", "HEAD"], capture_output=True, text=True,
            timeout=10, cwd=os.path.dirname(os.path.abspath(__file__)),
        ).stdout.strip() or None
    except Exception:
        meta["git_sha"] = None
    try:
        import jax
        meta["jax"] = jax.__version__
        dev = jax.devices()[0]
        meta["device_kind"] = dev.device_kind
        meta["backend"] = dev.platform
        meta["device_count"] = jax.device_count()
    except Exception:
        meta["jax"] = None
    return dict(meta)


def emit(name: str, us_per_call: float, derived: str = ""):
    """CSV row: name, us_per_call, derived (also recorded for JSON)."""
    print(f"{name},{us_per_call:.3f},{derived}")
    RESULTS.append({"name": name, "us_per_call": round(us_per_call, 3),
                    "derived": derived})


def timed(fn, *args, repeats: int = 1, **kw):
    t0 = time.time()
    out = None
    for _ in range(repeats):
        out = fn(*args, **kw)
    return out, (time.time() - t0) * 1e6 / repeats


def write_json(tag: str, rows: list[dict] | None = None) -> str:
    """Append one run record (`rows`, default: everything emitted so far)
    to BENCH_<tag>.json; returns the path. Existing history -- including
    the pre-append single-run {"rows": ...} layout -- is preserved."""
    rows = RESULTS if rows is None else rows
    out_dir = os.environ.get("BENCH_OUT", ".")
    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(out_dir, f"BENCH_{tag}.json")
    runs: list[dict] = []
    if os.path.exists(path):
        try:
            with open(path) as f:
                old = json.load(f)
            runs = old.get("runs", [])
            if "rows" in old:              # legacy overwrite-style layout
                runs.insert(0, {"rows": old["rows"]})
        except (json.JSONDecodeError, OSError):
            pass                           # corrupt history: start fresh
    runs.append({"ts": time.strftime("%Y-%m-%dT%H:%M:%S"),
                 "meta": run_meta(), "rows": rows})
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        json.dump({"tag": tag, "runs": runs}, f, indent=1)
    os.replace(tmp, path)
    print(f"[bench] appended {len(rows)} rows to {path} "
          f"({len(runs)} recorded runs)")
    return path
