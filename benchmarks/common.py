"""Shared helpers for the benchmark harness.

Every `emit` prints the historical ``name,us_per_call,derived`` CSV row
AND records it in an in-process results list; `write_json(tag)` dumps the
rows collected so far to ``BENCH_<tag>.json`` (under ``$BENCH_OUT`` if
set, else the cwd), so CI can upload the perf trajectory as an artifact.
"""
from __future__ import annotations

import json
import os
import time

RESULTS: list[dict] = []


def emit(name: str, us_per_call: float, derived: str = ""):
    """CSV row: name, us_per_call, derived (also recorded for JSON)."""
    print(f"{name},{us_per_call:.3f},{derived}")
    RESULTS.append({"name": name, "us_per_call": round(us_per_call, 3),
                    "derived": derived})


def timed(fn, *args, repeats: int = 1, **kw):
    t0 = time.time()
    out = None
    for _ in range(repeats):
        out = fn(*args, **kw)
    return out, (time.time() - t0) * 1e6 / repeats


def write_json(tag: str) -> str:
    """Dump everything emitted so far to BENCH_<tag>.json; returns path."""
    out_dir = os.environ.get("BENCH_OUT", ".")
    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(out_dir, f"BENCH_{tag}.json")
    with open(path, "w") as f:
        json.dump({"tag": tag, "rows": RESULTS}, f, indent=1)
    print(f"[bench] wrote {len(RESULTS)} rows to {path}")
    return path
