"""Paper Table 8: mapping quality (avg routing length, packet wait, ALUin
buffer depth) per dataset group, SSSP workload."""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit
from repro.core import SSSP, compile_mapping, simulate
from repro.graphs import make_dataset


def run(graphs_per_group: int = 3, sources: int = 3, effort: int = 1):
    rng = np.random.default_rng(0)
    out = {}
    for grp in ("SRN", "LRN", "Tree", "Syn"):
        lens, waits, depths = [], [], []
        for gi, g in enumerate(make_dataset(grp, graphs_per_group)):
            m = compile_mapping(g, effort=effort, seed=gi, program=SSSP)
            lens.append(m.avg_routing_length())
            srcs = [0] if grp == "Tree" else rng.integers(0, g.n, sources)
            for src in srcs:
                r = simulate(m, SSSP, src=int(src))
                waits.append(r.avg_pkt_wait)
                depths.append(r.max_aluin_depth)
        out[grp] = (np.mean(lens), np.mean(waits), np.max(depths))
        emit(f"table8_{grp}", 0.0,
             f"avg_routing_length={np.mean(lens):.2f} "
             f"pkt_wait_cyc={np.mean(waits):.2f} "
             f"aluin_depth_max={np.max(depths)}")
    return out


def main():
    run()


if __name__ == "__main__":
    main()
