"""Telemetry-overhead guard: tracing must stay cheap enough to leave on.

Times steady-state (post-compile) BFS queries with ``trace=`` off vs on
through both local fixpoints -- the on-device `lax.while_loop` (dense
streaming) and the host-driven compacted loop -- and fails (exit 1)
when either traced/untraced wall ratio exceeds ``--max-ratio``
(default 1.10, the documented <=10% bound). The graph is sized so the
relax work dominates the fixed-shape stat-buffer writes; medians over
several repeats keep the ratio robust to scheduler noise. Rows append
to BENCH_telemetry.json, so the overhead trajectory is recorded
alongside the kernel benches.

CI runs this as the `telemetry-overhead-smoke` job:

  BENCH_FAST=1 PYTHONPATH=src:. python -m \
      benchmarks.bench_telemetry_overhead --max-ratio 1.10
"""
from __future__ import annotations

import argparse
import os
import time

import numpy as np

from benchmarks.common import RESULTS, emit, write_json
from repro import api as flip
from repro.graphs import make_power_law


def _steady(fn, repeats: int) -> float:
    """Median wall of `repeats` calls (the executable is already warm)."""
    walls = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        walls.append(time.perf_counter() - t0)
    return float(np.median(walls))


def run(max_ratio: float = 1.10) -> float:
    """Benches both fixpoints; returns the worst traced/untraced ratio."""
    fast = bool(os.environ.get("BENCH_FAST"))
    n, m = (1024, 4096) if fast else (4096, 16384)
    repeats = 7 if fast else 11
    g = make_power_law(n, m, seed=0)
    worst = 0.0
    paths = [
        ("while_loop", flip.ExecutionPlan(compact=False)),   # device loop
        ("host_compact", flip.ExecutionPlan(compact=True)),  # host loop
    ]
    for label, plan in paths:
        cq = flip.compile(g, "bfs", plan)
        cq.query(0)                     # warm the untraced executable
        cq.query(0, trace=True)         # warm the traced one
        off = _steady(lambda: cq.query(0), repeats)
        on = _steady(lambda: cq.query(0, trace=True), repeats)
        ratio = on / off
        emit(f"telemetry_overhead_{label}_off", off * 1e6,
             f"steady-state BFS |V|={n} |E|={g.m}, trace off")
        emit(f"telemetry_overhead_{label}_on", on * 1e6, "trace=True")
        emit(f"telemetry_overhead_{label}_ratio", ratio,
             f"traced/untraced wall (guard <= {max_ratio:.2f})")
        worst = max(worst, ratio)
    return worst


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--max-ratio", type=float, default=1.10,
                    help="fail when traced/untraced steady-state wall "
                         "exceeds this on either fixpoint path")
    args = ap.parse_args()
    start = len(RESULTS)
    worst = None
    try:
        worst = run(args.max_ratio)
    finally:
        write_json("telemetry", rows=RESULTS[start:])
    print(f"[bench] worst tracing overhead ratio {worst:.3f} "
          f"(bound {args.max_ratio:.2f})")
    if worst > args.max_ratio:
        raise SystemExit(
            f"telemetry overhead {worst:.3f}x exceeds the "
            f"{args.max_ratio:.2f}x bound")


if __name__ == "__main__":
    main()
