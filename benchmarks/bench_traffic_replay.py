"""Traffic replay: continuous batching + result cache vs sync buckets.

Replays one recorded request trace -- Zipf-distributed sources (the
hot-source shape of real query traffic), mixed algebras, and interleaved
monotone edge-mutation batches -- through both serving front-ends over
the same graph:

  * baseline: the synchronous bucket `GraphServer` (resilience off, the
    bare dispatch path);
  * continuous: `AsyncGraphServer` -- rotating per-algebra fixpoint
    batches (lanes = B/2, so mixed-algebra traffic keeps per-window
    occupancy high), K-step admission windows, and the shared result
    cache short-circuiting repeated sources.

Both arms serve the identical stream; the bench ASSERTS every response
is bit-for-bit equal across arms before recording a single number --
the speedup is scheduling policy, never semantics. Rows record
queries/sec and p50/p99 end-to-end latency per arm, the speedup ratio,
and the cache hit rate, appended to BENCH_serving.json. CI runs this in
the `serving-replay-smoke` job with ``--min-speedup 1.5``:

  BENCH_FAST=1 PYTHONPATH=src:. python -m benchmarks.bench_traffic_replay \
      --min-speedup 1.5
"""
from __future__ import annotations

import argparse
import os
import time

import numpy as np

from benchmarks.common import RESULTS, emit, write_json
from repro.api import ExecutionPlan
from repro.graphs import make_power_law
from repro.launch.serve_graph import GraphServer
from repro.serving import AsyncGraphServer

ALGOS = ["bfs", "sssp"]


def _zipf_src(rng, n: int, a: float) -> int:
    """Zipf-distributed source id, clipped to the vertex set: a few hot
    sources dominate, exactly the traffic shape a result cache exists
    for."""
    return int(min(rng.zipf(a) - 1, n - 1))


def make_stream(g, n_requests: int, n_updates: int, zipf_a: float,
                seed: int):
    """One recorded trace: (algo, src) queries with ("update", batch)
    mutations at evenly spaced positions. Updates are ⊕-improving
    reweights plus one insert -- monotone, so the continuous arm's
    warm-start reuse stays legal (both arms replay the identical
    items)."""
    rng = np.random.default_rng(seed)
    upd_at = (set(np.linspace(n_requests // 3, n_requests - 4,
                              n_updates, dtype=int).tolist())
              if n_updates else set())
    stream, gc = [], g
    for i in range(n_requests):
        if i in upd_at:
            eu = gc.edge_sources()
            idx = rng.choice(gc.m, size=min(4, gc.m), replace=False)
            batch = [(int(eu[j]), int(gc.indices[j]),
                      float(gc.weights[j]) * 0.5) for j in idx]
            batch.append((int(rng.integers(g.n)),
                          int(rng.integers(g.n)), 1.0))
            stream.append(("update", batch))
            gc = gc.apply_updates(batch)
        stream.append((ALGOS[int(rng.integers(len(ALGOS)))],
                       _zipf_src(rng, g.n, zipf_a)))
    return stream


def _replay(srv, stream):
    """Serve the whole trace; returns (wall_s, requests)."""
    t0 = time.perf_counter()
    reqs = srv.serve(stream)
    return time.perf_counter() - t0, reqs


def _latency_quantiles(reqs):
    lat = np.sort(np.asarray([r.queue_wait_s + r.service_s
                              for r in reqs]))
    return (float(lat[len(lat) // 2]),
            float(lat[min(len(lat) - 1, int(len(lat) * 0.99))]))


def run(min_speedup: float = 0.0, zipf_a: float = 1.6) -> float:
    fast = bool(os.environ.get("BENCH_FAST"))
    n, m = (512, 2048) if fast else (2048, 8192)
    n_req = 64 if fast else 192
    n_upd = 2 if fast else 4
    repeats = 3 if fast else 5
    batch, lanes, k = 8, 4, 2
    g = make_power_law(n, m, seed=0)
    stream = make_stream(g, n_req, n_upd, zipf_a, seed=1)
    plan = ExecutionPlan(mode="data", batch=batch)

    # one long-lived server per arm, exactly like production serving:
    # sessions stay hot across repeats, updates step the graph version
    # forward each replay (the same trace stays valid and monotone).
    # Repeat 0 is the compile warmup and is dropped from the medians.
    bucket = GraphServer(g, plan=plan, resilience=False)
    cont = AsyncGraphServer(g, plan=plan, segment_steps=k, lanes=lanes)
    for a in ALGOS:
        bucket.session(a)
        cont.session(a)

    walls = {"bucket": [], "continuous": []}
    quants = {}
    for rep in range(repeats + 1):
        wb, rb = _replay(bucket, stream)
        wc, rc = _replay(cont, stream)
        # semantics gate: the two schedulers must agree bit-for-bit on
        # every response of every repeat before any number is recorded
        assert all(r.ok for r in rb) and all(r.ok for r in rc)
        for qb, qc in zip(rb, rc):
            np.testing.assert_array_equal(qb.result, qc.result)
        if rep == 0:
            continue                   # compile/trace warmup
        walls["bucket"].append(wb)
        walls["continuous"].append(wc)
        quants = {"bucket": _latency_quantiles(rb),
                  "continuous": _latency_quantiles(rc)}

    n_served = n_req
    note = (f"|V|={n} |E|={g.m} {n_req} reqs zipf={zipf_a} "
            f"{n_upd} updates B={batch}")
    for arm in ("bucket", "continuous"):
        wall = float(np.median(walls[arm]))
        p50, p99 = quants[arm]
        extra = f" lanes={lanes} K={k}" if arm == "continuous" else ""
        emit(f"traffic_{arm}_qps", n_served / wall, note + extra)
        emit(f"traffic_{arm}_p50_us", p50 * 1e6, note + extra)
        emit(f"traffic_{arm}_p99_us", p99 * 1e6, note + extra)

    hit_rate = cont.cache.stats()["hit_rate"]
    emit("traffic_cache_hit_rate", hit_rate,
         f"shared result cache over the zipf={zipf_a} trace")
    speedup = (float(np.median(walls["bucket"]))
               / float(np.median(walls["continuous"])))
    emit("traffic_replay_speedup", speedup,
         f"continuous-batching q/s over sync buckets "
         f"(guard >= {min_speedup:.2f})" if min_speedup
         else "continuous-batching q/s over sync buckets")
    return speedup


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--min-speedup", type=float, default=0.0,
                    help="fail when continuous-batching queries/sec is "
                         "below this multiple of the sync-bucket "
                         "baseline (0 = record only)")
    ap.add_argument("--zipf", type=float, default=1.6,
                    help="Zipf exponent of the source distribution")
    args = ap.parse_args()
    start = len(RESULTS)
    speedup = None
    try:
        speedup = run(args.min_speedup, args.zipf)
    finally:
        write_json("serving", rows=RESULTS[start:])
    print(f"[bench] traffic replay: continuous batching {speedup:.2f}x "
          f"sync-bucket q/s (guard >= {args.min_speedup:.2f}x)")
    if args.min_speedup and speedup < args.min_speedup:
        raise SystemExit(
            f"continuous-batching speedup {speedup:.2f}x is below the "
            f"{args.min_speedup:.2f}x bound")


if __name__ == "__main__":
    main()
