"""Frontier-density sweep: dense vs frontier-compacted relax-step cost.

Measures one jnp-path relaxation step on a power-law graph at several
frontier densities (fraction of active source *tiles*), dense streaming
vs compacted streaming (`frontier_relax(..., compact=True)`). This is the
memory-system half of FLIP's data-centric skip: dense streaming touches
every one of the nb weight blocks regardless of the frontier, compacted
streaming touches only blocks with an active source tile, so the sparse
step should cost O(active/nb) of the dense one.

Used three ways:
  * `benchmarks/bench_kernels.py` calls `run()` so the rows land in the
    recorded BENCH_kernels.json perf trajectory;
  * `python -m benchmarks.bench_frontier_density` writes its own
    BENCH_frontier_density.json;
  * CI runs it with ``--min-speedup`` as a regression guard: the job
    fails if the 1%-density compacted step is not measurably cheaper
    than the dense step.
"""
from __future__ import annotations

import argparse
import os

import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, timed, write_json
from repro.graphs import make_power_law
from repro.kernels.frontier import build_blocks, frontier_relax

DENSITIES = ((0.01, "1pct"), (0.05, "5pct"), (1.0, "100pct"))


def _step_times(fast: bool, algo: str = "sssp", seed: int = 0):
    """{label: (dense_us, compact_us, active_tiles)} for one relax step."""
    n, tile = (2048, 64) if fast else (4096, 128)
    g = make_power_law(n, 3 * n, seed=seed)
    bg = build_blocks(g, algo, tile=tile)
    sr = bg.algebra.semiring
    rng = np.random.default_rng(seed)
    attrs = bg.to_tiled(rng.uniform(0.5, 9, g.n).astype(np.float32))
    repeats = 5 if fast else 20
    out = {}
    for density, label in DENSITIES:
        # density = fraction of active source tiles: activity is confined
        # to the first k tiles (frontier locality), matching how a real
        # fixpoint's live frontier clusters under the FLIP placement
        k = max(1, int(round(density * bg.ntiles)))
        mask = np.zeros((bg.ntiles, bg.tile), dtype=bool)
        mask[:k] = rng.random((k, bg.tile)) < 0.5
        sv = jnp.where(jnp.asarray(mask), attrs, np.float32(sr.zero))
        fd = lambda: frontier_relax(sv, attrs, bg, mode="jnp",
                                    compact=False).block_until_ready()
        fc = lambda: frontier_relax(sv, attrs, bg, mode="jnp",
                                    compact=True).block_until_ready()
        fd(), fc()                                   # warm the executables
        np.testing.assert_array_equal(
            np.asarray(frontier_relax(sv, attrs, bg, mode="jnp")),
            np.asarray(frontier_relax(sv, attrs, bg, mode="jnp",
                                      compact=True)))
        _, us_d = timed(fd, repeats=repeats)
        _, us_c = timed(fc, repeats=repeats)
        out[label] = (us_d, us_c, k)
    return out, g, bg


def run(fast: bool | None = None) -> float:
    """Emit the sweep rows; returns the 1%-density dense/compact ratio."""
    fast = bool(os.environ.get("BENCH_FAST")) if fast is None else fast
    size = "2k" if fast else "4k"
    times, g, bg = _step_times(fast)
    nb = bg.blocks.shape[0]
    for label, (us_d, us_c, k) in times.items():
        note = (f"power-law |V|={g.n} blocks={nb} "
                f"active_tiles={k}/{bg.ntiles}")
        emit(f"frontier_step_dense_{size}_{label}", us_d, note)
        emit(f"frontier_step_compact_{size}_{label}", us_c, note)
    speedup = times["1pct"][0] / times["1pct"][1]
    emit(f"frontier_compact_speedup_{size}_1pct", speedup,
         "dense/compacted step wall ratio at 1% active tiles "
         "(x, higher is better)")
    return speedup


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--min-speedup", type=float, default=0.0,
                    help="fail (exit 1) if the 1%%-density compacted step "
                         "is not this many times faster than dense")
    args = ap.parse_args()
    speedup = run()
    write_json("frontier_density")
    if args.min_speedup and speedup < args.min_speedup:
        raise SystemExit(
            f"frontier compaction regression: sparse-frontier speedup "
            f"{speedup:.2f}x < required {args.min_speedup:.2f}x")


if __name__ == "__main__":
    main()
