"""Feature-width sweep: the vector-state amortization win.

Measures one jnp-path relaxation step on a power-law graph at feature
widths d in {1, 8, 32, 128} for two contraction regimes:

  * ``plus_times`` -- the (+, x) semiring contracts each (T, T) weight
    block against a (T, d) feature slab as one MXU matmul, so the
    marginal cost of a lane is tiny: one d=32 step should be far
    cheaper than 32 sequential d=1 steps over the same weight stream;
  * ``min_plus``   -- the tropical ⊕-reduce runs on the VPU (slab-swept
    broadcast min), so its per-lane scaling bounds what the idempotent
    algebras (multi-landmark BFS) gain from the shared weight stream.

Each row records us/call plus effective GFLOP/s (2 * nb * T^2 * d
flop-equivalents per step -- one multiply + one accumulate per block
entry per lane, the standard SpMM accounting).

Used three ways:
  * `benchmarks/bench_kernels.py` calls `run()` so the rows land in the
    recorded BENCH_kernels.json perf trajectory;
  * `python -m benchmarks.bench_features` writes its own
    BENCH_features.json;
  * CI runs it with ``--min-speedup`` as a regression guard: the job
    fails unless one d=32 plus_times step beats 32 sequential d=1 steps
    by the required factor.
"""
from __future__ import annotations

import argparse
import os

import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, timed, write_json
from repro.graphs import make_power_law
from repro.kernels.frontier import build_blocks, frontier_relax

DIMS = (1, 8, 32, 128)
ALGOS = (("plus_times", "pagerank"), ("min_plus", "sssp"))


def _sweep(fast: bool, seed: int = 0):
    """{(semiring, d): us_per_call} for one dense jnp relax step."""
    n, tile = (2048, 64) if fast else (4096, 128)
    g = make_power_law(n, 3 * n, seed=seed)
    rng = np.random.default_rng(seed)
    repeats = 5 if fast else 20
    times, nblocks = {}, {}
    for sr_name, algo in ALGOS:
        bg = build_blocks(g, algo, tile=tile)
        nblocks[sr_name] = int(bg.blocks.shape[0])
        for d in DIMS:
            shape = (bg.ntiles, bg.tile) + ((d,) if d > 1 else ())
            sv = jnp.asarray(rng.uniform(0.5, 9, shape)
                             .astype(np.float32))
            carry = jnp.asarray(rng.uniform(0.5, 9, shape)
                                .astype(np.float32))
            f = lambda: frontier_relax(sv, carry, bg, mode="jnp",
                                       compact=False,
                                       feature_dim=d).block_until_ready()
            f()                                  # warm the executable
            _, us = timed(f, repeats=repeats)
            times[(sr_name, d)] = us
    return times, nblocks, g, tile


def run(fast: bool | None = None) -> float:
    """Emit the d-sweep rows; returns the plus_times amortization win
    (32 sequential d=1 steps / one d=32 step)."""
    fast = bool(os.environ.get("BENCH_FAST")) if fast is None else fast
    size = "2k" if fast else "4k"
    times, nblocks, g, tile = _sweep(fast)
    for (sr_name, d), us in sorted(times.items()):
        flops = 2.0 * nblocks[sr_name] * tile * tile * d
        gflops = flops / (us * 1e-6) / 1e9
        emit(f"feature_step_{sr_name}_{size}_d{d}", us,
             f"power-law |V|={g.n} blocks={nblocks[sr_name]} d={d} "
             f"eff_gflops={gflops:.2f}")
    speedup = 32 * times[("plus_times", 1)] / times[("plus_times", 32)]
    emit(f"feature_amortization_{size}_plus_times_d32", speedup,
         "32 sequential d=1 steps / one d=32 step, same weight stream "
         "(x, higher is better)")
    trop = 32 * times[("min_plus", 1)] / times[("min_plus", 32)]
    emit(f"feature_amortization_{size}_min_plus_d32", trop,
         "32 sequential d=1 steps / one d=32 step, VPU ⊕-reduce "
         "(x, higher is better)")
    return speedup


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--min-speedup", type=float, default=0.0,
                    help="fail (exit 1) unless one d=32 plus_times step "
                         "beats 32 sequential d=1 steps by this factor")
    args = ap.parse_args()
    speedup = run()
    write_json("features")
    if args.min_speedup and speedup < args.min_speedup:
        raise SystemExit(
            f"vector-state regression: d=32 plus_times amortization "
            f"{speedup:.2f}x < required {args.min_speedup:.2f}x")


if __name__ == "__main__":
    main()
