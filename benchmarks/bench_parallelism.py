"""Paper Fig. 11 + Fig. 4: frontier parallelism on FLIP vs unrolled
op-centric CGRA."""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit
from repro.core import PROGRAMS, baselines, compile_mapping, simulate
from repro.graphs import make_dataset


def run(groups=("LRN", "Syn"), algos=("bfs", "sssp", "wcc"),
        graphs_per_group: int = 3, sources: int = 4, effort: int = 1,
        skip=()):
    rng = np.random.default_rng(0)
    out = {}
    for grp in groups:
        for algo in algos:
            if (grp, algo) in skip:
                emit(f"fig11_{grp}_{algo}", 0.0, "skipped_in_fast_mode")
                continue
            pars, maxp = [], []
            for gi, g in enumerate(make_dataset(grp, graphs_per_group)):
                mapping = compile_mapping(g, effort=effort, seed=gi,
                                          program=PROGRAMS[algo])
                for src in rng.integers(0, g.n, sources):
                    r = simulate(mapping, PROGRAMS[algo], src=int(src))
                    pars.append(r.avg_parallelism)
                    maxp.append(r.max_parallelism)
            q25, med = np.percentile(pars, [25, 50])
            out[(grp, algo)] = (q25, med, np.mean(maxp))
            emit(f"fig11_{grp}_{algo}", 0.0,
                 f"par_q25={q25:.1f} par_median={med:.1f} "
                 f"par_max_avg={np.mean(maxp):.1f}")
    # Fig. 4: unrolling saturates on the op-centric CGRA
    for u in (1, 2, 3, 4):
        emit(f"fig4_unroll_{u}", 0.0,
             f"speedup={baselines.unroll_speedup(u):.2f}x")
    return out


def main():
    run()


if __name__ == "__main__":
    main()
