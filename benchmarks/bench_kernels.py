"""Kernel micro-benchmarks (CPU wall time of the jnp paths + interpret
correctness cost; on TPU these dispatch to the Pallas kernels).

Emits the per-algebra frontier-relax rows future PRs track, a batched
(B, ntiles, T) relax row, the dense-vs-compacted frontier-density sweep
(`bench_frontier_density`), the feature-width d-sweep
(`bench_features`), and the end-to-end multi-query batching win:
B=32 BFS sources on an LRN road network through one batched
`CompiledQuery.query` fixpoint vs 32 sequential scalar queries on the
same compiled session. Results append to
BENCH_kernels.json via `common.write_json` -- written even when a bench
section fails, so the perf trajectory never silently loses a run.
"""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import (bench_features, bench_frontier_density,
                        bench_incremental)
from benchmarks.common import RESULTS, emit, timed, write_json
from repro import api as flip
from repro.algebra import ALGEBRAS
from repro.graphs import make_dataset, make_road_network
from repro.kernels.frontier import build_blocks, frontier_relax
from repro.models.attention import attend
from repro.kernels.ssd.ref import ssd_ref


def run():
    fast = bool(os.environ.get("BENCH_FAST"))
    # frontier relax step (jnp path), one timing per registered algebra:
    # future PRs read these rows to track the per-semiring perf trajectory
    # row ids carry the graph size: BENCH_FAST runs a 256-vertex graph,
    # full runs the historical 1k one, and the two must never be compared
    # under one name in the recorded trajectory
    n = 256 if fast else 1024
    size = "256" if fast else "1k"
    g = make_road_network(n, seed=0)
    rng = np.random.default_rng(0)
    bgs = {}
    for algo in sorted(ALGEBRAS):
        if ALGEBRAS[algo].feature_dim != 1:
            continue   # vector programs: bench_features owns the d-sweep
        bg = bgs[algo] = build_blocks(g, algo, tile=128)
        alg = bg.algebra
        vals = (alg.initial_attrs(g.n, 0) if alg.kind == "residual"
                else rng.uniform(0, 10, g.n).astype(np.float32))
        attrs = bg.to_tiled(vals)   # generic mid-run state
        f = jax.jit(lambda s, a, bg=bg: frontier_relax(s, a, bg,
                                                       mode="jnp"))
        f(attrs, attrs).block_until_ready()
        _, us = timed(lambda: f(attrs, attrs).block_until_ready(),
                      repeats=20)
        emit(f"kernel_frontier_relax_{size}_{algo}", us,
             f"semiring={alg.semiring.name} edges={g.m} "
             f"blocks={bg.blocks.shape[0]}")

    # batched relax: B=32 queries against the same resident block stream
    bg = bgs["bfs"]
    batt = bg.to_tiled(rng.uniform(0, 10, (32, g.n)).astype(np.float32))
    fb = jax.jit(lambda s, a: frontier_relax(s, a, bg, mode="jnp"))
    fb(batt, batt).block_until_ready()
    _, us = timed(lambda: fb(batt, batt).block_until_ready(), repeats=20)
    emit(f"kernel_frontier_relax_{size}_bfs_b32", us,
         f"batched B=32 edges={g.m} blocks={bg.blocks.shape[0]}")

    # dense vs frontier-compacted streaming across frontier densities
    bench_frontier_density.run(fast)

    # feature-width (d) sweep: vector-state amortization of the weight
    # stream (matmul contraction vs sequential scalar steps)
    bench_features.run(fast)

    # incremental-vs-scratch recompute after a streaming update batch
    bench_incremental.run(fast)

    bench_batching_win(fast)

    # attention (lax_flash path)
    q = jnp.ones((1, 2048, 4, 64), jnp.float32)
    k = jnp.ones((1, 2048, 2, 64), jnp.float32)
    fa = jax.jit(lambda q, k: attend(q, k, k, True, None,
                                     impl="lax_flash"))
    fa(q, k).block_until_ready()
    _, us = timed(lambda: fa(q, k).block_until_ready(), repeats=3)
    emit("kernel_attention_2k", us, "causal flash, S=2048")

    # SSD chunked scan
    x = jnp.ones((1, 1024, 4, 32), jnp.float32)
    dt = jnp.full((1, 1024, 4), 0.1, jnp.float32)
    bm = jnp.ones((1, 1024, 16), jnp.float32)
    al = jnp.zeros((4,), jnp.float32)
    d = jnp.zeros((4,), jnp.float32)
    fs = jax.jit(lambda x, dt, bm: ssd_ref(x, dt, bm, bm, al, d,
                                           chunk=128)[0])
    fs(x, dt, bm).block_until_ready()
    _, us = timed(lambda: fs(x, dt, bm).block_until_ready(), repeats=5)
    emit("kernel_ssd_1k", us, "chunk=128")


def bench_batching_win(fast: bool):
    """End-to-end multi-query amortization: B=32 BFS sources on the LRN
    dataset, one shared batched fixpoint vs 32 sequential scalar queries
    (same compiled session, same jit cache, same backend)."""
    g = next(make_dataset("LRN", 1, seed0=0))
    rng = np.random.default_rng(0)
    srcs = rng.choice(g.n, size=32, replace=False)
    cq = flip.compile(g, "bfs", flip.ExecutionPlan(tile=128))
    r_solo = cq.query(int(srcs[0]))            # warm the solo executable
    r_bat = cq.query(srcs)                     # warm the batched one
    _, us_seq = timed(lambda: [cq.query(int(s)) for s in srcs],
                      repeats=1 if fast else 3)
    _, us_bat = timed(lambda: cq.query(srcs),
                      repeats=1 if fast else 3)
    emit("frontier_bfs_lrn_seq32", us_seq,
         f"32 sequential scalar queries |V|={g.n}")
    emit("frontier_bfs_lrn_batch32", us_bat,
         "one batched query fixpoint, B=32")
    emit("frontier_bfs_lrn_batch32_speedup", us_seq / us_bat,
         "sequential/batched wall ratio (x, higher is better)")
    # compile-vs-steady split (satellite): the warm-up calls above were
    # the first dispatches of their shapes, so their compile_s is the
    # jit-trace share a cold server pays once per executable
    emit("frontier_bfs_lrn_compile_solo", r_solo.compile_s * 1e6,
         "first solo dispatch compile share (jit trace + lowering)")
    emit("frontier_bfs_lrn_compile_batch32", r_bat.compile_s * 1e6,
         "first B=32 dispatch compile share")
    # telemetry summary rows: traced re-run of the batched fixpoint
    # (tracing compiles its own executable; results stay bit-identical)
    rt = cq.query(srcs, trace=True)
    s = rt.telemetry.summary()
    emit("frontier_bfs_lrn_batch32_active_tile_frac",
         s["mean_active_tile_fraction"] * 100,
         f"mean % of tiles live per step over {s['traced_steps']} "
         f"traced steps")
    emit("frontier_bfs_lrn_batch32_blocks_fetched",
         s["blocks_fetched_total"],
         f"HBM block fetches (skipped={s['blocks_skipped_total']}); "
         f"steps hist {rt.telemetry.steps_histogram()}")


def main():
    start = len(RESULTS)
    try:
        run()
    finally:
        # always persist this module's rows (even partial ones on a bench
        # failure): BENCH_kernels.json is the recorded perf trajectory
        write_json("kernels", rows=RESULTS[start:])


if __name__ == "__main__":
    main()
