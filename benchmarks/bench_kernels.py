"""Kernel micro-benchmarks (CPU wall time of the jnp paths + interpret
correctness cost; on TPU these dispatch to the Pallas kernels)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, timed
from repro.graphs import make_road_network
from repro.kernels.frontier import build_blocks, frontier_relax
from repro.models.attention import attend
from repro.kernels.ssd.ref import ssd_ref


def run():
    # frontier relax step (jnp path)
    g = make_road_network(1024, seed=0)
    bg = build_blocks(g, "sssp", tile=128)
    attrs = bg.to_tiled(np.random.default_rng(0)
                        .uniform(0, 10, g.n).astype(np.float32))
    sv = attrs
    f = jax.jit(lambda s, a: frontier_relax(s, a, bg, mode="jnp"))
    f(sv, attrs).block_until_ready()
    _, us = timed(lambda: f(sv, attrs).block_until_ready(), repeats=20)
    emit("kernel_frontier_relax_1k", us,
         f"edges={g.m} blocks={bg.blocks.shape[0]}")

    # attention (lax_flash path)
    q = jnp.ones((1, 2048, 4, 64), jnp.float32)
    k = jnp.ones((1, 2048, 2, 64), jnp.float32)
    fa = jax.jit(lambda q, k: attend(q, k, k, True, None,
                                     impl="lax_flash"))
    fa(q, k).block_until_ready()
    _, us = timed(lambda: fa(q, k).block_until_ready(), repeats=3)
    emit("kernel_attention_2k", us, "causal flash, S=2048")

    # SSD chunked scan
    x = jnp.ones((1, 1024, 4, 32), jnp.float32)
    dt = jnp.full((1, 1024, 4), 0.1, jnp.float32)
    bm = jnp.ones((1, 1024, 16), jnp.float32)
    al = jnp.zeros((4,), jnp.float32)
    d = jnp.zeros((4,), jnp.float32)
    fs = jax.jit(lambda x, dt, bm: ssd_ref(x, dt, bm, bm, al, d,
                                           chunk=128)[0])
    fs(x, dt, bm).block_until_ready()
    _, us = timed(lambda: fs(x, dt, bm).block_until_ready(), repeats=5)
    emit("kernel_ssd_1k", us, "chunk=128")


def main():
    run()


if __name__ == "__main__":
    main()
