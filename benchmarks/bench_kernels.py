"""Kernel micro-benchmarks (CPU wall time of the jnp paths + interpret
correctness cost; on TPU these dispatch to the Pallas kernels)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, timed
from repro.algebra import ALGEBRAS
from repro.graphs import make_road_network
from repro.kernels.frontier import build_blocks, frontier_relax
from repro.models.attention import attend
from repro.kernels.ssd.ref import ssd_ref


def run():
    # frontier relax step (jnp path), one timing per registered algebra:
    # future PRs read these rows to track the per-semiring perf trajectory
    g = make_road_network(1024, seed=0)
    rng = np.random.default_rng(0)
    for algo in sorted(ALGEBRAS):
        bg = build_blocks(g, algo, tile=128)
        alg = bg.algebra
        vals = (alg.initial_attrs(g.n, 0) if alg.kind == "residual"
                else rng.uniform(0, 10, g.n).astype(np.float32))
        attrs = bg.to_tiled(vals)   # generic mid-run state
        f = jax.jit(lambda s, a, bg=bg: frontier_relax(s, a, bg,
                                                       mode="jnp"))
        f(attrs, attrs).block_until_ready()
        _, us = timed(lambda: f(attrs, attrs).block_until_ready(),
                      repeats=20)
        emit(f"kernel_frontier_relax_1k_{algo}", us,
             f"semiring={alg.semiring.name} edges={g.m} "
             f"blocks={bg.blocks.shape[0]}")

    # attention (lax_flash path)
    q = jnp.ones((1, 2048, 4, 64), jnp.float32)
    k = jnp.ones((1, 2048, 2, 64), jnp.float32)
    fa = jax.jit(lambda q, k: attend(q, k, k, True, None,
                                     impl="lax_flash"))
    fa(q, k).block_until_ready()
    _, us = timed(lambda: fa(q, k).block_until_ready(), repeats=3)
    emit("kernel_attention_2k", us, "causal flash, S=2048")

    # SSD chunked scan
    x = jnp.ones((1, 1024, 4, 32), jnp.float32)
    dt = jnp.full((1, 1024, 4), 0.1, jnp.float32)
    bm = jnp.ones((1, 1024, 16), jnp.float32)
    al = jnp.zeros((4,), jnp.float32)
    d = jnp.zeros((4,), jnp.float32)
    fs = jax.jit(lambda x, dt, bm: ssd_ref(x, dt, bm, bm, al, d,
                                           chunk=128)[0])
    fs(x, dt, bm).block_until_ready()
    _, us = timed(lambda: fs(x, dt, bm).block_until_ready(), repeats=5)
    emit("kernel_ssd_1k", us, "chunk=128")


def main():
    run()


if __name__ == "__main__":
    main()
