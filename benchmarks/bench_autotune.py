"""Autotuner-quality guard: the tuned plan must actually be good.

Runs one full measured tune on a fixed power-law graph (fresh tmp
store, fixed seed) and gates on two ratios computed from the tuner's
own measurement table:

  * ``vs_default`` -- default-plan cost / chosen cost. Must stay
    >= 1.0: the static default is always in the candidate list, and
    the tuner picks the argmin, so falling below 1.0 means the
    selection logic regressed.
  * ``vs_worst`` -- worst *measured* candidate / chosen. Must clear
    >= 1.2: the knob space must keep containing genuinely bad
    configurations the tuner steers around (dense streaming at a
    sparse frontier, a pessimal tile). If every candidate measures the
    same, the sweep has collapsed and tuning is dead weight.

Ratios restrict to measured candidates -- the analytically-priced ones
(interpret) would inflate vs_worst with a model number, not evidence.
A store-roundtrip probe (second tune = cache hit, identical plan) rides
along. Rows append to BENCH_autotune.json.

CI runs this as the `autotune-smoke` job:

  BENCH_FAST=1 PYTHONPATH=src:. python -m benchmarks.bench_autotune \
      --min-vs-default 1.0 --min-vs-worst 1.2
"""
from __future__ import annotations

import argparse
import os
import tempfile

from benchmarks.common import RESULTS, emit, write_json
from repro.graphs import make_power_law


def run(min_vs_default: float = 1.0,
        min_vs_worst: float = 1.2) -> tuple[float, float]:
    """One measured tune + gates; returns (vs_default, vs_worst)."""
    from repro.autotune import TuningStore, autotune
    fast = bool(os.environ.get("BENCH_FAST"))
    n = 2048 if fast else 8192
    g = make_power_law(n, 3 * n, seed=0)
    with tempfile.TemporaryDirectory() as d:
        store = TuningStore(os.path.join(d, "autotune.json"))
        rep = autotune(g, "bfs", seed=0, store=store)
        measured = [(s, rep.scores[s.plan.key()]) for s in rep.samples
                    if s.source == "measured"]
        default_score = next(iter(rep.scores.values()))  # base is first
        chosen_score = rep.scores[rep.chosen.key()]
        worst_score = max(sc for _, sc in measured)
        vs_default = default_score / chosen_score
        vs_worst = worst_score / chosen_score
        emit("autotune_chosen_step_us", chosen_score,
             f"power-law |V|={g.n} |E|={g.m} tile={rep.chosen.tile} "
             f"relax={rep.chosen.relax_mode} "
             f"compact={rep.chosen.compact} "
             f"({len(measured)}/{len(rep.samples)} measured)")
        emit("autotune_default_step_us", default_score,
             "static ExecutionPlan() on the same measurement table")
        emit("autotune_vs_default", vs_default,
             f"default/chosen step cost (guard >= {min_vs_default})")
        emit("autotune_vs_worst", vs_worst,
             f"worst-measured/chosen step cost (guard >= "
             f"{min_vs_worst})")
        # store roundtrip: the second tune must be a cache hit that
        # reproduces the plan bit-for-bit
        rep2 = autotune(g, "bfs", seed=0, store=store)
        roundtrip = float(rep2.cached
                          and rep2.chosen.key() == rep.chosen.key())
        emit("autotune_store_roundtrip", roundtrip,
             "1.0 = second tune served from the store, same plan")
        if not roundtrip:
            raise SystemExit("autotune store roundtrip failed: second "
                             "tune was not a cache hit with the same "
                             "plan")
    return vs_default, vs_worst


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--min-vs-default", type=float, default=1.0,
                    help="fail when the chosen plan is slower than the "
                         "static default on the tuner's own table")
    ap.add_argument("--min-vs-worst", type=float, default=1.2,
                    help="fail when the chosen plan does not beat the "
                         "worst measured candidate by this factor")
    args = ap.parse_args()
    start = len(RESULTS)
    ratios = None
    try:
        ratios = run(args.min_vs_default, args.min_vs_worst)
    finally:
        write_json("autotune", rows=RESULTS[start:])
    vs_default, vs_worst = ratios
    print(f"[bench] tuned plan: {vs_default:.2f}x vs default "
          f"(bound >= {args.min_vs_default}), {vs_worst:.2f}x vs worst "
          f"measured candidate (bound >= {args.min_vs_worst})")
    if vs_default < args.min_vs_default:
        raise SystemExit(
            f"tuned plan is {vs_default:.3f}x the default (< "
            f"{args.min_vs_default}): selection regressed")
    if vs_worst < args.min_vs_worst:
        raise SystemExit(
            f"tuned plan only {vs_worst:.3f}x the worst measured "
            f"candidate (< {args.min_vs_worst}): the sweep collapsed")


if __name__ == "__main__":
    main()
