"""Incremental-vs-scratch recompute cost after a streaming update batch.

The compounding claim behind delta-driven recompute: after a small
monotone mutation batch the resumed fixpoint relaxes only what the batch
can improve, and with frontier-compacted streaming the tiny delta
frontier fetches almost nothing -- so the step cost should collapse
relative to a from-scratch rerun on the same post-update engine (same
backend, same compiled executables).

Measured here on an LRN-scale road network: converge SSSP once, halve
the weights of a few random edges (⊕-improving, touching <=1% of the
vertices), step the session with `CompiledQuery.update`, then time the
warm-started `query(src, warm=prev)` against a from-scratch
`query(src)`. Both results are verified bit-identical
before the clock starts. Rows are appended to **BENCH_kernels.json**
(the recorded kernel perf trajectory):

  incremental_sssp_<size>_scratch / _warm    wall us per recompute
  incremental_sssp_<size>_speedup            scratch/warm wall ratio
  incremental_sssp_<size>_step_reduction     scratch/warm fixpoint steps

CI runs the fast (2k-vertex) configuration; `--min-speedup` turns the
run into a regression guard.
"""
from __future__ import annotations

import argparse
import os

import numpy as np

from benchmarks.common import RESULTS, emit, timed, write_json
from repro import api as flip
from repro.graphs import make_road_network


def _monotone_edge_batch(g, rng, k: int):
    """Shave 12.5% (a dyadic factor, so float relaxation stays exact)
    off the weights of ~k edges clustered around one random vertex --
    the shape of a real stream update (a localized traffic change), and
    a pure ⊕-improving batch under (min, +). Undirected graphs mirror
    each half-edge automatically."""
    start = int(rng.integers(g.n))
    seen, frontier, batch = {start}, [start], []
    while frontier and len(batch) < k:
        nxt = []
        for u in frontier:
            for v, w in zip(g.neighbors(u), g.edge_weights(u)):
                if len(batch) >= k:
                    break
                batch.append((int(u), int(v), float(w) * 0.875))
                if int(v) not in seen:
                    seen.add(int(v))
                    nxt.append(int(v))
        frontier = nxt
    return batch


def run(fast: bool | None = None) -> float:
    """Emit the incremental rows; returns the scratch/warm wall ratio."""
    fast = bool(os.environ.get("BENCH_FAST")) if fast is None else fast
    n = 2048 if fast else 16384                # full = ExtLRN scale
    size = "2k" if fast else "16k"
    g = make_road_network(n, seed=0, delete_frac=0.56)
    rng = np.random.default_rng(0)
    # data mode, compacted (the default plan)
    cq = flip.compile(g, "sssp", flip.ExecutionPlan(tile=128))
    src = int(g.center_vertex())
    prev = cq.query(src)                       # converge + warm the jit

    # <=1% of vertices affected: k edges touch at most 2k sources
    # (undirected mirroring makes both endpoints change out-edges)
    k = max(1, n // 512)
    batch = _monotone_edge_batch(g, rng, k)
    cq2, delta = cq.update(batch)
    assert delta.monotone, "weight halving must be monotone under min-plus"
    affected_pct = 100.0 * len(delta.affected_src) / n
    assert affected_pct <= 1.0, affected_pct

    warm_res = cq2.query(src, warm=prev)
    scratch_res = cq2.query(src)
    np.testing.assert_array_equal(warm_res.attrs,
                                  scratch_res.attrs)   # exactness gate
    steps_w = max(int(warm_res.steps), 1)
    steps_s = int(scratch_res.steps)

    repeats = 2 if fast else 3
    _, us_w = timed(lambda: cq2.query(src, warm=prev), repeats=repeats)
    _, us_s = timed(lambda: cq2.query(src), repeats=repeats)
    note = (f"road |V|={n} |E|={cq2.graph.m} {k} clustered edges "
            f"reweighted, "
            f"{len(delta.affected_src)} vertices affected "
            f"({affected_pct:.2f}%)")
    emit(f"incremental_sssp_{size}_scratch", us_s,
         f"{note}, {int(steps_s)} steps")
    emit(f"incremental_sssp_{size}_warm", us_w,
         f"{note}, {steps_w} steps")
    emit(f"incremental_sssp_{size}_speedup", us_s / us_w,
         "scratch/warm wall ratio after a <=1%-vertex monotone batch "
         "(x, higher is better)")
    emit(f"incremental_sssp_{size}_step_reduction",
         int(steps_s) / steps_w,
         "scratch/warm relaxation-step ratio (x, higher is better)")
    return us_s / us_w


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--min-speedup", type=float, default=0.0,
                    help="fail (exit 1) if the warm recompute is not "
                         "this many times faster than scratch")
    args = ap.parse_args()
    start = len(RESULTS)
    try:
        speedup = run()
    finally:
        # the incremental rows belong to the recorded kernel trajectory
        write_json("kernels", rows=RESULTS[start:])
    if args.min_speedup and speedup < args.min_speedup:
        raise SystemExit(
            f"incremental recompute regression: warm-start speedup "
            f"{speedup:.2f}x < required {args.min_speedup:.2f}x")


if __name__ == "__main__":
    main()
