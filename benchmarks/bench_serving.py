"""Serving-resilience overhead guard: the failure model must be free
when nothing fails.

Runs the same steady-state request stream through two `GraphServer`s
over one shared graph -- ``resilience=False`` (the bare dispatch path)
vs ``resilience=True`` (degradation ladder, admission control, finite
guard) -- with sessions compiled outside the clock, and fails (exit 1)
when the resilient/baseline wall ratio exceeds ``--max-overhead``
(default 1.05, the documented <=5% bound). The healthy-path invariant
is asserted, not assumed: the resilient server must finish with its
fallback and shed counters at ZERO and every request converged -- the
overhead being measured is pure bookkeeping, not degraded execution.

Rows append to BENCH_serving.json. CI runs this as part of the
`resilience-chaos-smoke` job:

  BENCH_FAST=1 PYTHONPATH=src:. python -m benchmarks.bench_serving \
      --max-overhead 1.05
"""
from __future__ import annotations

import argparse
import os
import time

import numpy as np

from benchmarks.common import RESULTS, emit, write_json
from repro.api import ExecutionPlan
from repro.graphs import make_power_law
from repro.launch.serve_graph import GraphServer


def _stream(n_vertices: int, algos, n_requests: int, seed: int):
    rng = np.random.default_rng(seed)
    return [(algos[int(rng.integers(len(algos)))],
             int(rng.integers(n_vertices)))
            for _ in range(n_requests)]


def _serve_wall(srv: GraphServer, stream, repeats: int) -> float:
    """Median wall of serving the whole stream (sessions warm)."""
    walls = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        for algo, src in stream:
            srv.submit(algo, src)
        srv.drain()
        walls.append(time.perf_counter() - t0)
    return float(np.median(walls))


def run(max_overhead: float = 1.05) -> float:
    fast = bool(os.environ.get("BENCH_FAST"))
    n, m = (512, 2048) if fast else (2048, 8192)
    n_req = 64 if fast else 256
    repeats = 5 if fast else 9
    batch = 8
    algos = ["bfs", "sssp"]
    g = make_power_law(n, m, seed=0)
    stream = _stream(n, algos, n_req, seed=1)
    plan = ExecutionPlan(mode="data", batch=batch)

    servers = {
        "baseline": GraphServer(g, plan=plan, resilience=False),
        "resilient": GraphServer(g, plan=plan, resilience=True,
                                 max_queue_depth=4 * batch),
    }
    walls = {}
    for label, srv in servers.items():
        for a in algos:
            srv.session(a)              # compile outside the clock
        _serve_wall(srv, stream, 1)     # warm every dispatch signature
        walls[label] = _serve_wall(srv, stream, repeats)
        emit(f"serving_{label}_us_per_req", walls[label] * 1e6 / n_req,
             f"steady-state, |V|={n} |E|={g.m} B={batch} "
             f"{n_req} reqs over {algos}")

    # healthy-path invariant: the resilient run must not have degraded,
    # shed, or failed anything -- its extra wall is pure bookkeeping
    st = servers["resilient"].stats()
    assert st["resilience"]["fallbacks"] == 0, st["resilience"]
    assert st["resilience"]["shed"] == 0, st["resilience"]
    assert st["failed"] == 0 and servers["resilient"].shed == 0
    emit("serving_resilient_fallbacks", st["resilience"]["fallbacks"],
         "must be 0 on the healthy path")
    emit("serving_resilient_shed", st["resilience"]["shed"],
         "must be 0 on the healthy path")

    ratio = walls["resilient"] / walls["baseline"]
    emit("serving_resilience_overhead_ratio", ratio,
         f"resilient/baseline steady-state wall "
         f"(guard <= {max_overhead:.2f})")
    return ratio


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--max-overhead", type=float, default=1.05,
                    help="fail when the resilient/baseline steady-state "
                         "serving wall exceeds this ratio")
    args = ap.parse_args()
    start = len(RESULTS)
    ratio = None
    try:
        ratio = run(args.max_overhead)
    finally:
        write_json("serving", rows=RESULTS[start:])
    print(f"[bench] serving resilience overhead {ratio:.3f}x "
          f"(bound {args.max_overhead:.2f}x)")
    if ratio > args.max_overhead:
        raise SystemExit(
            f"serving resilience overhead {ratio:.3f}x exceeds the "
            f"{args.max_overhead:.2f}x bound")


if __name__ == "__main__":
    main()
