"""Paper Fig. 12 + Sec. 5.2.5: scalability.

(a) Ext. LRN with runtime data swapping (graph >> on-chip capacity).
(b) PE-array scaling: 8x8 -> 12x12 -> 16x16 with proportionally larger
    road networks (performance per PE drops as diameter grows -- the
    paper's observation).
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit
from repro.core import BFS, PROGRAMS, FlipArch, baselines, \
    compile_mapping, simulate
from repro.graphs import make_road_network


def run_ext_lrn(n: int = None, algo: str = "bfs"):
    import os
    n = n or (1024 if os.environ.get("BENCH_FAST") else 2048)
    """Down-scaled Ext.LRN (full 16k runs too; 2k keeps CI fast)."""
    g = make_road_network(n, seed=0, delete_frac=0.56)
    mapping = compile_mapping(g, effort=0, seed=0)
    r = simulate(mapping, PROGRAMS[algo], src=0)
    t_flip = r.cycles / mapping.arch.freq_mhz
    t_cgra = baselines.cgra_cycles(algo, g, 0).time_us
    t_mcu = baselines.mcu_cycles(algo, g, 0).time_us
    emit(f"sec525_extlrn_{algo}_n{n}", t_flip,
         f"slices={mapping.num_copies()} swaps={r.swaps} "
         f"speedup_vs_cgra={t_cgra / t_flip:.1f}x "
         f"speedup_vs_mcu={t_mcu / t_flip:.1f}x")
    return r


def run_array_scaling(algo: str = None):
    import os
    algo = algo or ("bfs" if os.environ.get("BENCH_FAST") else "wcc")
    out = []
    for side in (8, 12, 16):
        arch = FlipArch(width=side, height=side)
        n = arch.capacity                      # fully-utilized memory
        g = make_road_network(n, seed=0)
        mapping = compile_mapping(g, arch=arch, effort=0, seed=0)
        r = simulate(mapping, PROGRAMS[algo], src=0)
        t = r.cycles / arch.freq_mhz
        mteps = g.m / t
        # paper Fig. 12 normalizes by power/area ~ #PEs
        out.append((side, mteps, mteps / arch.num_pes))
        emit(f"fig12_array_{side}x{side}", t,
             f"mteps={mteps:.0f} mteps_per_pe={mteps / arch.num_pes:.2f}")
    return out


def main():
    run_ext_lrn()
    run_array_scaling()


if __name__ == "__main__":
    main()
