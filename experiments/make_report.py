"""Generate EXPERIMENTS.md §Dry-run and §Roofline from the dryrun JSONs.

Usage: python experiments/make_report.py > /tmp/sections.md
"""
import glob
import json
import os

DIR = os.path.join(os.path.dirname(__file__), "dryrun")

ARCH_ORDER = ["qwen3_0_6b", "phi3_medium_14b", "mistral_nemo_12b",
              "gemma3_12b", "granite_moe_3b_a800m", "qwen3_moe_235b_a22b",
              "jamba_1_5_large_398b", "mamba2_370m", "hubert_xlarge",
              "chameleon_34b"]
SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def load():
    cells = {}
    for p in sorted(glob.glob(os.path.join(DIR, "*.json"))):
        name = os.path.basename(p)[:-5]
        parts = name.split("__")
        tag = parts[3] if len(parts) > 3 else ""
        with open(p) as f:
            d = json.load(f)
        d["tag"] = tag
        cells[(d.get("arch"), d.get("shape"),
               "multi" if "multi" in name else "single", tag)] = d
    return cells


def main():
    cells = load()

    print("## §Dry-run\n")
    print("Every runnable (arch x shape) cell lowered AND compiled for the"
          " production meshes; `memory_analysis()` bytes/device and the"
          " collective schedule recorded per cell "
          "(experiments/dryrun/*.json).\n")
    print("| arch | shape | single-pod (16,16) | multi-pod (2,16,16) |"
          " GiB/dev (single) |")
    print("|---|---|---|---|---|")
    for a in ARCH_ORDER:
        for s in SHAPE_ORDER:
            single = cells.get((a, s, "single", ""))
            multi = cells.get((a, s, "multi", ""))
            if single is None and multi is None:
                continue
            ok1 = "compiled" if single else "—"
            ok2 = "compiled" if multi else "—"
            mem = (f"{single['memory']['bytes_per_device'] / 2**30:.1f}"
                   if single else "—")
            print(f"| {a} | {s} | {ok1} | {ok2} | {mem} |")

    print("\n## §Roofline (single-pod, 256 chips, v5e targets)\n")
    print("Terms per DESIGN.md §9: compute = HLO_FLOPs/chip / 197 TF/s;")
    print("memory = HLO bytes-accessed/chip / 819 GB/s; collective = "
          "HLO collective payload bytes/chip / 50 GB/s.")
    print("Totals assembled per-component (superblock x repeat + head) "
          "because XLA's cost model counts scan bodies once; `useful` = "
          "6·N_active·D / total HLO FLOPs; `r-frac` = compute / dominant "
          "(roofline fraction).\n")
    print("| arch | shape | compute s | memory s | collective s | "
          "bottleneck | useful | r-frac | GiB/dev |")
    print("|---|---|---|---|---|---|---|---|---|")
    for a in ARCH_ORDER:
        for s in SHAPE_ORDER:
            c = cells.get((a, s, "single", ""))
            if c is None:
                continue
            r = c["roofline"]
            dom = max(r["compute_s"], r["memory_s"], r["collective_s"])
            rfrac = r["compute_s"] / dom if dom else 0
            print(f"| {a} | {s} | {r['compute_s']:.3f} | "
                  f"{r['memory_s']:.3f} | {r['collective_s']:.3f} | "
                  f"{r['dominant'].replace('_s', '')} | "
                  f"{c['useful_flops_frac']:.2f} | {rfrac:.2f} | "
                  f"{c['memory']['bytes_per_device'] / 2**30:.1f} |")

    print("\n### Skipped cells (DESIGN.md §7)\n")
    from importlib import import_module
    import sys
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..",
                                    "src"))
    from repro.configs import cells as cfg_cells
    _, skipped = cfg_cells()
    for a, s, reason in skipped:
        print(f"- `{a}` x `{s}`: {reason}")

    print("\n### Tagged experiment cells (hillclimb; see §Perf)\n")
    for key, c in sorted(cells.items()):
        if key[3]:
            r = c.get("roofline", {})
            print(f"- `{key[0]}__{key[1]}__{key[2]}__{key[3]}`: "
                  f"mem {c['memory']['bytes_per_device'] / 2**30:.1f} GiB,"
                  f" compute {r.get('compute_s', 0):.3f}s, memory "
                  f"{r.get('memory_s', 0):.3f}s, collective "
                  f"{r.get('collective_s', 0):.3f}s")


if __name__ == "__main__":
    main()
