#!/usr/bin/env python
"""Plan-autotuner sweep CLI: tune, show the evidence, fill the store.

    PYTHONPATH=src python tools/autotune.py --dataset LRN --algo bfs
    PYTHONPATH=src python tools/autotune.py --n 4096 --deg 3 \
        --algo sssp --no-measure --json tune.json

Profiles the graph, sweeps the legal ExecutionPlan candidates, prices
each (measured capped segments by default, the analytic model with
``--no-measure``), prints the full score table, and writes the chosen
knobs to the tuning store (``--store`` / $FLIP_AUTOTUNE_DB / the user
cache) so later `flip.compile(..., ExecutionPlan.auto(tuned=True))`
sessions over the same shape start tuned for free.
"""
from __future__ import annotations

import argparse
import json
import sys


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="sweep ExecutionPlan candidates for one graph")
    src = ap.add_mutually_exclusive_group()
    src.add_argument("--dataset", default=None,
                     choices=["Tree", "SRN", "LRN", "Syn", "ExtLRN"],
                     help="a Table-4 dataset (default: a power-law "
                          "graph of --n vertices)")
    src.add_argument("--n", type=int, default=4096,
                     help="power-law graph size when no --dataset")
    ap.add_argument("--deg", type=int, default=3,
                    help="power-law mean out-degree (m = deg * n)")
    ap.add_argument("--graph-seed", type=int, default=0)
    ap.add_argument("--algo", default="bfs")
    ap.add_argument("--feature-dim", type=int, default=0)
    ap.add_argument("--batch", type=int, default=0,
                    help="base serving bucket width (0 = solo plan)")
    ap.add_argument("--seed", type=int, default=0,
                    help="probe-source seed (the tune is deterministic "
                         "in it)")
    ap.add_argument("--no-measure", action="store_true",
                    help="price everything through the analytic cost "
                         "model: no wall clocks, fully deterministic")
    ap.add_argument("--budget-s", type=float, default=2.0,
                    help="per-candidate measurement budget gate")
    ap.add_argument("--segment-steps", type=int, default=8)
    ap.add_argument("--sources", type=int, default=4)
    ap.add_argument("--store", default=None,
                    help="tuning-store path (default FLIP_AUTOTUNE_DB "
                         "/ ~/.cache/flip/autotune.json)")
    ap.add_argument("--force", action="store_true",
                    help="re-sweep even on a store hit (result is "
                         "written back)")
    ap.add_argument("--json", default=None, metavar="FILE",
                    help="also write the full TuneReport as JSON")
    args = ap.parse_args(argv)

    from repro.api.plan import ExecutionPlan
    from repro.autotune import TuningStore, autotune
    from repro.graphs import make_dataset, make_power_law

    if args.dataset:
        g = next(make_dataset(args.dataset, 1, seed0=args.graph_seed))
    else:
        g = make_power_law(args.n, args.deg * args.n,
                           seed=args.graph_seed)
    print(f"[autotune] graph: |V|={g.n} |E|={g.m} algo={args.algo}")

    store = TuningStore(args.store)
    base = ExecutionPlan(batch=args.batch, feature_dim=args.feature_dim)
    report = autotune(
        g, args.algo, base_plan=base, seed=args.seed, store=store,
        force=args.force, measure=not args.no_measure,
        budget_s=args.budget_s, segment_steps=args.segment_steps,
        sources=args.sources)

    prof = report.profile
    print(f"[autotune] profile: fp={prof.fingerprint()} "
          f"backend={prof.backend} mean_density="
          f"{prof.mean_density:.4f} d={prof.feature_dim}")
    if report.cached:
        print(f"[autotune] store hit ({store.path}): {report.why}")
    else:
        print(f"[autotune] {len(report.samples)} candidates "
              f"(seed={report.seed}):")
        rows = sorted(zip(report.samples, report.scores.values()),
                      key=lambda t: t[1])
        for s, score in rows:
            p = s.plan
            mark = "*" if p.key() == report.chosen.key() else " "
            print(f"  {mark} tile={p.tile:<4} relax={p.relax_mode:<10}"
                  f" compact={str(p.compact):<5} batch={p.batch:<4}"
                  f" {score:10.1f} us/step  [{s.source}]")
        print(f"[autotune] chosen: {report.why}")
        print(f"[autotune] stored -> {store.path}")
    if args.json:
        with open(args.json, "w") as f:
            json.dump(report.to_json(), f, indent=1)
        print(f"[autotune] report -> {args.json}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
