#!/usr/bin/env python
"""CI coverage ratchet for the tier-1 suite.

Reads the JSON report produced by ``pytest --cov=src/repro
--cov-report=json`` and fails (exit 1) if line coverage of any guarded
package drops below its recorded baseline. Baselines are deliberate
floors a few points under the measured coverage at the time this guard
landed -- ratchet them UP when coverage improves, never down to make a
red build green.
"""
from __future__ import annotations

import json
import sys

# package prefix -> minimum percent line coverage (tier-1 suite, CPU).
# Recorded from a settrace line-coverage measurement of a representative
# suite subset (measured: algebra 97%, core 95%, graphs 98%,
# kernels/frontier 90%, api 87% under tests/test_api.py alone), floored
# ~5 points down for tool/denominator differences between that
# measurement and coverage.py.
BASELINES = {
    "src/repro/algebra/": 90.0,
    "src/repro/api/": 80.0,
    # the plan autotuner: profile/space/measure/model/store/tuner are
    # all driven end-to-end by tests/test_autotune.py (measured ~93%)
    "src/repro/autotune/": 85.0,
    "src/repro/core/": 85.0,
    "src/repro/graphs/": 90.0,
    "src/repro/kernels/frontier/": 85.0,
    "src/repro/obs/": 85.0,
    # the failure model must stay tested: taxonomy, ladder, fault
    # injection (measured ~93% under tests/test_resilience.py + the
    # chaos-serving fuzz axis)
    "src/repro/resilience/": 85.0,
    # continuous-batching scheduler, result cache, clocks (measured ~89%
    # under tests/test_serving_scheduler.py alone; the traffic fuzz axis
    # adds more)
    "src/repro/serving/": 85.0,
}


def main(path: str = "coverage.json") -> int:
    with open(path) as f:
        report = json.load(f)
    stats = {prefix: [0, 0] for prefix in BASELINES}
    for fname, data in report["files"].items():
        fname = fname.replace("\\", "/")
        for prefix, acc in stats.items():
            if fname.startswith(prefix):
                acc[0] += data["summary"]["covered_lines"]
                acc[1] += data["summary"]["num_statements"]
    failed = False
    for prefix, (covered, total) in sorted(stats.items()):
        if total == 0:
            print(f"FAIL {prefix}: no files measured (wrong --cov root?)")
            failed = True
            continue
        pct = 100.0 * covered / total
        floor = BASELINES[prefix]
        status = "ok  " if pct >= floor else "FAIL"
        if pct < floor:
            failed = True
        print(f"{status} {prefix}: {pct:.1f}% ({covered}/{total} lines), "
              f"floor {floor:.1f}%")
    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main(*sys.argv[1:]))
