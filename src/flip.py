"""flip: the FLIP accelerator's unified front door.

    import flip

    cq = flip.compile(graph, "sssp", flip.ExecutionPlan(tile=128))
    result = cq.query(5)

A thin alias of `repro.api` so user code reads like the paper: compile
a (graph, program, plan) triple once, then query the session. See
docs/API.md for the full reference and the legacy->new migration table.
"""
from repro.api import (BackendFailure, CapacityExceeded, CompiledQuery,
                       ConvergenceFailure, DeadlineExceeded, ExecutionPlan,
                       FlipError, InvalidRequest, Program, QueryResult,
                       WarmStart, compile, plan_from_cli,
                       resolve_cli_engine)

__all__ = [
    "ExecutionPlan", "Program", "CompiledQuery", "QueryResult",
    "WarmStart", "compile", "plan_from_cli", "resolve_cli_engine",
    "FlipError", "InvalidRequest", "CapacityExceeded",
    "DeadlineExceeded", "ConvergenceFailure", "BackendFailure",
]
