"""Sharded, async, atomic checkpointing (no external deps).

Layout: <dir>/step_<N>/
    manifest.json          -- tree structure, shapes, dtypes, step, extras
    arr_<i>.npy            -- one file per leaf (host-gathered)
    _COMMITTED             -- written last; a checkpoint without it is
                              ignored on restore (atomic-commit marker)

Async: `save(..., blocking=False)` snapshots leaves to host memory on the
caller's thread (cheap; device->host copy) and writes files on a
background thread, so the train loop overlaps I/O with compute --
the standard large-cluster pattern. `wait()` joins the writer.

Restore: `load_pytree` reads the newest committed step and (if a mesh is
active) device_puts each leaf with its target sharding -- this is also the
elastic-resize path: a checkpoint written on one mesh restores onto any
other mesh because leaves are stored unsharded (host-complete).

On multi-host clusters each leaf would be gathered via
jax.experimental.multihost_utils; this container is single-process, so
the gather is a plain device_get (documented limitation, same API).
"""
from __future__ import annotations

import json
import os
import shutil
import threading

import jax
import ml_dtypes
import numpy as np

# numpy .npy cannot serialize ml_dtypes (bfloat16 etc.); store them as raw
# uint views and record the logical dtype in the manifest
_VIEW_DTYPES = {"bfloat16": np.uint16, "float8_e4m3fn": np.uint8,
                "float8_e5m2": np.uint8}


def _to_storable(a: np.ndarray):
    name = str(a.dtype)
    if name in _VIEW_DTYPES:
        return a.view(_VIEW_DTYPES[name]), name
    return a, name


def _from_storable(a: np.ndarray, dtype_name: str):
    if dtype_name in _VIEW_DTYPES:
        return a.view(getattr(ml_dtypes, dtype_name))
    return a


def _flatten_with_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    paths = ["/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                      for k in path) for path, _ in flat]
    leaves = [leaf for _, leaf in flat]
    return paths, leaves, treedef


def save_pytree(tree, directory: str, step: int, extras: dict | None = None):
    """Synchronous sharded save with atomic commit."""
    paths, leaves, _ = _flatten_with_paths(tree)
    host = [np.asarray(jax.device_get(leaf)) for leaf in leaves]
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)
    stored = [_to_storable(a) for a in host]
    manifest = {"step": step, "paths": paths,
                "dtypes": [name for _, name in stored],
                "shapes": [list(a.shape) for a in host],
                "extras": extras or {}}
    for i, (a, _) in enumerate(stored):
        np.save(os.path.join(tmp, f"arr_{i}.npy"), a)
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    with open(os.path.join(tmp, "_COMMITTED"), "w") as f:
        f.write("ok")
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


def committed_steps(directory: str) -> list[int]:
    if not os.path.isdir(directory):
        return []
    out = []
    for name in os.listdir(directory):
        if name.startswith("step_") and not name.endswith(".tmp") and \
                os.path.exists(os.path.join(directory, name, "_COMMITTED")):
            out.append(int(name.split("_")[1]))
    return sorted(out)


def load_pytree(tree_like, directory: str, step: int | None = None,
                shardings=None):
    """Restore into the structure of `tree_like` (abstract or concrete).

    `shardings`: optional matching pytree of NamedSharding -- leaves are
    device_put with them (the elastic-resharding path)."""
    steps = committed_steps(directory)
    if not steps:
        raise FileNotFoundError(f"no committed checkpoint in {directory}")
    step = steps[-1] if step is None else step
    d = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    paths, _, treedef = _flatten_with_paths(tree_like)
    assert paths == manifest["paths"], (
        "checkpoint tree structure mismatch")
    leaves = [_from_storable(np.load(os.path.join(d, f"arr_{i}.npy")),
                             manifest["dtypes"][i])
              for i in range(len(paths))]
    if shardings is not None:
        sh_leaves = jax.tree_util.tree_leaves(shardings)
        leaves = [jax.device_put(a, s) for a, s in zip(leaves, sh_leaves)]
    else:
        leaves = [jax.device_put(a) for a in leaves]
    tree = jax.tree_util.tree_unflatten(treedef, leaves)
    return tree, manifest["step"], manifest.get("extras", {})


class CheckpointManager:
    """Async manager with retention. One background writer at a time."""

    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        self._thread: threading.Thread | None = None

    def save(self, tree, step: int, extras: dict | None = None,
             blocking: bool = False):
        self.wait()
        # snapshot on caller thread (device -> host), write in background
        paths, leaves, treedef = _flatten_with_paths(tree)
        host = [np.asarray(jax.device_get(x)) for x in leaves]
        snap = jax.tree_util.tree_unflatten(treedef, host)

        def _write():
            save_pytree(snap, self.directory, step, extras)
            self._gc()

        if blocking:
            _write()
        else:
            self._thread = threading.Thread(target=_write, daemon=True)
            self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def restore(self, tree_like, shardings=None, step: int | None = None):
        return load_pytree(tree_like, self.directory, step, shardings)

    def latest_step(self) -> int | None:
        steps = committed_steps(self.directory)
        return steps[-1] if steps else None

    def _gc(self):
        steps = committed_steps(self.directory)
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:08d}"),
                          ignore_errors=True)
