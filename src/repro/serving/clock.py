"""Injectable scheduler clocks: real time, or a deterministic virtual one.

Every time-dependent decision the continuous-batching scheduler makes --
queue-wait accounting, deadline expiry, latency histograms -- reads one
`Clock` object instead of `time.monotonic()`. Production serving uses
`SystemClock` (real monotonic time). Tests use `VirtualClock`: time only
moves when the scheduler reports work (`on_steps`, a fixed cost per
fixpoint step) or the test advances it explicitly, so every interleaving
-- which query retires in which admission window, which deadline expires
mid-fixpoint -- is a pure function of the submission sequence and
replays bit-for-bit. No sleeps, no flaky timing tests.
"""
from __future__ import annotations

import dataclasses
import time


class SystemClock:
    """Real time: `now()` is `time.monotonic()`; scheduler work reports
    are no-ops (wall time advances by itself)."""

    virtual = False

    def now(self) -> float:
        return time.monotonic()

    def on_steps(self, n: int) -> None:
        """The scheduler ran an admission window of `n` fixpoint
        iterations; real time already accounts for it."""


@dataclasses.dataclass
class VirtualClock:
    """Deterministic logical time for replayable scheduling tests.

    `now()` returns the current logical time; it advances only via
    `advance(dt)` (explicit test control) and `on_steps(n)` (the
    scheduler reporting an admission window of `n` fixpoint iterations,
    costed at `step_cost_s` each -- the lanes of a window run in
    parallel, so a window's cost is its iteration count, not the sum of
    per-lane steps). With every time source under test control, a
    deadline expiring in window 3 of a rotating batch is an assertable
    fact, not a race.
    """

    step_cost_s: float = 1.0
    t: float = 0.0
    virtual = True

    def now(self) -> float:
        return self.t

    def advance(self, dt: float) -> None:
        if dt < 0:
            raise ValueError(f"virtual time cannot rewind (advance({dt}))")
        self.t += float(dt)

    def on_steps(self, n: int) -> None:
        self.t += float(n) * self.step_cost_s
