"""Bounded cross-query result cache keyed (graph fingerprint, algebra,
source).

Zipf-shaped serving traffic repeats sources constantly; a converged
fixpoint is immutable for a given graph version, so the second query for
(fp, algo, src) can be answered from memory in O(1) instead of re-running
the fixpoint. Coherence is structural, not temporal: the fingerprint is
part of the key and lookups always use the *current* graph's
fingerprint, so an entry for a superseded graph version can never be
served -- there is no TTL to mis-tune. On a graph update the superseded
generation is explicitly retired (`retire_fp`): its converged entries
are harvested as warm-start candidates for exactly one version step (the
PR-5 provenance rule) and then dropped, so the bound is never wasted on
dead versions.

The bound is LRU over whole entries (a (n[, d]) float32 vector each);
`capacity=0` disables caching entirely (the A/B baseline).
"""
from __future__ import annotations

import dataclasses
from collections import OrderedDict

import numpy as np


@dataclasses.dataclass(frozen=True)
class CacheEntry:
    """One converged query result: attrs in original vertex order plus
    the step count the cold run took (served verbatim on a hit, so hits
    are bit-identical to the cold query -- steps included)."""
    attrs: np.ndarray
    steps: int


class ResultCache:
    """LRU map of (graph_fp, algo, src) -> `CacheEntry`.

    Only *converged* results may be inserted: a partial (budget- or
    deadline-stopped) relaxation is request-specific state, not a
    property of (graph, algo, src), and serving it to a later query
    would silently truncate that query's answer.
    """

    def __init__(self, capacity: int = 256):
        if capacity < 0:
            raise ValueError(f"cache capacity must be >= 0, got "
                             f"{capacity}")
        self.capacity = int(capacity)
        self._entries: OrderedDict[tuple, CacheEntry] = OrderedDict()
        self.hits = 0
        self.misses = 0
        self.evictions = 0

    def __len__(self) -> int:
        return len(self._entries)

    # ------------------------------------------------------------ #
    def get(self, fp: str, algo: str, src: int) -> CacheEntry | None:
        """Hit -> the entry (promoted to most-recently-used); miss ->
        None. Callers must pass the *current* graph fingerprint -- that
        is the whole coherence argument."""
        if not self.capacity:
            return None
        key = (fp, algo, int(src))
        entry = self._entries.get(key)
        if entry is None:
            self.misses += 1
            return None
        self._entries.move_to_end(key)
        self.hits += 1
        return entry

    def put(self, fp: str, algo: str, src: int, attrs: np.ndarray,
            steps: int) -> None:
        """Insert one converged result; evicts least-recently-used
        entries beyond the bound. The stored array is frozen
        (non-writeable) so a hit can be served zero-copy without a later
        caller mutating every other hit's view."""
        if not self.capacity:
            return
        attrs = np.asarray(attrs)
        if not attrs.flags.writeable:
            frozen = attrs                    # already frozen: share it
        else:
            frozen = attrs.copy()
            frozen.setflags(write=False)
        self._entries[(fp, algo, int(src))] = CacheEntry(frozen,
                                                         int(steps))
        self._entries.move_to_end((fp, algo, int(src)))
        while len(self._entries) > self.capacity:
            self._entries.popitem(last=False)
            self.evictions += 1

    # ------------------------------------------------------------ #
    def retire_fp(self, fp: str) -> dict:
        """Drop every entry of graph generation `fp` and return them as
        ``{(algo, src): CacheEntry}`` -- the warm-start candidate set
        for the *next* generation (valid across exactly one update; the
        scheduler re-validates monotonicity per algebra before using
        one)."""
        retired = {}
        for key in [k for k in self._entries if k[0] == fp]:
            entry = self._entries.pop(key)
            retired[(key[1], key[2])] = entry
        return retired

    def clear(self) -> None:
        self._entries.clear()

    # ------------------------------------------------------------ #
    def stats(self) -> dict:
        lookups = self.hits + self.misses
        return {
            "capacity": self.capacity,
            "entries": len(self._entries),
            "hits": self.hits,
            "misses": self.misses,
            "hit_rate": (self.hits / lookups) if lookups else 0.0,
            "evictions": self.evictions,
        }
