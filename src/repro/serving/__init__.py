"""repro.serving: continuous-batching async query serving.

The request-level execution layer over `repro.api` sessions:

  * `scheduler` -- `AsyncGraphServer`, the continuous-batching front
    door: per-algebra rotating fixpoint batches whose converged lanes
    retire and refill from a request queue every K steps, and
    `RotatingBatch`, the lane mechanics;
  * `cache`     -- the bounded LRU `ResultCache` keyed (graph
    fingerprint, algebra, src): cross-query sharing with structural
    coherence, plus warm-start harvesting across one graph update;
  * `clock`     -- injectable time (`SystemClock` / `VirtualClock`):
    every scheduling decision is deterministic and replayable under a
    virtual clock;
  * `request`   -- `ServeRequest`, the per-query outcome record
    (result or typed error, never neither).

See docs/SERVING.md for the design and soundness arguments, and
`repro.launch.serve_graph` (`--scheduler continuous`) for the CLI.
"""
from repro.serving.cache import CacheEntry, ResultCache
from repro.serving.clock import SystemClock, VirtualClock
from repro.serving.request import ServeRequest
from repro.serving.scheduler import AsyncGraphServer, RotatingBatch

__all__ = [
    "AsyncGraphServer", "RotatingBatch",
    "ResultCache", "CacheEntry",
    "ServeRequest",
    "SystemClock", "VirtualClock",
]
