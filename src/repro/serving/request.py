"""The serving request record shared by both front-ends.

One `ServeRequest` per submitted query, carrying its outcome (result or
typed error -- never neither: zero lost requests is the serving-layer
invariant from PR 8) plus the latency split the scheduler measured on
its injectable clock. Field-compatible with the synchronous bucket
server's `GraphRequest` so stream drivers, benches, and the CLI treat
requests from either front-end identically.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.resilience.errors import FlipError


@dataclasses.dataclass
class ServeRequest:
    req_id: int
    algo: str
    src: int
    result: np.ndarray | None = None
    steps: int | None = None
    t_submit: float = 0.0        # clock.now() at enqueue
    queue_wait_s: float = 0.0    # enqueue -> admission into a slot
    service_s: float = 0.0       # admission -> retirement
    error: FlipError | None = None   # typed failure, if any
    converged: bool = True       # False: `result` is a flagged partial
    deadline_expired: bool = False
    max_steps: int | None = None     # per-request step budget
    deadline_s: float | None = None  # per-request budget as submitted
    t_deadline: float | None = None  # absolute deadline on the clock
    # --- continuous-batching provenance -------------------------- #
    cache_hit: bool = False      # served from the shared result cache
    warm_started: bool = False   # fixpoint resumed from a cached result
    slot: int | None = None      # rotating-batch lane that served it
    admit_window: int | None = None  # admission-window ordinal

    @property
    def done(self) -> bool:
        """Processed: the server produced a result OR a typed error.
        Every submitted request ends `done` -- nothing is ever lost."""
        return self.result is not None or self.error is not None

    @property
    def ok(self) -> bool:
        """Fully served: converged result, no error."""
        return self.result is not None and self.error is None
