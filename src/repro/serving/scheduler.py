"""Continuous-batching scheduler: a rotating fixpoint batch per algebra.

The synchronous bucket server (`repro.launch.serve_graph.GraphServer`)
dispatches fixed-size buckets: a query arriving one step after a
dispatch waits out the *entire* previous fixpoint, and every bucket
waits for its slowest member. This scheduler applies Flip's own
data-centric idea at the request level -- work is admitted by the
runtime state of the system, not a static schedule:

  * each algebra owns ONE long-lived (B, ntiles, T[, d]) fixpoint state
    -- the *rotating batch* -- whose B lanes hold independent in-flight
    queries (or sit inert);
  * the fixpoint advances in bounded segments of K steps
    (`FlipEngine.run_segment`, the step-boundary yield hook): at every
    segment boundary the scheduler retires converged lanes, refills
    them from the request queue, and enforces deadlines -- so a new
    query joins the warm batch within K steps instead of waiting out a
    whole bucket;
  * lanes are independent along the batch axis (the PR-2 contract), so
    every retired query's result is bit-for-bit its solo run, under any
    admission interleaving;
  * a bounded LRU `ResultCache` keyed (graph fingerprint, algebra, src)
    short-circuits repeated sources entirely, and across one graph
    update the superseded generation's converged results become
    warm-start candidates (PR-5 provenance: exactly one version step,
    monotone deltas only);
  * all timing flows through an injectable `Clock`: with a
    `VirtualClock` every interleaving -- admissions, retirements,
    deadline expiries -- is a deterministic, replayable function of the
    submission sequence (the whole test story; see
    tests/test_serving_scheduler.py).

`AsyncGraphServer` is the request-level front door, API-compatible with
`GraphServer` (`submit` / `update` / `drain` / `serve` / `stats`).
See docs/SERVING.md for the rotation-soundness argument, the
cache-coherence matrix, and SLO accounting.
"""
from __future__ import annotations

import dataclasses
from collections import deque

import numpy as np

from repro import api as flip
from repro.algebra import get_algebra
from repro.api import CompiledQuery, ExecutionPlan
from repro.graphs.csr import Graph
from repro.obs import MetricsRegistry
from repro.resilience import (CapacityExceeded, ConvergenceFailure,
                              DeadlineExceeded, InvalidRequest, classify)
from repro.serving.cache import ResultCache
from repro.serving.clock import SystemClock
from repro.serving.request import ServeRequest


class RotatingBatch:
    """One algebra's continuously-batched fixpoint: B lanes of state,
    a request (or None) per lane, and per-lane admission bookkeeping.
    The scheduler owns the policy; this owns the lane mechanics.

    The resident state lives as HOST numpy arrays between windows:
    admissions are in-place row writes (no device dispatch per lane),
    and `run_window` round-trips through the device once per segment.
    Solo initial states are memoized per source -- Zipf traffic repeats
    sources constantly, and a cold miss's init cost is the same tiled
    scatter every time."""

    def __init__(self, session: CompiledQuery, nslots: int):
        self.cq = session
        self.engine = session.engine
        self.nslots = int(nslots)
        self.state = tuple(np.array(x)
                           for x in self.engine.idle_state(self.nslots))
        self.slots: list[ServeRequest | None] = [None] * self.nslots
        self.t_admit = [0.0] * self.nslots
        self.windows = 0
        self._init_cache: dict[int, tuple] = {}

    @property
    def occupied(self) -> list[int]:
        return [b for b, r in enumerate(self.slots) if r is not None]

    @property
    def idle(self) -> list[int]:
        return [b for b, r in enumerate(self.slots) if r is None]

    def _solo_init(self, src: int, warm):
        """(attrs, aux, frontier) rows of one freshly initialized (or
        warm-resumed) solo query. Cold inits are memoized per source;
        warm resumes depend on the candidate attrs, so they are not."""
        if warm is None:
            init = self._init_cache.get(src)
            if init is None:
                if len(self._init_cache) >= 1024:
                    self._init_cache.clear()
                a1, x1, f1 = self.engine.initial_state([int(src)])
                init = (np.array(a1)[0], np.array(x1)[0],
                        np.array(f1)[0])
                self._init_cache[src] = init
            return init
        a1, x1, f1 = self.engine.initial_state([int(src)], warm=warm)
        return np.array(a1)[0], np.array(x1)[0], np.array(f1)[0]

    def admit(self, b: int, req: ServeRequest, now: float,
              warm=None) -> None:
        """Write `req`'s solo state into lane `b` (in-place host
        writes); queue wait ends here."""
        a1, x1, f1 = self._solo_init(req.src, warm)
        attrs, aux, frontier = self.state
        attrs[b], aux[b], frontier[b] = a1, x1, f1
        self.slots[b] = req
        self.t_admit[b] = now
        req.slot = b
        req.steps = 0
        req.queue_wait_s = now - req.t_submit

    def evict(self, b: int) -> ServeRequest:
        """Free lane `b` (retirement or failure). The lane's state is
        left as-is -- a converged lane's frontier is already empty, so
        it is inert until the next `admit` overwrites it."""
        req, self.slots[b] = self.slots[b], None
        return req

    def reset(self) -> None:
        """All lanes idle (the failure-isolation path): ⊕-identity
        attrs, empty frontiers."""
        self.state = tuple(np.array(x)
                           for x in self.engine.idle_state(self.nslots))
        self.slots = [None] * self.nslots

    def finalize_lane(self, b: int) -> np.ndarray:
        """Original-vertex-order result of lane `b` alone."""
        attrs, aux, _ = self.state
        return np.asarray(
            self.engine.finalize_state(attrs[b:b + 1], aux[b:b + 1])[0])

    def budget_left(self, b: int) -> int:
        """Steps lane `b` may still take before its budget (per-request
        `max_steps`, else the session valve) exhausts."""
        req = self.slots[b]
        cap = (self.engine.max_steps if req.max_steps is None
               else req.max_steps)
        return max(0, cap - (req.steps or 0))

    def run_window(self, k: int):
        """One bounded segment: every occupied lane advances at most
        ``min(k, budget_left)`` steps. Returns ``(steps, converged,
        iterations)`` -- per-lane steps taken, the end-of-segment
        convergence mask, and the window's iteration count (its cost on
        the clock: lanes run in parallel, so a window costs its longest
        lane, not the sum)."""
        budgets = np.zeros(self.nslots, dtype=np.int32)
        for b in self.occupied:
            budgets[b] = min(int(k), self.budget_left(b))
        state, steps, converged = self.engine.run_segment(
            self.state, budgets)
        # fresh host copies: the next admission writes rows in place,
        # which must never alias a buffer the device still owns
        self.state = tuple(np.array(x) for x in state)
        self.windows += 1
        for b in self.occupied:
            self.slots[b].steps += int(steps[b])
        return steps, converged, int(steps.max(initial=0))


@dataclasses.dataclass
class AsyncGraphServer:
    """Continuous-batching graph-query server with a shared result
    cache.

    Pass a full `plan` (its `batch` is the rotating-batch width B), or
    the per-knob fields which fold into one plan at construction --
    exactly the `GraphServer` surface, plus the scheduler knobs:

    segment_steps  -- K, the admission window: converged lanes retire
                      and queued queries are admitted every K fixpoint
                      steps. Smaller K = lower admission latency, more
                      host sync; K is a latency/throughput knob only,
                      results are bit-exact at any K.
    lanes          -- rotating-batch width PER ALGEBRA (default: the
                      plan's batch). Mixed-algebra traffic splits load
                      across per-algebra batches, so narrower lanes
                      keep per-window occupancy (and utilization) high;
                      another policy knob, never a semantics one.
    cache_capacity -- bounded LRU result-cache entries (0 disables).
    warm_reuse     -- resume repeated sources from the superseded
                      generation's cached fixpoints across one graph
                      update (monotone deltas only; always exact).
    clock          -- `SystemClock` (default) or a `VirtualClock` for
                      deterministic replay.
    """

    graph: Graph
    batch: int = 8
    tile: int = 128
    mode: str = "data"
    relax_mode: str = "auto"
    compact: bool | str = "auto"
    plan: ExecutionPlan | None = None
    segment_steps: int = 4
    lanes: int | None = None
    cache_capacity: int = 256
    warm_reuse: bool = True
    max_queue_depth: int = 0     # pending-queue bound per algebra
    quotas: dict | None = None   # per-algo overrides of max_queue_depth
    clock: object = None         # SystemClock | VirtualClock

    def __post_init__(self):
        if self.plan is None:
            self.plan = ExecutionPlan(
                mode=self.mode, relax_mode=self.relax_mode,
                compact=self.compact, tile=self.tile, batch=self.batch)
        elif self.plan.batch:
            self.batch = self.plan.batch
        else:
            self.plan = dataclasses.replace(self.plan, batch=self.batch)
        if self.plan.distributed or self.plan.mesh is not None:
            raise ValueError(
                "continuous batching needs host-observable step "
                "boundaries; the distributed (shard_map) fixpoint has "
                "none -- serve distributed plans through the bucket "
                "GraphServer")
        if self.batch < 1:
            raise ValueError(
                f"rotating batch needs >= 1 slot, got batch={self.batch}")
        if self.lanes is None:
            self.lanes = self.batch
        if not isinstance(self.lanes, int) or self.lanes < 1:
            raise ValueError(
                f"lanes must be a positive int, got {self.lanes!r}")
        if not isinstance(self.segment_steps, int) \
                or self.segment_steps < 1:
            raise ValueError(
                f"segment_steps must be a positive int, got "
                f"{self.segment_steps!r}")
        if self.clock is None:
            self.clock = SystemClock()
        self.cache = ResultCache(self.cache_capacity)
        self._batches: dict[str, RotatingBatch] = {}
        self._queues: dict[str, deque] = {}
        # per-algebra (delta, {src: frozen attrs}) from the last update:
        # warm-start candidates, valid for exactly this graph version
        self._warm: dict[str, tuple] = {}
        self._next_id = 0
        self.windows = 0         # lifetime admission-window ordinal
        self.completed = 0
        self.failed = 0
        self.shed = 0
        self.updates_applied = 0
        self.metrics = MetricsRegistry()

    # ------------------------------------------------------------ #
    @property
    def pending(self) -> int:
        """Requests not yet retired: queued + in-flight."""
        return (sum(len(q) for q in self._queues.values())
                + sum(len(rb.occupied) for rb in self._batches.values()))

    def session(self, algo: str) -> CompiledQuery:
        """The compiled session backing `algo`'s rotating batch (built
        lazily on first use, stepped across graph updates)."""
        return self._batch(algo).cq

    def _batch(self, algo: str) -> RotatingBatch:
        rb = self._batches.get(algo)
        if rb is None:
            self._check_algo(algo)
            cq = flip.compile(self.graph, algo, self.plan)
            rb = self._batches[algo] = RotatingBatch(cq, self.lanes)
        return rb

    @staticmethod
    def _check_algo(algo: str) -> None:
        try:
            get_algebra(algo)
        except ValueError as e:
            raise InvalidRequest(str(e), value=algo) from None

    def _check_src(self, src) -> int:
        if not isinstance(src, (int, np.integer)):
            raise InvalidRequest(
                f"source must be an integer vertex id, got {src!r}",
                value=src)
        if src < 0 or src >= self.graph.n:
            raise InvalidRequest(
                f"source {int(src)} is out of range for this graph "
                f"(|V| = {self.graph.n}; valid ids are 0.."
                f"{self.graph.n - 1})", value=int(src))
        return int(src)

    # ------------------------------------------------------------ #
    def submit(self, algo: str, src: int, *, max_steps: int | None = None,
               deadline_s: float | None = None) -> ServeRequest:
        """Enqueue one query (malformed requests raise `InvalidRequest`
        synchronously; operational rejections come back as a request
        carrying a typed error, exactly the bucket-server contract).

        A result-cache hit completes the request immediately --
        bit-identical attrs and step count to the cold query, zero
        queue wait, zero fixpoint work. Deadlines are measured from
        THIS call on the server's clock, so queue wait consumes them.
        """
        self._check_algo(algo)
        src = self._check_src(src)
        if max_steps is not None and (
                not isinstance(max_steps, (int, np.integer))
                or max_steps < 1):
            raise InvalidRequest(
                f"max_steps must be a positive int, got {max_steps!r}",
                value=max_steps)
        if deadline_s is None:
            deadline_s = self.plan.deadline_s
        if deadline_s is not None and not (
                isinstance(deadline_s, (int, float)) and deadline_s > 0):
            raise InvalidRequest(
                f"deadline_s must be a positive number of seconds, got "
                f"{deadline_s!r}", value=deadline_s)
        now = self.clock.now()
        req = ServeRequest(
            self._next_id, algo, src, t_submit=now,
            max_steps=None if max_steps is None else int(max_steps),
            deadline_s=deadline_s,
            t_deadline=(None if deadline_s is None
                        else now + float(deadline_s)))
        self._next_id += 1
        # cross-query sharing: a converged fixpoint for (fp, algo, src)
        # is immutable for this graph version -- serve it from memory
        entry = self.cache.get(self.graph.fingerprint(), algo, src)
        if entry is not None:
            req.result = entry.attrs
            req.steps = entry.steps
            req.cache_hit = True
            self.metrics.counter("cache.hit").inc()
            self.metrics.counter(f"completed.{algo}").inc()
            self.metrics.histogram(f"latency_s.{algo}").observe(0.0)
            self.completed += 1
            return req
        if self.cache.capacity:
            self.metrics.counter("cache.miss").inc()
        queue = self._queues.setdefault(algo, deque())
        limit = (self.quotas or {}).get(algo, self.max_queue_depth)
        if limit and len(queue) >= limit:
            req.error = CapacityExceeded(
                f"queue for {algo!r} is full ({len(queue)}/{limit}); "
                "request shed (reject-newest)",
                depth=len(queue), limit=limit)
            self.shed += 1
            self.metrics.counter(f"shed.{algo}").inc()
            self.metrics.counter(f"errors.{req.error.code}").inc()
            return req
        queue.append(req)
        return req

    # ------------------------------------------------------------ #
    def pump(self) -> int:
        """One admission window: for every algebra (deterministic
        sorted order) expire dead queued requests, refill idle lanes
        from the queue, then advance the rotating batch by one K-step
        segment and retire what finished. Returns the number of
        requests still pending. An empty pump (nothing queued, nothing
        in flight) is a no-op -- the clock does not advance."""
        for algo in sorted(set(self._queues) | set(self._batches)):
            self._expire_queued(algo)
            self._refill(algo)
            rb = self._batches.get(algo)
            if rb is not None and rb.occupied:
                self._run_window(algo, rb)
        self._refresh_gauges()
        return self.pending

    def drain(self) -> None:
        """Pump until every submitted request is retired."""
        while self.pending:
            self.pump()

    def serve(self, stream) -> list[ServeRequest]:
        """Run a whole iterable of ``(algo, src)`` queries and
        ``("update", batch)`` mutations; an update drains every query
        submitted before it (they see the pre-update graph) and later
        queries run against the mutated graph -- submission order is
        graph-version order, exactly the bucket-server semantics.

        The scheduler makes progress WHILE the stream arrives: once the
        backlog covers the rotating batch's lanes, each further submit
        pumps one admission window. Early queries therefore retire (and
        populate the result cache) before later repeats of the same
        source are submitted -- the continuous-batching behavior, not
        submit-everything-then-drain."""
        reqs = []
        for algo, arg in stream:
            if algo == "update":
                self.update(arg)
            else:
                reqs.append(self.submit(algo, arg))
                if self.pending >= self.batch:
                    self.pump()
        self.drain()
        return reqs

    # ------------------------------------------------------------ #
    def _expire_queued(self, algo: str) -> None:
        """A request whose deadline passed while queued is retired with
        a typed error and no fixpoint work: queue wait consumed its
        whole budget."""
        queue = self._queues.get(algo)
        if not queue:
            return
        now = self.clock.now()
        live = deque()
        for req in queue:
            if req.t_deadline is not None and req.t_deadline <= now:
                req.queue_wait_s = now - req.t_submit
                req.deadline_expired = True
                req.error = DeadlineExceeded(
                    f"request {req.req_id} ({algo}, src {req.src}) "
                    f"expired after {req.queue_wait_s:.3g}s in queue "
                    f"(deadline {req.deadline_s}s); no work done",
                    deadline_s=req.deadline_s or 0.0,
                    elapsed_s=req.queue_wait_s, where="queue")
                self.failed += 1
                self.metrics.counter(f"errors.{req.error.code}").inc()
                self.metrics.counter(f"expired_in_queue.{algo}").inc()
            else:
                live.append(req)
        self._queues[algo] = live

    def _refill(self, algo: str) -> None:
        """Admit queued queries into idle lanes, FIFO."""
        queue = self._queues.get(algo)
        if not queue:
            return
        rb = self._batch(algo)
        for b in rb.idle:
            if not queue:
                break
            req = queue.popleft()
            req.admit_window = self.windows
            rb.admit(b, req, self.clock.now(), warm=self._warm_for(req))
            self.metrics.counter(f"admitted.{algo}").inc()
            self.metrics.histogram(f"queue_wait_s.{algo}").observe(
                req.queue_wait_s)

    def _warm_for(self, req: ServeRequest):
        """Warm-start candidate for this (algo, src), if the last
        update left one and its delta is monotone-resumable (PR-5
        provenance: exactly one graph-version step)."""
        if not self.warm_reuse or req.algo not in self._warm:
            return None
        delta, candidates = self._warm[req.algo]
        attrs = candidates.get(req.src)
        if attrs is None:
            return None
        ws = self._batches[req.algo].engine.resolve_warm(attrs, delta)
        if ws is not None:
            req.warm_started = True
            self.metrics.counter(f"warm_started.{req.algo}").inc()
        return ws

    def _run_window(self, algo: str, rb: RotatingBatch) -> None:
        """One K-step segment plus the retirement pass."""
        occupied = rb.occupied
        try:
            steps, converged, iters = rb.run_window(self.segment_steps)
        except Exception as e:                      # noqa: BLE001
            # typed per-request failure, never a lost bucket: classify,
            # attach, and reset the lanes so the stream keeps serving
            err = classify(e, 0)
            now = self.clock.now()
            for b in occupied:
                req = rb.evict(b)
                req.error = err
                req.service_s = now - rb.t_admit[b]
                self.failed += 1
                self.metrics.counter(f"errors.{err.code}").inc()
            rb.reset()
            return
        self.clock.on_steps(iters)
        self.windows += 1
        self.metrics.counter(f"windows.{algo}").inc()
        self.metrics.histogram("window_iters").observe(iters)
        now = self.clock.now()
        for b in occupied:
            req = rb.slots[b]
            if bool(converged[b]):
                self._retire(rb, b, now, converged=True)
            elif rb.budget_left(b) == 0:
                self._retire(rb, b, now, converged=False,
                             error=ConvergenceFailure(
                                 f"request {req.req_id} ({algo}, src "
                                 f"{req.src}) hit its step budget at "
                                 f"step {req.steps} without converging "
                                 "(partial result attached)",
                                 steps=req.steps,
                                 max_steps=req.max_steps))
            elif req.t_deadline is not None and req.t_deadline <= now:
                req.deadline_expired = True
                self._retire(rb, b, now, converged=False,
                             error=DeadlineExceeded(
                                 f"request {req.req_id} ({algo}, src "
                                 f"{req.src}) stopped at step "
                                 f"{req.steps}: deadline "
                                 f"{req.deadline_s}s expired (partial "
                                 "result attached)",
                                 deadline_s=req.deadline_s or 0.0,
                                 elapsed_s=now - req.t_submit,
                                 where="fixpoint"))

    def _retire(self, rb: RotatingBatch, b: int, now: float, *,
                converged: bool, error=None) -> None:
        """Produce lane `b`'s result (full or flagged partial), attach
        the outcome, free the lane, and feed the cache."""
        req = rb.slots[b]
        req.result = rb.finalize_lane(b)
        req.converged = converged
        req.service_s = now - rb.t_admit[b]
        rb.evict(b)
        m = self.metrics
        if converged:
            self.cache.put(self.graph.fingerprint(), req.algo, req.src,
                           req.result, req.steps)
            self.completed += 1
            m.counter(f"completed.{req.algo}").inc()
        else:
            # a partial is attached AND flagged: the typed error says why
            req.error = error
            self.failed += 1
            m.counter(f"errors.{error.code}").inc()
        m.histogram(f"latency_s.{req.algo}").observe(
            req.queue_wait_s + req.service_s)
        m.histogram(f"service_s.{req.algo}").observe(req.service_s)
        m.histogram(f"steps.{req.algo}").observe(req.steps)

    # ------------------------------------------------------------ #
    def update(self, updates) -> dict:
        """Apply one edge-mutation batch between queries: drain first
        (every submitted query runs against the graph version current
        at its submission), step every session incrementally, retire
        the superseded cache generation into warm-start candidates, and
        reset the rotating batches (all lanes idle on the new version).
        Returns the per-algebra `UpdateDelta`s."""
        self.drain()
        updates = list(updates)
        old_fp = self.graph.fingerprint()
        g2 = self.graph.apply_updates(updates)
        retired = self.cache.retire_fp(old_fp)
        self._warm = {}
        deltas = {}
        for algo, rb in list(self._batches.items()):
            cq2, delta = rb.cq.update(updates, new_graph=g2)
            self._batches[algo] = RotatingBatch(cq2, self.lanes)
            deltas[algo] = delta
            if self.warm_reuse:
                cand = {src: e.attrs for (a, src), e in retired.items()
                        if a == algo}
                if cand:
                    self._warm[algo] = (delta, cand)
        self.graph = g2
        self.updates_applied += 1
        self.metrics.counter("updates.applied").inc()
        return deltas

    # ------------------------------------------------------------ #
    def _refresh_gauges(self) -> None:
        m = self.metrics
        m.gauge("queue_depth").set(
            sum(len(q) for q in self._queues.values()))
        occ = [len(rb.occupied) / rb.nslots
               for rb in self._batches.values()]
        m.gauge("occupancy").set(float(np.mean(occ)) if occ else 0.0)
        m.gauge("cache.hit_rate").set(self.cache.stats()["hit_rate"])

    def stats(self) -> dict:
        """JSON-ready scheduler statistics: queue/occupancy state, the
        cache's hit/eviction ledger, lifetime counters, and the full
        metrics snapshot."""
        self._refresh_gauges()
        snap = self.metrics.snapshot()
        return {
            "scheduler": "continuous",
            "segment_steps": self.segment_steps,
            "queue_depth": int(sum(len(q)
                                   for q in self._queues.values())),
            "queue_depth_per_algo": {a: len(q) for a, q
                                     in self._queues.items() if q},
            "occupancy": snap["gauges"].get("occupancy", 0.0),
            "slots": {a: len(rb.occupied)
                      for a, rb in self._batches.items()},
            "windows": self.windows,
            "cache": self.cache.stats(),
            "completed": self.completed,
            "failed": self.failed,
            "shed": self.shed,
            "updates_applied": self.updates_applied,
            "metrics": snap,
        }
