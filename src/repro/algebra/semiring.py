"""Semirings underlying FLIP's vertex-centric execution.

Every FLIP layer computes, per relaxation step, a blocked semiring
matrix-vector product

    cand[v] = ⊕_u ( src_vals[u] ⊗ W[u, v] )        (gather/combine)
    new[v]  = carry[v] ⊕ cand[v]                    (merge)

where W is the tiled adjacency with absent edges holding the ⊕-identity
(`zero`), inactive sources also hold `zero`, and `carry` is whatever the
algorithm folds into the merge (current attributes for monotone
algorithms, the un-pushed residual for delta-PageRank). The semiring
contract the kernels rely on:

  * ⊕ is associative and commutative with identity `zero`;
  * ⊗ has identity `one` and `zero` annihilates it: zero ⊗ x = zero,
    so padding blocks / inactive lanes drop out of the reduction.

Idempotent ⊕ (min/max/or) additionally makes the merge monotone, which is
what the asynchronous cycle simulator needs (see `VertexAlgebra.sim_ok`).

Each op comes in a numpy and a jnp flavour: the numpy side feeds the
cycle simulator and the host-side oracles, the jnp side is traced into
the Pallas kernel / jnp fallback / shard_map engine. Instances are
module-level singletons so they hash by identity and are safe static
arguments to `jax.jit`.

Vector-valued vertex state generalizes the step above to `(n, d)`
feature blocks: `cand[v, f] = ⊕_u (src_vals[u, f] ⊗ W[u, v])`, i.e. the
same contraction applied independently per feature lane `f`. Per tile
that is a `(T, T) × (T, d)` contraction, exposed as `contract_jnp`:
for (+, ×) it IS a matmul (`W.T @ sv`, an MXU op on TPU); for every
other ⊕/⊗ pair it is a broadcast-⊗ then ⊕-reduce over the source axis,
swept in static d-slabs so the `(S, D, slab)` intermediate stays small
inside a Pallas kernel body.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True, eq=False)
class Semiring:
    """(⊕, ⊗) pair with identities and the reductions the kernels need.

    `eq=False` keeps the default identity hash/eq, so passing a semiring
    as a `static_argnames` entry to `jax.jit` caches one executable per
    singleton instead of retracing.
    """

    name: str
    zero: float                 # ⊕-identity; absent edge / inactive lane
    one: float                  # ⊗-identity; source bootstrap value
    add_np: Callable            # ⊕ elementwise, numpy (a ufunc: build_blocks
                                #   uses its `.at` for the edge scatter; a
                                #   plain callable falls back to a slow loop)
    mul_np: Callable            # ⊗ elementwise, numpy
    add_jnp: Callable           # ⊕ elementwise, jnp
    mul_jnp: Callable           # ⊗ elementwise, jnp
    add_reduce_jnp: Callable    # ⊕-reduction along an axis, jnp
    segment_reduce_jnp: Callable  # ⊕-reduction by segment id, jnp
    idempotent: bool            # x ⊕ x == x (min/max/or, not +)
    contract_jnp: Callable = None  # (..., S, d) ⊗ (..., S, D) -> (..., D, d)
                                #   tile contraction over the source axis;
                                #   derived from add/mul when not given

    def monotone_under(self, old_vals, new_vals) -> bool:
        """Warm-start soundness hook for streaming graph updates.

        `old_vals`/`new_vals` are the stored ⊗ operands of the touched
        adjacency cells before and after an update batch, with the
        ⊕-identity standing for an absent edge. Returns True iff every
        new value ⊕-dominates its old value (``new ⊕ old == new``) --
        i.e. the batch only inserts edges or moves weights in the
        ⊕-improving direction. Under an idempotent ⊕ the relaxation
        fixpoint is then monotone in the edge values, so a previous
        fixpoint is a sound resume state: re-seeding only the touched
        sources converges to exactly the from-scratch result. Edge
        deletions / ⊕-worsening reweights (``old`` strictly dominating)
        and non-idempotent ⊕ (re-relaxing would double-count, e.g.
        (+,x) delta-PageRank) return False and require a full recompute.
        """
        if not self.idempotent:
            return False
        old = np.asarray(old_vals, dtype=np.float32)
        new = np.asarray(new_vals, dtype=np.float32)
        return bool(np.all(self.add_np(new, old) == new))

    def __post_init__(self):
        if self.contract_jnp is None:
            object.__setattr__(
                self, "contract_jnp",
                _generic_contract(self.add_reduce_jnp, self.mul_jnp))


def _generic_contract(add_reduce, mul, slab: int = 8):
    """Generic (⊕, ⊗) tile contraction, swept in static d-slabs.

    ``sv`` is ``(..., S, d)`` source state, ``w`` is ``(..., S, D)``
    weights; the result is ``(..., D, d)``:
    ``out[.., v, f] = ⊕_u sv[.., u, f] ⊗ w[.., u, v]``. The broadcast
    intermediate is ``(..., S, D, slab)`` -- bounded by the static slab
    width so the Pallas kernel body's VMEM working set stays small even
    at d=128 (see kernels/frontier/frontier.py's budget math).
    """
    def contract(sv, w):
        d = sv.shape[-1]
        outs = [
            add_reduce(mul(sv[..., :, None, k:k + slab],
                           w[..., :, :, None]), axis=-3)
            for k in range(0, d, slab)
        ]
        return outs[0] if len(outs) == 1 else jnp.concatenate(outs, -1)
    return contract


def _matmul_contract(sv, w):
    """(+, ×) tile contraction as a true matmul: ``w.T @ sv`` contracts
    the source axis on the MXU ((T, T) × (T, d) per tile)."""
    return jnp.matmul(jnp.swapaxes(w, -1, -2), sv,
                      preferred_element_type=jnp.float32)


def _segment_or(x, seg, num_segments):
    return jax.ops.segment_max(x, seg, num_segments=num_segments)


MIN_PLUS = Semiring(
    name="min_plus", zero=float("inf"), one=0.0,
    add_np=np.minimum, mul_np=np.add,
    add_jnp=jnp.minimum, mul_jnp=jnp.add,
    add_reduce_jnp=jnp.min,
    segment_reduce_jnp=lambda x, s, n: jax.ops.segment_min(
        x, s, num_segments=n),
    idempotent=True,
)

MAX_MIN = Semiring(
    name="max_min", zero=float("-inf"), one=float("inf"),
    add_np=np.maximum, mul_np=np.minimum,
    add_jnp=jnp.maximum, mul_jnp=jnp.minimum,
    add_reduce_jnp=jnp.max,
    segment_reduce_jnp=lambda x, s, n: jax.ops.segment_max(
        x, s, num_segments=n),
    idempotent=True,
)

# boolean (or, and) carried in {0.0, 1.0} float32 so every layer keeps a
# single dtype; max == or and min == and on that domain.
OR_AND = Semiring(
    name="or_and", zero=0.0, one=1.0,
    add_np=np.maximum, mul_np=np.minimum,
    add_jnp=jnp.maximum, mul_jnp=jnp.minimum,
    add_reduce_jnp=jnp.max,
    segment_reduce_jnp=_segment_or,
    idempotent=True,
)

PLUS_TIMES = Semiring(
    name="plus_times", zero=0.0, one=1.0,
    add_np=np.add, mul_np=np.multiply,
    add_jnp=jnp.add, mul_jnp=jnp.multiply,
    add_reduce_jnp=jnp.sum,
    segment_reduce_jnp=lambda x, s, n: jax.ops.segment_sum(
        x, s, num_segments=n),
    idempotent=False,
    contract_jnp=_matmul_contract,
)

SEMIRINGS = {s.name: s for s in (MIN_PLUS, MAX_MIN, OR_AND, PLUS_TIMES)}
