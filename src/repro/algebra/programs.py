"""Vertex algebras: a semiring plus everything an algorithm needs to run
on every FLIP layer (cycle simulator, JAX engine, Pallas kernel, tables).

A `VertexAlgebra` is the generalized vertex program (paper Fig. 5): the
message along edge (u, v) is `attr_u ⊗ W[u, v]`, destinations merge with
⊕, and a vertex scatters iff it became "active". Two activity kinds:

  * monotone  -- attrs improve monotonically under an idempotent ⊕
    (min/max/or); a vertex is active exactly when its attribute strictly
    improved. BFS / SSSP / WCC / widest-path / reachability. These run
    on the asynchronous cycle simulator too (`sim_ok=True`): idempotence
    makes the fixpoint order-independent.
  * residual  -- attrs are un-pushed residual mass over a non-idempotent
    ⊕ (+,x); a vertex is active while its residual exceeds `tol`, and an
    auxiliary per-vertex accumulator (the PageRank score) absorbs every
    pushed residual. Delta-PageRank. Not expressible on the async
    simulator (duplicated in-flight mass would double-count), so
    `sim_ok=False`.

Edge weights are materialized once at table/block build time via
`edge_value` (the ⊗ operand), so every execution layer sees the same
numbers: BFS stores 1 (hop), WCC stores the ⊗-identity (pure label
copy), PageRank stores damping/outdeg(u).

Registering a new algorithm == one `VertexAlgebra(...)` entry in
`ALGEBRAS` plus a numpy oracle in `repro.graphs.reference` (see
docs/ALGEBRA.md).
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

from repro.algebra.semiring import (MAX_MIN, MIN_PLUS, OR_AND, PLUS_TIMES,
                                    Semiring)


def landmarks(n: int, src, d: int) -> np.ndarray:
    """The d landmark vertices feature column f is seeded from.

    Deterministic and shared verbatim by the algebra inits, the numpy
    oracles and the examples: landmark f is the query source advanced by
    f strides of ~n/d, so landmarks spread over the vertex id space and
    landmark 0 is always the source itself. `src` may be a scalar or a
    (B,) batch; the result gains a matching leading axis.
    """
    srcs = np.asarray(src, dtype=np.int64)
    lm = (srcs[..., None] + np.arange(d, dtype=np.int64)
          * max(1, n // d)) % n
    return lm


@dataclasses.dataclass(frozen=True, eq=False)
class VertexAlgebra:
    name: str
    semiring: Semiring
    kind: str = "monotone"       # 'monotone' | 'residual'
    weight_rule: str = "graph"   # 'graph' | 'hop' | 'identity' | 'degree_damped'
    undirected: bool = False     # scatter along both half-edges (WCC)
    all_start: bool = False      # every vertex starts active (WCC, PageRank)
    sim_ok: bool | None = None   # async-simulator expressibility; None =
                                 # derive (idempotent ⊕ and monotone kind)
    exe_update: int = 5          # instructions when the attribute changes
    exe_noupdate: int = 4        # instructions when it does not
    tol: float = 0.0             # residual activity threshold ('residual')
    damping: float = 0.85        # PageRank damping ('degree_damped')
    atol: float = 1e-6           # oracle-comparison tolerance
    feature_dim: int = 1         # native width of the vertex state: 1 =
                                 # classic scalar programs; d > 1 = (n, d)
                                 # feature blocks (multi-landmark / labels)
    feature_init: str = "broadcast"  # how column f of a (n, d) init is
                                 # seeded: 'broadcast' repeats the scalar
                                 # init, 'landmarks' seeds column f at
                                 # landmark f of `landmarks(n, src, d)`

    def __post_init__(self):
        # The asynchronous simulator re-merges in-flight duplicates, which
        # is only sound when ⊕ is idempotent and there is no side
        # accumulator; sim_ok can opt out of that but never opt in. The
        # packet-level simulator is scalar-state only.
        sound = (self.semiring.idempotent and self.kind == "monotone"
                 and self.feature_dim == 1)
        object.__setattr__(
            self, "sim_ok",
            sound if self.sim_ok is None else (self.sim_ok and sound))
        if self.feature_dim < 1:
            raise ValueError(
                f"{self.name}: feature_dim must be >= 1, "
                f"got {self.feature_dim}")
        if self.feature_init not in ("broadcast", "landmarks"):
            raise ValueError(
                f"{self.name}: unknown feature_init {self.feature_init!r}")

    # ------------------------------------------------------------------ #
    # edge materialization (blocks, routing tables)
    # ------------------------------------------------------------------ #
    def edge_value(self, u: int, v: int, w: float,
                   outdeg: np.ndarray) -> float:
        """The ⊗ operand stored for edge (u, v) of raw weight w (scalar
        view of `edge_values`, so there is exactly one dispatch table).
        Downstream consumers (tables/simulator) cast through float32 in
        `message`, so the f32 production here loses nothing."""
        return float(self.edge_values(np.asarray([u]), np.asarray([v]),
                                      np.asarray([w], dtype=np.float32),
                                      outdeg)[0])

    def edge_values(self, u: np.ndarray, v: np.ndarray, w: np.ndarray,
                    outdeg: np.ndarray) -> np.ndarray:
        """Vectorized ⊗ operands over whole edge arrays (the block-build
        hot path)."""
        u = np.asarray(u)
        if self.weight_rule == "graph":
            return np.asarray(w, dtype=np.float32)
        if self.weight_rule == "hop":
            return np.ones(u.shape, dtype=np.float32)
        if self.weight_rule == "identity":
            return np.full(u.shape, np.float32(self.semiring.one),
                           dtype=np.float32)
        if self.weight_rule == "degree_damped":
            return (self.damping /
                    outdeg[u].astype(np.float64)).astype(np.float32)
        raise ValueError(f"unknown weight_rule {self.weight_rule!r}")

    # ------------------------------------------------------------------ #
    # initial state (original vertex order; engine re-tiles it)
    #
    # `src` is a single source vertex or a sequence of B of them: a scalar
    # yields the classic (n,) vectors, a sequence yields (B, n) -- one
    # independent query per row, the layout every batched layer threads
    # through as (B, ntiles, T).
    #
    # At feature_dim d > 1 (passed explicitly, or the algebra's native
    # width) the state grows a trailing feature axis -- (n, d) / (B, n, d)
    # -- seeded per `feature_init`; the frontier stays per-vertex.
    # ------------------------------------------------------------------ #
    def initial_attrs(self, n: int, src, feature_dim: int | None = None
                      ) -> np.ndarray:
        sr = self.semiring
        d = self.feature_dim if feature_dim is None else feature_dim
        srcs = np.atleast_1d(np.asarray(src, dtype=np.int64))
        b = srcs.shape[0]
        if d > 1 and self.feature_init == "landmarks":
            lm = landmarks(n, srcs, d)                       # (b, d)
            seed = ((1.0 - self.damping) if self.kind == "residual"
                    else sr.one)
            base = 0.0 if self.kind == "residual" else sr.zero
            a = np.full((b, n, d), base, dtype=np.float32)
            a[np.arange(b)[:, None], lm, np.arange(d)[None, :]] = \
                np.float32(seed)
            return a if np.ndim(src) else a[0]
        if self.kind == "residual":
            # un-pushed residual of the series p = sum_k M^k b
            a = np.full((b, n), (1.0 - self.damping) / n, dtype=np.float32)
        elif self.all_start:         # WCC: label = own id
            a = np.broadcast_to(np.arange(n, dtype=np.float32),
                                (b, n)).copy()
        else:
            a = np.full((b, n), sr.zero, dtype=np.float32)
            a[np.arange(b), srcs] = np.float32(sr.one)
        if d > 1:                    # 'broadcast': d identical columns
            a = np.repeat(a[..., None], d, axis=-1)
        return a if np.ndim(src) else a[0]

    def initial_frontier(self, n: int, src, feature_dim: int | None = None
                         ) -> np.ndarray:
        d = self.feature_dim if feature_dim is None else feature_dim
        srcs = np.atleast_1d(np.asarray(src, dtype=np.int64))
        b = srcs.shape[0]
        if d > 1 and self.feature_init == "landmarks":
            # active exactly at the seeded landmarks (per-vertex frontier)
            f = np.zeros((b, n), dtype=bool)
            f[np.arange(b)[:, None], landmarks(n, srcs, d)] = True
        elif self.all_start or self.kind == "residual":
            f = np.ones((b, n), dtype=bool)
        else:
            f = np.zeros((b, n), dtype=bool)
            f[np.arange(b), srcs] = True
        return f if np.ndim(src) else f[0]

    # ------------------------------------------------------------------ #
    # simulator-side scalar ops (numpy)
    # ------------------------------------------------------------------ #
    @property
    def source_value(self) -> float:
        """Bootstrap packet value installed at the source vertex."""
        return float(self.semiring.one)

    def message(self, attr_u, w):
        """Value carried by a packet along edge (u, v) with stored w."""
        return self.semiring.mul_np(np.float32(attr_u), np.float32(w))

    def merge(self, attr_v, msg):
        return self.semiring.add_np(attr_v, msg)

    def improved_np(self, new, old):
        """Strict ⊕-improvement (direction-free: works for min and max)."""
        return np.logical_and(self.semiring.add_np(new, old) == new,
                              new != old)

    def exe_cycles(self, updated: bool) -> int:
        return self.exe_update if updated else self.exe_noupdate

    # ------------------------------------------------------------------ #
    # engine-side step hooks (jnp, traced under jit/shard_map)
    #
    # All hooks are elementwise over the state arrays, so they accept any
    # leading query axes unchanged: the engine passes (ntiles, T) for one
    # query and (B, ntiles, T) for a batch, and each row of the batch
    # behaves exactly like an independent single-query run.
    #
    # With `features=True` the state carries a trailing feature axis
    # ((..., T, d)) while the frontier stays per-vertex ((..., T)): the
    # frontier broadcasts over the lanes on scatter, and per-lane
    # activity any-reduces back to the vertex on post-step.
    # ------------------------------------------------------------------ #
    def improved_jnp(self, new, old):
        return jnp.logical_and(self.semiring.add_jnp(new, old) == new,
                               new != old)

    def scatter_carry_jnp(self, attrs, frontier, op_mode: bool,
                          features: bool = False):
        """(src_vals, carry) for one relax step.

        The kernel computes  new = carry ⊕ (⊕_u src_vals[u] ⊗ W[u, ·]);
        monotone algebras carry their current attrs (merge folds "no
        update" in), residual algebras carry only the *un-absorbed*
        residual -- active lanes push theirs out, so they carry zero.
        """
        sr = self.semiring
        f = frontier[..., None] if features else frontier
        if self.kind == "residual":
            if op_mode:
                return attrs, jnp.zeros_like(attrs)
            sv = jnp.where(f, attrs, sr.zero)
            return sv, jnp.where(f, sr.zero, attrs)
        sv = attrs if op_mode else jnp.where(f, attrs, sr.zero)
        return sv, attrs

    def post_step_jnp(self, attrs, aux, src_vals, new_attrs,
                      features: bool = False):
        """(attrs', aux', frontier') after a relax step."""
        if self.kind == "residual":
            act = new_attrs > self.tol
            return (new_attrs, aux + src_vals,
                    jnp.any(act, axis=-1) if features else act)
        imp = self.improved_jnp(new_attrs, attrs)
        return (new_attrs, aux,
                jnp.any(imp, axis=-1) if features else imp)

    def finalize(self, attrs, aux):
        """Result vector reported to the caller."""
        return aux if self.kind == "residual" else attrs

    # ------------------------------------------------------------------ #
    # result comparison (tests, CLI self-check, examples)
    # ------------------------------------------------------------------ #
    @staticmethod
    def finite(x):
        """Map ±inf to distinguishable sentinels: widest-path results
        legitimately contain both +inf (source) and -inf (unreached)."""
        return np.clip(np.nan_to_num(np.asarray(x, dtype=np.float64),
                                     posinf=1e30, neginf=-1e30),
                       -1e30, 1e30)

    def results_match(self, got, ref) -> bool:
        """Oracle comparison at this algebra's tolerance.

        A scalar program run at feature_dim d > 1 ('broadcast' init)
        yields d identical columns; comparing such a `(n, d)` result
        against the scalar `(n,)` oracle broadcasts the oracle over the
        feature axis.
        """
        got, ref = np.asarray(got), np.asarray(ref)
        if got.ndim == ref.ndim + 1:
            ref = ref[..., None]
        return bool(np.allclose(self.finite(got), self.finite(ref),
                                atol=self.atol))


# ---------------------------------------------------------------------- #
# registry
# ---------------------------------------------------------------------- #
BFS = VertexAlgebra("bfs", MIN_PLUS, weight_rule="hop",
                    exe_update=5, exe_noupdate=4)
SSSP = VertexAlgebra("sssp", MIN_PLUS, weight_rule="graph",
                     exe_update=5, exe_noupdate=4)
WCC = VertexAlgebra("wcc", MIN_PLUS, weight_rule="identity",
                    undirected=True, all_start=True,
                    exe_update=4, exe_noupdate=2)
WIDEST = VertexAlgebra("widest", MAX_MIN, weight_rule="graph",
                       exe_update=5, exe_noupdate=4)
REACH = VertexAlgebra("reach", OR_AND, weight_rule="identity",
                      exe_update=4, exe_noupdate=2)
PAGERANK = VertexAlgebra("pagerank", PLUS_TIMES, kind="residual",
                         weight_rule="degree_damped", all_start=True,
                         exe_update=6, exe_noupdate=3,
                         tol=1e-9, damping=0.85, atol=1e-4)
# Vector-state programs (feature_dim > 1): column f runs from landmark f
# of `landmarks(n, src, d)`. multi_bfs embeds every vertex by its hop
# distance to d landmarks (one min_plus relaxation amortizing each weight
# block over d lanes); labelprop diffuses d seeded label masses through
# the damped-walk (+, x) operator -- argmax over the feature axis is the
# propagated community label (seeded label spreading).
MULTI_BFS = VertexAlgebra("multi_bfs", MIN_PLUS, weight_rule="hop",
                          exe_update=5, exe_noupdate=4,
                          feature_dim=8, feature_init="landmarks")
LABELPROP = VertexAlgebra("labelprop", PLUS_TIMES, kind="residual",
                          weight_rule="degree_damped",
                          exe_update=6, exe_noupdate=3,
                          tol=1e-9, damping=0.85, atol=1e-4,
                          feature_dim=8, feature_init="landmarks")

ALGEBRAS: dict[str, VertexAlgebra] = {
    a.name: a for a in (BFS, SSSP, WCC, WIDEST, REACH, PAGERANK,
                        MULTI_BFS, LABELPROP)
}


def get_algebra(name: str) -> VertexAlgebra:
    try:
        return ALGEBRAS[name]
    except KeyError:
        raise ValueError(
            f"unknown algorithm {name!r}; registered: "
            f"{sorted(ALGEBRAS)}") from None


def register_algebra(algebra: VertexAlgebra) -> VertexAlgebra:
    """Add a new algorithm to every execution layer at once."""
    ALGEBRAS[algebra.name] = algebra
    return algebra
