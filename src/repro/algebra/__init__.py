from repro.algebra.semiring import (MIN_PLUS, MAX_MIN, OR_AND, PLUS_TIMES,
                                    SEMIRINGS, Semiring)
from repro.algebra.programs import (ALGEBRAS, BFS, LABELPROP, MULTI_BFS,
                                    PAGERANK, REACH, SSSP, WCC, WIDEST,
                                    VertexAlgebra, get_algebra, landmarks,
                                    register_algebra)

__all__ = [
    "Semiring", "SEMIRINGS",
    "MIN_PLUS", "MAX_MIN", "OR_AND", "PLUS_TIMES",
    "VertexAlgebra", "ALGEBRAS", "get_algebra", "register_algebra",
    "BFS", "SSSP", "WCC", "WIDEST", "REACH", "PAGERANK",
    "MULTI_BFS", "LABELPROP", "landmarks",
]
