"""phi3-medium-14b [dense] -- 40L d_model=5120 40H (GQA kv=10) d_ff=17920
vocab=100352, RoPE SwiGLU GQA. [arXiv:2404.14219; unverified]"""
from repro.models.config import ModelConfig, BlockSpec

CONFIG = ModelConfig(
    name="phi3-medium-14b", family="dense",
    num_layers=40, d_model=5120, num_heads=40, num_kv_heads=10,
    head_dim=128, d_ff=17920, vocab_size=100352,
    pattern=(BlockSpec(kind="attn"),),
)

SMOKE = ModelConfig(
    name="phi3-medium-14b-smoke", family="dense",
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=1,
    head_dim=16, d_ff=192, vocab_size=256,
    pattern=(BlockSpec(kind="attn"),),
    param_dtype="float32", activation_dtype="float32",
)
