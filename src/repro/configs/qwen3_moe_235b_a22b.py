"""qwen3-moe-235b-a22b [moe] -- 94L d_model=4096 64H (GQA kv=4)
d_ff(expert)=1536 vocab=151936, MoE 128 experts top-8, qk_norm.
[hf:Qwen/Qwen3-30B-A3B; hf]  head_dim=128 (Qwen3 convention).
94 layers is prime-ish for scan; pattern length 1, repeat 94."""
from repro.models.config import ModelConfig, BlockSpec

CONFIG = ModelConfig(
    name="qwen3-moe-235b-a22b", family="moe",
    num_layers=94, d_model=4096, num_heads=64, num_kv_heads=4,
    head_dim=128, d_ff=1536, vocab_size=151936,
    qk_norm=True, rope_theta=1e6,
    num_experts=128, top_k=8, expert_d_ff=1536,
    pattern=(BlockSpec(kind="attn", moe=True),),
)

SMOKE = ModelConfig(
    name="qwen3-moe-235b-a22b-smoke", family="moe",
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
    head_dim=16, d_ff=96, vocab_size=256,
    qk_norm=True, num_experts=8, top_k=2, expert_d_ff=96,
    pattern=(BlockSpec(kind="attn", moe=True),),
    param_dtype="float32", activation_dtype="float32",
)
