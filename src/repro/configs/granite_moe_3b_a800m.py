"""granite-moe-3b-a800m [moe] -- 32L d_model=1536 24H (GQA kv=8)
d_ff(expert)=512 vocab=49155, MoE 40 experts top-8.
[hf:ibm-granite/granite-3.0-1b-a400m-base; hf]
NB assignment lists both "40e" and "32 experts"; we use the structured
field 40e (DESIGN.md Sec. 8)."""
from repro.models.config import ModelConfig, BlockSpec

CONFIG = ModelConfig(
    name="granite-moe-3b-a800m", family="moe",
    num_layers=32, d_model=1536, num_heads=24, num_kv_heads=8,
    head_dim=64, d_ff=512, vocab_size=49155,
    num_experts=40, top_k=8, expert_d_ff=512, tie_embeddings=True,
    pattern=(BlockSpec(kind="attn", moe=True),),
)

SMOKE = ModelConfig(
    name="granite-moe-3b-a800m-smoke", family="moe",
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
    head_dim=16, d_ff=64, vocab_size=256,
    num_experts=8, top_k=2, expert_d_ff=64, tie_embeddings=True,
    pattern=(BlockSpec(kind="attn", moe=True),),
    param_dtype="float32", activation_dtype="float32",
)
