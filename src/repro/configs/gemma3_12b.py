"""gemma3-12b [dense] -- 48L d_model=3840 16H (GQA kv=8) d_ff=15360
vocab=262144, 5:1 local:global sliding window, 128k ctx.
[hf:google/gemma-3-1b-pt; unverified]
head_dim=256 (Gemma-3 convention; see DESIGN.md Sec. 8), window=1024."""
from repro.models.config import ModelConfig, BlockSpec

_PATTERN = tuple([BlockSpec(kind="attn", window=1024)] * 5
                 + [BlockSpec(kind="attn", window=None)])

CONFIG = ModelConfig(
    name="gemma3-12b", family="dense",
    num_layers=48, d_model=3840, num_heads=16, num_kv_heads=8,
    head_dim=256, d_ff=15360, vocab_size=262144,
    qk_norm=True, rope_theta=1e6, tie_embeddings=True,
    pattern=_PATTERN,
)

SMOKE = ModelConfig(
    name="gemma3-12b-smoke", family="dense",
    num_layers=6, d_model=64, num_heads=4, num_kv_heads=2,
    head_dim=16, d_ff=128, vocab_size=256,
    qk_norm=True, tie_embeddings=True,
    pattern=tuple([BlockSpec(kind="attn", window=16)] * 5
                  + [BlockSpec(kind="attn", window=None)]),
    param_dtype="float32", activation_dtype="float32",
)
