"""jamba-1.5-large-398b [hybrid] -- 72L d_model=8192 64H (GQA kv=8)
d_ff=24576 vocab=65536, MoE 16 experts top-2, Mamba:attn 7:1 interleave.
[arXiv:2403.19887; hf]
Layout per Jamba paper: 8-layer period, attention at index 4 (middle),
MoE replaces the FFN every other layer (odd indices). SSM layers use our
SSD (Mamba-2) block -- the TPU-idiomatic chunked form (DESIGN.md Sec. 8).
"""
from repro.models.config import ModelConfig, BlockSpec

_PATTERN = tuple(
    BlockSpec(kind=("attn" if i == 4 else "mamba"), moe=(i % 2 == 1))
    for i in range(8)
)

CONFIG = ModelConfig(
    name="jamba-1.5-large-398b", family="hybrid",
    num_layers=72, d_model=8192, num_heads=64, num_kv_heads=8,
    head_dim=128, d_ff=24576, vocab_size=65536,
    num_experts=16, top_k=2, expert_d_ff=24576,
    ssm_state=128, ssm_expand=2, ssm_head_dim=128,
    pattern=_PATTERN,
)

SMOKE = ModelConfig(
    name="jamba-1.5-large-398b-smoke", family="hybrid",
    num_layers=8, d_model=64, num_heads=4, num_kv_heads=2,
    head_dim=16, d_ff=128, vocab_size=256,
    num_experts=4, top_k=2, expert_d_ff=128,
    ssm_state=16, ssm_expand=2, ssm_head_dim=32, ssm_chunk=16,
    pattern=tuple(
        BlockSpec(kind=("attn" if i == 4 else "mamba"), moe=(i % 2 == 1))
        for i in range(8)),
    param_dtype="float32", activation_dtype="float32",
)
