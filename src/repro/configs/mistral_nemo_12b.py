"""mistral-nemo-12b [dense] -- 40L d_model=5120 32H (GQA kv=8) d_ff=14336
vocab=131072, 128k ctx. [hf:mistralai/Mistral-Nemo-Base-2407; hf]"""
from repro.models.config import ModelConfig, BlockSpec

CONFIG = ModelConfig(
    name="mistral-nemo-12b", family="dense",
    num_layers=40, d_model=5120, num_heads=32, num_kv_heads=8,
    head_dim=128, d_ff=14336, vocab_size=131072,
    rope_theta=1e6,
    pattern=(BlockSpec(kind="attn"),),
)

SMOKE = ModelConfig(
    name="mistral-nemo-12b-smoke", family="dense",
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
    head_dim=16, d_ff=160, vocab_size=256, rope_theta=1e6,
    pattern=(BlockSpec(kind="attn"),),
    param_dtype="float32", activation_dtype="float32",
)
