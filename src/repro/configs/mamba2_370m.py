"""mamba2-370m [ssm] -- 48L d_model=1024 (attn-free) vocab=50280,
ssm_state=128, SSD (state-space duality). [arXiv:2405.21060; unverified]
expand=2 -> d_inner=2048, head_dim=64 -> 32 SSD heads."""
from repro.models.config import ModelConfig, BlockSpec

CONFIG = ModelConfig(
    name="mamba2-370m", family="ssm",
    num_layers=48, d_model=1024, num_heads=0, num_kv_heads=0,
    head_dim=0, d_ff=0, vocab_size=50280,
    ssm_state=128, ssm_expand=2, ssm_head_dim=64,
    tie_embeddings=True,
    pattern=(BlockSpec(kind="mamba", has_ffn=False),),
)

SMOKE = ModelConfig(
    name="mamba2-370m-smoke", family="ssm",
    num_layers=2, d_model=64, num_heads=0, num_kv_heads=0,
    head_dim=0, d_ff=0, vocab_size=256,
    ssm_state=16, ssm_expand=2, ssm_head_dim=32, ssm_chunk=16,
    tie_embeddings=True,
    pattern=(BlockSpec(kind="mamba", has_ffn=False),),
    param_dtype="float32", activation_dtype="float32",
)
