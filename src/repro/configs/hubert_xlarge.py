"""hubert-xlarge [audio] -- 48L d_model=1280 16H (MHA kv=16) d_ff=5120
vocab=504, encoder-only (w2v2 arch). [arXiv:2106.07447; unverified]
Modality frontend is a STUB per the assignment: input_specs() provides
precomputed frame embeddings (batch, frames, d_model); the conv feature
extractor is out of scope. Loss: frame-level CE over the 504 cluster
vocabulary (masked-prediction stub). No decode shapes (encoder)."""
from repro.models.config import ModelConfig, BlockSpec

CONFIG = ModelConfig(
    name="hubert-xlarge", family="audio",
    num_layers=48, d_model=1280, num_heads=16, num_kv_heads=16,
    head_dim=80, d_ff=5120, vocab_size=504,
    causal=False, frontend="frames",
    pattern=(BlockSpec(kind="attn"),),
)

SMOKE = ModelConfig(
    name="hubert-xlarge-smoke", family="audio",
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=4,
    head_dim=16, d_ff=128, vocab_size=32,
    causal=False, frontend="frames",
    pattern=(BlockSpec(kind="attn"),),
    param_dtype="float32", activation_dtype="float32",
)
