"""One config module per assigned architecture (+ the paper's own graph
workloads in flip_graph.py). `get(name)` returns the full ModelConfig;
`get_smoke(name)` a reduced same-family config for CPU smoke tests;
`SHAPES` the assigned input-shape set; `cells()` the (arch x shape) cells
with the DESIGN.md Sec. 7 skip rules applied.
"""
from __future__ import annotations

import dataclasses
import importlib

ARCH_IDS = [
    "qwen3_0_6b",
    "phi3_medium_14b",
    "mistral_nemo_12b",
    "gemma3_12b",
    "granite_moe_3b_a800m",
    "qwen3_moe_235b_a22b",
    "jamba_1_5_large_398b",
    "mamba2_370m",
    "hubert_xlarge",
    "chameleon_34b",
]

# assigned input shapes: name -> (seq_len, global_batch, step kind)
SHAPES = {
    "train_4k":    dict(seq_len=4_096,   global_batch=256, step="train"),
    "prefill_32k": dict(seq_len=32_768,  global_batch=32,  step="prefill"),
    "decode_32k":  dict(seq_len=32_768,  global_batch=128, step="decode"),
    "long_500k":   dict(seq_len=524_288, global_batch=1,   step="decode"),
}


def get(name: str):
    mod = importlib.import_module(f"repro.configs.{name}")
    return mod.CONFIG


def get_smoke(name: str):
    mod = importlib.import_module(f"repro.configs.{name}")
    return mod.SMOKE


def shape_supported(cfg, shape_name: str) -> tuple[bool, str]:
    """Skip rules of DESIGN.md Sec. 7. Returns (supported, reason)."""
    spec = SHAPES[shape_name]
    if spec["step"] == "decode" and not cfg.has_decode:
        return False, "encoder-only: no autoregressive decode step"
    if shape_name == "long_500k" and not cfg.supports_long_context():
        return False, ("pure full-attention decoder: 500k KV cache is not "
                       "sub-quadratic-servable (assignment skip rule)")
    if shape_name == "prefill_32k" and not cfg.causal:
        # encoders do run 32k forward; allowed
        return True, ""
    return True, ""


def cells():
    """All runnable (arch, shape) cells + the skip list."""
    run, skipped = [], []
    for a in ARCH_IDS:
        cfg = get(a)
        for s in SHAPES:
            ok, reason = shape_supported(cfg, s)
            (run if ok else skipped).append((a, s) if ok else (a, s, reason))
    return run, skipped
