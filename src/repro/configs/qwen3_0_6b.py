"""qwen3-0.6b [dense] -- 28L d_model=1024 16H (GQA kv=8) d_ff=3072
vocab=151936, qk_norm, GQA. [hf:Qwen/Qwen3-8B; hf]
head_dim=128 (Qwen3 decouples head_dim from d_model/heads)."""
from repro.models.config import ModelConfig, BlockSpec

CONFIG = ModelConfig(
    name="qwen3-0.6b", family="dense",
    num_layers=28, d_model=1024, num_heads=16, num_kv_heads=8,
    head_dim=128, d_ff=3072, vocab_size=151936,
    qk_norm=True, rope_theta=1e6, tie_embeddings=True,
    pattern=(BlockSpec(kind="attn"),),
)

SMOKE = ModelConfig(
    name="qwen3-0.6b-smoke", family="dense",
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
    head_dim=16, d_ff=128, vocab_size=256,
    qk_norm=True, rope_theta=1e6, tie_embeddings=True,
    pattern=(BlockSpec(kind="attn"),),
    param_dtype="float32", activation_dtype="float32",
)
