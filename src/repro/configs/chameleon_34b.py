"""chameleon-34b [vlm] -- 48L d_model=8192 64H (GQA kv=8) d_ff=22016
vocab=65536, early-fusion VQ image tokens. [arXiv:2405.09818; unverified]
The VQ tokenizer frontend is a STUB per the assignment: input_specs()
provides interleaved text+image token ids in the unified 65536 vocab; the
backbone is a standard dense decoder (qk-layernorm per Chameleon)."""
from repro.models.config import ModelConfig, BlockSpec

CONFIG = ModelConfig(
    name="chameleon-34b", family="vlm",
    num_layers=48, d_model=8192, num_heads=64, num_kv_heads=8,
    head_dim=128, d_ff=22016, vocab_size=65536,
    qk_norm=True,
    pattern=(BlockSpec(kind="attn"),),
)

SMOKE = ModelConfig(
    name="chameleon-34b-smoke", family="vlm",
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
    head_dim=16, d_ff=160, vocab_size=256, qk_norm=True,
    pattern=(BlockSpec(kind="attn"),),
    param_dtype="float32", activation_dtype="float32",
)
