"""FLIP graph-workload launcher: the paper's own application path.

Runs any registered algebra (BFS / SSSP / WCC / PageRank / widest-path /
reachability) on a Table-4 dataset through any of the three execution
layers:

  --engine sim     cycle-accurate FLIP simulator (paper evaluation vehicle)
  --engine jax     TPU-native frontier engine (single device)
  --engine dist    shard_map frontier engine over all local devices
  --engine op      op-centric mode (classic-CGRA functional analogue)

Example:
  PYTHONPATH=src python -m repro.launch.graph_run --algo sssp \
      --dataset LRN --engine sim --src 5
"""
from __future__ import annotations

import argparse
import time

import numpy as np

from repro.core import (compile_mapping, simulate, PROGRAMS, baselines)
from repro.core.engine import FlipEngine
from repro.graphs import make_dataset, reference


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--algo", default="bfs", choices=sorted(PROGRAMS))
    ap.add_argument("--dataset", default="LRN",
                    choices=["Tree", "SRN", "LRN", "Syn", "ExtLRN"])
    ap.add_argument("--engine", default="sim",
                    choices=["sim", "jax", "dist", "op"])
    ap.add_argument("--graph-seed", type=int, default=0)
    ap.add_argument("--src", type=int, default=0)
    ap.add_argument("--effort", type=int, default=1)
    args = ap.parse_args()

    g = next(make_dataset(args.dataset, 1, seed0=args.graph_seed))
    print(f"[graph] {args.dataset}: |V|={g.n} |E|={g.m}")
    t0 = time.time()
    mapping = compile_mapping(g, effort=args.effort,
                              program=PROGRAMS[args.algo])
    print(f"[graph] FLIP compile {time.time() - t0:.2f}s  "
          f"avg routing length {mapping.avg_routing_length():.2f}")

    ref, _ = reference.run(args.algo, g, args.src)
    if args.engine == "sim":
        if not PROGRAMS[args.algo].sim_ok:
            raise SystemExit(
                f"--engine sim cannot run {args.algo} (non-idempotent "
                "merge); use --engine jax/op/dist")
        r = simulate(mapping, PROGRAMS[args.algo], src=args.src)
        attrs = r.attrs
        mteps = g.m / (r.cycles / mapping.arch.freq_mhz)
        print(f"[graph] sim: {r.cycles} cycles "
              f"({r.cycles / mapping.arch.freq_mhz:.1f}us @100MHz), "
              f"parallelism avg={r.avg_parallelism:.1f} "
              f"max={r.max_parallelism}, {mteps:.0f} MTEPS, "
              f"pkt wait {r.avg_pkt_wait:.2f}cyc, swaps={r.swaps}")
        if args.algo in ("bfs", "sssp", "wcc"):   # calibrated baselines
            mcu = baselines.mcu_cycles(args.algo, g, args.src)
            cgra = baselines.cgra_cycles(args.algo, g, args.src)
            t_f = r.cycles / mapping.arch.freq_mhz
            print(f"[graph] speedup vs MCU {mcu.time_us / t_f:.1f}x, "
                  f"vs op-centric CGRA {cgra.time_us / t_f:.1f}x")
    elif args.engine in ("jax", "op"):
        eng = FlipEngine.build(g, args.algo, mapping=mapping,
                               mode=("op" if args.engine == "op" else
                                     "data"))
        t0 = time.time()
        attrs, steps = eng.run(args.src)
        print(f"[graph] {args.engine}: fixpoint in {steps} relaxation "
              f"steps ({time.time() - t0:.2f}s wall)")
    else:
        eng = FlipEngine.build(g, args.algo, mapping=mapping)
        attrs = eng.run_distributed(args.src)
        print("[graph] dist: done over local device mesh")

    print(f"[graph] correct vs reference: "
          f"{PROGRAMS[args.algo].results_match(attrs, ref)}")


if __name__ == "__main__":
    main()
