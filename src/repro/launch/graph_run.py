"""FLIP graph-workload launcher: the paper's own application path.

Runs any registered algebra (BFS / SSSP / WCC / PageRank / widest-path /
reachability) on a Table-4 dataset through any of the three execution
layers, in either fabric mode:

  --engine sim     cycle-accurate FLIP simulator (paper evaluation vehicle)
  --engine jax     TPU-native frontier engine (single device)
  --engine dist    shard_map frontier engine over all local devices
  --mode data|op   FLIP packet-triggered vs classic-CGRA full-sweep
                   (jax/dist engines; the simulator is data-centric only)

The jax/dist engines run through the unified query API: the CLI flags
fold into one `flip.ExecutionPlan` (`plan_from_cli`, which also accepts
the deprecated ``--engine op`` spelling of ``--engine jax --mode op``
with a one-time warning), and every query goes through
`flip.compile(graph, algo, plan).query(...)`.

Multi-query serving: `--srcs 0,5,9` runs a batch of sources through one
shared fixpoint; `--batch B` additionally routes them through the
`serve_graph.GraphServer` dispatch path in fixed-size buckets of B.

Examples:
  PYTHONPATH=src python -m repro.launch.graph_run --algo sssp \
      --dataset LRN --engine sim --src 5
  PYTHONPATH=src python -m repro.launch.graph_run --algo bfs \
      --dataset LRN --engine jax --srcs 0,5,9,12 --mode op
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import time

import numpy as np

from repro import api as flip
from repro.core import (compile_mapping, simulate, PROGRAMS, baselines)
from repro.graphs import make_dataset, reference


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--algo", default="bfs", choices=sorted(PROGRAMS))
    ap.add_argument("--dataset", default="LRN",
                    choices=["Tree", "SRN", "LRN", "Syn", "ExtLRN"])
    ap.add_argument("--engine", default="sim",
                    choices=["sim", "jax", "dist", "op"])
    ap.add_argument("--mode", default="data", choices=["data", "op"],
                    help="fabric mode for the jax/dist engines")
    ap.add_argument("--graph-seed", type=int, default=0)
    ap.add_argument("--src", type=int, default=0)
    ap.add_argument("--srcs", default=None,
                    help="comma list of sources: batched multi-query run "
                         "(jax/dist engines)")
    ap.add_argument("--batch", type=int, default=0,
                    help="with --srcs: dispatch through the serving "
                         "front-end in fixed-size buckets of this many "
                         "queries (0 = one fixpoint over all sources)")
    ap.add_argument("--compact", default="auto",
                    choices=["auto", "on", "off"],
                    help="frontier-compacted block streaming for the "
                         "jax/dist engines (auto = on for data mode)")
    ap.add_argument("--feature-dim", type=int, default=0,
                    help="feature width d of the vertex state: 0 adopts "
                         "the program's native width (1 for scalar "
                         "programs, 8 for multi_bfs/labelprop); d > 1 "
                         "on a scalar program runs d broadcast lanes. "
                         "jax/dist engines only")
    ap.add_argument("--updates", default=None, metavar="FILE",
                    help="JSON file of streaming edge mutations: a list "
                         "of [u, v, w] entries (w = null deletes, "
                         "omitted w inserts with weight 1) or a list of "
                         "such batches. Applied after the base query; "
                         "each batch is re-solved incrementally (warm "
                         "start when monotone under the algebra, full "
                         "recompute otherwise). jax/dist engines only.")
    ap.add_argument("--autotune", action="store_true",
                    help="let the plan autotuner pick the performance "
                         "knobs (tile / kernel / compaction / bucket) "
                         "for this graph, consulting the tuning store "
                         "(FLIP_AUTOTUNE_DB). jax engine only; "
                         "bit-exact with the untuned plan")
    ap.add_argument("--effort", type=int, default=1)
    ap.add_argument("--trace", default=None, metavar="FILE",
                    help="write a Chrome-trace JSON (chrome://tracing / "
                         "Perfetto) of the run: per-step frontier spans "
                         "for the jax engine, cycle-level parallelism "
                         "re-emitted through the same schema for sim")
    args = ap.parse_args()
    args.compact = {"auto": "auto", "on": True, "off": False}[args.compact]

    # one plan resolution folds every deprecated CLI spelling
    # (--engine op -> --engine jax --mode op, warns once)
    args.engine, args.mode = flip.resolve_cli_engine(args.engine,
                                                     args.mode)
    srcs = ([int(s) for s in args.srcs.split(",")]
            if args.srcs else None)
    if srcs is not None and args.engine == "sim":
        raise SystemExit("--srcs needs --engine jax/dist (the cycle "
                         "simulator runs one query per sweep)")
    if args.batch and args.engine != "jax":
        raise SystemExit("--batch dispatches through the single-device "
                         "serving front-end; use it with --engine jax")
    if args.updates and (args.engine not in ("jax", "dist")
                         or srcs is not None):
        raise SystemExit("--updates replays mutations through the "
                         "incremental engines; use it with --engine "
                         "jax/dist and a single --src")
    if args.trace and args.engine == "dist":
        raise SystemExit("--trace needs --engine sim/jax (per-step "
                         "tracing is not supported on the distributed "
                         "fixpoint yet)")
    if args.trace and args.batch:
        raise SystemExit("--trace traces one query/fixpoint; drop "
                         "--batch (use serve_graph --stats for serving "
                         "telemetry)")
    if args.autotune and args.engine != "jax":
        raise SystemExit("--autotune tunes the single-device jax plan "
                         "(sim has no ExecutionPlan; the distributed "
                         "fixpoint is not tunable) -- use --engine jax")
    if args.engine == "sim" and (args.feature_dim > 1
                                 or PROGRAMS[args.algo].feature_dim > 1):
        raise SystemExit("--engine sim runs scalar vertex state only; "
                         "vector programs / --feature-dim > 1 need "
                         "--engine jax/dist")

    g = next(make_dataset(args.dataset, 1, seed0=args.graph_seed))
    print(f"[graph] {args.dataset}: |V|={g.n} |E|={g.m}")
    t0 = time.time()
    mapping = compile_mapping(g, effort=args.effort,
                              program=PROGRAMS[args.algo])
    print(f"[graph] FLIP compile {time.time() - t0:.2f}s  "
          f"avg routing length {mapping.avg_routing_length():.2f}")

    if srcs is not None:
        ok = _run_batched(args, g, mapping, srcs)
        print(f"[graph] correct vs reference: {ok}")
        return

    if args.engine == "sim":
        if not PROGRAMS[args.algo].sim_ok:
            raise SystemExit(
                f"--engine sim cannot run {args.algo} (non-idempotent "
                "merge); use --engine jax/dist")
        r = simulate(mapping, PROGRAMS[args.algo], src=args.src)
        attrs = r.attrs
        if args.trace:
            from repro.obs import from_sim, write_chrome_trace
            tele = from_sim(r, freq_mhz=mapping.arch.freq_mhz)
            write_chrome_trace(args.trace, tele,
                               name=f"sim:{args.algo}")
            print(f"[graph] trace: {len(tele.dispatches[0].trace)} "
                  f"cycle spans -> {args.trace}")
        mteps = g.m / (r.cycles / mapping.arch.freq_mhz)
        print(f"[graph] sim: {r.cycles} cycles "
              f"({r.cycles / mapping.arch.freq_mhz:.1f}us @100MHz), "
              f"parallelism avg={r.avg_parallelism:.1f} "
              f"max={r.max_parallelism}, {mteps:.0f} MTEPS, "
              f"pkt wait {r.avg_pkt_wait:.2f}cyc, swaps={r.swaps}")
        if args.algo in ("bfs", "sssp", "wcc"):   # calibrated baselines
            mcu = baselines.mcu_cycles(args.algo, g, args.src)
            cgra = baselines.cgra_cycles(args.algo, g, args.src)
            t_f = r.cycles / mapping.arch.freq_mhz
            print(f"[graph] speedup vs MCU {mcu.time_us / t_f:.1f}x, "
                  f"vs op-centric CGRA {cgra.time_us / t_f:.1f}x")
    else:
        plan = _cli_plan(args)
        cq = flip.compile(g, args.algo, plan, mapping=mapping)
        if cq.tune is not None:
            print(f"[graph] autotune"
                  f"{' (store hit)' if cq.tune.cached else ''}: "
                  f"{cq.tune.why}")
        t0 = time.time()
        res = cq.query(args.src, trace=bool(args.trace))
        attrs = res.attrs
        where = ("local device mesh" if plan.distributed
                 else f"{time.time() - t0:.2f}s wall")
        print(f"[graph] {args.engine}/{args.mode}: fixpoint in "
              f"{res.steps} relaxation steps ({where})")
        if args.trace:
            _write_trace(args.trace, res, args.algo)

        if args.updates:
            g, attrs = _replay_updates(args, g, cq, res)

    ref, _ = reference.run(args.algo, g, args.src)
    print(f"[graph] correct vs reference: "
          f"{PROGRAMS[args.algo].results_match(attrs, ref)}")


def _cli_plan(args, **kw):
    """Fold the CLI knobs into one plan; --autotune sets the tuned flag
    so `flip.compile` routes through the plan autotuner."""
    plan = flip.plan_from_cli(args.engine, args.mode,
                              compact=args.compact,
                              feature_dim=args.feature_dim, **kw)
    if args.autotune:
        plan = dataclasses.replace(plan, tuned=True)
    return plan


def _write_trace(path, res, algo):
    """Write a traced QueryResult as Chrome-trace JSON and print the
    telemetry summary line."""
    from repro.obs import write_chrome_trace
    write_chrome_trace(path, res, name=f"query:{algo}")
    s = res.telemetry.summary()
    print(f"[graph] trace: {s['traced_steps']} step spans over "
          f"{s['dispatches']} dispatch(es), mean active-tile fraction "
          f"{s['mean_active_tile_fraction']:.3f}, compile "
          f"{res.compile_s:.2f}s -> {path}")


def _load_update_batches(path):
    """JSON `--updates` file: a single batch (list of [u, v, w?] entries,
    w = null deletes, omitted w = 1.0) or a list of such batches."""
    with open(path) as f:
        data = json.load(f)

    def is_update(e):
        return (isinstance(e, list) and 2 <= len(e) <= 3
                and all(isinstance(x, (int, float)) for x in e[:2])
                and (len(e) == 2 or e[2] is None
                     or isinstance(e[2], (int, float))))

    if not isinstance(data, list) or not data:
        raise SystemExit("--updates: JSON must be a non-empty list")
    if all(is_update(e) for e in data):        # one flat batch
        data = [data]
    elif not all(isinstance(b, list) and all(is_update(e) for e in b)
                 for b in data):
        raise SystemExit(
            "--updates: entries must be [u, v] / [u, v, w] / [u, v, null]"
            " triples, or a list of batches of them")
    return [[(int(e[0]), int(e[1]),
              (1.0 if len(e) < 3 else
               (None if e[2] is None else float(e[2]))))
             for e in batch] for batch in data]


def _replay_updates(args, g, cq, res):
    """Apply each update batch and re-solve incrementally: the session
    warm-starts from the previous fixpoint when the batch is monotone
    under the algebra, and falls back to a full recompute otherwise
    (the plan's warm='auto' policy) -- uniformly for jax and dist."""
    for i, batch in enumerate(_load_update_batches(args.updates)):
        t0 = time.time()
        cq, delta = cq.update(batch)
        res = cq.query(args.src, warm=res)
        print(f"[graph] update[{i}]: {len(batch)} edges -> "
              f"{delta.n_blocks_rebuilt} tiles rebuilt"
              f"{' (shape changed)' if delta.shape_changed else ''}, "
              f"{'warm' if delta.monotone else 'full'} recompute in "
              f"{res.steps} steps ({time.time() - t0:.2f}s, "
              f"{len(delta.affected_src)} vertices affected)")
    return cq.graph, res.attrs


def _run_batched(args, g, mapping, srcs) -> bool:
    """--srcs path: one batched fixpoint (or serving-bucket dispatch)."""
    t0 = time.time()
    if args.batch:
        from repro.launch.serve_graph import GraphServer
        plan = _cli_plan(args, batch=args.batch)
        srv = GraphServer(g, plan=plan, mapping=mapping)
        reqs = srv.serve((args.algo, s) for s in srcs)
        outs = [r.result for r in reqs]
        steps = [r.steps for r in reqs]
        how = (f"{srv.dispatches} serving dispatches of "
               f"B={args.batch}")
    else:
        plan = _cli_plan(args)
        res = flip.compile(g, args.algo, plan, mapping=mapping).query(
            np.asarray(srcs), trace=bool(args.trace))
        outs, steps = res.attrs, res.steps
        how = f"one {args.engine} batch of B={len(srcs)}"
        if args.trace:
            _write_trace(args.trace, res, args.algo)
    print(f"[graph] {args.engine}/{args.mode}: {len(srcs)} queries via "
          f"{how}, per-query steps {list(map(int, steps))} "
          f"({time.time() - t0:.2f}s wall)")
    ok = True
    for s, out in zip(srcs, outs):
        ref, _ = reference.run(args.algo, g, s)
        ok &= bool(PROGRAMS[args.algo].results_match(out, ref))
    return ok


if __name__ == "__main__":
    main()
