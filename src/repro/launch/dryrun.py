import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512")

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell:
  * builds the production mesh ((16,16) single-pod / (2,16,16) multi-pod),
  * jit's the step with in/out shardings from the declaration tables,
  * .lower(**input_specs).compile()  -- proving the distribution config is
    coherent (sharding mismatches, compile-time OOM, unsupported
    collectives all fail here),
  * records memory_analysis / cost_analysis / per-collective operand bytes
    parsed from the optimized HLO into experiments/dryrun/<cell>.json
    (EXPERIMENTS.md §Dry-run and §Roofline are generated from these).

Usage:
  python -m repro.launch.dryrun --arch qwen3_0_6b --shape train_4k \
      [--multi-pod] [--moe-dispatch gspmd] [--out DIR]
  python -m repro.launch.dryrun --all [--multi-pod]   # every runnable cell
"""
import argparse
import json
import re
import time
import traceback

import jax
import numpy as np

from repro import configs as C
from repro.distributed.sharding import DEFAULT_RULES, mesh_context
from repro.launch import mesh as mesh_lib
from repro.launch import steps as S
from repro.optim.adamw import AdamWConfig

COLLECTIVE_OPS = ("all-gather", "all-reduce", "reduce-scatter",
                  "all-to-all", "collective-permute")

# hardware constants (TPU v5e targets; DESIGN.md Sec. 9)
PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # B/s / chip
ICI_BW = 50e9                # B/s usable per chip (assignment constant)

_DTYPE_BYTES = {"f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4,
                "s8": 1, "u8": 1, "pred": 1, "f64": 8, "s64": 8,
                "u64": 8, "s16": 2, "u16": 2, "f8e4m3fn": 1,
                "f8e5m2": 1, "c64": 8, "c128": 16, "bf16[": 2}


def _shape_bytes(shape_str: str) -> int:
    """Bytes of one HLO shape literal like 'bf16[16,1024,128]{...}'."""
    m = re.match(r"([a-z0-9]+)\[([0-9,]*)\]", shape_str.strip())
    if not m:
        return 0
    dt, dims = m.group(1), m.group(2)
    nbytes = _DTYPE_BYTES.get(dt)
    if nbytes is None:
        return 0
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * nbytes


def collective_bytes(hlo_text: str) -> dict:
    """Sum operand bytes of every collective op in optimized HLO.

    Works on the per-op output shape (for all-gather and all-to-all the
    output is the full exchanged payload; for all-reduce/reduce-scatter
    the operand is; we take max(operand, output) as the wire-cost proxy).
    """
    out = {k: 0 for k in COLLECTIVE_OPS}
    counts = {k: 0 for k in COLLECTIVE_OPS}
    # lines look like:  %ag = bf16[16,..]{..} all-gather(bf16[1,..]{..} %x), ...
    for line in hlo_text.splitlines():
        line = line.strip()
        m = re.match(r"%?[\w.\-]+\s*=\s*(\([^)]*\)|[a-z0-9]+\[[0-9,]*\]\S*)\s+"
                     r"([a-z\-]+)", line)
        if not m:
            continue
        op = m.group(2)
        if op not in COLLECTIVE_OPS:
            continue
        shape_part = m.group(1)
        if shape_part.startswith("("):
            shapes = re.findall(r"[a-z0-9]+\[[0-9,]*\]", shape_part)
            out_bytes = sum(_shape_bytes(s) for s in shapes)
        else:
            out_bytes = _shape_bytes(shape_part)
        # operand shapes inside the call parens
        call = line[line.find(op) + len(op):]
        op_shapes = re.findall(r"[a-z0-9]+\[[0-9,]*\]", call)
        operand_bytes = sum(_shape_bytes(s) for s in op_shapes)
        out[op] += max(out_bytes, operand_bytes)
        counts[op] += 1
    out["counts"] = counts
    return out


def _cost_of(jitted, *abstract_args):
    lowered = jitted.lower(*abstract_args)
    compiled = lowered.compile()
    cost = compiled.cost_analysis()
    coll = collective_bytes(compiled.as_text())
    return {
        "flops": float(cost.get("flops", 0.0)),
        "bytes": float(cost.get("bytes accessed", 0.0)),
        "coll": sum(v for k, v in coll.items() if k != "counts"),
        "collectives": coll,
    }


def measure_components(cfg, shape: str, mesh, rules, moe_dispatch: str):
    """Roofline terms assembled from per-component compiles.

    XLA's cost model counts while/scan bodies once, so the whole-program
    numbers undercount depth. Here: total = superblock x repeat + head
    (+ embed). Inner attention kv-scans are unrolled in measurement mode
    so every visited block is counted.
    """
    import jax.numpy as jnp
    from repro.models import model as M
    from repro.models.layers import abstract_tree, ParamDecl
    from repro.distributed.sharding import logical_to_pspec
    from jax.sharding import NamedSharding

    spec = C.SHAPES[shape]
    B, S_len = spec["global_batch"], spec["seq_len"]
    step_kind = spec["step"]
    long_ctx = shape == "long_500k"
    act = jnp.bfloat16 if cfg.activation_dtype == "bfloat16" \
        else jnp.float32

    decls = M.superblock_decls(cfg)
    lp = abstract_tree(decls, jnp.bfloat16
                       if cfg.param_dtype == "bfloat16" else jnp.float32)
    lp_sh = jax.tree_util.tree_map(
        lambda d: NamedSharding(mesh, logical_to_pspec(
            d.shape, d.logical_axes, mesh, rules)),
        decls, is_leaf=lambda x: isinstance(x, ParamDecl))
    x_spec = jax.ShapeDtypeStruct((B, S_len if step_kind != "decode"
                                   else 1, cfg.d_model), act)
    x_sh = NamedSharding(mesh, logical_to_pspec(
        x_spec.shape, ("batch", "seq", None), mesh, rules))

    params = S.M.abstract_params(cfg)
    params_sh = S.param_shardings(cfg, mesh, rules)

    if step_kind in ("train", "prefill"):
        grad_it = step_kind == "train"

        def layer_fn(lp_, x):
            out, aux = M.apply_superblock(
                lp_, x, cfg, impl="lax_flash_unrolled",
                moe_dispatch=moe_dispatch, remat=grad_it)
            return jnp.sum(out.astype(jnp.float32)) + aux

        if grad_it:
            # grads carry the param/activation shardings, exactly like the
            # real train step (otherwise XLA replicates them with plain
            # all-reduces and the collective term overstates)
            f = jax.jit(jax.grad(layer_fn, argnums=(0, 1)),
                        in_shardings=(lp_sh, x_sh),
                        out_shardings=(lp_sh, x_sh))
        else:
            f = jax.jit(layer_fn, in_shardings=(lp_sh, x_sh))
        layer = _cost_of(f, lp, x_spec)

        # head: embed + final norm + CE (train) or logits (prefill)
        ins = S.input_specs(cfg, S_len, B, step_kind)
        batch_sh = S.batch_shardings(ins["batch"], mesh, rules)

        if step_kind == "train":
            def head_fn(params_, batch):
                x = M.embed_inputs(params_, batch, cfg)
                return M.head_loss(params_, x.astype(act),
                                   batch["labels"], cfg,
                                   scan_chunks=False)
            fh = jax.jit(jax.grad(head_fn), in_shardings=(params_sh,
                                                          batch_sh))
        else:
            def head_fn(params_, batch):
                x = M.embed_inputs(params_, batch, cfg)
                last = x[:, -1:]
                return jnp.einsum("bsd,vd->bsv", last,
                                  params_.get("lm_head", params_["embed"]))
            fh = jax.jit(head_fn, in_shardings=(params_sh, batch_sh))
        head = _cost_of(fh, params, ins["batch"])
    else:
        # decode: one superblock step + head logits
        cache_abs = M.abstract_cache(cfg, B, S_len, long_ctx=long_ctx)
        cache_axes = M.cache_logical_axes(cfg, long_ctx=long_ctx)
        one = {k: jax.tree_util.tree_map(
                   lambda a: jax.ShapeDtypeStruct(a.shape[1:], a.dtype),
                   v) for k, v in cache_abs.items()}
        one_axes = {k: jax.tree_util.tree_map(
                        lambda ax: ax[1:], v, is_leaf=lambda x:
                        isinstance(x, tuple)) for k, v in
                    cache_axes.items()}
        one_sh = jax.tree_util.tree_map(
            lambda a, ax: NamedSharding(mesh, logical_to_pspec(
                a.shape, ax, mesh, rules)),
            one, one_axes,
            is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))
        pos_spec = jax.ShapeDtypeStruct((B,), jnp.int32)
        pos_sh = NamedSharding(mesh, logical_to_pspec(
            (B,), ("batch",), mesh, rules))

        def layer_fn(lp_, c_, x, pos):
            return M.superblock_decode(lp_, c_, x, pos, cfg,
                                       long_ctx=long_ctx,
                                       moe_dispatch=moe_dispatch)
        f = jax.jit(layer_fn, in_shardings=(lp_sh, one_sh, x_sh, pos_sh))
        layer = _cost_of(f, lp, one, x_spec, pos_spec)

        def head_fn(params_, tokens):
            x = jnp.take(params_["embed"], tokens, axis=0).astype(act)
            return jnp.einsum("bsd,vd->bsv", x,
                              params_.get("lm_head", params_["embed"]))
        tok_spec = jax.ShapeDtypeStruct((B, 1), jnp.int32)
        tok_sh = NamedSharding(mesh, logical_to_pspec(
            (B, 1), ("batch", None), mesh, rules))
        fh = jax.jit(head_fn, in_shardings=(params_sh, tok_sh))
        head = _cost_of(fh, params, tok_spec)

    rep = cfg.repeat
    return {
        "layer": layer, "head": head, "repeat": rep,
        "flops": layer["flops"] * rep + head["flops"],
        "bytes": layer["bytes"] * rep + head["bytes"],
        "coll": layer["coll"] * rep + head["coll"],
    }


def run_cell(arch: str, shape: str, multi_pod: bool = False,
             moe_dispatch: str = "gspmd", rules=DEFAULT_RULES,
             save_dir: str | None = "experiments/dryrun",
             components: bool = True,
             tag: str = "") -> dict:
    cfg = C.get(arch)
    spec = C.SHAPES[shape]
    ok, reason = C.shape_supported(cfg, shape)
    if not ok:
        return {"arch": arch, "shape": shape, "skipped": reason}

    mesh = mesh_lib.make_production_mesh(multi_pod=multi_pod)
    long_ctx = shape == "long_500k"
    step_kind = spec["step"]
    t0 = time.time()
    with mesh_context(mesh, rules):
        if step_kind == "train":
            opt_cfg = AdamWConfig(
                moment_dtype=("bfloat16"
                              if cfg.param_count() > 50e9 else "float32"))
            step = S.make_train_step(cfg, opt_cfg,
                                     moe_dispatch=moe_dispatch)
            state = S.abstract_train_state(cfg, opt_cfg)
            state_sh = S.train_state_shardings(cfg, mesh, opt_cfg, rules)
            ins = S.input_specs(cfg, spec["seq_len"], spec["global_batch"],
                                "train")
            batch_sh = S.batch_shardings(ins["batch"], mesh, rules)
            jitted = jax.jit(step,
                             in_shardings=(state_sh, batch_sh),
                             out_shardings=(state_sh, None),
                             donate_argnums=(0,))
            lowered = jitted.lower(state, ins["batch"])
        elif step_kind == "prefill":
            step = S.make_prefill_step(cfg, moe_dispatch=moe_dispatch)
            params = S.M.abstract_params(cfg)
            params_sh = S.param_shardings(cfg, mesh, rules)
            ins = S.input_specs(cfg, spec["seq_len"], spec["global_batch"],
                                "prefill")
            batch_sh = S.batch_shardings(ins["batch"], mesh, rules)
            jitted = jax.jit(step, in_shardings=(params_sh, batch_sh))
            lowered = jitted.lower(params, ins["batch"])
        else:  # decode
            step = S.make_decode_step(cfg, long_ctx=long_ctx,
                                      moe_dispatch=moe_dispatch)
            params = S.M.abstract_params(cfg)
            params_sh = S.param_shardings(cfg, mesh, rules)
            ins = S.input_specs(cfg, spec["seq_len"], spec["global_batch"],
                                "decode", long_ctx=long_ctx)
            cache_sh = S.cache_shardings(cfg, mesh, spec["global_batch"],
                                         spec["seq_len"], rules,
                                         long_ctx=long_ctx)
            tok_sh = S.NamedSharding(mesh, S.logical_to_pspec(
                ins["tokens"].shape, ("batch", None), mesh, rules))
            pos_sh = S.NamedSharding(mesh, S.logical_to_pspec(
                ins["pos"].shape, ("batch",), mesh, rules))
            jitted = jax.jit(step,
                             in_shardings=(params_sh, cache_sh, tok_sh,
                                           pos_sh),
                             out_shardings=(None, cache_sh),
                             donate_argnums=(1,))
            lowered = jitted.lower(params, ins["cache"], ins["tokens"],
                                   ins["pos"])
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    hlo = compiled.as_text()
    coll = collective_bytes(hlo)

    # component-level measurement (scan bodies counted once by XLA's cost
    # model, so totals come from per-superblock + head compiles x repeat)
    if components:
        with mesh_context(mesh, rules):
            comp = measure_components(cfg, shape, mesh, rules, moe_dispatch)
    else:
        z = {"flops": 0.0, "bytes": 0.0, "coll": 0.0, "collectives": {}}
        comp = {"layer": z, "head": z, "repeat": cfg.repeat,
                "flops": float(cost.get("flops", 0.0)),
                "bytes": float(cost.get("bytes accessed", 0.0)),
                "coll": sum(v for k, v in coll.items() if k != "counts")}

    chips = int(np.prod(mesh.devices.shape))
    flops = comp["flops"]
    bytes_acc = comp["bytes"]
    coll_total = comp["coll"]

    # model flops: 6ND for train (fwd+bwd), 2ND forward-only per token
    n_active = cfg.param_count(active_only=True)
    tokens = spec["global_batch"] * (spec["seq_len"]
                                     if step_kind in ("train", "prefill")
                                     else 1)
    model_flops = (6 if step_kind == "train" else 2) * n_active * tokens

    result = {
        "arch": arch, "shape": shape,
        "mesh": "multi(2,16,16)" if multi_pod else "single(16,16)",
        "chips": chips, "step": step_kind,
        "moe_dispatch": moe_dispatch,
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "memory": {
            "bytes_per_device": int(getattr(mem, "temp_size_in_bytes", 0)
                                    + getattr(mem, "argument_size_in_bytes", 0)
                                    + getattr(mem, "output_size_in_bytes", 0)),
            "temp_bytes": int(getattr(mem, "temp_size_in_bytes", 0)),
            "arg_bytes": int(getattr(mem, "argument_size_in_bytes", 0)),
            "out_bytes": int(getattr(mem, "output_size_in_bytes", 0)),
            "peak_bytes": int(getattr(mem, "peak_memory_in_bytes", 0) or 0),
        },
        "hlo_flops": flops,
        "hlo_bytes": bytes_acc,
        "collective_bytes": coll_total,
        "components": {
            "layer": comp["layer"], "head": comp["head"],
            "repeat": comp["repeat"],
        },
        "whole_program": {
            "flops": float(cost.get("flops", 0.0)),
            "bytes": float(cost.get("bytes accessed", 0.0)),
            "collectives": coll,
            "note": "scan bodies counted once by XLA cost model",
        },
        "model_flops": model_flops,
        "roofline": {
            # cost_analysis is per-partition (the compiled executable is
            # one SPMD partition), i.e. already HLO_total/chips
            "compute_s": flops / PEAK_FLOPS,
            "memory_s": bytes_acc / HBM_BW,
            "collective_s": coll_total / ICI_BW,
        },
    }
    r = result["roofline"]
    dom = max(r, key=r.get)
    result["roofline"]["dominant"] = dom
    # fraction of compiled compute that is "useful" model math
    result["useful_flops_frac"] = (model_flops / (flops * chips)) \
        if flops else 0.0

    if save_dir:
        os.makedirs(save_dir, exist_ok=True)
        name = f"{arch}__{shape}__{'multi' if multi_pod else 'single'}"
        if tag:
            name += f"__{tag}"
        with open(os.path.join(save_dir, name + ".json"), "w") as f:
            json.dump(result, f, indent=1)
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--moe-dispatch", default="gspmd")
    ap.add_argument("--tag", default="")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--no-components", action="store_true",
                    help="skip per-component roofline compiles (multi-pod "
                         "validation pass)")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    cells = []
    if args.all:
        run, _ = C.cells()
        cells = run
    else:
        assert args.arch and args.shape
        cells = [(args.arch, args.shape)]

    for arch, shape in cells:
        name = f"{arch}__{shape}__{'multi' if args.multi_pod else 'single'}"
        if args.tag:
            name += f"__{args.tag}"
        path = os.path.join(args.out, name + ".json")
        if args.skip_existing and os.path.exists(path):
            print(f"[skip] {name}")
            continue
        try:
            r = run_cell(arch, shape, multi_pod=args.multi_pod,
                         moe_dispatch=args.moe_dispatch,
                         save_dir=args.out, tag=args.tag,
                         components=not args.no_components)
            if "skipped" in r:
                print(f"[skipped-by-rule] {name}: {r['skipped']}")
                continue
            mb = r["memory"]["bytes_per_device"] / 2**30
            print(f"[ok] {name}: compile={r['compile_s']}s "
                  f"mem/dev={mb:.2f}GiB dominant={r['roofline']['dominant']} "
                  f"useful={r['useful_flops_frac']:.2f}")
        except Exception as e:
            print(f"[FAIL] {name}: {e}")
            traceback.print_exc()


if __name__ == "__main__":
    main()
