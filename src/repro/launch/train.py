"""Training launcher: real end-to-end driver (also used by examples).

Features required for large-scale runnability and exercised here at small
scale: sharded+async checkpointing with atomic commit, exact resume
(data batch = f(seed, step)), heartbeat watchdog, supervised restart
(--supervise re-execs the loop subprocess on failure and picks up from the
newest committed checkpoint), elastic mesh derivation, optional int8
gradient compression with error feedback.

Examples:
  PYTHONPATH=src python -m repro.launch.train --arch qwen3_0_6b \
      --preset tiny --steps 50 --mesh 1x1
  PYTHONPATH=src python -m repro.launch.train --arch qwen3_0_6b \
      --steps 200 --resume --supervise
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import subprocess
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs as C
from repro.checkpoint import CheckpointManager
from repro.data import SyntheticTextDataset, make_batches
from repro.distributed.compression import compress_grads, init_feedback
from repro.distributed.health import HeartbeatMonitor, step_guard
from repro.distributed.sharding import mesh_context, DEFAULT_RULES
from repro.launch import mesh as mesh_lib
from repro.launch import steps as S
from repro.models import model as M
from repro.optim.adamw import AdamWConfig


def tiny_preset(cfg):
    """~15M-param variant for CPU end-to-end runs (same family)."""
    return dataclasses.replace(
        C.get_smoke(cfg.name.split("-")[0].replace(".", "_")) if False
        else cfg)


def parse_mesh(arg: str):
    if arg == "auto":
        return mesh_lib.elastic_mesh()
    dims = tuple(int(x) for x in arg.split("x"))
    axes = ("data", "model")[:len(dims)] if len(dims) == 2 else \
        (("data",) if len(dims) == 1 else ("pod", "data", "model"))
    return mesh_lib.make_mesh(dims, axes)


def train_loop(args) -> int:
    if args.preset == "tiny":
        cfg = C.get_smoke(args.arch)
        cfg = dataclasses.replace(cfg, vocab_size=512)
        seq, batch_size = args.seq, args.batch
    else:
        cfg = C.get(args.arch)
        seq, batch_size = 4096, 256
    opt_cfg = AdamWConfig(total_steps=args.steps, warmup_steps=args.steps // 10 + 1)
    mesh = parse_mesh(args.mesh) if args.mesh != "none" else None

    ds = SyntheticTextDataset(cfg.vocab_size, seq, batch_size,
                              seed=args.data_seed)
    ckpt = CheckpointManager(args.ckpt_dir, keep=3)

    grad_comp = compress_grads if args.grad_compression else None
    step_fn = S.make_train_step(cfg, opt_cfg, impl=args.attn_impl,
                                moe_dispatch=args.moe_dispatch,
                                grad_compression=grad_comp)

    with mesh_context(mesh, DEFAULT_RULES):
        start = 0
        if args.resume and ckpt.latest_step() is not None:
            abstract = S.abstract_train_state(cfg, opt_cfg)
            if grad_comp is not None:
                abstract["feedback"] = jax.tree_util.tree_map(
                    lambda p: jax.ShapeDtypeStruct(p.shape, jnp.float32),
                    abstract["params"])
            shardings = (S.train_state_shardings(cfg, mesh, opt_cfg)
                         if mesh is not None else None)
            if shardings is not None and grad_comp is not None:
                shardings["feedback"] = shardings["params"]
            state, start, _ = ckpt.restore(abstract, shardings)
            print(f"[train] resumed from step {start}")
        else:
            params = M.init_params(cfg, jax.random.PRNGKey(args.seed))
            from repro.optim.adamw import init_opt_state
            state = {"params": params,
                     "opt": init_opt_state(params, opt_cfg)}
            if grad_comp is not None:
                state["feedback"] = init_feedback(params)

        jit_step = jax.jit(step_fn, donate_argnums=(0,))
        hb = HeartbeatMonitor(timeout_s=args.heartbeat_timeout).start()

        t_last = time.time()
        for step, batch in make_batches(ds, start, args.steps - start):
            batch = {k: jnp.asarray(v) for k, v in batch.items()}

            def run():
                return jit_step(state, batch)
            state, metrics = step_guard(run, step)
            hb.beat()
            if (step + 1) % args.log_every == 0:
                loss = float(metrics["loss"])
                dt = time.time() - t_last
                t_last = time.time()
                tps = args.log_every * batch_size * seq / dt
                print(f"[train] step={step + 1} loss={loss:.4f} "
                      f"lr={float(metrics['lr']):.2e} "
                      f"gnorm={float(metrics['grad_norm']):.2f} "
                      f"tok/s={tps:,.0f}", flush=True)
            if (step + 1) % args.ckpt_every == 0 or step + 1 == args.steps:
                ckpt.save(state, step + 1, blocking=False)
        ckpt.wait()
        hb.stop()
        print("[train] done")
    return 0


def supervise(args) -> int:
    """Restart-on-failure supervisor (the 1000-node control loop, scaled
    down: the child is one SPMD job; on crash we re-exec with --resume)."""
    attempts = 0
    while attempts <= args.max_restarts:
        child_args = [sys.executable, "-m", "repro.launch.train"] + [
            a for a in sys.argv[1:] if a != "--supervise"]
        if "--resume" not in child_args:
            child_args.append("--resume")
        print(f"[supervisor] launch attempt {attempts + 1}")
        rc = subprocess.call(child_args)
        if rc == 0:
            return 0
        attempts += 1
        print(f"[supervisor] child failed rc={rc}; restarting from newest "
              f"committed checkpoint")
        time.sleep(args.restart_backoff_s)
    print("[supervisor] giving up")
    return 1


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3_0_6b")
    ap.add_argument("--preset", default="tiny", choices=["tiny", "full"])
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--mesh", default="none",
                    help="'none', 'auto', or dims like 2x4")
    ap.add_argument("--attn-impl", default="auto")
    ap.add_argument("--moe-dispatch", default="gspmd")
    ap.add_argument("--ckpt-dir", default="checkpoints/run")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--supervise", action="store_true")
    ap.add_argument("--max-restarts", type=int, default=3)
    ap.add_argument("--restart-backoff-s", type=float, default=1.0)
    ap.add_argument("--heartbeat-timeout", type=float, default=600.0)
    ap.add_argument("--grad-compression", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--data-seed", type=int, default=0)
    args = ap.parse_args()
    if args.supervise:
        sys.exit(supervise(args))
    sys.exit(train_loop(args))


if __name__ == "__main__":
    main()
