"""Step factories: jit-able train/prefill/decode steps with shardings.

`input_specs(cfg, shape)` returns ShapeDtypeStruct stand-ins for every
model input of an (arch x shape) cell -- weak-type-correct, shardable, no
device allocation -- plus the matching NamedShardings. The dry-run lowers
and compiles against these; the real launchers feed concrete arrays of the
same shapes.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.distributed.sharding import (ShardingRules, DEFAULT_RULES,
                                        logical_to_pspec, mesh_context)
from repro.models import model as M
from repro.models.config import ModelConfig
from repro.models.layers import ParamDecl
from repro.optim import adamw
from repro.optim.adamw import AdamWConfig


# --------------------------------------------------------------------- #
# shardings from declarations
# --------------------------------------------------------------------- #
def param_shardings(cfg: ModelConfig, mesh: Mesh,
                    rules: ShardingRules = DEFAULT_RULES):
    decls = M.param_decls(cfg)
    return jax.tree_util.tree_map(
        lambda d: NamedSharding(mesh, logical_to_pspec(
            d.shape, d.logical_axes, mesh, rules)),
        decls, is_leaf=lambda x: isinstance(x, ParamDecl))


def opt_shardings(cfg: ModelConfig, mesh: Mesh, opt_cfg: AdamWConfig,
                  rules: ShardingRules = DEFAULT_RULES):
    ps = param_shardings(cfg, mesh, rules)
    return {"mu": ps, "nu": ps,
            "step": NamedSharding(mesh, P())}


BATCH_AXES = {
    "tokens": ("batch", "seq"),
    "labels": ("batch", "seq"),
    "frames": ("batch", "seq", None),
}


def batch_shardings(specs, mesh: Mesh,
                    rules: ShardingRules = DEFAULT_RULES):
    """NamedShardings for an input_specs 'batch' dict (real shapes, so
    divisibility fallbacks resolve correctly)."""
    return {
        k: NamedSharding(mesh, logical_to_pspec(v.shape, BATCH_AXES[k],
                                                mesh, rules))
        for k, v in specs.items()
    }


def cache_shardings(cfg: ModelConfig, mesh: Mesh, batch: int, max_seq: int,
                    rules: ShardingRules = DEFAULT_RULES,
                    long_ctx: bool = False):
    axes = M.cache_logical_axes(cfg, long_ctx=long_ctx)
    abstract = M.abstract_cache(cfg, batch, max_seq, long_ctx=long_ctx)
    return jax.tree_util.tree_map(
        lambda a, ax: NamedSharding(mesh, logical_to_pspec(
            a.shape, ax, mesh, rules)),
        abstract, axes,
        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))


# --------------------------------------------------------------------- #
# abstract inputs per (arch x shape) cell
# --------------------------------------------------------------------- #
def input_specs(cfg: ModelConfig, seq_len: int, global_batch: int,
                step: str, long_ctx: bool = False):
    """ShapeDtypeStructs for one cell. For decode: (cache, tokens, pos)."""
    i32 = jnp.int32
    if step in ("train", "prefill"):
        if cfg.frontend == "frames":
            act = jnp.bfloat16 if cfg.activation_dtype == "bfloat16" \
                else jnp.float32
            batch = {
                "frames": jax.ShapeDtypeStruct(
                    (global_batch, seq_len, cfg.d_model), act),
                "labels": jax.ShapeDtypeStruct((global_batch, seq_len),
                                               i32),
            }
        else:
            batch = {
                "tokens": jax.ShapeDtypeStruct((global_batch, seq_len),
                                               i32),
                "labels": jax.ShapeDtypeStruct((global_batch, seq_len),
                                               i32),
            }
        return {"batch": batch}
    # decode: one new token against a seq_len-deep cache
    return {
        "cache": M.abstract_cache(cfg, global_batch, seq_len,
                                  long_ctx=long_ctx),
        "tokens": jax.ShapeDtypeStruct((global_batch, 1), i32),
        "pos": jax.ShapeDtypeStruct((global_batch,), i32),
    }


# --------------------------------------------------------------------- #
# steps
# --------------------------------------------------------------------- #
def make_train_step(cfg: ModelConfig, opt_cfg: AdamWConfig,
                    impl: str = "auto", moe_dispatch: str = "gspmd",
                    remat: bool = True, grad_compression=None):
    """(state, batch) -> (state, metrics). state = {params, opt}."""

    def train_step(state, batch):
        def loss_fn(params):
            return M.train_loss(params, batch, cfg, impl=impl,
                                moe_dispatch=moe_dispatch, remat=remat)
        loss, grads = jax.value_and_grad(loss_fn)(state["params"])
        if grad_compression is not None:
            grads, state_fb = grad_compression(grads,
                                               state.get("feedback"))
        params, opt, stats = adamw.adamw_update(
            grads, state["opt"], state["params"], opt_cfg)
        new_state = {"params": params, "opt": opt}
        if grad_compression is not None:
            new_state["feedback"] = state_fb
        return new_state, {"loss": loss, **stats}

    return train_step


def make_prefill_step(cfg: ModelConfig, impl: str = "auto",
                      moe_dispatch: str = "gspmd"):
    def prefill_step(params, batch):
        return M.prefill(params, batch, cfg, impl=impl,
                         moe_dispatch=moe_dispatch)
    return prefill_step


def make_decode_step(cfg: ModelConfig, long_ctx: bool = False,
                     moe_dispatch: str = "gspmd"):
    def serve_step(params, cache, tokens, pos):
        return M.decode_step(params, cache, tokens, pos, cfg,
                             long_ctx=long_ctx, moe_dispatch=moe_dispatch)
    return serve_step


def abstract_train_state(cfg: ModelConfig, opt_cfg: AdamWConfig):
    ap = M.abstract_params(cfg)
    return {"params": ap, "opt": adamw.abstract_opt_state(ap, opt_cfg)}


def train_state_shardings(cfg: ModelConfig, mesh: Mesh,
                          opt_cfg: AdamWConfig,
                          rules: ShardingRules = DEFAULT_RULES):
    return {"params": param_shardings(cfg, mesh, rules),
            "opt": opt_shardings(cfg, mesh, opt_cfg, rules)}
