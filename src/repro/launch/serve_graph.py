"""Graph-query serving front-end: batched multi-query dispatch.

The serving-side counterpart of a batched `CompiledQuery`: a stream of
(algo, src) requests -- multi-source BFS, landmark SSSP, personalized
PageRank probes, ... -- is bucketed by vertex algebra and dispatched in
fixed-size batches, so every dispatch relaxes B independent frontiers
against one shared weight-block stream (the whole batching win) and hits
one cached compiled session per (algebra, graph fingerprint, plan):

  * one `flip.compile` session (block build + jit cache) per algebra,
    built lazily on first request and reused for the life of the
    server; the cache key is (algebra, graph fingerprint, plan), so a
    wholesale `graph` swap or an out-of-band mutation can never
    silently serve stale results;
  * fixed batch size B (`plan.batch`): partial tail buckets are padded
    by repeating the last source, so every dispatch reuses the same
    (B, ntiles, T) executable instead of recompiling per tail size;
  * per-request results and step counts are returned in submission
    order, exactly equal to what a solo `query(src)` would produce
    (the per-query convergence mask guarantees bit-for-bit equality).

Streaming mutations interleave with queries: `update(batch)` (or an
``("update", batch)`` stream item) drains the pending buckets against
the pre-update graph -- submission order is also graph-version order --
then steps every cached session to the new graph version incrementally
(`CompiledQuery.update`). Value-only rebuilds keep all array shapes, so
the compiled relax executables stay hot; only a batch that activates a
previously empty tile pair retraces.

This module is the synchronous-bucket front-end. The continuous-batching
scheduler (`repro.serving.AsyncGraphServer`: rotating fixpoint batches,
shared result cache, injectable clock -- see docs/SERVING.md) serves the
same streams through the same CLI via ``--scheduler continuous``; both
front-ends return results bit-for-bit equal to solo queries, so the
choice is purely a latency/throughput policy.

CLI demo (synthetic request stream over one dataset graph):

  PYTHONPATH=src python -m repro.launch.serve_graph --dataset LRN \
      --algos bfs,sssp,pagerank --requests 64 --batch 8 --updates 4 \
      --scheduler continuous
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import time

import numpy as np

from repro import api as flip
from repro.algebra import ALGEBRAS, get_algebra
from repro.api import CompiledQuery, ExecutionPlan
from repro.distributed.health import HeartbeatMonitor
from repro.graphs import make_dataset, reference
from repro.graphs.csr import Graph
from repro.obs import MetricsRegistry
from repro.resilience import (CapacityExceeded, ConvergenceFailure,
                              DeadlineExceeded, FaultInjector, FlipError,
                              InvalidRequest, classify, fallback_chain,
                              finite_guard)
from repro.serving import AsyncGraphServer


@dataclasses.dataclass
class GraphRequest:
    req_id: int
    algo: str
    src: int
    result: np.ndarray | None = None
    steps: int | None = None
    t_submit: float = 0.0        # perf_counter at enqueue
    queue_wait_s: float = 0.0    # enqueue -> dispatch start
    service_s: float = 0.0       # dispatch wall minus compile share
    # --- resilience surface -------------------------------------- #
    error: FlipError | None = None   # typed failure, if any
    converged: bool = True       # False: `result` is a flagged partial
    deadline_expired: bool = False
    rung: int = 0                # degradation-ladder rung that served it
    max_steps: int | None = None     # per-request step budget
    deadline_s: float | None = None  # per-request budget (relative, as
                                     # given at submit)
    t_deadline: float | None = None  # absolute monotonic deadline

    @property
    def done(self) -> bool:
        """Processed: the server produced a result OR a typed error.
        Every submitted request ends `done` -- nothing is ever lost."""
        return self.result is not None or self.error is not None

    @property
    def ok(self) -> bool:
        """Fully served: converged result, no error."""
        return self.result is not None and self.error is None


@dataclasses.dataclass
class GraphServer:
    """Buckets (algo, src) requests per algebra and dispatches fixed-size
    batches through a compiled-session cache.

    Pass a full `plan` (its `batch` is the serving bucket size), or use
    the per-knob fields (batch/tile/mode/relax_mode/compact) which fold
    into one plan at construction."""

    graph: Graph
    batch: int = 8
    tile: int = 128
    mode: str = "data"
    relax_mode: str = "auto"
    compact: bool | str = "auto"  # frontier-compacted block streaming for
                                  # every cached session ('auto' = on for
                                  # data mode); exact, so serving results
                                  # stay bit-for-bit the solo runs
    mapping: object = None       # optional FLIP Mapping: placement-induced
                                 # block sparsity for every cached session
    plan: ExecutionPlan | None = None   # overrides the per-knob fields
    # --- resilience knobs ---------------------------------------- #
    resilience: bool = True      # degradation ladder + finite guard +
                                 # admission control; False = the bare
                                 # dispatch path (the bench A/B baseline)
    max_queue_depth: int = 0     # per-algo queued-request bound
                                 # (0 = unbounded); newest shed first
    quotas: dict | None = None   # per-algo overrides of max_queue_depth
    fault_injector: FaultInjector | None = None  # chaos-test hook
    heartbeat: HeartbeatMonitor | None = None    # beat()s per dispatch

    def __post_init__(self):
        if self.plan is None:
            self.plan = ExecutionPlan(
                mode=self.mode, relax_mode=self.relax_mode,
                compact=self.compact, tile=self.tile, batch=self.batch)
        elif self.plan.batch:
            self.batch = self.plan.batch
        else:
            self.plan = dataclasses.replace(self.plan, batch=self.batch)
        # sessions keyed by (algo, graph fingerprint, plan): stale graph
        # versions can never be served, and updates insert fresh keys
        self._sessions: dict[tuple, CompiledQuery] = {}
        self._buckets: dict[str, list[GraphRequest]] = {}
        self._chains: dict[str, list] = {}   # per-algo degradation ladder
        self._next_id = 0
        self._dispatch_seq = 0   # lifetime bucket-dispatch ordinal (the
                                 # fault injector's pinning axis)
        self.dispatches = 0
        self.completed = 0
        self.failed = 0          # requests finished with a typed error
        self.shed = 0            # requests rejected by admission control
        self.updates_applied = 0
        # per-server metrics: session-cache hit/miss, per-algo latency /
        # queue-wait / service / steps histograms, update+rebuild
        # timings, fallback/shed/error counters
        self.metrics = MetricsRegistry()

    # ------------------------------------------------------------ #
    def session(self, algo: str,
                plan: ExecutionPlan | None = None) -> CompiledQuery:
        """Compiled-session cache: block build + jit executables are
        paid once per (algebra, graph fingerprint, plan), then shared
        by every batch. Degradation-ladder rungs pass their own `plan`,
        so fallback sessions coexist with (and never evict) the primary
        for the current graph version."""
        plan = self.plan if plan is None else plan
        fp = self.graph.fingerprint()
        key = (algo, fp, plan.key())
        cq = self._sessions.get(key)
        if cq is None:
            self.metrics.counter("sessions.miss").inc()
            self._check_algo(algo)   # fail fast on unknown algorithms
            # supersede this algebra's sessions for OLDER graph versions
            # only (wholesale swaps would otherwise leak one
            # BlockedGraph per version for the server's lifetime);
            # same-version sessions under other plans are the ladder's
            # fallback rungs and stay hot
            for k in [k for k in self._sessions
                      if k[0] == algo and k[1] != fp]:
                del self._sessions[k]
            t0 = time.perf_counter()
            cq = flip.compile(self.graph, algo, plan,
                              mapping=self.mapping)
            self.metrics.histogram("session_build_s").observe(
                time.perf_counter() - t0)
            self._sessions[key] = cq
        else:
            self.metrics.counter("sessions.hit").inc()
        return cq

    @staticmethod
    def _check_algo(algo: str) -> None:
        """Unknown algorithms are an `InvalidRequest` (still a
        ValueError, so pre-taxonomy call sites keep working)."""
        try:
            get_algebra(algo)
        except ValueError as e:
            raise InvalidRequest(str(e), value=algo) from None

    def engine(self, algo: str):
        """The FlipEngine backing this algebra's cached session (legacy
        accessor; prefer `session`)."""
        return self.session(algo).engine

    @property
    def _engines(self) -> dict:
        """Legacy algo-keyed view of the engines serving the *current*
        graph version (older sessions are never served)."""
        fp = self.graph.fingerprint()
        pk = self.plan.key()
        return {algo: cq.engine
                for (algo, f, k), cq in self._sessions.items()
                if f == fp and k == pk}

    # ------------------------------------------------------------ #
    def update(self, updates) -> dict:
        """Apply one edge-mutation batch between queries.

        Pending buckets are drained first, so every already-submitted
        query runs against the graph version current at its submission.
        Each cached session is then stepped to the new graph version
        incrementally (`CompiledQuery.update`): only the touched tiles
        are recomputed, and value-only rebuilds reuse every compiled
        executable (shapes unchanged) -- only a shape-changing rebuild
        (previously empty tile pair activated) retraces on its next
        dispatch. Returns the per-algebra `UpdateDelta`s."""
        self.drain()
        t0 = time.perf_counter()
        updates = list(updates)    # consumed once per cached session
        g2 = self.graph.apply_updates(updates)
        old_fp, pk = self.graph.fingerprint(), self.plan.key()
        deltas = {}
        for (algo, fp, k), cq in list(self._sessions.items()):
            if fp != old_fp:
                del self._sessions[(algo, fp, k)]   # prune stale versions
                continue
            # step EVERY current-version session -- the primary plan and
            # any degradation-ladder rungs alike -- so a post-update
            # fallback can never serve the pre-update graph
            tr = time.perf_counter()
            cq2, delta = cq.update(updates, new_graph=g2)
            self.metrics.histogram("rebuild_s").observe(
                time.perf_counter() - tr)
            del self._sessions[(algo, fp, k)]
            self._sessions[(algo, g2.fingerprint(), k)] = cq2
            if k == pk or algo not in deltas:
                deltas[algo] = delta
        self.graph = g2
        self.updates_applied += 1
        self.metrics.histogram("update_s").observe(time.perf_counter() - t0)
        self.metrics.counter("updates.applied").inc()
        return deltas

    # ------------------------------------------------------------ #
    def submit(self, algo: str, src: int, *, max_steps: int | None = None,
               deadline_s: float | None = None) -> GraphRequest:
        """Enqueue one query; a full bucket dispatches immediately.

        Malformed requests (unknown algorithm, out-of-range source, bad
        budget) raise `InvalidRequest` here, synchronously -- a caller
        bug should fail the call, not poison a batch. Operational
        rejections (admission control) instead come back as a request
        carrying a typed `CapacityExceeded` error: the stream survives,
        the caller sees exactly which request was shed.

        max_steps  -- per-request fixpoint step budget (partial results
                      come back flagged `converged=False`).
        deadline_s -- per-request wall-clock budget, measured from THIS
                      call (queue wait counts); default plan.deadline_s.
        """
        self._check_algo(algo)
        src = self._check_src(src)
        if max_steps is not None and (
                not isinstance(max_steps, (int, np.integer))
                or max_steps < 1):
            raise InvalidRequest(
                f"max_steps must be a positive int, got {max_steps!r}",
                value=max_steps)
        if deadline_s is None:
            deadline_s = self.plan.deadline_s
        if deadline_s is not None and not (
                isinstance(deadline_s, (int, float)) and deadline_s > 0):
            raise InvalidRequest(
                f"deadline_s must be a positive number of seconds, got "
                f"{deadline_s!r}", value=deadline_s)
        req = GraphRequest(
            self._next_id, algo, int(src), t_submit=time.perf_counter(),
            max_steps=None if max_steps is None else int(max_steps),
            deadline_s=deadline_s,
            t_deadline=(None if deadline_s is None
                        else time.monotonic() + float(deadline_s)))
        self._next_id += 1
        bucket = self._buckets.setdefault(algo, [])
        limit = ((self.quotas or {}).get(algo, self.max_queue_depth)
                 if self.resilience else 0)
        if limit and len(bucket) >= limit:
            # reject-newest: accepted requests keep their latency; the
            # shed request is returned processed (typed error), never
            # silently dropped
            req.error = CapacityExceeded(
                f"queue for {algo!r} is full ({len(bucket)}/{limit}); "
                "request shed (reject-newest)",
                depth=len(bucket), limit=limit)
            self.shed += 1
            self.metrics.counter(f"shed.{algo}").inc()
            self.metrics.counter(
                f"errors.{req.error.code}").inc()
            return req
        bucket.append(req)
        if len(bucket) >= self.batch:
            self._dispatch(algo)
        return req

    def _check_src(self, src) -> int:
        """Source range check at the admission edge: a negative id would
        silently gather from the end of the attr arrays; an id >= |V|
        would fail deep inside a jit trace."""
        if not isinstance(src, (int, np.integer)):
            raise InvalidRequest(
                f"source must be an integer vertex id, got {src!r}",
                value=src)
        if src < 0 or src >= self.graph.n:
            raise InvalidRequest(
                f"source {int(src)} is out of range for this graph "
                f"(|V| = {self.graph.n}; valid ids are 0.."
                f"{self.graph.n - 1})", value=int(src))
        return int(src)

    def drain(self) -> None:
        """Flush every partial bucket (tail of the request stream)."""
        for algo in list(self._buckets):
            if self._buckets[algo]:
                self._dispatch(algo)

    def serve(self, stream) -> list[GraphRequest]:
        """Convenience: run a whole iterable of requests and return the
        queries completed, in submission order. Items are ``(algo, src)``
        queries or ``("update", batch)`` mutations; an update drains the
        queries submitted before it (they see the pre-update graph) and
        every later query runs against the mutated graph."""
        reqs = []
        for algo, arg in stream:
            if algo == "update":
                self.update(arg)
            else:
                reqs.append(self.submit(algo, arg))
        self.drain()
        return reqs

    # ------------------------------------------------------------ #
    def _ladder(self, algo: str) -> list:
        """The degradation ladder for this server's plan: rung 0 is the
        primary plan AS CONFIGURED (so it hits the same session-cache
        key the non-resilient path uses), later rungs come from
        `fallback_chain` (relax_mode -> 'jnp', then compact -> False;
        every rung exact and pre-validated). Cached per algebra."""
        chain = self._chains.get(algo)
        if chain is None:
            resolved = fallback_chain(self.plan, get_algebra(algo))
            chain = [self.plan] + resolved[1:]
            self._chains[algo] = chain
        return chain

    def _remaining(self, reqs) -> list | None:
        """Per-request deadline budget left, relative to now (the
        session API takes relative deadlines; the request stores the
        absolute one, so queue wait and ladder retries consume it).
        Expired-in-queue entries clamp to an epsilon: the engine then
        stops them at step 0 and flags `deadline_expired` -- same code
        path as a mid-fixpoint expiry."""
        if all(r.t_deadline is None for r in reqs):
            return None
        now = time.monotonic()
        return [None if r.t_deadline is None
                else max(r.t_deadline - now, 1e-9) for r in reqs]

    def _run_ladder(self, algo: str, reqs: list, dispatch_id: int):
        """One bucket through the engine, retried once per ladder rung
        on retryable failure. Returns ``(QueryResult, attrs, rung)`` of
        the first rung that served, or raises the last typed error."""
        srcs = np.asarray([r.src for r in reqs])
        budgets = None
        if any(r.max_steps is not None for r in reqs):
            budgets = [self.plan.max_steps if r.max_steps is None
                       else r.max_steps for r in reqs]
        plans = self._ladder(algo) if self.resilience else [self.plan]
        err = None
        for rung, plan in enumerate(plans):
            if self.heartbeat is not None:
                self.heartbeat.beat()
            try:
                if self.fault_injector is not None:
                    self.fault_injector.before_dispatch(
                        algo, dispatch_id, rung)
                res = self.session(algo, plan).query(
                    srcs, max_steps=budgets,
                    deadline_s=self._remaining(reqs))
                attrs = np.asarray(res.attrs)
                if self.fault_injector is not None:
                    attrs = self.fault_injector.after_dispatch(
                        algo, dispatch_id, rung, attrs)
                if self.resilience:
                    finite_guard(attrs)
                if self.heartbeat is not None:
                    self.heartbeat.beat()    # re-arm after a stall
                if rung:
                    self.metrics.counter(f"fallback.{algo}").inc()
                    self.metrics.counter(f"fallback_rung.{rung}").inc()
                return res, attrs, rung
            except Exception as e:              # noqa: BLE001
                err = classify(e, rung)
                self.metrics.counter(
                    f"dispatch_errors.{err.code}").inc()
                if not (self.resilience and err.retryable
                        and rung + 1 < len(plans)):
                    raise err from getattr(err, "cause", None)
                self.metrics.histogram("fallback_retry_s").observe(
                    time.perf_counter() - reqs[0].t_submit)
        raise err                                # pragma: no cover

    def _dispatch(self, algo: str) -> None:
        """Dispatch one bucket with per-request failure isolation.

        The bucket stays queued until the dispatch has an outcome for
        every request: success attaches results, ladder exhaustion
        attaches the typed error to each request individually -- a
        failure can never lose requests or take down the stream (the
        pre-resilience server popped the bucket first, so any raise
        dropped every request in it)."""
        reqs = self._buckets.get(algo) or []
        if not reqs:
            return
        dispatch_id = self._dispatch_seq
        self._dispatch_seq += 1
        t_start = time.perf_counter()
        m = self.metrics
        try:
            res, attrs, rung = self._run_ladder(algo, reqs, dispatch_id)
        except FlipError as e:
            # ladder exhausted (or non-retryable): fail THIS bucket's
            # requests individually; server and stream keep serving
            self._buckets[algo] = []
            service = time.perf_counter() - t_start
            for req in reqs:
                req.error = e
                req.queue_wait_s = t_start - req.t_submit
                req.service_s = service
                m.counter(f"errors.{e.code}").inc()
            m.counter(f"failed.{algo}").inc(len(reqs))
            self.failed += len(reqs)
            return
        self._buckets[algo] = []
        t_done = time.perf_counter()
        # queue-wait vs service split: waiting is per request (enqueue ->
        # dispatch start); service is the dispatch wall shared by the
        # bucket, with the first-dispatch compile share carved out so the
        # latency histograms describe steady-state serving
        service = (t_done - t_start) - res.compile_s
        conv = np.broadcast_to(np.atleast_1d(res.converged), (len(reqs),))
        exp = np.broadcast_to(np.atleast_1d(res.deadline_expired),
                              (len(reqs),))
        for b, req in enumerate(reqs):
            req.result = attrs[b]
            req.steps = int(res.steps[b])
            req.rung = rung
            req.converged = bool(conv[b])
            req.deadline_expired = bool(exp[b])
            req.queue_wait_s = t_start - req.t_submit
            req.service_s = service
            if not req.converged:
                # partial result: typed error says WHY it is partial
                if req.deadline_expired:
                    req.error = DeadlineExceeded(
                        f"request {req.req_id} ({algo}, src {req.src}) "
                        f"stopped at step {req.steps}: deadline "
                        f"{req.deadline_s}s expired (partial result "
                        "attached)", deadline_s=req.deadline_s or 0.0,
                        elapsed_s=req.queue_wait_s + service)
                else:
                    req.error = ConvergenceFailure(
                        f"request {req.req_id} ({algo}, src {req.src}) "
                        f"hit its step budget at step {req.steps} "
                        "without converging (partial result attached)",
                        steps=req.steps, max_steps=req.max_steps)
                m.counter(f"errors.{req.error.code}").inc()
                self.failed += 1
            m.histogram(f"latency_s.{algo}").observe(
                req.queue_wait_s + service)
            m.histogram(f"queue_wait_s.{algo}").observe(req.queue_wait_s)
            m.histogram(f"service_s.{algo}").observe(service)
            m.histogram(f"steps.{algo}").observe(req.steps)
        if res.compile_s:
            m.histogram("compile_s").observe(res.compile_s)
        m.counter(f"dispatches.{algo}").inc(res.dispatches)
        m.counter("requests.completed").inc(len(reqs))
        self.dispatches += res.dispatches
        self.completed += len(reqs)

    # ------------------------------------------------------------ #
    def stats(self) -> dict:
        """JSON-ready server statistics: queue state, session-cache
        hit/miss, lifetime counters, and the full metrics snapshot
        (per-algo latency / queue-wait / service / steps histograms,
        update and rebuild timings, compile-time histogram)."""
        snap = self.metrics.snapshot()
        queue = {algo: len(b) for algo, b in self._buckets.items() if b}
        return {
            "queue_depth": int(sum(queue.values())),
            "queue_depth_per_algo": queue,
            "sessions_cached": len(self._sessions),
            "session_cache": {
                "hits": snap["counters"].get("sessions.hit", 0),
                "misses": snap["counters"].get("sessions.miss", 0),
            },
            "completed": self.completed,
            "failed": self.failed,
            "shed": self.shed,
            "dispatches": self.dispatches,
            "updates_applied": self.updates_applied,
            "resilience": {
                "enabled": self.resilience,
                "fallbacks": self.metrics.sum_counters("fallback."),
                "shed": self.metrics.sum_counters("shed."),
                "errors": self.metrics.sum_counters("errors."),
                "dispatch_errors":
                    self.metrics.sum_counters("dispatch_errors."),
                "heartbeat_stalls": (0 if self.heartbeat is None
                                     else self.heartbeat.stall_count),
                "faults_fired": (0 if self.fault_injector is None
                                 else len(self.fault_injector.fired)),
            },
            "metrics": snap,
        }


# ----------------------------------------------------------------- #
# CLI demo: synthetic request stream over one Table-4 dataset graph
# ----------------------------------------------------------------- #
def _random_update_batch(g, rng, k: int = 4):
    """Small mutation batch for the demo stream: ⊕-improving reweights
    (halved weights) of k random existing edges plus one random insert."""
    eu = g.edge_sources()
    idx = rng.choice(g.m, size=min(k, g.m), replace=False)
    batch = [(int(eu[i]), int(g.indices[i]), float(g.weights[i]) * 0.5)
             for i in idx]
    batch.append((int(rng.integers(g.n)), int(rng.integers(g.n)),
                  float(rng.integers(1, 9))))
    return batch


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="LRN",
                    choices=["Tree", "SRN", "LRN", "Syn", "ExtLRN"])
    ap.add_argument("--graph-seed", type=int, default=0)
    ap.add_argument("--algos", default="bfs,sssp,pagerank",
                    help="comma list of registered algebras to sample")
    ap.add_argument("--requests", type=int, default=64)
    ap.add_argument("--updates", type=int, default=0,
                    help="interleave this many random edge-update batches "
                         "into the stream; queries after an update run "
                         "against the mutated graph")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--tile", type=int, default=128)
    ap.add_argument("--mode", default="data", choices=["data", "op"])
    ap.add_argument("--compact", default="auto",
                    choices=["auto", "on", "off"],
                    help="frontier-compacted block streaming (auto = on "
                         "for data mode)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--scheduler", default="bucket",
                    choices=["bucket", "continuous"],
                    help="'bucket': synchronous fixed-size buckets "
                         "(this module); 'continuous': the rotating-"
                         "batch scheduler with a shared result cache "
                         "(repro.serving) -- results are bit-identical "
                         "either way")
    ap.add_argument("--segment-steps", type=int, default=4,
                    help="continuous scheduler only: fixpoint steps per "
                         "admission window (K); converged queries retire "
                         "and queued ones are admitted every K steps")
    ap.add_argument("--cache-capacity", type=int, default=256,
                    help="continuous scheduler only: shared result-cache "
                         "entries (0 disables cross-query sharing)")
    ap.add_argument("--no-resilience", action="store_true",
                    help="disable the degradation ladder / finite guard "
                         "/ admission control (the bare dispatch path; "
                         "the benchmark A/B baseline)")
    ap.add_argument("--max-queue-depth", type=int, default=0,
                    help="per-algo queued-request bound (0 = unbounded); "
                         "newest requests are shed with a typed "
                         "CapacityExceeded")
    ap.add_argument("--max-steps", type=int, default=None,
                    help="per-request fixpoint step budget (partials "
                         "come back flagged, with a typed error)")
    ap.add_argument("--deadline-s", type=float, default=None,
                    help="per-request wall-clock budget in seconds")
    ap.add_argument("--fault-rate", type=float, default=0.0,
                    help="chaos demo: inject seeded faults (backend "
                         "raise / NaN poison) into this fraction of "
                         "dispatches; the ladder must absorb them")
    ap.add_argument("--fault-seed", type=int, default=0)
    ap.add_argument("--check", action="store_true",
                    help="verify every successful response against the "
                         "numpy oracle")
    ap.add_argument("--stats", action="store_true",
                    help="print the server stats() JSON (queue depth, "
                         "session-cache hit/miss, per-algo latency "
                         "histograms, update timings) after the stream")
    args = ap.parse_args()

    algos = [a.strip() for a in args.algos.split(",") if a.strip()]
    for a in algos:
        get_algebra(a)
    g = next(make_dataset(args.dataset, 1, seed0=args.graph_seed))
    print(f"[serve] {args.dataset}: |V|={g.n} |E|={g.m} "
          f"algos={algos} B={args.batch}")

    rng = np.random.default_rng(args.seed)
    # interleave update batches at evenly spaced stream positions; track
    # the graph version each query will be dispatched against so --check
    # verifies every response against the right oracle snapshot
    update_at = (set(np.linspace(1, args.requests - 1, args.updates,
                                 dtype=int).tolist())
                 if args.updates else set())
    stream, snapshots, g_cur = [], [], g
    for i in range(args.requests):
        if i in update_at:
            batch = _random_update_batch(g_cur, rng)
            stream.append(("update", batch))
            g_cur = g_cur.apply_updates(batch)
        stream.append((algos[int(rng.integers(len(algos)))],
                       int(rng.integers(g.n))))
        snapshots.append(g_cur)

    compact = {"auto": "auto", "on": True, "off": False}[args.compact]
    plan = ExecutionPlan(mode=args.mode, compact=compact, tile=args.tile,
                         batch=args.batch, deadline_s=args.deadline_s)
    injector = (FaultInjector.random(args.fault_seed, args.requests,
                                     algos=algos, rate=args.fault_rate)
                if args.fault_rate > 0 else None)
    if args.scheduler == "continuous":
        if injector is not None:
            raise SystemExit("--fault-rate drives the bucket server's "
                             "dispatch hook; use --scheduler bucket "
                             "for the chaos demo")
        srv = AsyncGraphServer(g, plan=plan,
                               segment_steps=args.segment_steps,
                               cache_capacity=args.cache_capacity,
                               max_queue_depth=args.max_queue_depth)
    else:
        srv = GraphServer(g, plan=plan,
                          resilience=not args.no_resilience,
                          max_queue_depth=args.max_queue_depth,
                          fault_injector=injector)
    for a in algos:                      # build/compile outside the clock
        srv.session(a)
    submit_kw = {} if args.max_steps is None \
        else {"max_steps": args.max_steps}
    t0 = time.time()
    reqs = []
    for algo, arg in stream:
        if algo == "update":
            srv.update(arg)
        else:
            reqs.append(srv.submit(algo, arg, **submit_kw))
    srv.drain()
    wall = time.time() - t0
    assert all(r.done for r in reqs), "server lost requests"
    n_ok = sum(r.ok for r in reqs)
    if args.scheduler == "continuous":
        cache = srv.cache.stats()
        print(f"[serve] {len(reqs)} requests in {wall:.2f}s "
              f"({len(reqs) / wall:.1f} req/s) over {srv.windows} "
              f"admission windows of K={args.segment_steps} on "
              f"B={args.batch} lanes, {srv.updates_applied} update "
              f"batches applied; {n_ok} ok, {srv.failed} failed "
              f"(typed), {srv.shed} shed; cache hit rate "
              f"{cache['hit_rate']:.0%} ({cache['hits']} hits)")
    else:
        print(f"[serve] {len(reqs)} requests in {wall:.2f}s "
              f"({len(reqs) / wall:.1f} req/s) over {srv.dispatches} "
              f"dispatches of B={args.batch}, {srv.updates_applied} "
              f"update batches applied; {n_ok} ok, {srv.failed} failed "
              f"(typed), {srv.shed} shed, "
              f"{srv.metrics.sum_counters('fallback.')} fallbacks")
    if args.stats:
        print(json.dumps(srv.stats(), indent=2, sort_keys=True))
    if args.check:
        bad = 0
        checked = 0
        for r, g_snap in zip(reqs, snapshots):
            if not r.ok:
                continue                 # typed failure, not a result
            checked += 1
            ref, _ = reference.run(r.algo, g_snap, r.src)
            bad += not ALGEBRAS[r.algo].results_match(r.result, ref)
        print(f"[serve] oracle check: {checked - bad}/{checked} correct "
              f"({len(reqs) - checked} failed requests excluded)")
        if bad:
            raise SystemExit(1)


if __name__ == "__main__":
    main()
