"""Graph-query serving front-end: batched multi-query dispatch.

The serving-side counterpart of a batched `CompiledQuery`: a stream of
(algo, src) requests -- multi-source BFS, landmark SSSP, personalized
PageRank probes, ... -- is bucketed by vertex algebra and dispatched in
fixed-size batches, so every dispatch relaxes B independent frontiers
against one shared weight-block stream (the whole batching win) and hits
one cached compiled session per (algebra, graph fingerprint, plan):

  * one `flip.compile` session (block build + jit cache) per algebra,
    built lazily on first request and reused for the life of the
    server; the cache key is (algebra, graph fingerprint, plan), so a
    wholesale `graph` swap or an out-of-band mutation can never
    silently serve stale results;
  * fixed batch size B (`plan.batch`): partial tail buckets are padded
    by repeating the last source, so every dispatch reuses the same
    (B, ntiles, T) executable instead of recompiling per tail size;
  * per-request results and step counts are returned in submission
    order, exactly equal to what a solo `query(src)` would produce
    (the per-query convergence mask guarantees bit-for-bit equality).

Streaming mutations interleave with queries: `update(batch)` (or an
``("update", batch)`` stream item) drains the pending buckets against
the pre-update graph -- submission order is also graph-version order --
then steps every cached session to the new graph version incrementally
(`CompiledQuery.update`). Value-only rebuilds keep all array shapes, so
the compiled relax executables stay hot; only a batch that activates a
previously empty tile pair retraces.

CLI demo (synthetic request stream over one dataset graph):

  PYTHONPATH=src python -m repro.launch.serve_graph --dataset LRN \
      --algos bfs,sssp,pagerank --requests 64 --batch 8 --updates 4
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import time

import numpy as np

from repro import api as flip
from repro.algebra import ALGEBRAS, get_algebra
from repro.api import CompiledQuery, ExecutionPlan
from repro.graphs import make_dataset, reference
from repro.graphs.csr import Graph
from repro.obs import MetricsRegistry


@dataclasses.dataclass
class GraphRequest:
    req_id: int
    algo: str
    src: int
    result: np.ndarray | None = None
    steps: int | None = None
    t_submit: float = 0.0        # perf_counter at enqueue
    queue_wait_s: float = 0.0    # enqueue -> dispatch start
    service_s: float = 0.0       # dispatch wall minus compile share

    @property
    def done(self) -> bool:
        return self.result is not None


@dataclasses.dataclass
class GraphServer:
    """Buckets (algo, src) requests per algebra and dispatches fixed-size
    batches through a compiled-session cache.

    Pass a full `plan` (its `batch` is the serving bucket size), or use
    the per-knob fields (batch/tile/mode/relax_mode/compact) which fold
    into one plan at construction."""

    graph: Graph
    batch: int = 8
    tile: int = 128
    mode: str = "data"
    relax_mode: str = "auto"
    compact: bool | str = "auto"  # frontier-compacted block streaming for
                                  # every cached session ('auto' = on for
                                  # data mode); exact, so serving results
                                  # stay bit-for-bit the solo runs
    mapping: object = None       # optional FLIP Mapping: placement-induced
                                 # block sparsity for every cached session
    plan: ExecutionPlan | None = None   # overrides the per-knob fields

    def __post_init__(self):
        if self.plan is None:
            self.plan = ExecutionPlan(
                mode=self.mode, relax_mode=self.relax_mode,
                compact=self.compact, tile=self.tile, batch=self.batch)
        elif self.plan.batch:
            self.batch = self.plan.batch
        else:
            self.plan = dataclasses.replace(self.plan, batch=self.batch)
        # sessions keyed by (algo, graph fingerprint, plan): stale graph
        # versions can never be served, and updates insert fresh keys
        self._sessions: dict[tuple, CompiledQuery] = {}
        self._buckets: dict[str, list[GraphRequest]] = {}
        self._next_id = 0
        self.dispatches = 0
        self.completed = 0
        self.updates_applied = 0
        # per-server metrics: session-cache hit/miss, per-algo latency /
        # queue-wait / service / steps histograms, update+rebuild timings
        self.metrics = MetricsRegistry()

    # ------------------------------------------------------------ #
    def session(self, algo: str) -> CompiledQuery:
        """Compiled-session cache: block build + jit executables are
        paid once per (algebra, graph fingerprint, plan), then shared
        by every batch."""
        key = (algo, self.graph.fingerprint(), self.plan.key())
        cq = self._sessions.get(key)
        if cq is None:
            self.metrics.counter("sessions.miss").inc()
            get_algebra(algo)        # fail fast on unknown algorithms
            # supersede this algebra's sessions for older graph
            # versions (wholesale swaps would otherwise leak one
            # BlockedGraph per version for the server's lifetime)
            for k in [k for k in self._sessions if k[0] == algo]:
                del self._sessions[k]
            t0 = time.perf_counter()
            cq = flip.compile(self.graph, algo, self.plan,
                              mapping=self.mapping)
            self.metrics.histogram("session_build_s").observe(
                time.perf_counter() - t0)
            self._sessions[key] = cq
        else:
            self.metrics.counter("sessions.hit").inc()
        return cq

    def engine(self, algo: str):
        """The FlipEngine backing this algebra's cached session (legacy
        accessor; prefer `session`)."""
        return self.session(algo).engine

    @property
    def _engines(self) -> dict:
        """Legacy algo-keyed view of the engines serving the *current*
        graph version (older sessions are never served)."""
        fp = self.graph.fingerprint()
        pk = self.plan.key()
        return {algo: cq.engine
                for (algo, f, k), cq in self._sessions.items()
                if f == fp and k == pk}

    # ------------------------------------------------------------ #
    def update(self, updates) -> dict:
        """Apply one edge-mutation batch between queries.

        Pending buckets are drained first, so every already-submitted
        query runs against the graph version current at its submission.
        Each cached session is then stepped to the new graph version
        incrementally (`CompiledQuery.update`): only the touched tiles
        are recomputed, and value-only rebuilds reuse every compiled
        executable (shapes unchanged) -- only a shape-changing rebuild
        (previously empty tile pair activated) retraces on its next
        dispatch. Returns the per-algebra `UpdateDelta`s."""
        self.drain()
        t0 = time.perf_counter()
        updates = list(updates)    # consumed once per cached session
        g2 = self.graph.apply_updates(updates)
        old_fp, pk = self.graph.fingerprint(), self.plan.key()
        deltas = {}
        for (algo, fp, k), cq in list(self._sessions.items()):
            if fp != old_fp or k != pk:
                del self._sessions[(algo, fp, k)]   # prune stale versions
                continue
            tr = time.perf_counter()
            cq2, deltas[algo] = cq.update(updates, new_graph=g2)
            self.metrics.histogram("rebuild_s").observe(
                time.perf_counter() - tr)
            del self._sessions[(algo, fp, k)]
            self._sessions[(algo, g2.fingerprint(), k)] = cq2
        self.graph = g2
        self.updates_applied += 1
        self.metrics.histogram("update_s").observe(time.perf_counter() - t0)
        self.metrics.counter("updates.applied").inc()
        return deltas

    # ------------------------------------------------------------ #
    def submit(self, algo: str, src: int) -> GraphRequest:
        """Enqueue one query; a full bucket dispatches immediately."""
        get_algebra(algo)            # reject unknown algorithms at submit
        req = GraphRequest(self._next_id, algo, int(src),
                           t_submit=time.perf_counter())
        self._next_id += 1
        bucket = self._buckets.setdefault(algo, [])
        bucket.append(req)
        if len(bucket) >= self.batch:
            self._dispatch(algo)
        return req

    def drain(self) -> None:
        """Flush every partial bucket (tail of the request stream)."""
        for algo in list(self._buckets):
            if self._buckets[algo]:
                self._dispatch(algo)

    def serve(self, stream) -> list[GraphRequest]:
        """Convenience: run a whole iterable of requests and return the
        queries completed, in submission order. Items are ``(algo, src)``
        queries or ``("update", batch)`` mutations; an update drains the
        queries submitted before it (they see the pre-update graph) and
        every later query runs against the mutated graph."""
        reqs = []
        for algo, arg in stream:
            if algo == "update":
                self.update(arg)
            else:
                reqs.append(self.submit(algo, arg))
        self.drain()
        return reqs

    # ------------------------------------------------------------ #
    def _dispatch(self, algo: str) -> None:
        reqs, self._buckets[algo] = self._buckets[algo], []
        t_start = time.perf_counter()
        # the session's plan.batch pads the tail bucket to the fixed
        # batch size (repeat of the last source): same (B, ntiles, T)
        # shapes -> jit cache hit, padded rows dropped
        res = self.session(algo).query(
            np.asarray([r.src for r in reqs]))
        t_done = time.perf_counter()
        # queue-wait vs service split: waiting is per request (enqueue ->
        # dispatch start); service is the dispatch wall shared by the
        # bucket, with the first-dispatch compile share carved out so the
        # latency histograms describe steady-state serving
        service = (t_done - t_start) - res.compile_s
        m = self.metrics
        for b, req in enumerate(reqs):
            req.result = res.attrs[b]
            req.steps = int(res.steps[b])
            req.queue_wait_s = t_start - req.t_submit
            req.service_s = service
            m.histogram(f"latency_s.{algo}").observe(
                req.queue_wait_s + service)
            m.histogram(f"queue_wait_s.{algo}").observe(req.queue_wait_s)
            m.histogram(f"service_s.{algo}").observe(service)
            m.histogram(f"steps.{algo}").observe(req.steps)
        if res.compile_s:
            m.histogram("compile_s").observe(res.compile_s)
        m.counter(f"dispatches.{algo}").inc(res.dispatches)
        m.counter("requests.completed").inc(len(reqs))
        self.dispatches += res.dispatches
        self.completed += len(reqs)

    # ------------------------------------------------------------ #
    def stats(self) -> dict:
        """JSON-ready server statistics: queue state, session-cache
        hit/miss, lifetime counters, and the full metrics snapshot
        (per-algo latency / queue-wait / service / steps histograms,
        update and rebuild timings, compile-time histogram)."""
        snap = self.metrics.snapshot()
        queue = {algo: len(b) for algo, b in self._buckets.items() if b}
        return {
            "queue_depth": int(sum(queue.values())),
            "queue_depth_per_algo": queue,
            "sessions_cached": len(self._sessions),
            "session_cache": {
                "hits": snap["counters"].get("sessions.hit", 0),
                "misses": snap["counters"].get("sessions.miss", 0),
            },
            "completed": self.completed,
            "dispatches": self.dispatches,
            "updates_applied": self.updates_applied,
            "metrics": snap,
        }


# ----------------------------------------------------------------- #
# CLI demo: synthetic request stream over one Table-4 dataset graph
# ----------------------------------------------------------------- #
def _random_update_batch(g, rng, k: int = 4):
    """Small mutation batch for the demo stream: ⊕-improving reweights
    (halved weights) of k random existing edges plus one random insert."""
    eu = g.edge_sources()
    idx = rng.choice(g.m, size=min(k, g.m), replace=False)
    batch = [(int(eu[i]), int(g.indices[i]), float(g.weights[i]) * 0.5)
             for i in idx]
    batch.append((int(rng.integers(g.n)), int(rng.integers(g.n)),
                  float(rng.integers(1, 9))))
    return batch


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="LRN",
                    choices=["Tree", "SRN", "LRN", "Syn", "ExtLRN"])
    ap.add_argument("--graph-seed", type=int, default=0)
    ap.add_argument("--algos", default="bfs,sssp,pagerank",
                    help="comma list of registered algebras to sample")
    ap.add_argument("--requests", type=int, default=64)
    ap.add_argument("--updates", type=int, default=0,
                    help="interleave this many random edge-update batches "
                         "into the stream; queries after an update run "
                         "against the mutated graph")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--tile", type=int, default=128)
    ap.add_argument("--mode", default="data", choices=["data", "op"])
    ap.add_argument("--compact", default="auto",
                    choices=["auto", "on", "off"],
                    help="frontier-compacted block streaming (auto = on "
                         "for data mode)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--check", action="store_true",
                    help="verify every response against the numpy oracle")
    ap.add_argument("--stats", action="store_true",
                    help="print the server stats() JSON (queue depth, "
                         "session-cache hit/miss, per-algo latency "
                         "histograms, update timings) after the stream")
    args = ap.parse_args()

    algos = [a.strip() for a in args.algos.split(",") if a.strip()]
    for a in algos:
        get_algebra(a)
    g = next(make_dataset(args.dataset, 1, seed0=args.graph_seed))
    print(f"[serve] {args.dataset}: |V|={g.n} |E|={g.m} "
          f"algos={algos} B={args.batch}")

    rng = np.random.default_rng(args.seed)
    # interleave update batches at evenly spaced stream positions; track
    # the graph version each query will be dispatched against so --check
    # verifies every response against the right oracle snapshot
    update_at = (set(np.linspace(1, args.requests - 1, args.updates,
                                 dtype=int).tolist())
                 if args.updates else set())
    stream, snapshots, g_cur = [], [], g
    for i in range(args.requests):
        if i in update_at:
            batch = _random_update_batch(g_cur, rng)
            stream.append(("update", batch))
            g_cur = g_cur.apply_updates(batch)
        stream.append((algos[int(rng.integers(len(algos)))],
                       int(rng.integers(g.n))))
        snapshots.append(g_cur)

    compact = {"auto": "auto", "on": True, "off": False}[args.compact]
    plan = ExecutionPlan(mode=args.mode, compact=compact, tile=args.tile,
                         batch=args.batch)
    srv = GraphServer(g, plan=plan)
    for a in algos:                      # build/compile outside the clock
        srv.session(a)
    t0 = time.time()
    reqs = srv.serve(stream)
    wall = time.time() - t0
    assert all(r.done for r in reqs)
    print(f"[serve] {len(reqs)} requests in {wall:.2f}s "
          f"({len(reqs) / wall:.1f} req/s) over {srv.dispatches} "
          f"dispatches of B={args.batch}, {srv.updates_applied} update "
          f"batches applied")
    if args.stats:
        print(json.dumps(srv.stats(), indent=2, sort_keys=True))
    if args.check:
        bad = 0
        for r, g_snap in zip(reqs, snapshots):
            ref, _ = reference.run(r.algo, g_snap, r.src)
            bad += not ALGEBRAS[r.algo].results_match(r.result, ref)
        print(f"[serve] oracle check: {len(reqs) - bad}/{len(reqs)} correct")
        if bad:
            raise SystemExit(1)


if __name__ == "__main__":
    main()
