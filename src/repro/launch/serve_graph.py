"""Graph-query serving front-end: batched multi-query dispatch.

The serving-side counterpart of `FlipEngine.run_batch`: a stream of
(algo, src) requests -- multi-source BFS, landmark SSSP, personalized
PageRank probes, ... -- is bucketed by vertex algebra and dispatched in
fixed-size batches, so every dispatch relaxes B independent frontiers
against one shared weight-block stream (the whole batching win) and hits
one cached compiled engine per (algebra, mode):

  * one `FlipEngine` (block build + jit cache) per algebra, built lazily
    on first request and reused for the life of the server;
  * fixed batch size B: partial tail buckets are padded by repeating the
    last source, so every dispatch reuses the same (B, ntiles, T)
    executable instead of recompiling per tail size;
  * per-request results and step counts are returned in submission
    order, exactly equal to what a solo `run(src)` would produce
    (run_batch's per-query convergence mask guarantees bit-for-bit
    equality).

Streaming mutations interleave with queries: `update(batch)` (or an
``("update", batch)`` stream item) drains the pending buckets against the
pre-update graph -- submission order is also graph-version order -- then
rebuilds every cached engine incrementally through
`BlockedGraph.apply_updates`. Value-only rebuilds keep all array shapes,
so the compiled relax executables stay hot; only a batch that activates a
previously empty tile pair retraces. The engine cache is keyed by the
graph's content fingerprint, so a wholesale `graph` swap (not just
`update`) also invalidates it instead of silently serving stale results.

CLI demo (synthetic request stream over one dataset graph):

  PYTHONPATH=src python -m repro.launch.serve_graph --dataset LRN \
      --algos bfs,sssp,pagerank --requests 64 --batch 8 --updates 4
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import numpy as np

from repro.algebra import ALGEBRAS, get_algebra
from repro.core.engine import FlipEngine
from repro.graphs import make_dataset, reference
from repro.graphs.csr import Graph


@dataclasses.dataclass
class GraphRequest:
    req_id: int
    algo: str
    src: int
    result: np.ndarray | None = None
    steps: int | None = None

    @property
    def done(self) -> bool:
        return self.result is not None


@dataclasses.dataclass
class GraphServer:
    """Buckets (algo, src) requests per algebra and dispatches fixed-size
    batches through a compiled-engine cache."""

    graph: Graph
    batch: int = 8
    tile: int = 128
    mode: str = "data"
    relax_mode: str = "auto"
    compact: bool | str = "auto"  # frontier-compacted block streaming for
                                  # every cached engine ('auto' = on for
                                  # data mode); exact, so serving results
                                  # stay bit-for-bit the solo runs
    mapping: object = None       # optional FLIP Mapping: placement-induced
                                 # block sparsity for every cached engine

    def __post_init__(self):
        self._engines: dict[str, FlipEngine] = {}
        self._buckets: dict[str, list[GraphRequest]] = {}
        self._next_id = 0
        self.dispatches = 0
        self.completed = 0
        self.updates_applied = 0

    # ------------------------------------------------------------ #
    def engine(self, algo: str) -> FlipEngine:
        """Compiled-engine cache: block build + jit executables are paid
        once per algebra, then shared by every batch. Keyed by the
        graph's content fingerprint, not just the algorithm: a cached
        engine whose layout was built from a different graph (wholesale
        `srv.graph` swap, mutation applied behind the server's back) is
        rebuilt instead of silently serving the old graph's results."""
        fp = self.graph.fingerprint()
        eng = self._engines.get(algo)
        if eng is None or eng.bg.graph_fp != fp:
            get_algebra(algo)        # fail fast on unknown algorithms
            self._engines[algo] = FlipEngine.build(
                self.graph, algo, mapping=self.mapping, tile=self.tile,
                mode=self.mode, relax_mode=self.relax_mode,
                compact=self.compact)
        return self._engines[algo]

    # ------------------------------------------------------------ #
    def update(self, updates) -> dict:
        """Apply one edge-mutation batch between queries.

        Pending buckets are drained first, so every already-submitted
        query runs against the graph version current at its submission.
        Each cached engine is then re-blocked incrementally
        (`FlipEngine.apply_updates`): only the touched tiles are
        recomputed, and value-only rebuilds reuse every compiled
        executable (shapes unchanged) -- only a shape-changing rebuild
        (previously empty tile pair activated) retraces on its next
        dispatch. Returns the per-algebra `UpdateDelta`s."""
        self.drain()
        updates = list(updates)    # consumed once per cached engine
        g2 = self.graph.apply_updates(updates)
        deltas = {}
        for algo, eng in list(self._engines.items()):
            self._engines[algo], deltas[algo] = eng.apply_updates(
                g2, updates)
        self.graph = g2
        self.updates_applied += 1
        return deltas

    # ------------------------------------------------------------ #
    def submit(self, algo: str, src: int) -> GraphRequest:
        """Enqueue one query; a full bucket dispatches immediately."""
        get_algebra(algo)            # reject unknown algorithms at submit
        req = GraphRequest(self._next_id, algo, int(src))
        self._next_id += 1
        bucket = self._buckets.setdefault(algo, [])
        bucket.append(req)
        if len(bucket) >= self.batch:
            self._dispatch(algo)
        return req

    def drain(self) -> None:
        """Flush every partial bucket (tail of the request stream)."""
        for algo in list(self._buckets):
            if self._buckets[algo]:
                self._dispatch(algo)

    def serve(self, stream) -> list[GraphRequest]:
        """Convenience: run a whole iterable of requests and return the
        queries completed, in submission order. Items are ``(algo, src)``
        queries or ``("update", batch)`` mutations; an update drains the
        queries submitted before it (they see the pre-update graph) and
        every later query runs against the mutated graph."""
        reqs = []
        for algo, arg in stream:
            if algo == "update":
                self.update(arg)
            else:
                reqs.append(self.submit(algo, arg))
        self.drain()
        return reqs

    # ------------------------------------------------------------ #
    def _dispatch(self, algo: str) -> None:
        reqs, self._buckets[algo] = self._buckets[algo], []
        # pad the tail bucket to the fixed batch size with a repeat of
        # the last source: same (B, ntiles, T) shapes -> jit cache hit
        srcs = [r.src for r in reqs]
        srcs += [srcs[-1]] * (self.batch - len(srcs))
        outs, steps = self.engine(algo).run_batch(np.asarray(srcs))
        for b, req in enumerate(reqs):
            req.result = outs[b]
            req.steps = int(steps[b])
        self.dispatches += 1
        self.completed += len(reqs)


# ----------------------------------------------------------------- #
# CLI demo: synthetic request stream over one Table-4 dataset graph
# ----------------------------------------------------------------- #
def _random_update_batch(g, rng, k: int = 4):
    """Small mutation batch for the demo stream: ⊕-improving reweights
    (halved weights) of k random existing edges plus one random insert."""
    eu = g.edge_sources()
    idx = rng.choice(g.m, size=min(k, g.m), replace=False)
    batch = [(int(eu[i]), int(g.indices[i]), float(g.weights[i]) * 0.5)
             for i in idx]
    batch.append((int(rng.integers(g.n)), int(rng.integers(g.n)),
                  float(rng.integers(1, 9))))
    return batch


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="LRN",
                    choices=["Tree", "SRN", "LRN", "Syn", "ExtLRN"])
    ap.add_argument("--graph-seed", type=int, default=0)
    ap.add_argument("--algos", default="bfs,sssp,pagerank",
                    help="comma list of registered algebras to sample")
    ap.add_argument("--requests", type=int, default=64)
    ap.add_argument("--updates", type=int, default=0,
                    help="interleave this many random edge-update batches "
                         "into the stream; queries after an update run "
                         "against the mutated graph")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--tile", type=int, default=128)
    ap.add_argument("--mode", default="data", choices=["data", "op"])
    ap.add_argument("--compact", default="auto",
                    choices=["auto", "on", "off"],
                    help="frontier-compacted block streaming (auto = on "
                         "for data mode)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--check", action="store_true",
                    help="verify every response against the numpy oracle")
    args = ap.parse_args()

    algos = [a.strip() for a in args.algos.split(",") if a.strip()]
    for a in algos:
        get_algebra(a)
    g = next(make_dataset(args.dataset, 1, seed0=args.graph_seed))
    print(f"[serve] {args.dataset}: |V|={g.n} |E|={g.m} "
          f"algos={algos} B={args.batch}")

    rng = np.random.default_rng(args.seed)
    # interleave update batches at evenly spaced stream positions; track
    # the graph version each query will be dispatched against so --check
    # verifies every response against the right oracle snapshot
    update_at = (set(np.linspace(1, args.requests - 1, args.updates,
                                 dtype=int).tolist())
                 if args.updates else set())
    stream, snapshots, g_cur = [], [], g
    for i in range(args.requests):
        if i in update_at:
            batch = _random_update_batch(g_cur, rng)
            stream.append(("update", batch))
            g_cur = g_cur.apply_updates(batch)
        stream.append((algos[int(rng.integers(len(algos)))],
                       int(rng.integers(g.n))))
        snapshots.append(g_cur)

    compact = {"auto": "auto", "on": True, "off": False}[args.compact]
    srv = GraphServer(g, batch=args.batch, tile=args.tile, mode=args.mode,
                      compact=compact)
    for a in algos:                      # build/compile outside the clock
        srv.engine(a)
    t0 = time.time()
    reqs = srv.serve(stream)
    wall = time.time() - t0
    assert all(r.done for r in reqs)
    print(f"[serve] {len(reqs)} requests in {wall:.2f}s "
          f"({len(reqs) / wall:.1f} req/s) over {srv.dispatches} "
          f"dispatches of B={args.batch}, {srv.updates_applied} update "
          f"batches applied")
    if args.check:
        bad = 0
        for r, g_snap in zip(reqs, snapshots):
            ref, _ = reference.run(r.algo, g_snap, r.src)
            bad += not ALGEBRAS[r.algo].results_match(r.result, ref)
        print(f"[serve] oracle check: {len(reqs) - bad}/{len(reqs)} correct")
        if bad:
            raise SystemExit(1)


if __name__ == "__main__":
    main()
