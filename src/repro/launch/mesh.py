"""Production mesh construction.

Defined as functions (never module-level constants) so importing this
module never touches jax device state. The dry-run sets
XLA_FLAGS=--xla_force_host_platform_device_count=512 before any jax
import; smoke tests and benchmarks see the real (1-device) platform.

Mesh shapes:
  single-pod: (16, 16)    axes ("data", "model")   = 256 chips (one v5e pod)
  multi-pod : (2, 16, 16) axes ("pod", "data", "model") = 512 chips
The "pod" axis is pure data parallelism (DCN-friendly: one gradient
reduction per step crosses it).
"""
from __future__ import annotations

import jax
import numpy as np
from jax.sharding import Mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape: tuple, axes: tuple) -> Mesh:
    """Arbitrary mesh for tests/examples (e.g. (2,4) on 8 host devices)."""
    return jax.make_mesh(shape, axes)


def elastic_mesh(preferred: tuple = (16, 16),
                 axes: tuple = ("data", "model")) -> Mesh:
    """Build the largest mesh the live device set supports (elastic
    scaling: on restart after losing hosts, keep the model axis and shrink
    the data axis -- checkpoint resharding handles the rest)."""
    n = len(jax.devices())
    model = preferred[-1]
    while model > 1 and n % model:
        model //= 2
    data = n // model
    return jax.make_mesh((data, model), axes)
