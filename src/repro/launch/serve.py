"""Serving launcher: continuous-batching decode loop.

The serving loop is FLIP's frontier semantics applied to requests
(DESIGN.md Sec. 3): decode slots are PEs, requests are packets; slots
activate when a request arrives and retire at EOS, so the active set
evolves dynamically exactly like the vertex frontier -- no global
barrier, new work is admitted every step.

Usage (CPU demo):
  PYTHONPATH=src python -m repro.launch.serve --arch qwen3_0_6b \
      --preset tiny --slots 8 --requests 32 --max-new 32
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs as C
from repro.launch import steps as S
from repro.models import model as M


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3_0_6b")
    ap.add_argument("--preset", default="tiny", choices=["tiny", "full"])
    ap.add_argument("--slots", type=int, default=8)
    ap.add_argument("--requests", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=32)
    ap.add_argument("--max-seq", type=int, default=256)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = C.get_smoke(args.arch) if args.preset == "tiny" else C.get(args.arch)
    if not cfg.has_decode:
        raise SystemExit(f"{cfg.name} is encoder-only; nothing to serve")
    rng = np.random.default_rng(args.seed)
    params = M.init_params(cfg, jax.random.PRNGKey(args.seed))
    step = jax.jit(S.make_decode_step(cfg), donate_argnums=(1,))

    b = args.slots
    cache = M.init_cache(cfg, b, args.max_seq)
    tokens = jnp.zeros((b, 1), jnp.int32)
    pos = jnp.zeros((b,), jnp.int32)

    # request queue: (prompt_token, target_len)
    queue = [(int(rng.integers(1, cfg.vocab_size)),
              int(rng.integers(4, args.max_new))) for _ in range(args.requests)]
    active = [None] * b          # per-slot: [req_id, generated, target]
    done = 0
    t0 = time.time()
    steps = 0
    decoded_tokens = 0
    while done < args.requests:
        # admission: fill idle slots from the queue (frontier activation)
        tok_host = np.array(tokens)
        pos_host = np.array(pos)
        for s in range(b):
            if active[s] is None and queue:
                prompt, tgt = queue.pop(0)
                rid = args.requests - len(queue) - 1
                active[s] = [rid, 0, tgt]
                tok_host[s, 0] = prompt
                pos_host[s] = 0
        tokens = jnp.asarray(tok_host)
        pos = jnp.asarray(pos_host)

        logits, cache = step(params, cache, tokens, pos)
        nxt = jnp.argmax(logits[:, 0], axis=-1).astype(jnp.int32)
        steps += 1

        nxt_host = np.asarray(nxt)
        tok_host = np.array(tokens)
        pos_host = np.array(pos)
        for s in range(b):
            if active[s] is None:
                continue
            decoded_tokens += 1
            active[s][1] += 1
            if active[s][1] >= active[s][2] or pos_host[s] + 1 >= args.max_seq:
                done += 1
                active[s] = None       # slot retires (frontier deactivation)
            else:
                tok_host[s, 0] = nxt_host[s]
                pos_host[s] += 1
        tokens = jnp.asarray(tok_host)
        pos = jnp.asarray(pos_host)
        if steps % 16 == 0:
            util = sum(a is not None for a in active) / b
            print(f"[serve] step={steps} done={done}/{args.requests} "
                  f"slot-util={util:.2f}", flush=True)
    dt = time.time() - t0
    print(f"[serve] {args.requests} requests, {decoded_tokens} tokens in "
          f"{steps} steps, {dt:.1f}s ({decoded_tokens / dt:.1f} tok/s)")


if __name__ == "__main__":
    main()
