"""Shared layer primitives + the parameter declaration system.

Parameters are declared once (shape + logical axes + init scale) via
`ParamDecl`; the same declaration produces real arrays (`init_params`),
abstract ShapeDtypeStructs for the dry-run, and NamedShardings for pjit
in_shardings. One source of truth per tensor.
"""
from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed.sharding import constrain


@dataclasses.dataclass(frozen=True)
class ParamDecl:
    shape: tuple
    logical_axes: tuple
    init: str = "normal"          # normal | zeros | ones | embed
    scale: float | None = None    # fan-in default when None


def declare_dense(in_dim: int, out_dims: tuple, in_axis: str,
                  out_axes: tuple) -> ParamDecl:
    return ParamDecl(shape=(in_dim, *out_dims),
                     logical_axes=(in_axis, *out_axes))


def init_param(key, decl: ParamDecl, dtype) -> jnp.ndarray:
    if decl.init == "zeros":
        return jnp.zeros(decl.shape, dtype)
    if decl.init == "ones":
        return jnp.ones(decl.shape, dtype)
    fan_in = decl.shape[0] if len(decl.shape) > 1 else decl.shape[0]
    scale = decl.scale if decl.scale is not None else 1.0 / math.sqrt(fan_in)
    if decl.init == "embed":
        scale = 1.0
    return (jax.random.normal(key, decl.shape, jnp.float32)
            * scale).astype(dtype)


def init_tree(key, decls, dtype):
    """decls: nested dict of ParamDecl -> same-structure dict of arrays."""
    flat, treedef = jax.tree_util.tree_flatten(
        decls, is_leaf=lambda x: isinstance(x, ParamDecl))
    keys = jax.random.split(key, len(flat))
    vals = [init_param(k, d, dtype) for k, d in zip(keys, flat)]
    return jax.tree_util.tree_unflatten(treedef, vals)


def abstract_tree(decls, dtype):
    return jax.tree_util.tree_map(
        lambda d: jax.ShapeDtypeStruct(d.shape, dtype), decls,
        is_leaf=lambda x: isinstance(x, ParamDecl))


def stack_decls(decls, repeat: int):
    """Stack a block's declarations along a leading 'layers' axis (scan)."""
    return jax.tree_util.tree_map(
        lambda d: ParamDecl(shape=(repeat, *d.shape),
                            logical_axes=("layers", *d.logical_axes),
                            init=d.init, scale=d.scale),
        decls, is_leaf=lambda x: isinstance(x, ParamDecl))


# --------------------------------------------------------------------- #
# primitives
# --------------------------------------------------------------------- #
def rms_norm(x, gamma, eps: float):
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return ((x * jax.lax.rsqrt(var + eps)) * (1.0 + gamma.astype(
        jnp.float32))).astype(dt)


def rotary(q, k, positions, theta: float):
    """Apply RoPE. q/k: (..., S, H, D); positions: (..., S)."""
    d = q.shape[-1]
    freqs = jnp.exp(
        -jnp.arange(0, d, 2, dtype=jnp.float32) / d * jnp.log(theta))
    ang = positions[..., :, None].astype(jnp.float32) * freqs  # (..., S, D/2)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    cos = cos[..., :, None, :]   # broadcast over heads
    sin = sin[..., :, None, :]

    def rot(x):
        x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
        return jnp.concatenate(
            [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)

    return rot(q).astype(q.dtype), rot(k).astype(k.dtype)


def swiglu(x, w_gate, w_in, w_out, act_axis: str = "act_mlp"):
    """SwiGLU FFN with explicit sequence-parallel transitions: all-gather
    the seq axis once on entry (x arrives seq-sharded from the residual
    stream), run tensor-parallel over the ffn axis, and let the caller's
    residual constraint reduce-scatter the output back to seq-sharded --
    the Megatron SP pattern, stated explicitly so GSPMD never has to
    arbitrate the seq-vs-ffn axis conflict per einsum."""
    x = constrain(x, "batch", None, None)
    h = jax.nn.silu(jnp.einsum("bsd,df->bsf", x, w_gate)) \
        * jnp.einsum("bsd,df->bsf", x, w_in)
    h = constrain(h, "batch", None, act_axis)
    return jnp.einsum("bsf,fd->bsd", h, w_out)
