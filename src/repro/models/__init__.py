from repro.models.config import ModelConfig, BlockSpec

__all__ = ["ModelConfig", "BlockSpec"]
