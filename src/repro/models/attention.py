"""GQA attention: train/prefill (chunked online-softmax) + decode paths.

Three implementations, one math:
  * plain      -- einsum softmax; small sequences (smoke tests).
  * lax-flash  -- python-unrolled query chunks x lax.scan'd KV chunks with
                  online softmax. Memory O(chunk^2), and causal/window
                  chunk skipping keeps HLO FLOPs at ~S^2/2 (resp. S*W):
                  the XLA-level equivalent of flash attention, used for
                  the multi-pod dry-run (Pallas cannot lower to the CPU
                  stand-in backend) and as the CPU fallback.
  * pallas     -- kernels/attention flash kernel on real TPUs (tests run
                  it in interpret mode).
Decode attends a single query over a (possibly seq-sharded) KV cache --
reductions over the sharded axis become psums under GSPMD (flash-decoding
layout, DESIGN.md Sec. 5).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed.sharding import constrain
from repro.models.config import ModelConfig
from repro.models.layers import ParamDecl, rms_norm, rotary

NEG_INF = -1e30


def decls(cfg: ModelConfig) -> dict:
    d, h, k, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    out = {
        "wq": ParamDecl((d, h, hd), ("embed", "heads", "head_dim")),
        "wk": ParamDecl((d, k, hd), ("embed", "kv_heads", "head_dim")),
        "wv": ParamDecl((d, k, hd), ("embed", "kv_heads", "head_dim")),
        "wo": ParamDecl((h, hd, d), ("heads", "head_dim", "embed")),
    }
    if cfg.qk_norm:
        out["q_norm"] = ParamDecl((hd,), (None,), init="zeros")
        out["k_norm"] = ParamDecl((hd,), (None,), init="zeros")
    return out


def _mask_bias(q_pos, k_pos, causal: bool, window: int | None):
    """(..., Q, K) additive bias from absolute positions."""
    ok = jnp.ones(q_pos.shape[-1:] + k_pos.shape[-1:], dtype=bool)
    if causal:
        ok &= k_pos[None, :] <= q_pos[:, None]
    if window is not None:
        ok &= k_pos[None, :] > (q_pos[:, None] - window)
    return jnp.where(ok, 0.0, NEG_INF)


def _expand_kv(k, num_heads: int):
    """GQA: expand kv heads to q heads by gather (k[:, :, h // g]).

    NB: deliberately a gather on the head axis rather than a reshape of
    the q head axis into (kh, g) -- a 16-way-sharded head axis cannot be
    reshaped to (8, 2) without resharding, and the gather keeps everything
    head-sharded (XLA fuses the broadcast into the einsum).
    """
    g = num_heads // k.shape[2]
    if g == 1:
        return k
    return jnp.take(k, jnp.arange(num_heads) // g, axis=2)


def _plain_attention(q, k, v, q_pos, k_pos, causal, window):
    """q: (B,S,H,hd) k/v: (B,T,K,hd)."""
    b, s, h, hd = q.shape
    k = _expand_kv(k, h)
    v = _expand_kv(v, h)
    scores = jnp.einsum("bshd,bthd->bhst", q, k).astype(jnp.float32)
    scores = scores / np.sqrt(hd) + _mask_bias(q_pos, k_pos, causal, window)
    p = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bhst,bthd->bshd", p, v)
    return out


def _lax_flash(q, k, v, causal, window, chunk_q=1024, chunk_kv=1024,
               unroll_kv: bool = False):
    """Unrolled-q-chunk / scanned-kv-chunk online softmax.

    Chunk skipping: for causal masks, q chunk i only visits kv chunks
    [lo_i, i]; with a sliding window, lo_i = (i*cq - window) // ckv.
    Each q chunk is checkpointed: its inner-scan softmax residuals are
    recomputed in the backward pass instead of being saved (bounds live
    memory to one chunk pair). `unroll_kv=True` unrolls the kv scan so
    compiled.cost_analysis() counts every chunk (roofline measurement
    mode; XLA's cost model counts loop bodies once).
    """
    b, s, h, hd = q.shape
    t = k.shape[1]
    k = _expand_kv(k, h)
    v = _expand_kv(v, h)
    cq = min(chunk_q, s)
    ckv = min(chunk_kv, t)
    assert s % cq == 0 and t % ckv == 0, (s, cq, t, ckv)
    nq, nkv = s // cq, t // ckv
    scale = 1.0 / np.sqrt(hd)

    def make_q_chunk(i):
        if causal:
            hi = i + 1
            lo = 0 if window is None else max(
                0, (i * cq - (window + ckv - 1)) // ckv)
        else:
            lo, hi = 0, nkv
        idxs = jnp.arange(lo, hi)

        @jax.checkpoint
        def q_chunk(q_i, q_pos, k, v):
            def step(carry, j):
                m, l, acc = carry
                k_j = jax.lax.dynamic_slice_in_dim(k, j * ckv, ckv, axis=1)
                v_j = jax.lax.dynamic_slice_in_dim(v, j * ckv, ckv, axis=1)
                k_pos = j * ckv + jnp.arange(ckv)
                sc = jnp.einsum("bqhd,bthd->bhqt", q_i, k_j)
                sc = sc.astype(jnp.float32) * scale
                sc = sc + _mask_bias(q_pos, k_pos, causal, window)
                m_new = jnp.maximum(m, sc.max(axis=-1))
                p = jnp.exp(sc - m_new[..., None])
                corr = jnp.exp(m - m_new)
                l_new = l * corr + p.sum(axis=-1)
                acc_new = acc * corr[..., None] + jnp.einsum(
                    "bhqt,bthd->bhqd", p.astype(v.dtype), v_j)
                return (m_new, l_new, acc_new), None

            m0 = jnp.full((b, h, cq), NEG_INF, jnp.float32)
            l0 = jnp.zeros((b, h, cq), jnp.float32)
            a0 = jnp.zeros((b, h, cq, hd), jnp.float32)
            (m, l, acc), _ = jax.lax.scan(
                step, (m0, l0, a0), idxs,
                unroll=len(idxs) if unroll_kv else 1)
            o = (acc / jnp.maximum(l, 1e-30)[..., None]).astype(q.dtype)
            return o.transpose(0, 2, 1, 3)       # (b, cq, h, hd)

        return q_chunk

    outs = []
    for i in range(nq):
        q_i = q[:, i * cq:(i + 1) * cq]
        q_pos = i * cq + jnp.arange(cq)
        outs.append(make_q_chunk(i)(q_i, q_pos, k, v))
    return jnp.concatenate(outs, axis=1)


def attend(q, k, v, causal: bool, window: int | None, impl: str = "auto"):
    """Full-sequence attention dispatch. q,k,v: (B,S,H/K,hd)."""
    s = q.shape[1]
    if impl == "auto":
        impl = "lax_flash" if s > 1024 else "plain"
    if impl == "plain":
        pos = jnp.arange(s)
        return _plain_attention(q, k, v, pos, pos, causal, window)
    if impl == "lax_flash":
        return _lax_flash(q, k, v, causal, window)
    if impl == "lax_flash_unrolled":     # roofline measurement mode
        return _lax_flash(q, k, v, causal, window, unroll_kv=True)
    if impl in ("pallas", "pallas_interpret"):
        from repro.kernels.attention.ops import flash_attention
        return flash_attention(q, k, v, causal=causal, window=window,
                               interpret=(impl == "pallas_interpret"))
    raise ValueError(impl)


# --------------------------------------------------------------------- #
# layer entry points
# --------------------------------------------------------------------- #
def _project_qkv(p, x, cfg: ModelConfig, positions):
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.rms_eps)
        k = rms_norm(k, p["k_norm"], cfg.rms_eps)
    q, k = rotary(q, k, positions, cfg.rope_theta)
    return q, k, v


def apply(p, x, cfg: ModelConfig, window: int | None, impl: str = "auto"):
    """Training / prefill self-attention over the full sequence.

    Returns (out, (k, v)) so prefill can keep the cache. Explicit SP
    transition: one seq all-gather on entry (x arrives seq-sharded),
    head-parallel compute, reduce-scatter back via the caller's residual
    constraint.
    """
    b, s, _ = x.shape
    x = constrain(x, "batch", None, None)
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))
    q, k, v = _project_qkv(p, x, cfg, positions)
    q = constrain(q, "batch", None, "act_heads", None)
    k = constrain(k, "batch", None, "act_heads", None)
    v = constrain(v, "batch", None, "act_heads", None)
    o = attend(q, k, v, causal=cfg.causal, window=window, impl=impl)
    o = constrain(o, "batch", None, "act_heads", None)
    out = jnp.einsum("bshk,hkd->bsd", o, p["wo"])
    return out, (k, v)


def decode(p, x, cache_k, cache_v, pos, cfg: ModelConfig,
           window: int | None, long_ctx: bool = False):
    """Single-token decode. x: (B,1,d); cache: (B,T,K,hd); pos: (B,) int32.

    Sliding-window layers use a RING cache of length T == window (slot =
    pos % window), so a gemma3-style local layer holds O(window) state even
    at 500k context. Global layers use T == max_seq. The cache's T axis is
    sharded ('kv_seq' / 'long_kv_seq'); softmax reductions over it become
    psums under GSPMD (flash-decoding layout).
    """
    b = x.shape[0]
    t = cache_k.shape[1]
    kv_ax = "long_kv_seq" if long_ctx else "kv_seq"
    ring = window is not None and t == window
    q, k_new, v_new = _project_qkv(p, x, cfg, pos[:, None])

    slot = (pos % t) if ring else pos
    onehot = jax.nn.one_hot(slot, t, dtype=cache_k.dtype)   # (B, T)
    cache_k = cache_k * (1 - onehot[..., None, None]) \
        + onehot[..., None, None] * k_new[:, :1]
    cache_v = cache_v * (1 - onehot[..., None, None]) \
        + onehot[..., None, None] * v_new[:, :1]
    cache_k = constrain(cache_k, "batch", kv_ax, "kv_heads", None)
    cache_v = constrain(cache_v, "batch", kv_ax, "kv_heads", None)

    kh = cache_k.shape[2]
    g = cfg.num_heads // kh
    qr = q.reshape(b, kh, g, cfg.head_dim)
    scores = jnp.einsum("bkgd,btkd->bkgt", qr, cache_k).astype(jnp.float32)
    scores = scores / np.sqrt(cfg.head_dim)
    slots = jnp.arange(t)
    if ring:
        # absolute position held by each ring slot; all are <= pos and
        # > pos - window by construction, only warmup slots are invalid
        abs_pos = pos[:, None] - ((pos[:, None] - slots[None, :]) % t)
        ok = abs_pos >= 0
    else:
        ok = slots[None, :] <= pos[:, None]
        if window is not None:
            ok &= slots[None, :] > (pos[:, None] - window)
    scores = jnp.where(ok[:, None, None, :], scores, NEG_INF)
    pattn = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
    o = jnp.einsum("bkgt,btkd->bkgd", pattn, cache_v)
    o = o.reshape(b, 1, cfg.num_heads, cfg.head_dim)
    out = jnp.einsum("bshk,hkd->bsd", o, p["wo"])
    return out, (cache_k, cache_v)
