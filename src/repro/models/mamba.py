"""Mamba-2 (SSD) block: projections + causal depthwise conv + SSD + gate.

Used by mamba2-370m (pure SSM stack) and jamba-1.5 (hybrid 7:1 with
attention). Decode carries (conv_state, ssm_state) -- O(1) per token,
which is what makes the long_500k cell servable (DESIGN.md Sec. 7).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.distributed.sharding import constrain
from repro.models.config import ModelConfig
from repro.models.layers import ParamDecl, rms_norm
from repro.kernels.ssd.ops import ssd_chunked
from repro.kernels.ssd.ref import ssd_step_ref


def decls(cfg: ModelConfig) -> dict:
    d, di = cfg.d_model, cfg.ssm_d_inner
    n, h, k = cfg.ssm_state, cfg.ssm_heads, cfg.ssm_conv
    return {
        "wz": ParamDecl((d, di), ("embed", "ssm_inner")),
        "wx": ParamDecl((d, di), ("embed", "ssm_inner")),
        "wB": ParamDecl((d, n), ("embed", "state")),
        "wC": ParamDecl((d, n), ("embed", "state")),
        "wdt": ParamDecl((d, h), ("embed", "ssm_heads")),
        "dt_bias": ParamDecl((h,), (None,), init="zeros"),
        "A_log": ParamDecl((h,), (None,), init="zeros"),
        "D": ParamDecl((h,), (None,), init="zeros"),
        "conv_x": ParamDecl((k, di), ("conv", "ssm_inner"),
                            init="normal", scale=0.5),
        "conv_B": ParamDecl((k, n), ("conv", "state"),
                            init="normal", scale=0.5),
        "conv_C": ParamDecl((k, n), ("conv", "state"),
                            init="normal", scale=0.5),
        "gate_norm": ParamDecl((di,), (None,), init="zeros"),
        "w_out": ParamDecl((di, d), ("ssm_inner", "embed")),
    }


def _causal_conv(x, w):
    """Depthwise causal conv via shifted adds. x: (B,L,C); w: (K,C)."""
    k = w.shape[0]
    out = x * w[k - 1]
    for i in range(1, k):
        shifted = jnp.pad(x[:, :-i], ((0, 0), (i, 0), (0, 0)))
        out = out + shifted * w[k - 1 - i]
    return out


def _conv_step(state, xt, w):
    """One-token conv. state: (B,K-1,C) past inputs; xt: (B,C)."""
    k = w.shape[0]
    window = jnp.concatenate([state, xt[:, None]], axis=1)   # (B,K,C)
    y = jnp.einsum("bkc,kc->bc", window, w)
    return y, window[:, 1:]


def apply(p, x, cfg: ModelConfig, impl: str = "auto"):
    """Full-sequence SSD block. x: (B,L,d) -> (B,L,d)."""
    z = jnp.einsum("bld,di->bli", x, p["wz"])
    xc = jnp.einsum("bld,di->bli", x, p["wx"])
    Bc = jnp.einsum("bld,dn->bln", x, p["wB"])
    Cc = jnp.einsum("bld,dn->bln", x, p["wC"])
    dt = jax.nn.softplus(
        jnp.einsum("bld,dh->blh", x, p["wdt"]) + p["dt_bias"])
    xc = jax.nn.silu(_causal_conv(xc, p["conv_x"]))
    Bc = jax.nn.silu(_causal_conv(Bc, p["conv_B"]))
    Cc = jax.nn.silu(_causal_conv(Cc, p["conv_C"]))
    xc = constrain(xc, "batch", None, "act_heads")

    b, l, di = xc.shape
    xh = xc.reshape(b, l, cfg.ssm_heads, cfg.ssm_head_dim)
    chunk = min(cfg.ssm_chunk, l)
    y, _ = ssd_chunked(xh, dt, Bc, Cc, p["A_log"], p["D"],
                       chunk=chunk, impl=impl)
    y = y.reshape(b, l, di)
    y = rms_norm(y * jax.nn.silu(z), p["gate_norm"], cfg.rms_eps)
    return jnp.einsum("bli,id->bld", y, p["w_out"])


def init_cache(cfg: ModelConfig, batch: int, dtype):
    """(conv states for x/B/C, ssm state)."""
    k, di, n = cfg.ssm_conv, cfg.ssm_d_inner, cfg.ssm_state
    return {
        "conv_x": jnp.zeros((batch, k - 1, di), dtype),
        "conv_B": jnp.zeros((batch, k - 1, n), dtype),
        "conv_C": jnp.zeros((batch, k - 1, n), dtype),
        "ssm": jnp.zeros((batch, cfg.ssm_heads, n, cfg.ssm_head_dim),
                         jnp.float32),
    }


def decode(p, x, cache, cfg: ModelConfig):
    """One-token step. x: (B,1,d). Returns (out (B,1,d), new cache)."""
    xt = x[:, 0]
    z = jnp.einsum("bd,di->bi", xt, p["wz"])
    xc = jnp.einsum("bd,di->bi", xt, p["wx"])
    Bc = jnp.einsum("bd,dn->bn", xt, p["wB"])
    Cc = jnp.einsum("bd,dn->bn", xt, p["wC"])
    dt = jax.nn.softplus(jnp.einsum("bd,dh->bh", xt, p["wdt"])
                         + p["dt_bias"])
    xc, conv_x = _conv_step(cache["conv_x"], xc, p["conv_x"])
    Bc, conv_B = _conv_step(cache["conv_B"], Bc, p["conv_B"])
    Cc, conv_C = _conv_step(cache["conv_C"], Cc, p["conv_C"])
    xc, Bc, Cc = jax.nn.silu(xc), jax.nn.silu(Bc), jax.nn.silu(Cc)

    xh = xc.reshape(-1, cfg.ssm_heads, cfg.ssm_head_dim)
    y, ssm = ssd_step_ref(xh, dt, Bc, Cc, p["A_log"], p["D"], cache["ssm"])
    y = y.reshape(xt.shape[0], cfg.ssm_d_inner)
    y = rms_norm(y * jax.nn.silu(z), p["gate_norm"], cfg.rms_eps)
    out = jnp.einsum("bi,id->bd", y, p["w_out"])[:, None]
    return out, {"conv_x": conv_x, "conv_B": conv_B, "conv_C": conv_C,
                 "ssm": ssm}
