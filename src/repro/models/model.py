"""Full model assembly: embedding -> scan(pattern blocks) -> norm -> head.

Design notes (DESIGN.md Sec. 5):
  * scan-over-layers with stacked per-pattern params: HLO size is O(1) in
    depth; full remat (`nothing_saveable`) keeps live activations to one
    layer's residual stream.
  * the residual stream is constrained ("batch", "seq", None): batch over
    (pod, data), sequence parallelism over "model"; blocks internally
    re-shard to head/mlp/expert parallelism.
  * cross-entropy is computed in sequence chunks with vocab-sharded logits
    (remat'd), so full (B, S, V) logits never materialize.
  * decode keeps per-layer KV/SSM caches; sliding-window layers get ring
    caches of length `window`.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed.sharding import constrain
from repro.models import attention, mamba, moe
from repro.models.config import BlockSpec, ModelConfig
from repro.models.layers import (ParamDecl, abstract_tree, init_tree,
                                 rms_norm, stack_decls, swiglu)

AUX_WEIGHT = 0.01     # load-balance loss weight


def _dtype(name: str):
    return {"float32": jnp.float32, "bfloat16": jnp.bfloat16,
            "float16": jnp.float16}[name]


# --------------------------------------------------------------------- #
# parameter declarations
# --------------------------------------------------------------------- #
def _ffn_decls(cfg: ModelConfig) -> dict:
    d, f = cfg.d_model, cfg.d_ff
    return {
        "w_gate": ParamDecl((d, f), ("embed", "mlp")),
        "w_in": ParamDecl((d, f), ("embed", "mlp")),
        "w_out": ParamDecl((f, d), ("mlp", "embed")),
    }


def _block_decls(cfg: ModelConfig, spec: BlockSpec) -> dict:
    out = {"norm1": ParamDecl((cfg.d_model,), (None,), init="zeros")}
    if spec.kind == "attn":
        out["attn"] = attention.decls(cfg)
    else:
        out["mamba"] = mamba.decls(cfg)
    if spec.has_ffn:
        out["norm2"] = ParamDecl((cfg.d_model,), (None,), init="zeros")
        out["ffn"] = moe.decls(cfg) if spec.moe else _ffn_decls(cfg)
    return out


def param_decls(cfg: ModelConfig) -> dict:
    blocks = {
        f"block{i}": stack_decls(_block_decls(cfg, spec), cfg.repeat)
        for i, spec in enumerate(cfg.pattern)
    }
    out = {
        "embed": ParamDecl((cfg.padded_vocab, cfg.d_model),
                           ("vocab", "embed"), init="embed",
                           scale=1.0),
        "blocks": blocks,
        "final_norm": ParamDecl((cfg.d_model,), (None,), init="zeros"),
    }
    if not cfg.tie_embeddings:
        out["lm_head"] = ParamDecl((cfg.padded_vocab, cfg.d_model),
                                   ("vocab", "embed"))
    return out


def init_params(cfg: ModelConfig, key):
    return init_tree(key, param_decls(cfg), _dtype(cfg.param_dtype))


def abstract_params(cfg: ModelConfig):
    return abstract_tree(param_decls(cfg), _dtype(cfg.param_dtype))


# --------------------------------------------------------------------- #
# forward (train / prefill)
# --------------------------------------------------------------------- #
def _run_block(bp, x, cfg: ModelConfig, spec: BlockSpec, impl: str,
               moe_dispatch: str, return_kv: bool = False):
    h = rms_norm(x, bp["norm1"], cfg.rms_eps)
    kv = None
    if spec.kind == "attn":
        a, kv = attention.apply(bp["attn"], h, cfg, spec.window, impl=impl)
    else:
        a = mamba.apply(bp["mamba"], h, cfg, impl=impl)
    x = x + a
    x = constrain(x, "batch", "seq", None)
    aux = jnp.float32(0.0)
    if spec.has_ffn:
        h = rms_norm(x, bp["norm2"], cfg.rms_eps)
        if spec.moe:
            f, aux = moe.apply(bp["ffn"], h, cfg, dispatch=moe_dispatch)
        else:
            f = swiglu(h, bp["ffn"]["w_gate"], bp["ffn"]["w_in"],
                       bp["ffn"]["w_out"])
        x = x + f
        x = constrain(x, "batch", "seq", None)
    return (x, aux, kv) if return_kv else (x, aux)


def backbone(params, x, cfg: ModelConfig, impl: str = "auto",
             moe_dispatch: str = "gspmd", remat: bool = True):
    """x: (B,S,d) embeddings -> (hidden (B,S,d), aux loss scalar).

    Remat is per-BLOCK (not per-superblock): long patterns (gemma3's 6,
    jamba's 8) would otherwise have every layer's recomputed internals
    live simultaneously during the superblock backward; per-block
    checkpoints bound the live set to one layer + the pattern's saved
    residual inputs.
    """
    x = constrain(x, "batch", "seq", None)

    def superblock(carry, layer_params):
        x, aux = carry
        for i, spec in enumerate(cfg.pattern):
            blk = lambda bp, x, spec=spec: _run_block(
                bp, x, cfg, spec, impl, moe_dispatch)
            if remat:
                blk = jax.checkpoint(
                    blk, policy=jax.checkpoint_policies.nothing_saveable)
            x, a = blk(layer_params[f"block{i}"], x)
            aux = aux + a
        return (x, aux), None

    (x, aux), _ = jax.lax.scan(superblock, (x, jnp.float32(0.0)),
                               params["blocks"])
    x = rms_norm(x, params["final_norm"], cfg.rms_eps)
    return x, aux


def embed_inputs(params, batch, cfg: ModelConfig):
    act = _dtype(cfg.activation_dtype)
    if cfg.frontend == "frames":
        return batch["frames"].astype(act)
    emb = jnp.take(params["embed"], batch["tokens"], axis=0)
    return (emb * np.sqrt(cfg.d_model)).astype(act)


def _head_weights(params):
    return params.get("lm_head", params["embed"])


def ce_chunk_loss(w, h_c, y_c, cfg: ModelConfig):
    """CE over one sequence chunk with vocab-sharded logits."""
    logits = jnp.einsum("bsd,vd->bsv", h_c, w).astype(jnp.float32)
    logits = constrain(logits, "batch", None, "act_vocab")
    lse = jax.nn.logsumexp(logits, axis=-1)
    onehot = (jnp.arange(cfg.padded_vocab)[None, None, :]
              == y_c[:, :, None])
    lbl = jnp.sum(jnp.where(onehot, logits, 0.0), axis=-1)
    return jnp.sum(lse - lbl)


def chunked_ce(params, hidden, labels, cfg: ModelConfig,
               num_chunks: int = 8, scan: bool = True):
    """Mean token cross-entropy in sequence chunks: full (B,S,V) logits
    never materialize; each chunk is remat'd, and the chunk loop is a
    lax.scan so XLA provably reuses one chunk's buffers (scan=False
    unrolls for roofline measurement -- cost_analysis counts loop bodies
    once)."""
    b, s, _ = hidden.shape
    num_chunks = min(num_chunks, s)
    assert s % num_chunks == 0
    cs = s // num_chunks
    w = _head_weights(params)
    chunk_loss = jax.checkpoint(
        lambda h_c, y_c: ce_chunk_loss(w, h_c, y_c, cfg))

    if scan:
        def body(total, i):
            h_c = jax.lax.dynamic_slice_in_dim(hidden, i * cs, cs, axis=1)
            y_c = jax.lax.dynamic_slice_in_dim(labels, i * cs, cs, axis=1)
            return total + chunk_loss(h_c, y_c), None
        total, _ = jax.lax.scan(body, jnp.float32(0.0),
                                jnp.arange(num_chunks))
    else:
        total = jnp.float32(0.0)
        for i in range(num_chunks):
            h_c = jax.lax.dynamic_slice_in_dim(hidden, i * cs, cs, axis=1)
            y_c = jax.lax.dynamic_slice_in_dim(labels, i * cs, cs, axis=1)
            total = total + chunk_loss(h_c, y_c)
    return total / (b * s)


def train_loss(params, batch, cfg: ModelConfig, impl: str = "auto",
               moe_dispatch: str = "gspmd", remat: bool = True):
    x = embed_inputs(params, batch, cfg)
    hidden, aux = backbone(params, x, cfg, impl=impl,
                           moe_dispatch=moe_dispatch, remat=remat)
    ce = chunked_ce(params, hidden, batch["labels"], cfg)
    return ce + AUX_WEIGHT * aux


def prefill(params, batch, cfg: ModelConfig, impl: str = "auto",
            moe_dispatch: str = "gspmd"):
    """Forward pass returning last-position logits (inference prefill).

    (Caches are produced by re-running decode for served requests; the
    prefill *shape cell* measures the forward pass itself.)
    """
    x = embed_inputs(params, batch, cfg)
    hidden, _ = backbone(params, x, cfg, impl=impl,
                         moe_dispatch=moe_dispatch, remat=False)
    last = hidden[:, -1:]
    logits = jnp.einsum("bsd,vd->bsv", last,
                        _head_weights(params)).astype(jnp.float32)
    return constrain(logits, "batch", None, "act_vocab")


# --------------------------------------------------------------------- #
# component entry points (roofline measurement: XLA's cost model counts
# scan bodies once, so the dry-run compiles one superblock / the head
# separately and scales by `repeat` -- see launch/dryrun.py)
# --------------------------------------------------------------------- #
def superblock_decls(cfg: ModelConfig) -> dict:
    """Unstacked declarations for one scan body (all pattern positions)."""
    return {f"block{i}": _block_decls(cfg, spec)
            for i, spec in enumerate(cfg.pattern)}


def apply_superblock(layer_params, x, cfg: ModelConfig,
                     impl: str = "lax_flash_unrolled",
                     moe_dispatch: str = "gspmd", remat: bool = True):
    """One scan-body application (forward). Returns (x, aux).
    Mirrors backbone(): per-block remat."""
    aux = jnp.float32(0.0)
    for i, spec in enumerate(cfg.pattern):
        blk = lambda bp, x, spec=spec: _run_block(
            bp, x, cfg, spec, impl, moe_dispatch)
        if remat:
            blk = jax.checkpoint(
                blk, policy=jax.checkpoint_policies.nothing_saveable)
        x, a = blk(layer_params[f"block{i}"], x)
        aux = aux + a
    return x, aux


def superblock_decode(layer_params, layer_cache, x, pos, cfg: ModelConfig,
                      long_ctx: bool = False, moe_dispatch: str = "gspmd"):
    """One decode scan-body application. Returns (x, new_cache)."""
    from repro.models import attention as A
    new_cache = {}
    for i, spec in enumerate(cfg.pattern):
        bp = layer_params[f"block{i}"]
        c = layer_cache[f"block{i}"]
        h = rms_norm(x, bp["norm1"], cfg.rms_eps)
        if spec.kind == "attn":
            a, (ck, cv) = A.decode(bp["attn"], h, c["k"], c["v"], pos, cfg,
                                   spec.window, long_ctx=long_ctx)
            new_cache[f"block{i}"] = {"k": ck, "v": cv}
        else:
            a, nc = mamba.decode(bp["mamba"], h, c, cfg)
            new_cache[f"block{i}"] = nc
        x = x + a
        if spec.has_ffn:
            h = rms_norm(x, bp["norm2"], cfg.rms_eps)
            if spec.moe:
                f, _ = moe.apply(bp["ffn"], h, cfg, dispatch=moe_dispatch)
            else:
                f = swiglu(h, bp["ffn"]["w_gate"], bp["ffn"]["w_in"],
                           bp["ffn"]["w_out"])
            x = x + f
    return x, new_cache


def head_loss(params, hidden, labels, cfg: ModelConfig,
              scan_chunks: bool = True):
    """Final norm + CE (the non-repeated tail of the train step)."""
    h = rms_norm(hidden, params["final_norm"], cfg.rms_eps)
    return chunked_ce(params, h, labels, cfg, scan=scan_chunks)


# --------------------------------------------------------------------- #
# decode
# --------------------------------------------------------------------- #
def cache_len(cfg: ModelConfig, spec: BlockSpec, max_seq: int) -> int:
    if spec.window is not None:
        return min(spec.window, max_seq)
    return max_seq


def abstract_cache(cfg: ModelConfig, batch: int, max_seq: int,
                   long_ctx: bool = False):
    """Cache structure as ShapeDtypeStructs -- NO allocation (a 500k-deep
    cache is hundreds of GB; the dry-run must never materialize it)."""
    act = _dtype(cfg.activation_dtype)
    cache = {}
    for i, spec in enumerate(cfg.pattern):
        if spec.kind == "attn":
            t = cache_len(cfg, spec, max_seq)
            shape = (cfg.repeat, batch, t, cfg.num_kv_heads, cfg.head_dim)
            cache[f"block{i}"] = {
                "k": jax.ShapeDtypeStruct(shape, act),
                "v": jax.ShapeDtypeStruct(shape, act),
            }
        else:
            k, di, n = cfg.ssm_conv, cfg.ssm_d_inner, cfg.ssm_state
            r = cfg.repeat
            cache[f"block{i}"] = {
                "conv_x": jax.ShapeDtypeStruct((r, batch, k - 1, di), act),
                "conv_B": jax.ShapeDtypeStruct((r, batch, k - 1, n), act),
                "conv_C": jax.ShapeDtypeStruct((r, batch, k - 1, n), act),
                "ssm": jax.ShapeDtypeStruct(
                    (r, batch, cfg.ssm_heads, n, cfg.ssm_head_dim),
                    jnp.float32),
            }
    return cache


def init_cache(cfg: ModelConfig, batch: int, max_seq: int,
               long_ctx: bool = False):
    """Concrete zero caches (serving); structure matches abstract_cache."""
    return jax.tree_util.tree_map(
        lambda a: jnp.zeros(a.shape, a.dtype),
        abstract_cache(cfg, batch, max_seq, long_ctx),
        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))


def cache_logical_axes(cfg: ModelConfig, long_ctx: bool = False):
    """Logical axes pytree matching init_cache's structure."""
    kv_ax = "long_kv_seq" if long_ctx else "kv_seq"
    axes = {}
    for i, spec in enumerate(cfg.pattern):
        if spec.kind == "attn":
            a = ("layers", "batch", kv_ax, "kv_heads", "head_dim")
            axes[f"block{i}"] = {"k": a, "v": a}
        else:
            axes[f"block{i}"] = {
                "conv_x": ("layers", "batch", None, "ssm_inner"),
                "conv_B": ("layers", "batch", None, "state"),
                "conv_C": ("layers", "batch", None, "state"),
                "ssm": ("layers", "batch", "ssm_heads", "state", None),
            }
    return axes


def decode_step(params, cache, tokens, pos, cfg: ModelConfig,
                long_ctx: bool = False, moe_dispatch: str = "gspmd"):
    """One serving step. tokens: (B,1) int32; pos: (B,) int32 positions.

    Returns (logits (B,1,V) f32, new cache).
    """
    act = _dtype(cfg.activation_dtype)
    if cfg.frontend == "frames":
        raise ValueError("encoder models have no decode step")
    x = (jnp.take(params["embed"], tokens, axis=0)
         * np.sqrt(cfg.d_model)).astype(act)

    def superblock(x, scanned):
        layer_params, layer_cache = scanned
        new_cache = {}
        aux = jnp.float32(0.0)
        for i, spec in enumerate(cfg.pattern):
            bp = layer_params[f"block{i}"]
            c = layer_cache[f"block{i}"]
            h = rms_norm(x, bp["norm1"], cfg.rms_eps)
            if spec.kind == "attn":
                a, (ck, cv) = attention.decode(
                    bp["attn"], h, c["k"], c["v"], pos, cfg, spec.window,
                    long_ctx=long_ctx)
                new_cache[f"block{i}"] = {"k": ck, "v": cv}
            else:
                a, nc = mamba.decode(bp["mamba"], h, c, cfg)
                new_cache[f"block{i}"] = nc
            x = x + a
            if spec.has_ffn:
                h = rms_norm(x, bp["norm2"], cfg.rms_eps)
                if spec.moe:
                    f, _ = moe.apply(bp["ffn"], h, cfg,
                                     dispatch=moe_dispatch)
                else:
                    f = swiglu(h, bp["ffn"]["w_gate"], bp["ffn"]["w_in"],
                               bp["ffn"]["w_out"])
                x = x + f
        return x, new_cache

    x, new_cache = jax.lax.scan(superblock, x,
                                (params["blocks"], cache))
    x = rms_norm(x, params["final_norm"], cfg.rms_eps)
    logits = jnp.einsum("bsd,vd->bsv", x,
                        _head_weights(params)).astype(jnp.float32)
    return constrain(logits, "batch", None, "act_vocab"), new_cache
