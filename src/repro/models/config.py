"""Model configuration covering all assigned architecture families.

A model is `embedding -> repeat x pattern(BlockSpec...) -> norm -> head`.
The pattern (a short list of per-layer block descriptors) captures every
assigned family: dense decoders are a 1-long pattern, gemma3 is a 6-long
5:1 local:global pattern, jamba is an 8-long 1:7 attn:mamba pattern with
alternating MoE, mamba2 is a 1-long SSM pattern, hubert is an encoder
(bidirectional, no decode). The stack is scanned over `repeat` with the
pattern's parameters stacked on the leading axis (O(1) HLO in depth).
"""
from __future__ import annotations

import dataclasses
from typing import Literal

BlockKind = Literal["attn", "mamba"]


@dataclasses.dataclass(frozen=True)
class BlockSpec:
    """One layer inside the repeating pattern."""
    kind: BlockKind = "attn"
    window: int | None = None    # sliding-window size (None = global)
    moe: bool = False            # MoE FFN instead of dense FFN
    has_ffn: bool = True         # mamba2 pure-SSM blocks have no FFN


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                  # dense | moe | hybrid | ssm | audio | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int
    pattern: tuple[BlockSpec, ...] = (BlockSpec(),)
    # attention details
    qk_norm: bool = False
    rope_theta: float = 1e4
    rms_eps: float = 1e-6
    causal: bool = True          # False = encoder (hubert)
    # MoE
    num_experts: int = 0
    top_k: int = 0
    expert_d_ff: int = 0
    capacity_factor: float = 1.25
    # SSM (mamba2 / jamba)
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_conv: int = 4
    ssm_chunk: int = 256
    # modality frontend: 'none' = token ids; 'frames' = precomputed
    # embeddings (audio/vision stubs per the assignment)
    frontend: str = "none"
    tie_embeddings: bool = False
    # numerics
    param_dtype: str = "bfloat16"
    activation_dtype: str = "bfloat16"

    # ------------------------------------------------------------------ #
    def __post_init__(self):
        assert self.num_layers % len(self.pattern) == 0, (
            f"{self.name}: num_layers {self.num_layers} must be a multiple "
            f"of pattern length {len(self.pattern)}")

    @property
    def repeat(self) -> int:
        return self.num_layers // len(self.pattern)

    @property
    def padded_vocab(self) -> int:
        """Embedding/head tables pad the vocab to a multiple of 256 so the
        vocab axis shards evenly (e.g. granite's 49155 -> 49408); labels
        never reference the padding classes (DESIGN.md Sec. 8)."""
        return -(-self.vocab_size // 256) * 256

    @property
    def ssm_d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.ssm_d_inner // self.ssm_head_dim

    @property
    def has_decode(self) -> bool:
        return self.causal

    def supports_long_context(self) -> bool:
        """True if every pattern position is sub-quadratic-servable at 500k
        (SSM, sliding-window); global-attention layers are allowed because
        decode attends O(L) per step with a seq-sharded cache, but a config
        of ONLY global full-attention layers is excluded per assignment."""
        kinds = [(b.kind, b.window) for b in self.pattern]
        return any(k == "mamba" or w is not None for k, w in kinds)

    # rough parameter count (embedding + blocks), for 6ND model-flops
    def param_count(self, active_only: bool = False) -> int:
        d, f = self.d_model, self.d_ff
        emb = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        total = emb
        for b in self.pattern:
            layer = 0
            if b.kind == "attn":
                q = d * self.num_heads * self.head_dim
                kv = 2 * d * self.num_kv_heads * self.head_dim
                o = self.num_heads * self.head_dim * d
                layer += q + kv + o
            else:
                di, n = self.ssm_d_inner, self.ssm_state
                layer += d * (2 * di + 2 * n + self.ssm_heads)  # in_proj
                layer += di * d                                  # out_proj
                layer += self.ssm_conv * (di + 2 * n)            # conv
            if b.has_ffn:
                if b.moe:
                    e = self.num_experts if not active_only else self.top_k
                    layer += e * 3 * d * self.expert_d_ff + d * self.num_experts
                else:
                    layer += 3 * d * f
            total += layer * self.repeat
        return total
