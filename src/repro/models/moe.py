"""Mixture-of-Experts FFN with expert-parallel dispatch.

This is the paper-technique integration point (DESIGN.md Sec. 3): tokens
are *packets*, experts are *vertices pinned to devices*, and the router is
the Inter-Table. Two dispatch paths:

  * gspmd (baseline)  -- scatter/gather dispatch into a capacity buffer
    (E, C, d) with experts sharded over 'model'; GSPMD inserts the
    collectives. Paper-faithful "classic" EP, used for the roofline
    baseline.
  * shard_map (optimized, `dispatch="all_to_all"`) -- explicit per-device
    dispatch + jax.lax.all_to_all over the 'model' axis. Deterministic
    collective schedule; the §Perf hillclimb measures it against gspmd.

Expert placement (`placement_perm`): a permutation from
repro.core.placement (FLIP mapping compiler on router co-activation
stats). Applying it at weight layout time groups co-firing experts on the
same shard -- with the shard-granularity dispatch it directly reduces
all-to-all bytes.

Load-balance aux loss: Switch-style mean(f_e * p_e) * E, returned to the
caller and accumulated through the layer scan.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed.sharding import constrain, current_mesh
from repro.models.config import ModelConfig
from repro.models.layers import ParamDecl


def decls(cfg: ModelConfig) -> dict:
    d, e, f = cfg.d_model, cfg.num_experts, cfg.expert_d_ff
    return {
        "router": ParamDecl((d, e), ("embed", None)),
        "w_gate": ParamDecl((e, d, f), ("experts", "embed", "expert_mlp")),
        "w_in": ParamDecl((e, d, f), ("experts", "embed", "expert_mlp")),
        "w_out": ParamDecl((e, f, d), ("experts", "expert_mlp", "embed")),
    }


def _top_k(logits, k):
    """Returns (weights (T,k) softmaxed over the k, ids (T,k))."""
    vals, ids = jax.lax.top_k(logits, k)
    w = jax.nn.softmax(vals, axis=-1)
    return w, ids


def _capacity(tokens: int, num_experts: int, k: int, factor: float) -> int:
    c = int(np.ceil(tokens * k * factor / num_experts))
    return max(8, -(-c // 8) * 8)   # round up to 8 for lane alignment


def _expert_ffn(w, h):
    """h: (E, C, d) -> (E, C, d), per-expert SwiGLU."""
    a = jax.nn.silu(jnp.einsum("ecd,edf->ecf", h, w["w_gate"]))
    b = jnp.einsum("ecd,edf->ecf", h, w["w_in"])
    return jnp.einsum("ecf,efd->ecd", a * b, w["w_out"])


def apply(p, x, cfg: ModelConfig, dispatch: str = "gspmd"):
    """x: (B, S, d). Returns (y, aux_loss)."""
    b, s, d = x.shape
    e, k = cfg.num_experts, cfg.top_k

    mesh = current_mesh()
    if dispatch == "all_to_all" and mesh is not None \
            and "model" in mesh.shape \
            and e % mesh.shape["model"] == 0:
        from repro.distributed.moe_ep import moe_all_to_all
        y, aux = moe_all_to_all(p, x, cfg)
        return y.astype(x.dtype), aux

    y, aux = _dispatch_gspmd(p, x, cfg)
    return y.astype(x.dtype), aux


# --------------------------------------------------------------------- #
# baseline: GShard-style grouped dispatch, GSPMD picks collectives
# --------------------------------------------------------------------- #
def _num_groups(b: int, s: int):
    """Token groups = shard-local slabs: (batch shards) x (seq shards).

    Dispatch positions/capacities are computed per group so the cumsum
    never crosses devices; the (G, E, C, d) buffer is then re-constrained
    from G-sharded to E-sharded, which is where GSPMD inserts the
    dispatch collective (GShard's all-to-all).
    """
    mesh = current_mesh()
    if mesh is None:
        return 1, 1
    dp = 1
    for a in ("pod", "data"):
        if a in mesh.shape:
            dp *= mesh.shape[a]
    nm = mesh.shape.get("model", 1)
    gb = dp if b % dp == 0 else 1
    gs = nm if s % nm == 0 else 1
    return gb, gs


def _positions_in_expert(flat_ids, e: int):
    """Slot of each (token, choice) within its expert's capacity buffer,
    via a (T*k, E) one-hot cumsum -- no (T, E, C) tensor."""
    onehot = jax.nn.one_hot(flat_ids, e, dtype=jnp.int32)   # (T*k, E)
    pos = jnp.cumsum(onehot, axis=0) - 1                    # 0-based slot
    return jnp.take_along_axis(pos, flat_ids[:, None], axis=1)[:, 0]


def _group_dispatch(xt, weights, ids, w, cap: int, e: int, k: int):
    """Per-group capacity dispatch + expert FFN + combine. xt: (T_g, d)."""
    t, d = xt.shape
    flat_ids = ids.reshape(-1)
    pos = _positions_in_expert(flat_ids, e)
    keep = pos < cap
    src = jnp.repeat(jnp.arange(t), k)
    buf = jnp.zeros((e, cap, d), xt.dtype)
    buf = buf.at[flat_ids, jnp.where(keep, pos, 0)].add(
        jnp.where(keep[:, None], xt[src], 0.0), mode="drop")
    return buf, (flat_ids, pos, keep, src)


def _dispatch_gspmd(p, x, cfg: ModelConfig):
    b, s, d = x.shape
    e, k = cfg.num_experts, cfg.top_k
    gb, gs = _num_groups(b, s)
    g = gb * gs
    tg = (b * s) // g
    # (B, S, d) -> (G, T_g, d), shard-local slabs
    xg = x.reshape(gb, b // gb, gs, s // gs, d).transpose(0, 2, 1, 3, 4)
    xg = xg.reshape(g, tg, d)
    xg = constrain(xg, "batch_seq_groups", None, None)

    logits = jnp.einsum("gtd,de->gte", xg,
                        p["router"]).astype(jnp.float32)
    weights, ids = _top_k(logits, k)                        # (G, T_g, k)

    # Switch-style load-balance loss (global)
    probs = jax.nn.softmax(logits, axis=-1)
    occupancy = jnp.zeros((e,), jnp.float32).at[ids.reshape(-1)].add(
        1.0) / (g * tg * k)
    aux = jnp.sum(occupancy * probs.mean(axis=(0, 1))) * e

    cap = _capacity(tg, e, k, cfg.capacity_factor)
    buf, meta = jax.vmap(
        lambda xt, wt, it: _group_dispatch(xt, wt, it, p, cap, e, k)
    )(xg, weights, ids)                                     # (G, E, C, d)
    buf = constrain(buf, "batch_seq_groups", None, None, None)
    # reshard G-major -> E-major: the GShard dispatch collective
    buf = constrain(buf, "moe_groups", "experts", None, None)
    h = jax.nn.silu(jnp.einsum("gecd,edf->gecf", buf, p["w_gate"])) \
        * jnp.einsum("gecd,edf->gecf", buf, p["w_in"])
    out = jnp.einsum("gecf,efd->gecd", h, p["w_out"])
    out = constrain(out, "moe_groups", "experts", None, None)
    # reshard back and combine per group
    out = constrain(out, "batch_seq_groups", None, None, None)

    flat_ids, pos, keep, _ = meta

    def combine(out_g, flat_g, pos_g, keep_g, w_g):
        # gather + reshape-sum: the inverse of the dispatch is a pure
        # gather (slot -> token), so no scatter is needed -- GSPMD
        # implements batched scatters by replicate+all-reduce, which is
        # exactly what this avoids.
        gathered = out_g[flat_g, jnp.where(keep_g, pos_g, 0)]
        gathered = jnp.where(keep_g[:, None], gathered,
                             jnp.zeros((), out_g.dtype))
        gathered = gathered.reshape(tg, k, d)
        w = w_g.astype(out_g.dtype)[:, :, None]
        return jnp.sum(gathered * w, axis=1)

    yg = jax.vmap(combine)(out, flat_ids, pos, keep, weights)
    yg = constrain(yg, "batch_seq_groups", None, None)
    y = yg.reshape(gb, gs, b // gb, s // gs, d).transpose(0, 2, 1, 3, 4)
    return y.reshape(b, s, d), aux

