"""Deterministic synthetic data pipeline.

A seeded Markov-ish token stream (bigram structure so models actually have
something learnable), resumable by step index: batch i is a pure function
of (seed, i), which is what makes checkpoint-restart exact -- no iterator
state needs to be saved beyond the step counter. Prefetch is a background
thread producing the next batch while the step runs.
"""
from __future__ import annotations

import queue
import threading

import numpy as np


class SyntheticTextDataset:
    """Learnable synthetic LM stream: next-token = f(prev) + noise."""

    def __init__(self, vocab_size: int, seq_len: int, global_batch: int,
                 seed: int = 0, noise: float = 0.1):
        self.vocab = vocab_size
        self.seq = seq_len
        self.batch = global_batch
        self.seed = seed
        self.noise = noise
        rng = np.random.default_rng(seed)
        # fixed random bigram successor table
        self._succ = rng.integers(0, vocab_size, size=vocab_size)

    def batch_at(self, step: int) -> dict:
        rng = np.random.default_rng((self.seed, step))
        toks = np.empty((self.batch, self.seq + 1), dtype=np.int32)
        toks[:, 0] = rng.integers(0, self.vocab, size=self.batch)
        for t in range(1, self.seq + 1):
            nxt = self._succ[toks[:, t - 1]]
            noise_mask = rng.random(self.batch) < self.noise
            nxt = np.where(noise_mask,
                           rng.integers(0, self.vocab, size=self.batch),
                           nxt)
            toks[:, t] = nxt
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}


def make_batches(dataset, start_step: int, num_steps: int,
                 prefetch: int = 2):
    """Prefetching iterator over dataset.batch_at(step)."""
    q: queue.Queue = queue.Queue(maxsize=prefetch)
    stop = object()

    def producer():
        for s in range(start_step, start_step + num_steps):
            q.put((s, dataset.batch_at(s)))
        q.put(stop)

    t = threading.Thread(target=producer, daemon=True)
    t.start()
    while True:
        item = q.get()
        if item is stop:
            break
        yield item
