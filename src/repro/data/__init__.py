from repro.data.pipeline import SyntheticTextDataset, make_batches

__all__ = ["SyntheticTextDataset", "make_batches"]
