"""Gradient compression with error feedback (cross-pod DP traffic).

int8 quantization with per-tensor scale and an error-feedback buffer
(residual accumulation), the standard trick for tolerating the lower
cross-pod (DCN) bandwidth at 1000+ node scale:

  q = round(g / s) clipped to int8, s = max|g| / 127
  feedback' = g - q * s        (re-injected into the next step's gradient)

Two integration points:
  * `compress_grads` -- pure pytree stage between jax.grad and the
    optimizer (models the wire format; used by make_train_step via
    `grad_compression=...`).
  * `compressed_psum` -- the explicit wire exchange: inside shard_map over
    the 'pod' axis, gradients are quantized, summed in int32, and
    dequantized with the psum'd scale. Collective payload shrinks 4x vs
    f32 (2x vs bf16); the dry-run's collective-bytes parse shows it.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp


def _quant(g, feedback):
    g32 = g.astype(jnp.float32)
    if feedback is not None:
        g32 = g32 + feedback
    scale = jnp.maximum(jnp.max(jnp.abs(g32)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(g32 / scale), -127, 127).astype(jnp.int8)
    return q, scale, g32


def _dequant(q, scale):
    return q.astype(jnp.float32) * scale


def compress_grads(grads, feedback_tree):
    """Quantize->dequantize each gradient leaf with error feedback.

    Returns (new_grads, new_feedback_tree). Pass as `grad_compression` to
    make_train_step: state['feedback'] threads the residuals.
    """
    leaves, treedef = jax.tree_util.tree_flatten(grads)
    fb_leaves = (jax.tree_util.tree_leaves(feedback_tree)
                 if feedback_tree is not None else [None] * len(leaves))
    new_g, new_fb = [], []
    for g, fb in zip(leaves, fb_leaves):
        q, scale, g32 = _quant(g, fb)
        deq = _dequant(q, scale)
        new_g.append(deq.astype(g.dtype))
        new_fb.append(g32 - deq)
    return (jax.tree_util.tree_unflatten(treedef, new_g),
            jax.tree_util.tree_unflatten(treedef, new_fb))


def init_feedback(params):
    return jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compressed_psum(x, axis_name: str, feedback=None):
    """int8-wire psum over `axis_name` (call inside shard_map).

    Exchanges int8 payload + one f32 scale; sums in int32; dequantizes
    with the max scale across the group. Returns (mean, new_feedback).
    """
    q, scale, g32 = _quant(x, feedback)
    scale_max = jax.lax.pmax(scale, axis_name)
    # requantize against the group scale so the int32 sum is consistent
    q = jnp.clip(jnp.round(g32 / scale_max), -127, 127).astype(jnp.int8)
    total = jax.lax.psum(q.astype(jnp.int32), axis_name)
    n = jax.lax.psum(jnp.ones((), jnp.int32), axis_name)
    mean = total.astype(jnp.float32) * scale_max / n.astype(jnp.float32)
    new_fb = g32 - _dequant(jnp.clip(jnp.round(g32 / scale_max), -127, 127)
                            .astype(jnp.int8), scale_max)
    return mean, new_fb
