"""Fault tolerance: heartbeat monitoring + supervised restart policy.

At 1000+ nodes the dominant failure mode is a host dropping out; the SPMD
step then either hangs (collective timeout) or the runtime raises. The
framework's answer (wired into launch/train.py --supervise):

  * HeartbeatMonitor: the train loop `beat()`s every step from the main
    thread; a watchdog thread flags a stall (hung collective / dead host)
    after `timeout_s` and invokes the registered callback.
  * Supervisor (in launch/train.py): runs the train loop as a subprocess;
    on nonzero exit or watchdog kill, re-launches it with --resume, which
    restores the newest committed checkpoint and (via elastic_mesh) a mesh
    that matches the surviving device set.
  * step_guard: wraps one train step; converts runtime errors into a
    StepFailure carrying the step index so the supervisor log shows where.

Straggler mitigation is structural in SPMD (no per-step stragglers within
a mesh: collectives synchronize); across steps, async checkpointing and
the prefetching data pipeline keep slow I/O off the critical path.
"""
from __future__ import annotations

import dataclasses
import threading
import time


class StepFailure(RuntimeError):
    def __init__(self, step: int, cause: BaseException):
        super().__init__(f"step {step} failed: {cause!r}")
        self.step = step
        self.cause = cause


@dataclasses.dataclass
class HeartbeatMonitor:
    timeout_s: float = 300.0
    on_stall: callable = None
    _last: float = dataclasses.field(default_factory=time.monotonic)
    _stop: bool = False
    _thread: threading.Thread | None = None
    stalled: bool = False

    def beat(self):
        self._last = time.monotonic()

    def start(self):
        def watch():
            while not self._stop:
                time.sleep(min(self.timeout_s / 4, 5.0))
                if time.monotonic() - self._last > self.timeout_s:
                    self.stalled = True
                    if self.on_stall is not None:
                        self.on_stall()
                    return
        self._thread = threading.Thread(target=watch, daemon=True)
        self._thread.start()
        return self

    def stop(self):
        self._stop = True


def step_guard(fn, step: int):
    """Run one step, wrapping failures with their step index."""
    try:
        return fn()
    except Exception as e:                      # noqa: BLE001
        raise StepFailure(step, e) from e
