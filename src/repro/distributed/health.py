"""Fault tolerance: heartbeat monitoring + supervised restart policy.

At 1000+ nodes the dominant failure mode is a host dropping out; the SPMD
step then either hangs (collective timeout) or the runtime raises. The
framework's answer (wired into launch/train.py --supervise):

  * HeartbeatMonitor: the train loop `beat()`s every step from the main
    thread; a watchdog thread flags a stall (hung collective / dead host)
    after `timeout_s` and invokes the registered callback.
  * Supervisor (in launch/train.py): runs the train loop as a subprocess;
    on nonzero exit or watchdog kill, re-launches it with --resume, which
    restores the newest committed checkpoint and (via elastic_mesh) a mesh
    that matches the surviving device set.
  * step_guard: wraps one train step; converts runtime errors into a
    StepFailure carrying the step index so the supervisor log shows where.

Straggler mitigation is structural in SPMD (no per-step stragglers within
a mesh: collectives synchronize); across steps, async checkpointing and
the prefetching data pipeline keep slow I/O off the critical path.
"""
from __future__ import annotations

import dataclasses
import threading
import time


class StepFailure(RuntimeError):
    def __init__(self, step: int, cause: BaseException):
        super().__init__(f"step {step} failed: {cause!r}")
        self.step = step
        self.cause = cause


@dataclasses.dataclass
class HeartbeatMonitor:
    """Watchdog over a loop that `beat()`s every step.

    The watchdog thread flags a stall (no beat for `timeout_s`) exactly
    once per stall episode -- `stalled` latches True, `stall_count`
    increments, `on_stall` fires -- then keeps watching: the next
    `beat()` re-arms it, so a monitor survives any number of stalls
    (the serving layer's injected step-stalls rely on this). `stop()`
    is synchronous: it wakes the watchdog, joins it, and holds the
    state lock while doing so, so no `on_stall` callback can start
    after `stop()` returns.
    """
    timeout_s: float = 300.0
    on_stall: callable = None
    poll_s: float | None = None     # watchdog wake interval (default
                                    # timeout_s/4, capped at 5s)
    stalled: bool = False           # latched until the next beat()
    stall_count: int = 0            # lifetime stall episodes

    def __post_init__(self):
        self._last = time.monotonic()
        self._lock = threading.RLock()
        self._wake = threading.Event()
        self._thread: threading.Thread | None = None

    def beat(self):
        with self._lock:
            self._last = time.monotonic()
            self.stalled = False            # re-arm for the next stall

    def start(self):
        if self._thread is not None and self._thread.is_alive():
            return self
        self._wake.clear()
        poll = self.poll_s if self.poll_s else min(self.timeout_s / 4, 5.0)

        def watch():
            while not self._wake.wait(poll):
                # the callback runs under the lock: stop() also takes
                # it, so shutdown can never race a stall notification
                with self._lock:
                    if self._wake.is_set():
                        return
                    if self.stalled:        # flagged; wait for a beat
                        continue
                    if time.monotonic() - self._last > self.timeout_s:
                        self.stalled = True
                        self.stall_count += 1
                        if self.on_stall is not None:
                            self.on_stall()

        self._thread = threading.Thread(target=watch, daemon=True)
        self._thread.start()
        return self

    def stop(self):
        with self._lock:
            self._wake.set()
        t = self._thread
        if t is not None and t is not threading.current_thread():
            t.join()
        self._thread = None


def step_guard(fn, step: int):
    """Run one step, wrapping failures with their step index."""
    try:
        return fn()
    except Exception as e:                      # noqa: BLE001
        raise StepFailure(step, e) from e
