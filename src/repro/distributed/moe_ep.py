"""Expert-parallel MoE dispatch with explicit all-to-all (shard_map).

The optimized counterpart to the GSPMD scatter/gather baseline in
repro.models.moe: a deterministic collective schedule,

    local top-k -> capacity buffer (E, C, d)
      -> all_to_all over 'model'   (tokens travel to their experts)
      -> per-local-expert SwiGLU   (E_loc, M*C, d)
      -> reverse all_to_all        (results travel home)
      -> weighted combine

This is FLIP's data-centric mode verbatim: data (tokens) routed to
statically-placed compute sites (experts), with the placement compiled by
repro.core.placement to cut traffic. Falls back to the GSPMD path when
num_experts doesn't divide the model-axis size.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro.distributed.sharding import current_mesh


def _capacity(tokens: int, num_experts: int, k: int, factor: float) -> int:
    c = int(np.ceil(tokens * k * factor / num_experts))
    return max(8, -(-c // 8) * 8)


def moe_all_to_all(p, x, cfg, model_axis: str = "model"):
    """x: (B, S, d) with batch over DP axes and seq over `model_axis`.

    Returns (y (B,S,d), aux loss). Requires E % mesh[model_axis] == 0.
    """
    mesh = current_mesh()
    m = mesh.shape[model_axis]
    e, k = cfg.num_experts, cfg.top_k
    assert e % m == 0
    e_loc = e // m
    dp = tuple(a for a in ("pod", "data") if a in mesh.shape)
    dp_spec = dp if len(dp) > 1 else (dp[0] if dp else None)

    def local_fn(wr, wg, wi, wo, x_loc):
        # wr: (d, e) replicated; wg/wi: (e_loc, d, f); wo: (e_loc, f, d)
        b_loc, s_loc, d = x_loc.shape
        t_loc = b_loc * s_loc
        xt = x_loc.reshape(t_loc, d)
        logits = jnp.einsum("td,de->te", xt, wr).astype(jnp.float32)
        vals, ids = jax.lax.top_k(logits, k)
        weights = jax.nn.softmax(vals, axis=-1)

        # aux (switch-style) with psums across the whole mesh
        probs = jax.nn.softmax(logits, axis=-1)
        occ = jnp.zeros((e,), jnp.float32).at[ids.reshape(-1)].add(1.0)
        axes = dp + (model_axis,)
        occ = jax.lax.psum(occ, axes)
        pm = jax.lax.psum(probs.sum(axis=0), axes)
        n_tok = jax.lax.psum(jnp.float32(t_loc), axes)
        aux = jnp.sum((occ / (n_tok * k)) * (pm / n_tok)) * e

        # capacity dispatch (local scatter into (E, C, d))
        cap = _capacity(t_loc, e, k, cfg.capacity_factor)
        flat = ids.reshape(-1)
        onehot = jax.nn.one_hot(flat, e, dtype=jnp.int32)
        pos = (jnp.cumsum(onehot, axis=0) - 1)
        pos = jnp.take_along_axis(pos, flat[:, None], axis=1)[:, 0]
        keep = pos < cap
        src = jnp.repeat(jnp.arange(t_loc), k)
        buf = jnp.zeros((e, cap, d), x_loc.dtype)
        buf = buf.at[flat, jnp.where(keep, pos, 0)].add(
            jnp.where(keep[:, None], xt[src], 0.0), mode="drop")

        # tokens -> expert shards
        recv = jax.lax.all_to_all(buf, model_axis, split_axis=0,
                                  concat_axis=1, tiled=True)
        # recv: (e_loc, M*C, d)
        h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", recv, wg)) \
            * jnp.einsum("ecd,edf->ecf", recv, wi)
        out = jnp.einsum("ecf,efd->ecd", h, wo)
        # results -> home shards
        back = jax.lax.all_to_all(out, model_axis, split_axis=1,
                                  concat_axis=0, tiled=True)
        # back: (e, C, d)
        gathered = back[flat, jnp.where(keep, pos, 0)]
        gathered = jnp.where(keep[:, None], gathered,
                             jnp.zeros((), out.dtype))
        y = jnp.sum(gathered.reshape(t_loc, k, d)
                    * weights.astype(out.dtype)[:, :, None], axis=1)
        return y.reshape(b_loc, s_loc, d), aux

    fn = shard_map(
        local_fn, mesh=mesh,
        in_specs=(P(None, None),                     # router replicated
                  P(model_axis, None, None),         # experts sharded
                  P(model_axis, None, None),
                  P(model_axis, None, None),
                  P(dp_spec, model_axis, None)),     # x: batch x seq-shard
        out_specs=(P(dp_spec, model_axis, None), P()),
        check_rep=False)
    return fn(p["router"], p["w_gate"], p["w_in"], p["w_out"], x)
