"""Logical-axis sharding (MaxText-style) for the whole framework.

Tensors are annotated with *logical* axis names; a rules table maps them to
mesh axes. `constrain` is a no-op when no mesh is active, so the exact same
model code runs on 1 CPU device (smoke tests) and on the 512-chip
production mesh (dry-run / real launch).

Default layout (DESIGN.md Sec. 5):
  batch        -> ("pod", "data")   activations: DP over pods + data rows
  seq          -> "model"           sequence parallelism between blocks
  kv_seq       -> "model"           decode KV caches (flash-decode style)
  long_kv_seq  -> ("data","model")  batch=1 long-context decode caches
  embed        -> "data"            weights: FSDP / ZeRO-3 shard
  heads/mlp/experts/vocab -> "model"  tensor/expert parallelism
A logical axis is silently replicated when the tensor dim is not divisible
by the mesh axis size (e.g. kv_heads=4 on a 16-wide model axis) -- the
fallback keeps every (arch x mesh) cell compilable; the roofline then
shows what the fallback costs.
"""
from __future__ import annotations

import contextlib
import dataclasses
import threading

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class ShardingRules:
    rules: dict

    def mesh_axes(self, logical: str | None):
        if logical is None:
            return None
        return self.rules.get(logical, None)


DEFAULT_RULES = ShardingRules(rules={
    # activations
    "batch": ("pod", "data"),
    "seq": "model",
    "kv_seq": "model",
    "long_kv_seq": ("data", "model"),
    "act_embed": None,
    "act_heads": "model",
    "act_mlp": "model",
    "act_vocab": "model",
    # weights
    "embed": "data",
    "heads": "model",
    "kv_heads": "model",
    "head_dim": None,
    "mlp": "model",
    "experts": "model",
    "expert_mlp": None,
    # MoE grouped dispatch (GShard flow): token groups span all token
    # shards before dispatch, and DP shards only after the (G,E) reshard
    "batch_seq_groups": ("pod", "data", "model"),
    "moe_groups": ("pod", "data"),
    "vocab": "model",
    "layers": None,
    "conv": None,
    "state": None,
    "ssm_inner": "model",
    "ssm_heads": "model",
})


def activation_rules(**overrides) -> ShardingRules:
    r = dict(DEFAULT_RULES.rules)
    r.update(overrides)
    return ShardingRules(rules=r)


# --------------------------------------------------------------------- #
# ambient mesh + rules (thread-local so tests can nest)
# --------------------------------------------------------------------- #
class _Ctx(threading.local):
    def __init__(self):
        self.mesh: Mesh | None = None
        self.rules: ShardingRules = DEFAULT_RULES


_CTX = _Ctx()


@contextlib.contextmanager
def mesh_context(mesh: Mesh | None, rules: ShardingRules | None = None):
    prev = (_CTX.mesh, _CTX.rules)
    _CTX.mesh = mesh
    if rules is not None:
        _CTX.rules = rules
    try:
        if mesh is not None:
            with mesh:
                yield
        else:
            yield
    finally:
        _CTX.mesh, _CTX.rules = prev


def current_mesh() -> Mesh | None:
    return _CTX.mesh


def _axis_ok(mesh: Mesh, dim: int, axes, strict: bool) -> bool:
    """Shardability check; tuples of mesh axes multiply.

    strict=True (jit ARGUMENT shardings: params, caches) requires exact
    divisibility -- pjit rejects uneven argument shardings. strict=False
    (with_sharding_constraint on intermediates) also allows uneven dims
    >= the axis size: GSPMD pads (e.g. phi3's 40 attention-head
    activations over a 16-wide model axis -> 3-per-shard, ~17% waste) --
    vastly better than the 16x memory blowup of replication. Dims smaller
    than the axis (GQA kv heads) replicate either way.
    """
    if axes is None:
        return True
    size = 1
    for a in (axes if isinstance(axes, tuple) else (axes,)):
        size *= mesh.shape[a]
    if dim % size == 0:
        return True
    # non-strict (intermediates): uneven sharding down to 1/4 occupancy --
    # even a 4-wide kv-head dim on a 16-wide axis beats replication: the
    # tensor is never all-gathered, consumers fetch single shards
    return (not strict) and 4 * dim >= size


# parameter-sharding fallbacks: when a tensor dim cannot take its primary
# mesh axis (e.g. 40 heads on a 16-wide axis, strict mode), a secondary
# logical axis of the same tensor may claim it instead (head_dim is a
# multiple of 16 for every assigned arch)
FALLBACK_RULES = {"head_dim": "model", "expert_mlp": "model",
                  "ssm_head_dim": "model"}


def logical_to_pspec(shape, logical_axes, mesh: Mesh | None = None,
                     rules: ShardingRules | None = None,
                     strict: bool = True) -> P:
    """PartitionSpec for a tensor given its logical axes (never fails:
    unshardable dims replicate). See _axis_ok for strict semantics."""
    mesh = mesh or _CTX.mesh
    rules = rules or _CTX.rules
    if mesh is None:
        return P()
    spec = []
    used: set = set()
    for dim, name in zip(shape, logical_axes):
        axes = rules.mesh_axes(name)
        if axes is not None:
            # drop mesh axes absent from this mesh (e.g. "pod" on a
            # single-pod mesh) or already used by another tensor dim
            flat = tuple(a for a in
                         (axes if isinstance(axes, tuple) else (axes,))
                         if a in mesh.shape and a not in used)
            axes = flat if flat else None
            if axes is not None and len(axes) == 1:
                axes = axes[0]
        if axes is not None and not _axis_ok(mesh, dim, axes, strict):
            axes = None
        if axes is not None:
            for a in (axes if isinstance(axes, tuple) else (axes,)):
                used.add(a)
        spec.append(axes)
    # second pass: let fallback axes claim still-unused mesh axes (e.g.
    # shard wq over head_dim when the head count can't take "model")
    for i, (dim, name) in enumerate(zip(shape, logical_axes)):
        if spec[i] is not None:
            continue
        fb = FALLBACK_RULES.get(name)
        if fb and fb in mesh.shape and fb not in used \
                and _axis_ok(mesh, dim, fb, strict):
            spec[i] = fb
            used.add(fb)
    return P(*spec)


def constrain(x, *logical_axes):
    """with_sharding_constraint by logical axes; no-op without a mesh.
    Intermediates may shard unevenly (strict=False)."""
    mesh = _CTX.mesh
    if mesh is None:
        return x
    spec = logical_to_pspec(x.shape, logical_axes, mesh, _CTX.rules,
                            strict=False)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, spec))


def named_sharding(shape, logical_axes, mesh: Mesh | None = None,
                   rules: ShardingRules | None = None):
    mesh = mesh or _CTX.mesh
    assert mesh is not None, "named_sharding requires a mesh"
    return NamedSharding(mesh, logical_to_pspec(shape, logical_axes, mesh,
                                                rules))
