from repro.distributed.sharding import (
    ShardingRules,
    DEFAULT_RULES,
    activation_rules,
    constrain,
    logical_to_pspec,
    mesh_context,
    current_mesh,
)

__all__ = [
    "ShardingRules", "DEFAULT_RULES", "activation_rules", "constrain",
    "logical_to_pspec", "mesh_context", "current_mesh",
]
