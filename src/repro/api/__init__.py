"""repro.api: the unified FLIP query surface.

    import flip                       # or: from repro import api as flip

    prog = flip.Program.get("sssp")   # algebra + numpy oracle, together
    plan = flip.ExecutionPlan(mode="data", tile=128)
    cq = flip.compile(graph, prog, plan)
    result = cq.query([0, 5, 9])      # QueryResult: attrs/steps/plan/...

Everything the fragmented `FlipEngine.run*` surface did -- solo runs,
batched multi-query fixpoints, shard_map distribution, serving-style
bucketed dispatch, streaming updates with incremental recompute -- is
one `compile` + `query` pair driven by a validated `ExecutionPlan`.
The legacy entry points survive as deprecated shims over the same
executor.
"""
from repro.api.plan import (ExecutionPlan, plan_from_cli,
                            resolve_cli_engine)
from repro.api.program import Program
from repro.api.session import CompiledQuery, QueryResult, compile
from repro.core.engine import WarmStart
from repro.obs.telemetry import DispatchTelemetry, QueryTelemetry
from repro.resilience.errors import (BackendFailure, CapacityExceeded,
                                     ConvergenceFailure, DeadlineExceeded,
                                     FlipError, InvalidRequest)

__all__ = [
    "ExecutionPlan", "Program", "CompiledQuery", "QueryResult",
    "WarmStart", "compile", "plan_from_cli", "resolve_cli_engine",
    "QueryTelemetry", "DispatchTelemetry",
    "FlipError", "InvalidRequest", "CapacityExceeded",
    "DeadlineExceeded", "ConvergenceFailure", "BackendFailure",
]
