"""Program: a vertex program as one user-facing object.

Before this existed, adding an algorithm meant two coordinated library
edits: a `VertexAlgebra` entry in `repro/algebra/programs.py` *and* a
numpy oracle branch in `repro/graphs/reference.py`. A `Program` bundles
the two halves -- the algebra that every execution layer runs and the
ground truth it is checked against -- and registers both atomically, so
a new algorithm is one user-side call:

    import flip
    from repro.algebra import Semiring, VertexAlgebra

    @flip.Program.define("minimax", min_max_semiring,
                         weight_rule="graph")
    def minimax_oracle(g, src):        # the decorated fn IS the oracle
        ...
        return best                    # (n,) numpy result

    flip.compile(g, "minimax").query(0).check()   # engine vs oracle

or, with a prebuilt algebra / callable oracle:

    prog = flip.Program.define(algebra=my_algebra, oracle=my_oracle)

`Program.get(name)` wraps an already-registered algorithm, so strings,
`VertexAlgebra`s, and `Program`s are interchangeable everywhere the api
accepts a program.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import numpy as np

from repro.algebra import (ALGEBRAS, Semiring, VertexAlgebra, get_algebra,
                           register_algebra)
from repro.graphs import reference


@dataclasses.dataclass(frozen=True)
class Program:
    """A vertex algebra paired with its numpy ground truth."""

    algebra: VertexAlgebra
    oracle: Callable | None = None   # (graph, src) -> result [, stats]

    @property
    def name(self) -> str:
        return self.algebra.name

    # -------------------------------------------------------------- #
    def reference(self, graph, src: int = 0) -> np.ndarray:
        """The oracle result alone (stats dropped)."""
        if self.oracle is None:
            raise ValueError(
                f"program {self.name!r} has no registered oracle")
        out = self.oracle(graph, src)
        if isinstance(out, tuple):
            out = out[0]
        return np.asarray(out)

    def check(self, graph, src, got) -> bool:
        """Compare an execution result against the oracle at the
        algebra's tolerance (±inf-safe)."""
        return bool(self.algebra.results_match(got,
                                               self.reference(graph, src)))

    # -------------------------------------------------------------- #
    @classmethod
    def get(cls, name: str) -> "Program":
        """Wrap an already-registered algorithm (algebra + oracle)."""
        return cls(get_algebra(name), reference.get_oracle(name))

    @classmethod
    def of(cls, program) -> "Program":
        """Coerce str | VertexAlgebra | Program to a Program. A bare
        VertexAlgebra picks up its registered oracle when one exists."""
        if isinstance(program, Program):
            return program
        if isinstance(program, VertexAlgebra):
            return cls(program, reference.get_oracle(program.name))
        if isinstance(program, str):
            return cls.get(program)
        raise TypeError(
            f"program must be a name, VertexAlgebra, or Program; got "
            f"{type(program).__name__}")

    # -------------------------------------------------------------- #
    @classmethod
    def define(cls, name: str | None = None,
               semiring: Semiring | None = None, *,
               algebra: VertexAlgebra | None = None,
               oracle: Callable | None = None,
               register: bool = True, **algebra_kwargs):
        """Build and register a Program in one call.

        Either pass a prebuilt ``algebra=VertexAlgebra(...)`` or let
        this construct one from ``(name, semiring, **algebra_kwargs)``
        (the `VertexAlgebra` fields: weight_rule, kind, undirected,
        all_start, tol, damping, ...). With ``oracle`` omitted, returns
        a decorator so the oracle function sits directly under the
        definition:

            @Program.define("minimax", MIN_MAX, weight_rule="graph")
            def minimax_oracle(g, src): ...

        Registration is atomic: the algebra lands in `ALGEBRAS` (every
        execution layer) and the oracle in `reference.ORACLES`
        (`reference.run` dispatch, --check paths, tests) together, or --
        with ``register=False`` -- not at all (a local, unregistered
        program still compiles via `flip.compile`).
        """
        if algebra is None:
            if name is None or semiring is None:
                raise TypeError(
                    "Program.define needs either algebra=VertexAlgebra("
                    "...) or (name, semiring, ...) to build one")
            algebra = VertexAlgebra(name, semiring, **algebra_kwargs)
        elif algebra_kwargs or name is not None or semiring is not None:
            raise TypeError(
                "Program.define takes either algebra=... or (name, "
                "semiring, **fields), not both")

        if oracle is None:
            def decorator(fn: Callable) -> "Program":
                return cls.define(algebra=algebra, oracle=fn,
                                  register=register)
            return decorator

        prog = cls(algebra, oracle)
        if register:
            register_algebra(algebra)
            reference.register_oracle(algebra.name, oracle)
        return prog

    def unregister(self) -> None:
        """Remove this program from both registries (test teardown)."""
        ALGEBRAS.pop(self.name, None)
        reference.ORACLES.pop(self.name, None)
