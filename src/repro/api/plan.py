"""ExecutionPlan: every execution knob of a FLIP query in one typed,
validated place.

The engine layers grew one string/bool knob at a time -- fabric `mode`,
kernel `relax_mode`, frontier `compact`ion, tile size, serving batch
size, device mesh, warm-start policy -- spread over `FlipEngine.build`
arguments, per-call parameters, and CLI flags with their own spellings.
An `ExecutionPlan` captures all of them as one frozen dataclass with a
single `resolve()` step that (a) validates every combination up front
(bad combos fail at compile time with one clear error, not deep inside a
jit trace) and (b) collapses every ``"auto"`` to its concrete choice, so
a resolved plan is a complete, reproducible record of how a query ran.

`flip.compile(graph, program, plan)` takes a plan (default:
`ExecutionPlan.auto()`) and attaches the resolved form to every
`QueryResult`.
"""
from __future__ import annotations

import dataclasses
import warnings

import jax

from repro.algebra import VertexAlgebra
from repro.kernels.frontier.ops import resolve_relax_mode

MODES = ("data", "op")
RELAX_MODES = ("auto", "pallas", "interpret", "jnp")
WARM_POLICIES = ("auto", "always", "never")


@dataclasses.dataclass(frozen=True)
class ExecutionPlan:
    """How a compiled query executes. All fields have working defaults;
    ``"auto"`` values are collapsed by `resolve()`.

    mode        -- 'data' (FLIP packet-triggered frontier execution) or
                   'op' (classic-CGRA full sweep per step).
    relax_mode  -- kernel dispatch: 'auto' (Pallas on TPU, jnp
                   elsewhere), 'pallas', 'interpret', or 'jnp'.
    compact     -- frontier-compacted block streaming: True / False /
                   'auto' (= on exactly for data mode). Always exact.
    tile        -- block tile size (vertices per tile).
    batch       -- serving bucket size: 0 runs any source sequence as
                   one fixpoint; B > 0 dispatches fixed-size, padded
                   buckets of B so every dispatch reuses one compiled
                   (B, ntiles, T) executable (the GraphServer policy).
    distributed -- run the shard_map fixpoint (destination tiles
                   sharded over `mesh_axis`, queries replicated).
    mesh        -- jax Mesh for distributed runs (None = all local
                   devices); supplying a mesh implies distributed=True.
    mesh_axis   -- mesh axis name the tiles shard over.
    warm        -- incremental-recompute policy for `query(..., warm=)`:
                   'auto' resumes from the prior result whenever sound
                   (monotone algebra + monotone update delta) and falls
                   back to scratch otherwise; 'always' errors instead of
                   falling back; 'never' forbids warm starts.
    feature_dim -- feature width d of the vertex state: 0 ('auto')
                   adopts the program's native width (1 for the scalar
                   programs, d for vector programs like multi_bfs);
                   d > 1 on a scalar program runs it over d broadcast
                   feature lanes ((n, d) results). A vector program can
                   only run at its native width -- `resolve()` rejects
                   mismatches.
    max_steps   -- fixpoint safety valve.
    deadline_s  -- default per-request wall-clock budget in seconds
                   (None = unbounded). `query(deadline_s=...)` overrides
                   per call; queries it stops come back as flagged
                   partials (`deadline_expired`), never silent
                   truncations. Not supported on distributed plans.
    tuned       -- ask the plan autotuner (`repro.autotune`) to pick
                   the performance knobs (tile / relax_mode / compact /
                   batch) for this (graph, program, backend) at compile
                   time, consulting the tuning store first. Pure
                   policy: a tuned plan is bit-exact with the default.
                   `resolve()` alone leaves the flag in place -- it has
                   no graph to tune against; `flip.compile` is where it
                   collapses.
    """

    mode: str = "data"
    relax_mode: str = "auto"
    compact: bool | str = "auto"
    tile: int = 128
    batch: int = 0
    distributed: bool = False
    mesh: object = None          # jax.sharding.Mesh | None
    mesh_axis: str = "data"
    warm: str = "auto"
    feature_dim: int = 0         # 0 = auto (the program's native width)
    max_steps: int = 100_000
    deadline_s: float | None = None
    tuned: bool = False

    # -------------------------------------------------------------- #
    @classmethod
    def auto(cls, **overrides) -> "ExecutionPlan":
        """The default plan (every knob on 'auto'), with overrides."""
        return cls(**overrides)

    def validate(self, algebra: VertexAlgebra | None = None) -> None:
        """Reject inconsistent knob combinations with one clear error.
        With `algebra`, additionally checks algebra-dependent combos
        (warm='always' needs a monotone algebra)."""
        if self.mode not in MODES:
            raise ValueError(
                f"plan.mode must be one of {MODES}, got {self.mode!r}")
        if self.relax_mode not in RELAX_MODES:
            raise ValueError(
                f"plan.relax_mode must be one of {RELAX_MODES}, got "
                f"{self.relax_mode!r}")
        if self.compact not in (True, False, "auto"):
            raise ValueError(
                "plan.compact must be True, False, or 'auto', got "
                f"{self.compact!r}")
        if self.compact is True and self.mode == "op":
            raise ValueError(
                "plan.compact=True is inconsistent with mode='op': an "
                "op-mode sweep relaxes every block by definition, so "
                "there is nothing to compact -- use mode='data' or "
                "compact='auto'")
        if not isinstance(self.tile, int) or self.tile < 1:
            raise ValueError(f"plan.tile must be a positive int, got "
                             f"{self.tile!r}")
        if not isinstance(self.batch, int) or self.batch < 0:
            raise ValueError(
                f"plan.batch must be an int >= 0 (0 = one fixpoint over "
                f"the whole source sequence), got {self.batch!r}")
        if self.warm not in WARM_POLICIES:
            raise ValueError(
                f"plan.warm must be one of {WARM_POLICIES}, got "
                f"{self.warm!r}")
        if not isinstance(self.feature_dim, int) or self.feature_dim < 0:
            raise ValueError(
                f"plan.feature_dim must be an int >= 0 (0 = the "
                f"program's native width), got {self.feature_dim!r}")
        if algebra is not None and algebra.feature_dim > 1 \
                and self.feature_dim not in (0, algebra.feature_dim):
            raise ValueError(
                f"plan.feature_dim={self.feature_dim} conflicts with "
                f"{algebra.name}'s native feature_dim "
                f"{algebra.feature_dim}; vector programs only run at "
                "their native width (use feature_dim=0 to adopt it)")
        if self.max_steps < 1:
            raise ValueError(
                f"plan.max_steps must be >= 1, got {self.max_steps}")
        if self.deadline_s is not None and not (
                isinstance(self.deadline_s, (int, float))
                and self.deadline_s > 0):
            raise ValueError(
                f"plan.deadline_s must be None or a positive number of "
                f"seconds, got {self.deadline_s!r}")
        if self.deadline_s is not None and (
                self.distributed or self.mesh is not None):
            raise ValueError(
                "plan.deadline_s is not supported on distributed plans: "
                "the shard_map fixpoint has no host-observable step "
                "boundary to enforce it at -- use max_steps")
        if not isinstance(self.tuned, bool):
            raise ValueError(
                f"plan.tuned must be a bool, got {self.tuned!r}")
        if self.tuned and (self.distributed or self.mesh is not None):
            raise ValueError(
                "plan.tuned is not supported on distributed plans: the "
                "tuning sweep measures local run_segment segments, "
                "which say nothing about shard_map dispatch -- tune a "
                "local plan, then add the mesh")
        if algebra is not None and self.warm == "always" \
                and algebra.kind != "monotone":
            raise ValueError(
                f"plan.warm='always' needs a monotone algebra; "
                f"{algebra.name} is {algebra.kind!r} (its fixpoint "
                "cannot resume from a prior result) -- use warm='auto' "
                "or 'never'")

    def resolve(self, algebra: VertexAlgebra | None = None) \
            -> "ExecutionPlan":
        """Validate and collapse every 'auto' to its concrete choice:
        relax_mode picks the backend kernel, compact follows the fabric
        mode, and a supplied mesh implies distributed execution. The
        returned plan is a complete record of how queries will run (and
        resolving it again is the identity)."""
        self.validate(algebra)
        relax = resolve_relax_mode(self.relax_mode)
        if relax == "pallas" and jax.default_backend() != "tpu":
            raise ValueError(
                "plan.relax_mode='pallas' needs a TPU backend, but "
                f"jax.default_backend() is {jax.default_backend()!r}; "
                "use 'interpret' (exact, slow) or 'jnp'")
        compact = (self.mode == "data" if self.compact == "auto"
                   else bool(self.compact))
        d = self.feature_dim
        if d == 0:
            d = algebra.feature_dim if algebra is not None else 1
        plan = dataclasses.replace(
            self, relax_mode=relax, compact=compact, feature_dim=d,
            distributed=bool(self.distributed or self.mesh is not None))
        plan.validate(algebra)
        return plan

    def key(self) -> tuple:
        """Hashable cache key (session caches key on fingerprint+plan).
        The mesh participates by identity: two plans over different mesh
        objects compile different executables."""
        return (self.mode, self.relax_mode, self.compact, self.tile,
                self.batch, self.distributed,
                None if self.mesh is None else id(self.mesh),
                self.mesh_axis, self.warm, self.feature_dim,
                self.max_steps, self.deadline_s, self.tuned)


# ------------------------------------------------------------------ #
# CLI spelling resolution (graph_run and friends)
# ------------------------------------------------------------------ #
def resolve_cli_engine(engine: str, mode: str) -> tuple[str, str]:
    """Collapse deprecated CLI spellings so every option has exactly one
    canonical form. ``--engine op`` is the pre-split spelling of
    ``--engine jax --mode op``: still accepted, warns once (the default
    warning filter deduplicates repeats)."""
    if engine == "op":
        warnings.warn(
            "--engine op is deprecated; use --engine jax --mode op",
            DeprecationWarning, stacklevel=2)
        return "jax", "op"
    return engine, mode


def plan_from_cli(engine: str, mode: str, compact: bool | str = "auto",
                  tile: int = 128, batch: int = 0,
                  feature_dim: int = 0) -> ExecutionPlan:
    """One ExecutionPlan from the graph_run-style CLI surface: folds the
    deprecated ``--engine op`` alias, maps ``--engine dist`` to a
    distributed plan, and threads the remaining knobs through unchanged.
    The 'sim' engine is still not an ExecutionPlan backend -- the cycle
    simulator runs its own mapped-fabric model -- though its cost
    vocabulary does reach plans indirectly, as the analytic bridge of
    the plan autotuner (`repro.autotune.measure`)."""
    engine, mode = resolve_cli_engine(engine, mode)
    if engine not in ("jax", "dist"):
        raise ValueError(
            f"engine {engine!r} has no ExecutionPlan (expected 'jax' or "
            "'dist'; 'sim' runs the cycle-accurate fabric simulator, "
            "which informs plan choice only through the autotuner's "
            "analytic cost bridge, not as an engine backend)")
    return ExecutionPlan(mode=mode, compact=compact, tile=tile,
                         batch=batch, distributed=(engine == "dist"),
                         feature_dim=feature_dim)
