"""The compile-then-query session: `flip.compile(graph, program, plan)`.

One front door replaces the fragmented `FlipEngine.run*` surface:

    import flip

    cq = flip.compile(graph, "sssp")              # CompiledQuery session
    r = cq.query(5)                               # scalar -> (n,) attrs
    rb = cq.query([0, 5, 9])                      # batch  -> (B, n)
    assert r.check()                              # vs the numpy oracle

    cq2, delta = cq.update(edge_batch)            # streaming mutation
    r2 = cq2.query(5, warm=r)                     # incremental recompute

`query` uniformly handles scalar, batched, bucketed (plan.batch > 0),
distributed (plan.distributed), and incremental (warm=) execution --
the plan decides *how*, never *what*: every path returns bit-for-bit
the same attrs. Results come back as a structured `QueryResult` with
the resolved plan, per-query steps, and wall time attached.
"""
from __future__ import annotations

import dataclasses
import time

import numpy as np

from repro.api.plan import ExecutionPlan
from repro.api.program import Program
from repro.core.engine import FlipEngine, WarmStart
from repro.graphs.csr import Graph
from repro.kernels.frontier.ops import UpdateDelta
from repro.obs.telemetry import QueryTelemetry
from repro.resilience.errors import ConvergenceFailure, InvalidRequest


@dataclasses.dataclass
class QueryResult:
    """One query's outcome: attrs in original vertex order ((n,) for a
    scalar source, (B, n) for a batch), per-query relaxation step
    counts (int / (B,) to match), the sources as queried, the resolved
    plan that produced it, and wall seconds. Usable directly as the
    `warm=` argument of a post-update `query` call.

    `compile_s` is the share of `wall_s` attributed to one-time jit
    tracing: the session tracks which dispatch signatures (solo /
    batch-of-B) it has executed before, and the full wall of each
    first-of-its-signature dispatch lands here -- so steady-state
    latency accounting (server histograms, benches) reads
    ``wall_s - compile_s`` and is never polluted by the first query's
    trace cost. `telemetry` is set iff the query ran with ``trace=``:
    per-dispatch, per-step frontier records (see `repro.obs`).

    `converged` (bool, or (B,) to match `srcs`) is the engine's
    per-query convergence mask: False means this query's fixpoint was
    stopped early -- by a `max_steps` / `deadline_s` budget or by the
    session-wide `plan.max_steps` valve -- and its attrs row is a
    flagged partial relaxation, not the fixpoint. `deadline_expired`
    marks which of those stops were the deadline's."""

    attrs: np.ndarray
    steps: int | np.ndarray
    srcs: int | np.ndarray
    plan: ExecutionPlan
    program: Program
    graph: Graph
    wall_s: float = 0.0
    dispatches: int = 1
    compile_s: float = 0.0
    telemetry: QueryTelemetry | None = None
    converged: bool | np.ndarray = True
    deadline_expired: bool | np.ndarray = False

    @property
    def batched(self) -> bool:
        return bool(np.ndim(self.srcs))

    @property
    def all_converged(self) -> bool:
        return bool(np.all(self.converged))

    def check(self) -> bool:
        """Verify every row against the program's numpy oracle at the
        algebra's tolerance. Fails loudly -- raises
        `ConvergenceFailure` -- if any query hit its step/deadline
        budget or `plan.max_steps`: a truncated fixpoint cannot be
        oracle-checked, and silently returning False would let callers
        mistake "not converged" for "wrong answer" (or worse, never
        notice a `max_steps` valve firing)."""
        if not self.all_converged:
            conv = np.atleast_1d(np.asarray(self.converged))
            bad = np.flatnonzero(~conv)
            raise ConvergenceFailure(
                f"cannot oracle-check a non-converged result: "
                f"quer{'y' if bad.size == 1 else 'ies'} "
                f"{bad.tolist()} stopped at "
                f"{np.atleast_1d(np.asarray(self.steps))[bad].tolist()} "
                "steps with a non-empty frontier (step/deadline budget "
                f"or plan.max_steps={self.plan.max_steps} hit)",
                steps=self.steps, max_steps=self.plan.max_steps)
        if not self.batched:
            return self.program.check(self.graph, int(self.srcs),
                                      self.attrs)
        return all(self.program.check(self.graph, int(s), self.attrs[b])
                   for b, s in enumerate(np.asarray(self.srcs)))


@dataclasses.dataclass
class CompiledQuery:
    """A compiled (graph, program, plan) session. Create via
    `flip.compile`; `plan` is already resolved (no 'auto' left)."""

    graph: Graph
    program: Program
    plan: ExecutionPlan
    engine: FlipEngine
    tune: object = None                # TuneReport when compiled with a
                                       # tuned=True plan (why the knobs
                                       # are what they are)
    delta: UpdateDelta | None = None   # set by update(): the last batch
    prev_fp: str | None = None         # fingerprint of the pre-update
                                       # graph the delta resumes from
    # dispatch signatures this session has executed: a signature's first
    # dispatch pays the one-time jit trace, so its wall is attributed to
    # QueryResult.compile_s. Shared across update()-derived sessions
    # (value-only rebuilds keep the compiled executables hot).
    _dispatched: set = dataclasses.field(default_factory=set, repr=False)

    # -------------------------------------------------------------- #
    def query(self, srcs, *, warm=None, trace: bool | int = False,
              max_steps=None, deadline_s=None) -> QueryResult:
        """Run the program from `srcs` under the session's plan.

        srcs  -- one source vertex (scalar result shapes) or a sequence
                 of B independent sources (batched shapes). With
                 plan.batch = B > 0, longer sequences dispatch in padded
                 fixed-size buckets of B (every dispatch reuses one
                 compiled executable -- the serving policy); with
                 plan.batch = 0 the whole sequence is one fixpoint.
                 Sources are range-checked here: an out-of-range id
                 raises `InvalidRequest` naming the bad value instead
                 of poisoning a batch with garbage gather indices.
        warm  -- resume from a prior converged result: a `QueryResult`
                 for the same sources on the pre-update session (the
                 session's last `update` delta decides soundness under
                 plan.warm policy), or an explicit `WarmStart`.
        trace -- per-step frontier tracing (see `repro.obs`): True, or
                 an int row capacity. The result's `telemetry` then
                 holds one `DispatchTelemetry` per engine dispatch.
                 Tracing is exact: attrs and steps are bit-identical to
                 the untraced run.
        max_steps  -- per-request step budget (int, or one per source),
                 clipped to plan.max_steps. A query stopped by it comes
                 back as a partial result with ``converged`` False --
                 never a silent truncation.
        deadline_s -- per-request wall-clock budget in seconds from this
                 call (float, or one per source; default
                 plan.deadline_s), enforced at host-observable fixpoint
                 step boundaries; `deadline_expired` marks queries it
                 stopped. Not supported on distributed plans.

        Every combination returns bit-for-bit the attrs a plain scratch
        scalar run would produce (budget-stopped queries excepted: they
        are flagged partials).
        """
        t0 = time.perf_counter()
        if trace and self.plan.distributed:
            raise ValueError(
                "query(trace=...) is not supported on a distributed "
                "plan yet; trace on a local plan")
        self._validate_srcs(srcs)
        if deadline_s is None:
            deadline_s = self.plan.deadline_s
        batched = bool(np.ndim(srcs))
        b = len(np.atleast_1d(srcs)) if batched else 1
        budgets = self._per_query(max_steps, b, "max_steps",
                                  dtype=np.int64, minimum=1,
                                  none_fill=self.plan.max_steps)
        # deadlines become absolute at the query's start, so a bucketed
        # query's later chunks see the *remaining* budget, not a fresh one
        rel = self._per_query(deadline_s, b, "deadline_s",
                              dtype=np.float64, minimum=0.0,
                              exclusive=True)
        deadline_abs = (None if rel is None
                        else time.monotonic() + np.where(
                            np.isnan(rel), np.inf, rel))
        if batched and b == 0:
            # degenerate empty batch: well-formed empty shapes (the
            # tiled engine state cannot represent B=0)
            d = self.plan.feature_dim
            shape = (0, self.graph.n, d) if d > 1 else (0, self.graph.n)
            return QueryResult(
                attrs=np.zeros(shape, dtype=np.float32),
                steps=np.zeros(0, dtype=np.int32),
                srcs=np.zeros(0, dtype=np.int64), plan=self.plan,
                program=self.program, graph=self.graph,
                wall_s=time.perf_counter() - t0, dispatches=0,
                converged=np.ones(0, dtype=bool),
                deadline_expired=np.zeros(0, dtype=bool),
                telemetry=QueryTelemetry([]) if trace else None)
        ws = self._resolve_warm(warm, srcs)
        teles: list = []
        compile_s = 0.0
        if not batched or self.plan.batch == 0:
            det, wall, first = self._dispatch(srcs, ws, trace, budgets,
                                              deadline_abs)
            out, steps = det.attrs, det.steps
            conv, expired = det.converged, det.deadline_expired
            dispatches = 1
            compile_s = wall if first else 0.0
            if det.telemetry is not None:
                teles.append(det.telemetry)
        else:
            # every batched query pads to fixed-size buckets of
            # plan.batch -- a short sequence too, so each dispatch
            # reuses one (B, ntiles, T) executable regardless of the
            # caller's tail size
            (out, steps, conv, expired, dispatches, teles, compile_s) = \
                self._query_bucketed(
                    np.atleast_1d(np.asarray(srcs, dtype=np.int64)),
                    ws, trace, budgets, deadline_abs)
        wall_s = time.perf_counter() - t0
        telemetry = None
        if trace:
            if self.tune is not None:
                # tuned sessions stamp their provenance on every
                # dispatch record: which knobs the tuner chose and why
                stamp = {
                    "chosen": {"tile": self.plan.tile,
                               "relax_mode": self.plan.relax_mode,
                               "compact": self.plan.compact,
                               "batch": self.plan.batch},
                    "why": self.tune.why,
                    "cached": self.tune.cached,
                    "fingerprint": self.tune.profile.fingerprint(),
                }
                for t in teles:
                    t.meta["autotune"] = stamp
            telemetry = QueryTelemetry(dispatches=teles, wall_s=wall_s,
                                       compile_s=compile_s)
        return QueryResult(attrs=out, steps=steps,
                           srcs=(np.asarray(srcs) if batched
                                 else int(srcs)),
                           plan=self.plan, program=self.program,
                           graph=self.graph, wall_s=wall_s,
                           dispatches=dispatches, compile_s=compile_s,
                           converged=conv, deadline_expired=expired,
                           telemetry=telemetry)

    def validate_sources(self, srcs) -> None:
        """Public admission-edge check: raise `InvalidRequest` unless
        every id in `srcs` is a vertex of this session's graph. The
        serving layer validates at submit time -- before a request is
        queued -- with exactly the check `query` would apply, so a
        malformed request fails synchronously instead of poisoning a
        rotating batch later."""
        self._validate_srcs(srcs)

    def _validate_srcs(self, srcs) -> None:
        """Source range check: every id must be a vertex of this graph.
        Rejecting here -- with the bad value named -- beats the
        alternatives: a negative id silently gathers from the end of
        the attr arrays (garbage results), an id >= n raises an opaque
        index error deep inside a jit trace."""
        a = np.atleast_1d(np.asarray(srcs))
        if a.size == 0:
            return
        if not np.issubdtype(a.dtype, np.integer):
            cast = a.astype(np.int64, casting="unsafe")
            if not np.array_equal(cast, a):
                raise InvalidRequest(
                    f"sources must be integer vertex ids, got dtype "
                    f"{a.dtype}", value=srcs)
            a = cast
        bad = (a < 0) | (a >= self.graph.n)
        if bad.any():
            v = int(a[bad][0])
            raise InvalidRequest(
                f"source {v} is out of range for this graph "
                f"(|V| = {self.graph.n}; valid ids are 0.."
                f"{self.graph.n - 1})", value=v)

    @staticmethod
    def _per_query(val, b: int, name: str, dtype, minimum,
                   exclusive: bool = False, none_fill=np.nan):
        """Broadcast a scalar-or-per-source budget to (b,), validating
        type and range. None entries mean "this query takes the
        default" and become `none_fill` (NaN -> no deadline for floats,
        plan.max_steps for step budgets)."""
        if val is None:
            return None
        arr = np.atleast_1d(np.asarray(
            [none_fill if v is None else v for v in np.atleast_1d(val)]))
        raw = arr
        try:
            arr = arr.astype(dtype)
        except (TypeError, ValueError):
            raise InvalidRequest(
                f"{name} must be numeric, got {val!r}", value=val)
        if np.issubdtype(dtype, np.integer) and not np.array_equal(
                arr.astype(np.float64), raw.astype(np.float64)):
            raise InvalidRequest(
                f"{name} must be whole numbers, got {val!r}", value=val)
        if arr.shape not in ((1,), (b,)):
            raise InvalidRequest(
                f"{name} has {arr.shape[0]} entries for {b} sources "
                "(pass a scalar or one per source)", value=val)
        finite = arr[~np.isnan(arr.astype(np.float64))]
        low = (finite <= minimum) if exclusive else (finite < minimum)
        if low.any():
            raise InvalidRequest(
                f"{name} must be {'>' if exclusive else '>='} "
                f"{minimum}, got {finite[low][0]}", value=val)
        return np.broadcast_to(arr, (b,))

    def _dispatch(self, srcs, ws, trace, budgets=None, deadline_abs=None):
        """One engine dispatch with compile-time attribution: returns
        ``(ExecutionDetail, wall_s, first)`` where `first` marks the
        first dispatch of this signature (its wall includes the
        one-time jit trace)."""
        # tracing rides extra stat buffers through the fixpoint carry,
        # so traced and untraced runs are distinct executables
        sig = ("solo" if not np.ndim(srcs) else len(srcs),
               self.plan.distributed, bool(trace))
        first = sig not in self._dispatched
        remaining = (None if deadline_abs is None
                     else np.asarray(deadline_abs) - time.monotonic())
        t0 = time.perf_counter()
        det = self.engine.execute(
            srcs, warm=ws, distributed=self.plan.distributed,
            mesh=self.plan.mesh, axis=self.plan.mesh_axis, trace=trace,
            max_steps=budgets, deadline_s=remaining, detail=True)
        wall = time.perf_counter() - t0
        self._dispatched.add(sig)
        if det.telemetry is not None:
            det.telemetry.wall_s = wall
        return det, wall, first

    def _query_bucketed(self, srcs, ws, trace, budgets=None,
                        deadline_abs=None):
        """plan.batch-sized dispatch: pad the tail bucket by repeating
        its last source (budgets and deadlines pad along with it) so
        every dispatch shares one (B, ntiles, T) executable, then drop
        the padded rows."""
        nb = self.plan.batch
        outs, steps, convs, exps = [], [], [], []
        dispatches, teles = 0, []
        compile_s = 0.0

        def pad(arr, i, k):
            if arr is None:
                return None
            chunk = np.asarray(arr)[i:i + k]
            return np.concatenate(
                [chunk, np.repeat(chunk[-1:], nb - k)])

        for i in range(0, len(srcs), nb):
            chunk = srcs[i:i + nb]
            k = len(chunk)
            padded = np.concatenate(
                [chunk, np.repeat(chunk[-1:], nb - k)])
            w = self._slice_warm(ws, i, k, nb)
            det, wall, first = self._dispatch(
                padded, w, trace, pad(budgets, i, k),
                pad(deadline_abs, i, k))
            if first:
                compile_s += wall
            if det.telemetry is not None:
                teles.append(det.telemetry)
            outs.append(det.attrs[:k])
            steps.append(det.steps[:k])
            convs.append(np.atleast_1d(det.converged)[:k])
            exps.append(np.atleast_1d(det.deadline_expired)[:k])
            dispatches += 1
        return (np.concatenate(outs), np.concatenate(steps),
                np.concatenate(convs), np.concatenate(exps),
                dispatches, teles, compile_s)

    def _slice_warm(self, ws, i, k, nb):
        """Per-bucket view of a warm start: batch-shared warm attrs
        ((n,), or (n, d) at feature_dim d > 1) broadcast to every
        bucket; per-query warm attrs ((B, n) / (B, n, d)) follow their
        queries (padded by repeating the chunk's last row, mirroring the
        source padding)."""
        shared_ndim = 2 if self.plan.feature_dim > 1 else 1
        if ws is None or np.ndim(ws.attrs) == shared_ndim:
            return ws
        rows = ws.attrs[i:i + k]
        rows = np.concatenate(
            [rows, np.repeat(rows[-1:], nb - k, axis=0)])
        return WarmStart(attrs=rows, seeds=ws.seeds)

    def _resolve_warm(self, warm, srcs) -> WarmStart | None:
        """Apply the plan's warm policy to the caller's `warm`."""
        if warm is None:
            return None
        if self.plan.warm == "never":
            raise ValueError(
                "this session's plan has warm='never'; query(warm=...) "
                "is forbidden -- recompute from scratch or compile with "
                "warm='auto'")
        if isinstance(warm, WarmStart):
            return warm
        if isinstance(warm, QueryResult):
            qs = np.atleast_1d(np.asarray(srcs, dtype=np.int64))
            wsrc = np.atleast_1d(np.asarray(warm.srcs, dtype=np.int64))
            # a converged result only resumes *its own* sources: a
            # scalar-source result may fan out over a batch of that
            # same source, anything else would converge to the wrong
            # query's fixpoint
            if not ((wsrc.shape == qs.shape and np.array_equal(wsrc, qs))
                    or (wsrc.size == 1 and bool(np.all(qs == wsrc[0])))):
                raise ValueError(
                    f"warm result was computed for sources "
                    f"{wsrc.tolist()} but this query asks for "
                    f"{qs.tolist()}; a warm start only resumes the "
                    "same sources")
            if self.delta is None:
                raise ValueError(
                    "query(warm=QueryResult) resumes across an update: "
                    "this session has no update delta (create it with "
                    "session.update(...)); pass an explicit WarmStart "
                    "to resume from arbitrary state")
            attrs = np.asarray(warm.attrs)
            batched_ndim = 3 if self.plan.feature_dim > 1 else 2
            if wsrc.size == 1 and attrs.ndim == batched_ndim \
                    and qs.shape != wsrc.shape:
                # single-source fan-out: a (1, n[, d]) batched result
                # broadcasts over the batch exactly like a scalar one
                attrs = attrs[0]
            if warm.graph.fingerprint() != self.prev_fp:
                # the delta's seeds only cover the *last* batch: a warm
                # result from an older (or unrelated) graph version
                # would silently miss earlier updates' improvements
                raise ValueError(
                    "warm result was not computed on this session's "
                    "pre-update graph version; re-query each version "
                    "(warm results are valid across exactly one "
                    "update), or pass an explicit WarmStart")
            ws = self.engine.resolve_warm(attrs, self.delta)
            if ws is None and self.plan.warm == "always":
                raise ValueError(
                    f"plan.warm='always' but the last update batch is "
                    f"not monotone under {self.program.name}'s ⊕ (or "
                    "the algebra is not monotone): incremental "
                    "recompute would be unsound")
            return ws
        raise TypeError(
            f"warm must be a QueryResult or WarmStart, got "
            f"{type(warm).__name__}")

    # -------------------------------------------------------------- #
    def update(self, updates, new_graph: Graph | None = None) \
            -> tuple["CompiledQuery", UpdateDelta]:
        """Streaming graph mutation: apply one edge-update batch and
        return ``(new_session, delta)``. The new session re-blocks only
        the touched tiles (value-only rebuilds keep every compiled
        executable hot) and remembers `delta`, so a subsequent
        ``query(src, warm=prev_result)`` resumes incrementally exactly
        when sound. This session is left untouched -- sessions are
        immutable snapshots of one graph version."""
        updates = list(updates)      # consumed twice (graph + engine)
        g2 = (self.graph.apply_updates(updates) if new_graph is None
              else new_graph)
        eng2, delta = self.engine.apply_updates(g2, updates)
        return dataclasses.replace(
            self, graph=g2, engine=eng2, delta=delta,
            prev_fp=self.graph.fingerprint()), delta


# ------------------------------------------------------------------ #
# the front door
# ------------------------------------------------------------------ #
def compile(graph: Graph, program, plan: ExecutionPlan | None = None, *,
            mapping=None, store=None) -> CompiledQuery:
    """Compile a (graph, program, plan) triple into a query session.

    graph   -- a `repro.graphs.csr.Graph`.
    program -- a registered algorithm name ('bfs', 'sssp', ...), a
               `VertexAlgebra`, or a `Program`.
    plan    -- an `ExecutionPlan` (default `ExecutionPlan.auto()`);
               validated and resolved here, so every knob conflict
               fails at compile time. With ``plan.tuned`` set (e.g.
               `ExecutionPlan.auto(tuned=True)`), the plan autotuner
               picks the performance knobs for this (graph, program,
               backend) -- consulting the tuning store first, so
               repeat compiles of the same shape are instant -- and
               the session's `tune` holds the `TuneReport`. Tuning is
               policy only: results stay bit-exact with the default.
    mapping -- optional FLIP `Mapping`: the placement-induced vertex
               ordering becomes block sparsity, exactly as in
               `FlipEngine.build`.
    store   -- optional `repro.autotune.TuningStore` for tuned plans
               (default: the `FLIP_AUTOTUNE_DB` / user-cache store).

    Returns a `CompiledQuery` whose `.query(srcs, warm=...)` covers
    scalar, batched, bucketed, distributed, and incremental execution
    under the one resolved plan.
    """
    prog = Program.of(program)
    plan = plan if plan is not None else ExecutionPlan()
    tune = None
    if plan.tuned:
        plan.validate(prog.algebra)
        from repro.autotune import resolve_tuned
        rplan, tune = resolve_tuned(graph, prog, plan, store=store)
    else:
        rplan = plan.resolve(prog.algebra)
    engine = FlipEngine.build(graph, prog.algebra, mapping=mapping,
                              tile=rplan.tile, mode=rplan.mode,
                              relax_mode=rplan.relax_mode,
                              compact=rplan.compact,
                              feature_dim=rplan.feature_dim)
    engine = dataclasses.replace(engine, max_steps=rplan.max_steps)
    return CompiledQuery(graph=graph, program=prog, plan=rplan,
                         engine=engine, tune=tune)
