"""Hand-rolled AdamW + cosine schedule (no external optimizer deps).

Moments are stored in a configurable dtype: float32 by default; bfloat16
halves optimizer memory for the 200B+ configs (a documented
distributed-optimization trade-off -- the dry-run memory analysis shows
the difference). Moment shardings mirror the parameter shardings, giving
ZeRO-3-equivalent placement for free.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr_peak: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    lr_min_ratio: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.01
    clip_norm: float = 1.0
    moment_dtype: str = "float32"     # or "bfloat16" for big models


def cosine_schedule(step, cfg: AdamWConfig):
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
                    0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    scale = cfg.lr_min_ratio + (1 - cfg.lr_min_ratio) * cos
    return cfg.lr_peak * warm * scale


def _mdtype(cfg: AdamWConfig):
    return jnp.bfloat16 if cfg.moment_dtype == "bfloat16" else jnp.float32


def init_opt_state(params, cfg: AdamWConfig):
    md = _mdtype(cfg)
    zeros = lambda p: jnp.zeros(p.shape, md)
    return {
        "mu": jax.tree_util.tree_map(zeros, params),
        "nu": jax.tree_util.tree_map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def abstract_opt_state(abstract_params, cfg: AdamWConfig):
    md = _mdtype(cfg)
    z = lambda p: jax.ShapeDtypeStruct(p.shape, md)
    return {
        "mu": jax.tree_util.tree_map(z, abstract_params),
        "nu": jax.tree_util.tree_map(z, abstract_params),
        "step": jax.ShapeDtypeStruct((), jnp.int32),
    }


def global_norm(tree):
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in leaves))


def adamw_update(grads, opt_state, params, cfg: AdamWConfig):
    """Returns (new_params, new_opt_state, stats)."""
    step = opt_state["step"] + 1
    lr = cosine_schedule(step, cfg)
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
    md = _mdtype(cfg)
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, g, mu, nu):
        g = g.astype(jnp.float32) * scale
        mu32 = mu.astype(jnp.float32) * b1 + (1 - b1) * g
        nu32 = nu.astype(jnp.float32) * b2 + (1 - b2) * jnp.square(g)
        mhat = mu32 / bc1
        vhat = nu32 / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) \
            + cfg.weight_decay * p.astype(jnp.float32)
        newp = (p.astype(jnp.float32) - lr * delta).astype(p.dtype)
        return newp, mu32.astype(md), nu32.astype(md)

    flat_p, tdef = jax.tree_util.tree_flatten(params)
    flat_g = jax.tree_util.tree_leaves(grads)
    flat_mu = jax.tree_util.tree_leaves(opt_state["mu"])
    flat_nu = jax.tree_util.tree_leaves(opt_state["nu"])
    out = [upd(p, g, m, n)
           for p, g, m, n in zip(flat_p, flat_g, flat_mu, flat_nu)]
    new_p = jax.tree_util.tree_unflatten(tdef, [o[0] for o in out])
    new_mu = jax.tree_util.tree_unflatten(tdef, [o[1] for o in out])
    new_nu = jax.tree_util.tree_unflatten(tdef, [o[2] for o in out])
    stats = {"lr": lr, "grad_norm": gnorm}
    return new_p, {"mu": new_mu, "nu": new_nu, "step": step}, stats
