"""GraphProfile: the cheap runtime-shape summary the plan autotuner
keys on.

Flip's win is matching the execution configuration to the *runtime*
shape of the data -- frontier density trajectory, degree profile,
feature width -- not just |V| and |E|. A `GraphProfile` captures that
shape from a few capped numpy probe steps (no device work, no jit):

  * size:       n, m;
  * degrees:    log2-bucketed out-degree histogram (hub-dominated
                power-law graphs and flat road networks land in
                visibly different buckets);
  * trajectory: estimated frontier-density per step from a capped
                BFS-style reachability probe -- the fraction of
                vertices newly activated each step, which is exactly
                what decides whether compaction pays and how fast the
                fixpoint densifies;
  * execution:  feature width d, JAX backend and device kind (a tuning
                result measured on CPU must never be served to a TPU
                session).

`fingerprint()` is a stable content hash of all of the above: two
sessions over the same graph shape on the same backend share one
tuning-store entry, and any change -- a mutation batch, a different d,
a different device -- changes the fingerprint, so stale entries are
structurally unreachable (see `repro.autotune.store`).
"""
from __future__ import annotations

import dataclasses
import hashlib

import numpy as np

from repro.graphs.csr import Graph

# probe caps: the profile must stay O(m) numpy work no matter the graph
PROBE_STEPS = 12           # frontier-expansion steps recorded
DEGREE_BUCKETS = 16        # log2 out-degree histogram buckets
SCHEMA = 1                 # bumped when the profile features change


@dataclasses.dataclass(frozen=True)
class GraphProfile:
    """Immutable runtime-shape summary of one (graph, d, backend)."""

    n: int
    m: int
    degree_hist: tuple          # (DEGREE_BUCKETS,) log2 out-deg counts
    density_trajectory: tuple   # per-probe-step newly-active fraction
    feature_dim: int
    backend: str                # jax.default_backend() at profile time
    device_kind: str

    # -------------------------------------------------------------- #
    @property
    def mean_density(self) -> float:
        """Mean per-step frontier density over the probe trajectory --
        the single scalar the analytic cost model leans on hardest."""
        t = self.density_trajectory
        return float(np.mean(t)) if t else 1.0

    @property
    def peak_density(self) -> float:
        t = self.density_trajectory
        return float(np.max(t)) if t else 1.0

    def fingerprint(self) -> str:
        """Stable content hash: the tuning-store key for this shape."""
        h = hashlib.blake2b(digest_size=16)
        h.update(f"{SCHEMA}|{self.n}|{self.m}|{self.feature_dim}|"
                 f"{self.backend}|{self.device_kind}".encode())
        h.update(np.asarray(self.degree_hist, dtype=np.int64).tobytes())
        # round so float noise can never fork the key
        h.update(np.round(np.asarray(self.density_trajectory,
                                     dtype=np.float64), 4).tobytes())
        return h.hexdigest()

    def to_json(self) -> dict:
        return {
            "schema": SCHEMA, "n": self.n, "m": self.m,
            "degree_hist": list(self.degree_hist),
            "density_trajectory": [round(float(x), 6)
                                   for x in self.density_trajectory],
            "feature_dim": self.feature_dim, "backend": self.backend,
            "device_kind": self.device_kind,
            "fingerprint": self.fingerprint(),
        }


def _probe_trajectory(graph: Graph, steps: int = PROBE_STEPS,
                      src: int | None = None) -> tuple:
    """Frontier-density trajectory from a capped reachability probe.

    Pure numpy BFS-style expansion from a deterministic source (the
    max-out-degree vertex: the hub is where serving traffic lands on a
    power-law graph, and any fixed rule keeps the profile -- and the
    fingerprint -- reproducible): per step, the fraction of vertices
    *newly* activated. Stops early when the frontier dies. This is the
    shape of the real fixpoint's activity, at O(m) total cost.
    """
    n = graph.n
    if n == 0:
        return ()
    if src is None:
        src = int(np.argmax(graph.out_degree()))
    indptr = np.asarray(graph.indptr, dtype=np.int64)
    indices = np.asarray(graph.indices, dtype=np.int64)
    starts = np.repeat(np.arange(n, dtype=np.int64), np.diff(indptr))
    visited = np.zeros(n, dtype=bool)
    frontier = np.zeros(n, dtype=bool)
    visited[src] = frontier[src] = True
    traj = [1.0 / n]
    for _ in range(steps - 1):
        # successors of the frontier, via the flat CSR expansion
        sel = frontier[starts]
        nxt = np.zeros(n, dtype=bool)
        nxt[indices[sel]] = True
        nxt &= ~visited
        if not nxt.any():
            break
        visited |= nxt
        frontier = nxt
        traj.append(float(nxt.sum()) / n)
    return tuple(traj)


def profile_graph(graph: Graph, *, feature_dim: int = 1,
                  backend: str | None = None,
                  device_kind: str | None = None,
                  probe_steps: int = PROBE_STEPS) -> GraphProfile:
    """Profile one graph for the autotuner (see module doc). Backend
    and device kind default to the live JAX runtime; pass them
    explicitly to profile *for* a target (or in tests)."""
    if backend is None or device_kind is None:
        import jax
        backend = backend or jax.default_backend()
        if device_kind is None:
            try:
                device_kind = jax.devices()[0].device_kind
            except Exception:
                device_kind = backend
    deg = graph.out_degree()
    buckets = np.minimum(
        np.log2(np.maximum(deg, 1)).astype(np.int64),
        DEGREE_BUCKETS - 1)
    hist = np.bincount(buckets, minlength=DEGREE_BUCKETS)
    return GraphProfile(
        n=int(graph.n), m=int(graph.m),
        degree_hist=tuple(int(x) for x in hist),
        density_trajectory=_probe_trajectory(graph, steps=probe_steps),
        feature_dim=int(feature_dim), backend=str(backend),
        device_kind=str(device_kind))
