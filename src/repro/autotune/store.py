"""TuningStore: the JSON database tuning amortizes through.

A tune is worth its cost exactly once per shape: the store keys chosen
plans on ``(profile fingerprint, algebra, backend)`` so every later
`flip.compile(..., ExecutionPlan.auto(tuned=True))` over the same graph
shape resolves instantly from disk -- across sessions, across
processes, across days.

Safety rules, all load-bearing:

  * **Stale entries are rejected, never served.** Every entry records
    the profile fingerprint and a schema version; `get` re-checks both
    (plus the algebra/backend of the key) and treats any mismatch as a
    miss. A graph mutation changes the fingerprint, so a post-update
    session can never inherit the pre-update tuning by accident.
  * **A broken store is an empty store.** Corrupt JSON, a partial
    write, a foreign file at the path -- all degrade to "no entries";
    tuning re-runs and the next `put` rewrites cleanly. The store must
    never be the thing that fails a query.
  * **Writes are atomic** (tmp + `os.replace`), so a crash mid-put
    leaves the previous generation intact.

The default path is ``$FLIP_AUTOTUNE_DB`` when set (CI and tests pin it
into their sandboxes), else ``~/.cache/flip/autotune.json``.
"""
from __future__ import annotations

import json
import os
import time

SCHEMA = 1


def default_store_path() -> str:
    env = os.environ.get("FLIP_AUTOTUNE_DB")
    if env:
        return env
    return os.path.join(os.path.expanduser("~"), ".cache", "flip",
                        "autotune.json")


class TuningStore:
    """Append/overwrite JSON map of tuning entries (see module doc)."""

    def __init__(self, path: str | None = None):
        self.path = path or default_store_path()

    # -------------------------------------------------------------- #
    @staticmethod
    def key(profile_fp: str, algebra: str, backend: str) -> str:
        return f"{profile_fp}|{algebra}|{backend}"

    def _load(self) -> dict:
        try:
            with open(self.path) as f:
                data = json.load(f)
        except (OSError, json.JSONDecodeError, ValueError):
            return {}
        entries = data.get("entries") if isinstance(data, dict) else None
        return entries if isinstance(entries, dict) else {}

    def get(self, profile_fp: str, algebra: str,
            backend: str) -> dict | None:
        """The stored entry for this exact (shape, algebra, backend),
        or None -- where None covers missing, corrupt, schema-drifted,
        and stale-fingerprint entries alike (they all mean re-tune)."""
        e = self._load().get(self.key(profile_fp, algebra, backend))
        if not isinstance(e, dict):
            return None
        if (e.get("schema") != SCHEMA
                or e.get("profile_fp") != profile_fp
                or e.get("algebra") != algebra
                or e.get("backend") != backend
                or not isinstance(e.get("plan"), dict)):
            return None
        return e

    def put(self, profile_fp: str, algebra: str, backend: str,
            plan_knobs: dict, *, score_us: float, seed: int,
            samples: list | None = None,
            profile_json: dict | None = None, why: str = "") -> dict:
        """Record one tuning outcome; returns the stored entry."""
        entry = {
            "schema": SCHEMA,
            "profile_fp": profile_fp,
            "algebra": algebra,
            "backend": backend,
            "plan": dict(plan_knobs),
            "score_us": round(float(score_us), 4),
            "seed": int(seed),
            "why": why,
            "created": time.strftime("%Y-%m-%dT%H:%M:%S"),
        }
        if samples is not None:
            entry["samples"] = samples
        if profile_json is not None:
            entry["profile"] = profile_json
        entries = self._load()
        entries[self.key(profile_fp, algebra, backend)] = entry
        d = os.path.dirname(os.path.abspath(self.path))
        os.makedirs(d, exist_ok=True)
        tmp = self.path + ".tmp"
        with open(tmp, "w") as f:
            json.dump({"schema": SCHEMA, "entries": entries}, f,
                      indent=1, sort_keys=True)
        os.replace(tmp, self.path)
        return entry

    def __len__(self) -> int:
        return len(self._load())
