"""Candidate pricing: measured fixpoint segments, analytic fallback.

Two pricing paths, one currency (microseconds per relaxation step per
query):

  * **measured** -- the ground truth. A candidate plan is built into a
    real `FlipEngine` and driven through the engine's bounded-segment
    surface (`run_segment`, the same yield hook the continuous-batching
    scheduler uses): a few deterministic probe sources, a capped step
    budget, best-of-`repeats` wall time. Segments mean a tune never
    pays for a full fixpoint per candidate, and because segmenting is
    exact (PR 9's bit-exactness contract) the measured steps are the
    real steps the plan would execute.

  * **analytic** -- the cycle-simulator bridge, for candidates too
    expensive to run (interpret-mode kernels, or sweeps over graphs
    where even a segment blows the tuning budget). The estimate reuses
    the seed cost vocabulary of `core/sim.py` / `core/mapping.py`'s
    `RuntimeEstimator`: per-delivery cost `t_tab + exe` cycles, work
    proportional to the streamed block volume, converted at the arch
    clock -- the same Algorithm-2 shape ("transfer + per-sibling
    processing"), applied per step instead of per edge pair. Absolute
    scale is calibrated only roughly; what the tuner needs from this
    path is *ordinal* honesty (dense > compacted at sparse frontiers,
    interpret >> jnp, cost grows with streamed volume), and that is
    structural.

Every sample records which path priced it (`source`), so a tuning
report can always say *why* a knob won.
"""
from __future__ import annotations

import dataclasses
import math
import time

import numpy as np

from repro.api.plan import ExecutionPlan
from repro.api.program import Program
from repro.autotune.profile import GraphProfile
from repro.core.arch import DEFAULT_ARCH, FlipArch
from repro.core.engine import FlipEngine
from repro.graphs.csr import Graph

# measurement defaults: a handful of sources x a short exact segment
PROBE_SOURCES = 4
SEGMENT_STEPS = 8
REPEATS = 3

# analytic-bridge constants (see module doc): the default instruction
# cycles per update-carrying vertex execution (paper Sec. 3: 4/5/5 with
# an attribute update -- each registered algebra carries its own
# `exe_update`, which `price_candidate` threads through) and the
# relative throughput of the kernel backends on one step's identical
# math. interpret executes the Pallas kernel body element-by-element
# under the interpreter -- three orders of magnitude off jnp is
# conservative in its favor.
EXE_UPDATE_CYCLES = 5
BACKEND_FACTOR = {"pallas": 0.25, "jnp": 1.0, "interpret": 1000.0}
MAC_PER_CYCLE = 64.0          # vectorized lanes per clock, jnp baseline
STEP_FIXED_CYCLES = 2_000.0   # per-step dispatch overhead


@dataclasses.dataclass(frozen=True)
class Sample:
    """One priced candidate: the tuner's unit of evidence.

    `features` optionally pins the cost-model regressor vector the
    sample was observed under -- bench-history rows come from *other*
    graphs, so their regressors cannot be recomputed from the current
    profile (see `repro.autotune.model`)."""
    plan: ExecutionPlan
    step_us: float          # microseconds per relaxation step per query
    steps: int              # steps actually executed (measured path)
    wall_s: float           # total harness wall (measured path)
    source: str             # 'measured' | 'analytic'
    features: tuple | None = None   # (1, blocks, volume) when pinned

    def to_json(self) -> dict:
        p = self.plan
        return {"tile": p.tile, "relax_mode": p.relax_mode,
                "compact": bool(p.compact), "batch": p.batch,
                "mode": p.mode, "step_us": round(self.step_us, 3),
                "steps": self.steps, "wall_s": round(self.wall_s, 6),
                "source": self.source}


def probe_sources(graph: Graph, seed: int,
                  count: int = PROBE_SOURCES) -> np.ndarray:
    """Deterministic probe sources: seeded draws without replacement
    (the whole tune is a pure function of (graph, plan space, seed))."""
    rng = np.random.default_rng(seed)
    count = max(1, min(count, graph.n))
    return np.sort(rng.choice(graph.n, size=count, replace=False)
                   .astype(np.int64))


def measure_plan(graph: Graph, program, plan: ExecutionPlan, *,
                 seed: int = 0, sources: int = PROBE_SOURCES,
                 segment_steps: int = SEGMENT_STEPS,
                 repeats: int = REPEATS) -> Sample:
    """Price one resolved plan by running real capped segments.

    Builds the candidate's engine directly (never through
    `flip.compile`, which could re-enter the tuner) and times
    `run_segment` over the probe batch: one untimed segment warms the
    executable, then each timed repeat re-enters from a fresh initial
    state so every repeat measures the same steps. Best-of-repeats
    guards against scheduler noise; the per-step normalization divides
    by the steps the engine actually took (a probe that converges
    early is priced on its real work, not its budget)."""
    prog = Program.of(program)
    eng = FlipEngine.build(
        graph, prog.algebra, tile=plan.tile, mode=plan.mode,
        relax_mode=plan.relax_mode, compact=plan.compact,
        feature_dim=plan.feature_dim)
    srcs = probe_sources(graph, seed, sources)
    budgets = np.full(len(srcs), segment_steps, dtype=np.int32)
    state0 = eng.initial_state(srcs)
    eng.run_segment(state0, budgets)            # warm the executable
    best, steps_total = math.inf, 1
    for _ in range(max(1, repeats)):
        state = eng.initial_state(srcs)
        t0 = time.perf_counter()
        _, steps, _ = eng.run_segment(state, budgets)
        wall = time.perf_counter() - t0
        if wall < best:
            best = wall
            steps_total = max(1, int(np.sum(steps)))
    return Sample(plan=plan, step_us=best * 1e6 / steps_total,
                  steps=steps_total, wall_s=best, source="measured")


# ------------------------------------------------------------------ #
# the cycle-sim bridge
# ------------------------------------------------------------------ #
def expected_blocks(n: int, m: int, tile: int) -> float:
    """Expected non-empty (src tile, dst tile) weight blocks when m
    edges land over the tile grid -- the occupancy of ntiles^2 cells
    under m throws, smooth and deterministic."""
    ntiles = max(1, -(-n // tile))
    cells = float(ntiles * ntiles)
    return cells * -math.expm1(m * math.log1p(-1.0 / cells)) \
        if cells > 1 else 1.0


def active_tile_fraction(density: float, tile: int) -> float:
    """P(a tile holds >= 1 active vertex) at per-vertex density p --
    the kernel's packet-trigger probability, which is what compaction
    actually skips on."""
    p = min(max(density, 0.0), 1.0)
    return float(-math.expm1(tile * math.log1p(-p))) if p < 1.0 else 1.0


def analytic_step_us(profile: GraphProfile, plan: ExecutionPlan,
                     arch: FlipArch = DEFAULT_ARCH,
                     exe_update: int = EXE_UPDATE_CYCLES) -> float:
    """Per-step cost estimate for one query, in model-microseconds.

    The Algorithm-2 shape from `RuntimeEstimator.edge_time`, applied
    at block granularity: each streamed block costs its T*T*d
    MAC-equivalents (throughput `MAC_PER_CYCLE`/cycle) plus a
    per-delivered-row processing term (`t_tab + exe_update` cycles, the
    sim's Intra-Table search + vertex execution), converted at the
    arch clock and scaled by the kernel backend's relative throughput.
    Compaction prices only the expected active blocks; dense streaming
    prices them all -- the exact asymmetry `DispatchTelemetry.summary`
    reports as hbm_weight_bytes_est."""
    t, d = plan.tile, max(profile.feature_dim, 1)
    nb = expected_blocks(profile.n, profile.m, t)
    af = active_tile_fraction(profile.mean_density, t)
    fetched = nb * (af if plan.compact else 1.0)
    mac_cycles = fetched * t * t * d / MAC_PER_CYCLE
    proc_cycles = fetched * t * (arch.t_tab + exe_update) \
        / MAC_PER_CYCLE
    cycles = mac_cycles + proc_cycles + STEP_FIXED_CYCLES
    return BACKEND_FACTOR.get(plan.relax_mode, 1.0) * cycles \
        / arch.freq_mhz


def estimated_measure_s(profile: GraphProfile, plan: ExecutionPlan, *,
                        sources: int = PROBE_SOURCES,
                        segment_steps: int = SEGMENT_STEPS,
                        repeats: int = REPEATS) -> float:
    """Predicted wall cost of *measuring* this candidate -- what the
    budget gate compares against before committing to a real run."""
    per_step = analytic_step_us(profile, plan) * 1e-6
    return per_step * segment_steps * max(1, sources) * (repeats + 1)


def price_candidate(graph: Graph, program, plan: ExecutionPlan,
                    profile: GraphProfile, *, measure_ok: bool = True,
                    seed: int = 0, budget_s: float | None = None,
                    sources: int = PROBE_SOURCES,
                    segment_steps: int = SEGMENT_STEPS,
                    repeats: int = REPEATS,
                    arch: FlipArch = DEFAULT_ARCH) -> Sample:
    """Measured when allowed and affordable, analytic otherwise. A
    measurement that fails outright (backend error) degrades to the
    analytic estimate rather than killing the sweep -- tuning must
    never be the thing that takes a session down."""
    exe = Program.of(program).algebra.exe_update
    if measure_ok and (budget_s is None or estimated_measure_s(
            profile, plan, sources=sources,
            segment_steps=segment_steps, repeats=repeats) <= budget_s):
        try:
            return measure_plan(graph, program, plan, seed=seed,
                                sources=sources,
                                segment_steps=segment_steps,
                                repeats=repeats)
        except Exception:
            pass
    return Sample(plan=plan,
                  step_us=analytic_step_us(profile, plan, arch,
                                           exe_update=exe),
                  steps=0, wall_s=0.0, source="analytic")
