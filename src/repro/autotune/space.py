"""The legal plan design space: every candidate the tuner may pick.

One rule keeps the tuner honest: *a candidate is legal iff
`ExecutionPlan.resolve()` accepts it*. The space enumerates the
performance-only knobs -- tile size, kernel dispatch, frontier
compaction, serving bucket width -- and funnels every combination
through the exact validation the session front door applies, so a plan
the tuner emits is a plan `flip.compile` would have accepted, with no
second validator to drift.

Knobs the space deliberately does NOT explore:

  * `mode` ('data' vs 'op') and `warm` are kept at the base plan's
    setting: both are *policy contracts* with the caller ('op' is the
    classic-CGRA baseline the user asked to see; `warm` decides which
    `query(warm=)` calls error), so flipping them behind the caller's
    back would change observable behavior, not just speed.
  * `distributed` / `mesh`: mesh topology is an infrastructure choice,
    not a per-graph knob.
  * `feature_dim`: the program's native width is semantics.

And one knob restriction that keeps "bit-exact" honest: `tile` and
`relax_mode` only vary when the algebra's ⊕ is *idempotent* (min / max
/ or). Re-tiling regroups the per-destination reduction, and the jnp
matmul vs interpret loop reassociate it differently -- bitwise inert
for an idempotent merge, a few-ulp drift for a non-idempotent one
(pagerank / labelprop's float +). For those algebras the sweep varies
only `compact` and `batch`, the two knobs whose exactness is
unconditional (compaction streams a subset of blocks, bucketing only
pads), so every candidate -- for every algebra -- stays bit-for-bit
the default plan's answer.

Candidates carry a `measured` hint: 'interpret' runs the Pallas kernel
body under the interpreter (orders of magnitude slower than jnp -- it
exists for kernel-exactness checks, not production), so sweeping it
with a wall-clock harness would dominate the whole tune; the tuner
prices it through the analytic model instead.
"""
from __future__ import annotations

import dataclasses

import jax

from repro.api.plan import ExecutionPlan

TILES = (64, 128, 256)


@dataclasses.dataclass(frozen=True)
class Candidate:
    """One legal plan plus how the tuner may price it."""
    plan: ExecutionPlan          # resolved (no 'auto' left)
    measure_ok: bool             # False: analytic/model pricing only

    @property
    def key(self) -> tuple:
        return self.plan.key()


def _relax_candidates(backend: str) -> list[tuple[str, bool]]:
    """(relax_mode, measure_ok) pairs legal on `backend`. jnp is legal
    everywhere; pallas only compiles on TPU; interpret is legal
    everywhere but priced analytically (see module doc)."""
    out = [("jnp", True)]
    if backend == "tpu":
        out.append(("pallas", True))
    out.append(("interpret", False))
    return out


def _batch_candidates(base_batch: int) -> tuple[int, ...]:
    """Bucket widths around the base plan's serving batch: a solo plan
    (batch=0) stays solo -- bucketing a caller who asked for one
    fixpoint changes dispatch shape for no measured reason -- while a
    serving plan explores halving/doubling its bucket."""
    if base_batch <= 0:
        return (0,)
    return tuple(sorted({max(1, base_batch // 2), base_batch,
                         base_batch * 2}))


def candidate_plans(base: ExecutionPlan, algebra=None,
                    backend: str | None = None) -> list[Candidate]:
    """Enumerate the legal candidates around `base` (see module doc).

    Every returned candidate has passed `ExecutionPlan.resolve(algebra)`
    -- combinations the validator rejects (compact=True with mode='op',
    pallas off-TPU, ...) are silently skipped, so the sweep can propose
    aggressively and let the one true validator prune. The base plan's
    own resolved form is always in the list: the tuner can therefore
    never pick something *worse than* the static default by
    construction of its argmin."""
    backend = backend or jax.default_backend()
    seen: set[tuple] = set()
    out: list[Candidate] = []
    exact_regroup = (algebra is None
                     or algebra.semiring.idempotent)
    tiles = TILES if exact_regroup else (base.tile,)
    relaxes = (_relax_candidates(backend) if exact_regroup
               else [(base.relax_mode,
                      base.relax_mode != "interpret")])
    combos = [(t, r, mok, c, b)
              for t in tiles
              for (r, mok) in relaxes
              for c in (True, False)
              for b in _batch_candidates(base.batch)]
    # the static default (base as-is) leads the list so ties break to it
    probes = [(base, True)] + [
        (dataclasses.replace(base, tile=t, relax_mode=r, compact=c,
                             batch=b, tuned=False), mok)
        for (t, r, mok, c, b) in combos]
    for plan, measure_ok in probes:
        try:
            resolved = dataclasses.replace(plan, tuned=False).resolve(
                algebra)
        except (ValueError, TypeError):
            continue
        k = resolved.key()
        if k in seen:
            continue
        seen.add(k)
        out.append(Candidate(plan=resolved, measure_ok=measure_ok))
    return out
