"""Plan autotuner: measured-cost-model design-space exploration that
picks `ExecutionPlan` knobs per (graph, algebra, backend).

Entry points:

  * `autotune(graph, program)` -- one full tune, returns a `TuneReport`
  * `ExecutionPlan.auto(tuned=True)` via `flip.compile` -- the session
    surface; consults the `TuningStore` so tuning amortizes
  * `tools/autotune.py` / `graph_run --autotune` -- the CLI surface

Tuning is policy, never semantics: every candidate the sweep can emit
is bit-exact with the default plan (see `repro.autotune.space`).
"""
from repro.autotune.measure import (Sample, analytic_step_us,
                                    measure_plan, price_candidate)
from repro.autotune.model import CostModel, load_bench_samples
from repro.autotune.profile import GraphProfile, profile_graph
from repro.autotune.space import Candidate, candidate_plans
from repro.autotune.store import TuningStore, default_store_path
from repro.autotune.tuner import (TuneReport, autotune, resolve_tuned)

__all__ = [
    "GraphProfile", "profile_graph",
    "Candidate", "candidate_plans",
    "Sample", "measure_plan", "price_candidate", "analytic_step_us",
    "CostModel", "load_bench_samples",
    "TuningStore", "default_store_path",
    "TuneReport", "autotune", "resolve_tuned",
]
