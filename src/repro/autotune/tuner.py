"""The tuner: profile -> sweep -> price -> model -> chosen plan.

`autotune(graph, program)` is the whole subsystem in one call:

  1. profile the graph (`repro.autotune.profile`) and look the
     fingerprint up in the tuning store -- a hit returns instantly
     (tuning amortizes across sessions);
  2. enumerate the legal candidate plans around the caller's base plan
     (`repro.autotune.space`);
  3. price every candidate (`repro.autotune.measure`): real capped
     `run_segment` timings where allowed and affordable, the analytic
     cycle-sim bridge otherwise;
  4. fit the cost model over the measured samples plus recorded bench
     history (`repro.autotune.model`) and use it to price the
     analytic-only candidates;
  5. pick the argmin -- with a deterministic tie-break: any candidate
     within `NOISE_BAND` of the best loses to the *earlier* candidate
     in sweep order, and the sweep puts the caller's base plan first.
     A tuned plan therefore only deviates from the static default when
     the evidence clears the noise floor, and the same
     (graph, program, base, seed) always tunes to the same plan in
     model-only mode (`measure=False`).

The chosen plan is pure policy: every candidate the sweep can emit
differs from the default only in tile / kernel dispatch / compaction /
bucket width, all bit-exact by the engine's contracts, so tuning can
change *when* the answer arrives but never *what* it is.
"""
from __future__ import annotations

import dataclasses

from repro.api.plan import ExecutionPlan
from repro.api.program import Program
from repro.autotune.measure import (PROBE_SOURCES, SEGMENT_STEPS, Sample,
                                    price_candidate)
from repro.autotune.model import CostModel, load_bench_samples
from repro.autotune.profile import GraphProfile, profile_graph
from repro.autotune.space import Candidate, candidate_plans
from repro.autotune.store import TuningStore
from repro.graphs.csr import Graph

# ties within this relative band break toward the earlier (= more
# default) candidate: a 2% win is measurement noise, not evidence
NOISE_BAND = 0.02
# default wall budget for the whole measured sweep's per-candidate gate
DEFAULT_BUDGET_S = 2.0

TUNED_KNOBS = ("tile", "relax_mode", "compact", "batch")


@dataclasses.dataclass
class TuneReport:
    """One tuning outcome: what was chosen, from what evidence, why."""

    profile: GraphProfile
    chosen: ExecutionPlan          # resolved; tuned flag cleared
    samples: list                  # [Sample] -- empty on a store hit
    why: str
    cached: bool                   # True: served from the store
    seed: int
    scores: dict = dataclasses.field(default_factory=dict)

    def to_json(self) -> dict:
        return {
            "profile": self.profile.to_json(),
            "chosen": {k: getattr(self.chosen, k) for k in TUNED_KNOBS},
            "samples": [s.to_json() for s in self.samples],
            "why": self.why, "cached": self.cached, "seed": self.seed,
        }


def _knobs_of(plan: ExecutionPlan) -> dict:
    return {k: getattr(plan, k) for k in TUNED_KNOBS}


def _plan_from_knobs(base: ExecutionPlan, knobs: dict, algebra) \
        -> ExecutionPlan | None:
    """Rehydrate a stored knob dict onto the caller's base plan --
    tunable knobs only, so a stored entry can never smuggle in a
    semantics change. None when the stored combo no longer resolves
    (e.g. a pallas entry replayed off-TPU): stale-by-environment is
    just another cache miss."""
    clean = {k: knobs[k] for k in TUNED_KNOBS if k in knobs}
    try:
        return dataclasses.replace(base, tuned=False, **clean) \
            .resolve(algebra)
    except (ValueError, TypeError, KeyError):
        return None


def _score_table(cands: list[Candidate], samples: list[Sample],
                 model: CostModel, profile: GraphProfile) -> list[float]:
    """Per-candidate cost in step-us: measured candidates score their
    own measurement; analytic-priced ones go through the fitted model
    (which itself falls back to the analytic bridge for backends the
    fit never saw)."""
    out = []
    for c, s in zip(cands, samples):
        if s.source == "measured":
            out.append(float(s.step_us))
        else:
            out.append(float(model.predict(profile, c.plan)))
    return out


def autotune(graph: Graph, program,
             base_plan: ExecutionPlan | None = None, *, seed: int = 0,
             store: TuningStore | None = None, force: bool = False,
             measure: bool = True,
             budget_s: float | None = DEFAULT_BUDGET_S,
             segment_steps: int = SEGMENT_STEPS,
             sources: int = PROBE_SOURCES,
             bench_history: bool = True) -> TuneReport:
    """Tune the ExecutionPlan knobs for (graph, program) -- module doc.

    measure=False runs the whole sweep through the analytic model: no
    wall clocks anywhere, so the chosen plan is a pure deterministic
    function of (graph shape, base plan, seed). `force=True` bypasses
    the store on read (the fresh result is still written back).
    """
    prog = Program.of(program)
    base = base_plan if base_plan is not None else ExecutionPlan()
    rbase = dataclasses.replace(base, tuned=False).resolve(prog.algebra)
    profile = profile_graph(graph, feature_dim=rbase.feature_dim)
    fp = profile.fingerprint()
    algebra_name = prog.algebra.name

    if store is not None and not force:
        entry = store.get(fp, algebra_name, profile.backend)
        if entry is not None:
            plan = _plan_from_knobs(rbase, entry["plan"], prog.algebra)
            if plan is not None:
                return TuneReport(
                    profile=profile, chosen=plan, samples=[],
                    why=entry.get("why", "") or "store hit",
                    cached=True, seed=int(entry.get("seed", seed)))

    cands = candidate_plans(rbase, prog.algebra, backend=profile.backend)
    samples = [
        price_candidate(graph, prog, c.plan, profile,
                        measure_ok=(measure and c.measure_ok),
                        seed=seed, budget_s=budget_s, sources=sources,
                        segment_steps=segment_steps)
        for c in cands]
    history = load_bench_samples() if bench_history else []
    model = CostModel.fit(samples + history, profile)
    scores = _score_table(cands, samples, model, profile)

    # argmin with the noise-band tie-break: the first candidate within
    # NOISE_BAND of the minimum wins, and sweep order puts the base
    # plan first -- so "barely better" never displaces the default
    best = min(scores)
    idx = next(i for i, s in enumerate(scores)
               if s <= best * (1.0 + NOISE_BAND))
    chosen, csample = cands[idx].plan, samples[idx]
    base_score = scores[0]
    why = (
        f"{csample.source} sweep over {len(cands)} candidates: "
        f"tile={chosen.tile} relax={chosen.relax_mode} "
        f"compact={chosen.compact} batch={chosen.batch} at "
        f"{scores[idx]:.1f}us/step vs default {base_score:.1f}us/step "
        f"(model fit on {model.n_samples} samples)")
    report = TuneReport(
        profile=profile, chosen=chosen, samples=samples, why=why,
        cached=False, seed=seed,
        scores={c.plan.key(): s for c, s in zip(cands, scores)})
    if store is not None:
        store.put(fp, algebra_name, profile.backend, _knobs_of(chosen),
                  score_us=scores[idx], seed=seed,
                  samples=[s.to_json() for s in samples],
                  profile_json=profile.to_json(), why=why)
    return report


def resolve_tuned(graph: Graph, program, plan: ExecutionPlan, *,
                  store: TuningStore | None = None,
                  seed: int = 0) -> tuple[ExecutionPlan, TuneReport]:
    """The session hook: collapse a ``tuned=True`` plan to its tuned
    concrete form. Consults the default store when none is given (so
    `flip.compile(..., ExecutionPlan.auto(tuned=True))` amortizes
    across sessions), returns the resolved chosen plan (tuned flag
    cleared) plus the report the session stamps into telemetry."""
    if store is None:
        store = TuningStore()
    report = autotune(graph, program, base_plan=plan, seed=seed,
                      store=store)
    return report.chosen, report
