"""Fitted cost model: measured samples in, per-step cost predictor out.

The analytic bridge (`measure.analytic_step_us`) is ordinally honest
but its absolute scale is guessed from arch constants. The fitted model
closes that gap with data: a least-squares fit of per-step cost against
the physically meaningful regressors

    x0 = 1                               (fixed per-step dispatch)
    x1 = streamed blocks                 (expected blocks x active frac
                                          under compaction, all blocks
                                          dense)
    x2 = streamed element volume         (x1 * T^2 * d -- the MAC/HBM
                                          term)

per backend (jnp / pallas / interpret have distinct throughput, so each
gets its own coefficients; a backend with no samples falls back to the
analytic estimate). Non-negative clamping keeps a noisy fit from ever
predicting negative cost.

Training data comes from two places:

  * the tune-time measured `Sample`s of the current sweep, and
  * recorded BENCH history (`load_bench_samples`): the append-safe
    ``BENCH_*.json`` files `benchmarks.common.write_json` accumulates
    carry kernel-step rows ("feature_step_*", "frontier_step_*") whose
    derived strings name the block count and feature width -- free
    extra observations of exactly the regressors above, from every
    bench run this machine ever recorded. Parsing is best-effort: a
    row that does not parse contributes nothing (history must never
    break a tune).
"""
from __future__ import annotations

import dataclasses
import json
import os
import re

import numpy as np

from repro.api.plan import ExecutionPlan
from repro.autotune.measure import (Sample, active_tile_fraction,
                                    analytic_step_us, expected_blocks)
from repro.autotune.profile import GraphProfile


def features_of(profile: GraphProfile, plan: ExecutionPlan) -> np.ndarray:
    """The regressor vector [1, streamed_blocks, streamed_volume]."""
    t, d = plan.tile, max(profile.feature_dim, 1)
    nb = expected_blocks(profile.n, profile.m, t)
    af = active_tile_fraction(profile.mean_density, t)
    fetched = nb * (af if plan.compact else 1.0)
    return np.asarray([1.0, fetched, fetched * t * t * d],
                      dtype=np.float64)


@dataclasses.dataclass
class CostModel:
    """Per-backend least-squares fit of step_us over `features_of`."""

    coef: dict                 # backend -> (3,) float64 coefficients
    n_samples: int = 0

    @classmethod
    def fit(cls, samples: list, profile: GraphProfile) -> "CostModel":
        """Fit from measured samples (analytic-sourced ones are
        excluded: fitting the model to its own fallback would launder
        guesses into 'data'). Needs >= 3 points per backend for the
        3-coefficient fit; fewer points leave that backend analytic."""
        by_backend: dict[str, list] = {}
        for s in samples:
            if getattr(s, "source", "measured") != "measured":
                continue
            by_backend.setdefault(s.plan.relax_mode, []).append(s)
        coef = {}
        for backend, ss in by_backend.items():
            if len(ss) < 3:
                continue
            x = np.stack([np.asarray(s.features, dtype=np.float64)
                          if s.features is not None
                          else features_of(profile, s.plan)
                          for s in ss])
            y = np.asarray([s.step_us for s in ss], dtype=np.float64)
            sol, *_ = np.linalg.lstsq(x, y, rcond=None)
            coef[backend] = sol
        return cls(coef=coef,
                   n_samples=sum(len(v) for v in by_backend.values()))

    def predict(self, profile: GraphProfile,
                plan: ExecutionPlan) -> float:
        """Predicted step_us; analytic fallback for backends the fit
        never saw, and clamped to a strictly positive floor."""
        c = self.coef.get(plan.relax_mode)
        if c is None:
            return analytic_step_us(profile, plan)
        return float(max(features_of(profile, plan) @ c, 1e-3))


# ------------------------------------------------------------------ #
# BENCH_*.json history -> extra training samples
# ------------------------------------------------------------------ #
# rows like:  feature_step_min_plus_2k_d8 , 512.3 ,
#             "power-law |V|=2048 blocks=519 d=8 eff_gflops=..."
_ROW_RE = re.compile(r"(?:feature|frontier)_step_")
_KV_RE = re.compile(r"\b(blocks|d|\|V\|)=(\d+)")


def load_bench_samples(paths=None, tile_default: int = 64) -> list:
    """Best-effort parse of recorded bench history into Samples.

    `paths` defaults to the repo-root BENCH files next to the
    `benchmarks` package (where `write_json` appends when BENCH_OUT is
    unset). Every failure mode -- missing file, corrupt JSON, legacy
    layout, unparseable derived string -- contributes zero samples,
    never an exception."""
    if paths is None:
        root = os.path.dirname(os.path.dirname(os.path.dirname(
            os.path.dirname(os.path.abspath(__file__)))))
        paths = [os.path.join(root, f"BENCH_{tag}.json")
                 for tag in ("kernels", "features", "frontier_density")]
    out: list = []
    for path in paths:
        try:
            with open(path) as f:
                data = json.load(f)
        except (OSError, json.JSONDecodeError, ValueError):
            continue
        runs = data.get("runs", []) if isinstance(data, dict) else []
        for run in runs:
            for row in run.get("rows", []) or []:
                s = _row_to_sample(row, tile_default)
                if s is not None:
                    out.append(s)
    return out


def _row_to_sample(row: dict, tile_default: int):
    """One bench row -> Sample, or None when it isn't a step-cost row
    with a parseable shape."""
    try:
        name, us = row.get("name", ""), float(row.get("us_per_call", 0))
    except (TypeError, ValueError):
        return None
    if not _ROW_RE.match(name) or us <= 0:
        return None
    kv = dict(_KV_RE.findall(row.get("derived", "") or ""))
    if "blocks" not in kv:
        return None
    d = int(kv.get("d", 1))
    blocks = float(kv["blocks"])
    # bench step rows are dense jnp relax steps at the bench tile, so
    # their regressors are exact: every block streamed, T^2*d volume
    plan = ExecutionPlan(relax_mode="jnp", compact=False,
                         tile=tile_default,
                         feature_dim=d if d > 1 else 0)
    feats = (1.0, blocks, blocks * tile_default * tile_default * d)
    return Sample(plan=plan, step_us=us, steps=1, wall_s=us * 1e-6,
                  source="measured", features=feats)
