"""Cycle-level simulator of FLIP's data-centric mode (paper Sec. 3, 5.1).

Models, per cycle:
  * YX dimension-ordered routing with per-link arbitration (one packet per
    directed link per cycle), pipelined hop latency `t_hop`, and
    credit-based flow control (bounded input buffers, Sec. 3.2.3);
  * packet delivery: slice-id check, Intra-Table search (t_tab), ALUin
    queueing; mismatched slices park in the cluster Memory Buffer;
  * vertex execution: 1 instruction/cycle, 4/5/5 (resp. 2/4/4) instructions
    with (resp. without) an attribute update; updates scatter one packet
    per destination PE per cycle from the ALUout buffer, farthest-first;
  * runtime data swapping (Sec. 3.3): an idle 2x2 cluster loads the slice
    with the earliest pending cached packet (t_swap cycles).

The simulator is the paper-faithful evaluation vehicle: Fig. 10/11/12 and
Table 8 are reproduced from its outputs (see benchmarks/).
"""
from __future__ import annotations

import dataclasses
from collections import deque

import numpy as np

from repro.core.arch import FlipArch
from repro.core.mapping import Mapping
from repro.core.tables import RoutingTables, build_tables
from repro.core.vertex_program import VertexProgram


@dataclasses.dataclass
class Packet:
    src_vertex: int
    value: float
    dst_pe: int
    dst_slice: int
    cur_pe: int
    born: int
    queue_wait: int = 0


@dataclasses.dataclass
class SimResult:
    cycles: int
    attrs: np.ndarray
    packets_delivered: int
    edges_relaxed: int
    avg_parallelism: float        # mean #busy PEs over busy cycles
    max_parallelism: int
    avg_pkt_wait: float           # cycles waiting for arbitration/credit
    max_aluin_depth: int
    swaps: int
    parallelism_trace: np.ndarray

    @property
    def mteps(self) -> float:
        """MTEPS at arch frequency is computed by callers (needs freq)."""
        return self.edges_relaxed / max(self.cycles, 1)


class _PE:
    __slots__ = ("inq", "aluin", "aluout", "busy_until", "pending_scatter",
                 "cur_task")

    def __init__(self):
        # input queues: one per port (4 directions); modeled as a single
        # arbiter-fed pool of per-port FIFOs
        self.inq = {d: deque() for d in ("N", "S", "E", "W", "L")}
        self.aluin: deque = deque()
        self.aluout: deque = deque()
        self.busy_until = -1
        self.cur_task = None         # (dst_vertex, value, src_vertex)
        self.pending_scatter: deque = deque()


def _port_from(arch: FlipArch, frm: int, to: int) -> str:
    fx, fy = arch.pe_xy(frm)
    tx, ty = arch.pe_xy(to)
    if ty > fy:
        return "N"      # arriving from south side
    if ty < fy:
        return "S"
    if tx > fx:
        return "W"
    return "E"


def _next_hop(arch: FlipArch, cur: int, dst: int) -> int:
    """YX dimension-ordered: travel Y first, then X."""
    cx, cy = arch.pe_xy(cur)
    dx, dy = arch.pe_xy(dst)
    if cy != dy:
        return arch.pe_id(cx, cy + (1 if dy > cy else -1))
    return arch.pe_id(cx + (1 if dx > cx else -1), cy)


def simulate(mapping: Mapping, program: VertexProgram,
             src: int = 0,
             tables: RoutingTables | None = None,
             max_cycles: int = 5_000_000) -> SimResult:
    if not program.sim_ok:
        raise ValueError(
            f"program {program.name!r} is not expressible on the "
            "asynchronous cycle simulator (non-idempotent merge); run it "
            "on the JAX engine instead")
    arch = mapping.arch
    g = mapping.graph
    tables = tables or build_tables(mapping, program)

    # NB: the bootstrap tasks below (src_v < 0) always scatter, so the
    # source's first update propagates even though its attribute is
    # pre-set by initial_attrs (a regular merge would see no change).
    attrs = program.initial_attrs(g.n, src).copy()
    pes = [_PE() for _ in range(arch.num_pes)]
    # intra-table fast lookup of a vertex's (copy, pe)
    pe_of, copy_of = mapping.pe_of, mapping.copy_of
    num_clusters = (arch.width // arch.cluster) * (arch.height // arch.cluster)
    num_copies = mapping.num_copies()

    # cluster state for data swapping
    loaded = np.zeros(num_clusters, dtype=np.int64)
    cluster_swap_until = np.full(num_clusters, -1, dtype=np.int64)
    membuf: dict[int, dict[int, deque]] = {c: {} for c in range(num_clusters)}

    cluster_pes = {c: [p for p in range(arch.num_pes)
                       if arch.cluster_of(p) == c]
                   for c in range(num_clusters)}

    # initial activations
    pending_initial: dict[tuple[int, int], list[int]] = {}
    if program.all_start:
        for v in range(g.n):
            key = (arch.cluster_of(int(pe_of[v])), int(copy_of[v]))
            pending_initial.setdefault(key, []).append(v)
        # the loaded slice per cluster starts at copy 0
        for (c, cp), vs in list(pending_initial.items()):
            if cp == 0:
                for v in vs:
                    pes[int(pe_of[v])].aluin.append((v, attrs[v], -1, 0))
                del pending_initial[(c, cp)]
    else:
        src_cluster = arch.cluster_of(int(pe_of[src]))
        loaded[src_cluster] = int(copy_of[src])
        pes[int(pe_of[src])].aluin.append((src, program.source_value, -1, 0))

    in_flight: list[tuple[int, Packet]] = []   # (arrive_cycle, pkt)
    cycle = 0
    delivered = 0
    relaxed = 0
    swaps = 0
    pkt_waits: list[int] = []
    max_aluin = 0
    par_trace: list[int] = []

    def cluster_idle(c: int) -> bool:
        if cluster_swap_until[c] >= cycle:
            return False
        for p in cluster_pes[c]:
            pe = pes[p]
            if pe.busy_until >= cycle or pe.aluin or pe.aluout or \
               pe.pending_scatter or any(pe.inq[d] for d in pe.inq):
                return False
        return True

    def occupancy(pe_idx: int) -> int:
        pe = pes[pe_idx]
        return sum(len(pe.inq[d]) for d in pe.inq)

    rr = 0  # round-robin arbiter offset
    while cycle < max_cycles:
        # ---------------- arrivals from the NoC ----------------------- #
        still = []
        for t, pkt in in_flight:
            if t == cycle:
                port = _port_from(arch, pkt.cur_pe, pkt.dst_pe) \
                    if pkt.cur_pe != pkt.dst_pe else "L"
                # cur_pe tracks the hop the packet just completed
                pes[pkt.cur_pe].inq[port].append(pkt)
            else:
                still.append((t, pkt))
        in_flight = still

        # ---------------- routing / delivery --------------------------- #
        # one packet per output link per cycle; round-robin over ports
        for p in range(arch.num_pes):
            pe = pes[p]
            link_used: set[int] = set()
            ports = ["L", "N", "S", "E", "W"]
            ports = ports[rr % 5:] + ports[:rr % 5]
            for d in ports:
                q = pe.inq[d]
                if not q:
                    continue
                pkt = q[0]
                if pkt.dst_pe == p:
                    # delivery: slice check then Intra-Table search
                    c = arch.cluster_of(p)
                    if pkt.dst_slice == loaded[c] and cluster_swap_until[c] < cycle:
                        q.popleft()
                        delivered += 1
                        pkt_waits.append(pkt.queue_wait)
                        for e in tables.intra_entries(pkt.dst_slice, p,
                                                      pkt.src_vertex):
                            pe.aluin.append((e.dst_vertex, pkt.value,
                                             pkt.src_vertex, e.weight))
                        max_aluin = max(max_aluin, len(pe.aluin))
                    else:
                        q.popleft()
                        membuf[c].setdefault(pkt.dst_slice,
                                             deque()).append(pkt)
                else:
                    nxt = _next_hop(arch, p, pkt.dst_pe)
                    if nxt in link_used:
                        pkt.queue_wait += 1
                        continue
                    # credit-based flow control: bounded downstream buffer
                    if occupancy(nxt) >= arch.input_buffer_depth:
                        pkt.queue_wait += 1
                        continue
                    link_used.add(nxt)
                    q.popleft()
                    pkt.cur_pe = nxt
                    in_flight.append((cycle + arch.t_hop, pkt))

        # ---------------- scatter issue (ALUout, 1 pkt/cycle) ---------- #
        for p in range(arch.num_pes):
            pe = pes[p]
            if pe.pending_scatter and len(pe.aluout) < arch.input_buffer_depth:
                pe.aluout.append(pe.pending_scatter.popleft())
            if pe.aluout:
                entry, value = pe.aluout[0]
                if entry.dst_pe == p:
                    # local destination: no NoC, straight to delivery
                    pe.aluout.popleft()
                    c = arch.cluster_of(p)
                    if entry.dst_slice == loaded[c] and \
                            cluster_swap_until[c] < cycle:
                        delivered += 1
                        for e in tables.intra_entries(entry.dst_slice, p,
                                                      entry.src_vertex):
                            pe.aluin.append((e.dst_vertex, value,
                                             entry.src_vertex, e.weight))
                    else:
                        membuf[c].setdefault(entry.dst_slice, deque()).append(
                            Packet(entry.src_vertex, value, p,
                                   entry.dst_slice, p, cycle))
                else:
                    pkt = Packet(entry.src_vertex, value, entry.dst_pe,
                                 entry.dst_slice, p, cycle)
                    nxt = _next_hop(arch, p, entry.dst_pe)
                    if occupancy(nxt) < arch.input_buffer_depth:
                        pe.aluout.popleft()
                        pkt.cur_pe = nxt
                        in_flight.append((cycle + arch.t_hop, pkt))

        # ---------------- execution ------------------------------------ #
        busy = 0
        for p in range(arch.num_pes):
            pe = pes[p]
            if pe.busy_until >= cycle:
                busy += 1
                continue
            if pe.cur_task is not None:
                # retire: apply merge, maybe scatter. Bootstrap/initial
                # tasks (src_v < 0) always scatter their value.
                v, value, src_v, w = pe.cur_task
                pe.cur_task = None
                if src_v < 0:
                    attrs[v] = program.merge(attrs[v], np.float32(value))
                    for e in tables.inter_entries(int(copy_of[v]), p, v):
                        pe.pending_scatter.append((e, float(attrs[v])))
                else:
                    msg = program.message(np.float32(value), np.float32(w))
                    relaxed += 1
                    if bool(program.improved_np(msg, attrs[v])):
                        attrs[v] = msg
                        for e in tables.inter_entries(int(copy_of[v]), p, v):
                            pe.pending_scatter.append((e, float(attrs[v])))
            if pe.aluin and pe.cur_task is None and pe.busy_until < cycle:
                v, value, src_v, w = pe.aluin.popleft()
                # table search + program execution; update/no-update cost
                # decided by a peek at the merge result
                msg = program.message(np.float32(value), np.float32(w)) \
                    if src_v >= 0 else np.float32(value)
                updated = src_v < 0 or bool(program.improved_np(msg, attrs[v]))
                cost = arch.t_tab + program.exe_cycles(updated)
                pe.busy_until = cycle + cost - 1
                pe.cur_task = (v, value, src_v, w)
                busy += 1
        par_trace.append(busy)

        # ---------------- runtime data swapping ------------------------ #
        for c in range(num_clusters):
            if cluster_swap_until[c] >= cycle:
                continue
            pend = {s: q for s, q in membuf[c].items() if q}
            pend_init = {cp for (cc, cp) in pending_initial if cc == c}
            if (pend or pend_init) and cluster_idle(c):
                # earliest pending task first
                cand = []
                for s, q in pend.items():
                    cand.append((q[0].born, s))
                for cp in pend_init:
                    cand.append((-1, cp))
                cand.sort()
                _, s = cand[0]
                cluster_swap_until[c] = cycle + arch.t_swap
                loaded[c] = s
                swaps += 1
                # replay buffered packets for slice s
                q = membuf[c].pop(s, deque())
                while q:
                    pkt = q.popleft()
                    for e in tables.intra_entries(s, pkt.dst_pe,
                                                  pkt.src_vertex):
                        pes[pkt.dst_pe].aluin.append(
                            (e.dst_vertex, pkt.value, pkt.src_vertex,
                             e.weight))
                    delivered += 1
                if (c, s) in pending_initial:
                    for v in pending_initial.pop((c, s)):
                        pes[int(pe_of[v])].aluin.append((v, attrs[v], -1, 0))

        rr += 1
        cycle += 1

        # ---------------- termination ---------------------------------- #
        if not in_flight and not any(
                pe.busy_until >= cycle or pe.cur_task is not None or pe.aluin
                or pe.aluout or pe.pending_scatter
                or any(pe.inq[d] for d in pe.inq) for pe in pes):
            if not any(q for bufs in membuf.values() for q in bufs.values()) \
                    and not pending_initial:
                break
            if not any(cluster_swap_until[c] >= cycle
                       for c in range(num_clusters)):
                # idle but pending swaps exist -> they trigger next cycle
                continue

    trace = np.asarray(par_trace, dtype=np.int64)
    busy_cycles = trace[trace > 0]
    return SimResult(
        cycles=cycle,
        attrs=attrs,
        packets_delivered=delivered,
        edges_relaxed=relaxed,
        avg_parallelism=float(busy_cycles.mean()) if len(busy_cycles) else 0.0,
        max_parallelism=int(trace.max()) if len(trace) else 0,
        avg_pkt_wait=float(np.mean(pkt_waits)) if pkt_waits else 0.0,
        max_aluin_depth=max_aluin,
        swaps=swaps,
        parallelism_trace=trace,
    )
