"""Cycle models of the paper's baseline architectures (Sec. 5.1).

MCU: ARM Cortex-M4F @64MHz running the textbook-optimal algorithms
(BFS O(V+E), binary-heap Dijkstra, WCC label propagation). Per-operation
cycle costs are calibrated so the model reproduces Table 5's measured
1.1 MTEPS on LRN (~58 cycles per traversed edge including queue
maintenance and flash/SRAM wait states on the M4F).

Classic op-centric CGRA: 8x8 @100MHz, statically-scheduled modulo mapping
(HyCUBE-class). Per the paper: BFS/WCC need 34/38 ops per edge iteration
and process one vertex at a time; the motivating example (Sec. 1.2) works
out to ~15 cycles per edge (dependence-limited II, SPM round trips); Table
5's 7.1 MTEPS on LRN implies ~14 cycles/edge -- we use 15/16 (BFS,
SSSP / WCC) with an unrolling model that saturates at ~1.3x (Fig. 4).
SSSP on the classic CGRA uses the O(V^2) algorithm (two kernels, 10/31
ops: vertex search + update), because the priority queue cannot be mapped
(Sec. 5.1).
"""
from __future__ import annotations

import dataclasses
import numpy as np

from repro.graphs import reference
from repro.graphs.csr import Graph

MCU_FREQ_MHZ = 64.0
CGRA_FREQ_MHZ = 100.0

# MCU per-op costs (cycles)
MCU_EDGE = 50        # inner-loop edge relaxation incl. loads/branches
MCU_VERTEX = 35      # queue pop + bookkeeping per vertex
MCU_HEAP_OP = 70     # binary heap push/pop (log V levels, cache misses)

# Classic CGRA per-edge-iteration cycles (modulo-scheduled kernel)
CGRA_EDGE = {"bfs": 15, "wcc": 16}
CGRA_SSSP_SCAN_II = 2     # pipelined vertex-search kernel (10 ops)
CGRA_SSSP_EDGE = 14       # update kernel (31 ops)
# Fig. 4: unrolling saturates due to inter-vertex dependencies
UNROLL_ALPHA = 0.65


@dataclasses.dataclass
class BaselineResult:
    cycles: float
    freq_mhz: float

    @property
    def time_us(self) -> float:
        return self.cycles / self.freq_mhz

    def mteps(self, edges: int) -> float:
        return edges / self.time_us if self.time_us > 0 else 0.0


def mcu_cycles(algo: str, g: Graph, src: int = 0) -> BaselineResult:
    if algo == "bfs":
        _, st = reference.bfs(g, src)
        cyc = st["edges_relaxed"] * MCU_EDGE + g.n * MCU_VERTEX
    elif algo == "sssp":
        _, st = reference.sssp(g, src)
        cyc = (st["edges_relaxed"] * MCU_EDGE
               + st["heap_pops"] * MCU_HEAP_OP + g.n * MCU_VERTEX)
    elif algo == "wcc":
        _, st = reference.wcc(g)
        cyc = st["edges_relaxed"] * (MCU_EDGE * 0.6) + g.n * MCU_VERTEX
    else:
        raise ValueError(algo)
    return BaselineResult(cycles=float(cyc), freq_mhz=MCU_FREQ_MHZ)


def unroll_speedup(unroll: int) -> float:
    """Effective parallelism from unrolling on the op-centric CGRA."""
    u = max(1, unroll)
    return u / (1.0 + UNROLL_ALPHA * (u - 1))


def cgra_cycles(algo: str, g: Graph, src: int = 0,
                unroll: int = 1) -> BaselineResult:
    if algo == "bfs":
        _, st = reference.bfs(g, src)
        cyc = st["edges_relaxed"] * CGRA_EDGE["bfs"] / unroll_speedup(unroll)
    elif algo == "wcc":
        _, st = reference.wcc(g)
        cyc = st["edges_relaxed"] * CGRA_EDGE["wcc"] / unroll_speedup(unroll)
    elif algo == "sssp":
        # O(V^2): V iterations x (scan all vertices + relax out-edges)
        deg = g.out_degree()
        cyc = 0.0
        for u in range(g.n):
            cyc += g.n * CGRA_SSSP_SCAN_II + float(deg[u]) * CGRA_SSSP_EDGE
        cyc /= unroll_speedup(unroll)
    else:
        raise ValueError(algo)
    return BaselineResult(cycles=float(cyc), freq_mhz=CGRA_FREQ_MHZ)
