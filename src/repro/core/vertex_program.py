"""Vertex-centric programs (paper Fig. 5 and Sec. 5.1).

A vertex program is an (Apply, Scatter) pair over a commutative, idempotent
"merge" semiring: an incoming message carrying the source vertex's attribute
is combined with the edge weight, merged into the destination attribute, and
scattered onward iff the attribute changed. BFS / SSSP / WCC are all
instances of the tropical (min, +) family:

  BFS : message = attr_u + 1        merge = min     (unit weights)
  SSSP: message = attr_u + w(u,v)   merge = min
  WCC : message = attr_u            merge = min     (label propagation,
        undirected edges, all vertices initially active with attr = id)

Instruction counts per paper Sec. 5.1: 4/5/5 (WCC/BFS/SSSP) when the
attribute updates, 2/4/4 when it does not.
"""
from __future__ import annotations

import dataclasses
import numpy as np

INF = np.float32(np.inf)


@dataclasses.dataclass(frozen=True)
class VertexProgram:
    name: str
    exe_update: int        # instructions when the vertex attribute changes
    exe_noupdate: int      # instructions when it does not
    uses_weights: bool     # message adds the edge weight
    add_one: bool          # message adds a constant 1 (BFS levels)
    all_start: bool        # every vertex starts active (WCC)
    undirected: bool       # scatter along both edge directions

    # -------------------------------------------------------------- #
    def initial_attrs(self, n: int, src: int) -> np.ndarray:
        if self.all_start:          # WCC: label = own id
            return np.arange(n, dtype=np.float32)
        a = np.full(n, INF, dtype=np.float32)
        a[src] = 0.0
        return a

    def message(self, attr_u: np.ndarray, w: np.ndarray):
        """Value carried by a packet along edge (u, v) with weight w."""
        if self.uses_weights:
            return attr_u + w
        if self.add_one:
            return attr_u + 1.0
        return attr_u

    @staticmethod
    def merge(attr_v, msg):
        return np.minimum(attr_v, msg)

    def exe_cycles(self, updated: bool) -> int:
        return self.exe_update if updated else self.exe_noupdate


BFS = VertexProgram("bfs", exe_update=5, exe_noupdate=4,
                    uses_weights=False, add_one=True,
                    all_start=False, undirected=False)
SSSP = VertexProgram("sssp", exe_update=5, exe_noupdate=4,
                     uses_weights=True, add_one=False,
                     all_start=False, undirected=False)
WCC = VertexProgram("wcc", exe_update=4, exe_noupdate=2,
                    uses_weights=False, add_one=False,
                    all_start=True, undirected=True)

PROGRAMS = {"bfs": BFS, "sssp": SSSP, "wcc": WCC}
