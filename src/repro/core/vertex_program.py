"""Vertex-centric programs (paper Fig. 5 and Sec. 5.1).

A vertex program is an (Apply, Scatter) pair over a semiring: an incoming
message carrying the source vertex's attribute is ⊗-combined with the
edge weight, ⊕-merged into the destination attribute, and scattered
onward iff the attribute became active. Since PR "Semiring algebra
subsystem", the program *is* a `repro.algebra.VertexAlgebra` -- this
module re-exports the registry so the cycle simulator, routing tables and
mapping compiler keep their historical import surface.

The classic tropical (min, +) family:

  BFS : message = attr_u ⊗ 1        ⊕ = min     (hop weights)
  SSSP: message = attr_u ⊗ w(u,v)   ⊕ = min
  WCC : message = attr_u ⊗ 0        ⊕ = min     (label propagation,
        undirected edges, all vertices initially active with attr = id)

plus the non-tropical algebras: widest-path (max, min), reachability
(or, and) and delta-PageRank (+, x; engine-only, `sim_ok=False`).

Instruction counts per paper Sec. 5.1: 4/5/5 (WCC/BFS/SSSP) when the
attribute updates, 2/4/4 when it does not.
"""
from __future__ import annotations

import numpy as np

from repro.algebra import (ALGEBRAS, BFS, PAGERANK, REACH, SSSP, WCC,
                           WIDEST, VertexAlgebra, get_algebra,
                           register_algebra)

# The vertex program *is* the algebra; the alias keeps old call sites
# (simulator, tables, mapping compiler) and type hints working.
VertexProgram = VertexAlgebra

INF = np.float32(np.inf)

PROGRAMS = ALGEBRAS

__all__ = [
    "VertexProgram", "VertexAlgebra", "PROGRAMS", "INF",
    "BFS", "SSSP", "WCC", "WIDEST", "REACH", "PAGERANK",
    "get_algebra", "register_algebra",
]
