"""Inter-PE / Intra-PE routing tables (paper Sec. 3.2, Fig. 7) and the
"farthest-one-first" data layout (Sec. 4.3).

Inter-Table (per PE): for each locally-stored vertex u, the destination PEs
of u's outgoing edges with their x/y offsets and destination slice ids.
One entry per (u, destination PE); entries are sorted by descending route
length so the longest (likely critical-path) packet is issued first.

Intra-Table (per PE): for each incoming edge (u -> v) with v stored locally,
the DRF register of v and the edge's ⊗ operand, hashed by src id (src % 8)
into short linked lists (avg search < 2 cycles -> arch.t_tab).

Stored weights are materialized through the program's algebra
(`edge_value`): BFS stores the hop constant 1, WCC the ⊗-identity,
SSSP/widest the raw graph weight -- so the simulator's
`message = attr ⊗ weight` needs no per-algorithm branching.
"""
from __future__ import annotations

import dataclasses

from repro.core.mapping import Mapping
from repro.core.vertex_program import VertexProgram
from repro.graphs.csr import Graph


@dataclasses.dataclass(frozen=True)
class InterEntry:
    src_vertex: int
    dst_pe: int
    dst_slice: int
    route_len: int


@dataclasses.dataclass(frozen=True)
class IntraEntry:
    src_vertex: int
    dst_vertex: int
    dst_register: int
    weight: float


def scatter_graph(graph: Graph, program: VertexProgram) -> Graph:
    """Edge set actually scattered along: undirected programs (WCC) send
    updates along both edge directions."""
    if not program.undirected:
        return graph
    edges, ws = [], []
    for u, v, w in graph.edge_list():
        edges.append((u, v)); ws.append(w)
        edges.append((v, u)); ws.append(w)
    return Graph.from_edges(graph.n, edges, ws, directed=True)


@dataclasses.dataclass
class RoutingTables:
    # inter[(copy, pe)][u] -> [InterEntry...] farthest-first
    inter: dict
    # intra[(copy, pe)][src_vertex] -> [IntraEntry...]
    intra: dict
    graph: Graph          # the scatter graph (symmetrized for WCC)

    def inter_entries(self, copy: int, pe: int, u: int):
        return self.inter.get((copy, pe), {}).get(u, [])

    def intra_entries(self, copy: int, pe: int, src: int):
        return self.intra.get((copy, pe), {}).get(src, [])


def build_tables(mapping: Mapping, program: VertexProgram,
                 farthest_first: bool = True) -> RoutingTables:
    g = scatter_graph(mapping.graph, program)
    outdeg = g.out_degree()
    reg = mapping.register_index()
    inter: dict = {}
    intra: dict = {}
    for u in range(g.n):
        u_key = (mapping.slice_of(u), int(mapping.pe_of[u]))
        # group u's out edges by destination PE: one packet per (u, dst PE)
        by_pe: dict[tuple[int, int], list[tuple[int, float]]] = {}
        for k in range(g.indptr[u], g.indptr[u + 1]):
            v = int(g.indices[k])
            w = program.edge_value(u, v, float(g.weights[k]), outdeg)
            v_key = (mapping.slice_of(v), int(mapping.pe_of[v]))
            by_pe.setdefault(v_key, []).append((v, w))
            intra.setdefault(v_key, {}).setdefault(u, []).append(
                IntraEntry(src_vertex=u, dst_vertex=v,
                           dst_register=int(reg[v]), weight=w))
        entries = [
            InterEntry(src_vertex=u, dst_pe=pe, dst_slice=sl,
                       route_len=mapping.arch.manhattan(
                           int(mapping.pe_of[u]), pe))
            for (sl, pe) in by_pe
        ]
        if farthest_first:
            entries.sort(key=lambda e: -e.route_len)
        inter.setdefault(u_key, {})[u] = entries
    return RoutingTables(inter=inter, intra=intra, graph=g)
