"""FLIP JAX engine: the TPU-native data-centric execution layer.

Two execution modes, matching the paper's dual-mode fabric (Sec. 3.4):

  * data-centric  -- frontier-driven: each step relaxes only blocks with
    active sources (the Pallas kernel skips inactive tiles), and the new
    frontier is the set of vertices the algebra marks active (attribute
    ⊕-improved for monotone algebras, residual above tolerance for
    delta-PageRank). This is FLIP's packet-triggered execution,
    vectorized.
  * op-centric    -- classic CGRA analogue: a full (unmasked) relaxation
    sweep every step (Bellman-Ford / power-iteration style), no
    data-driven skipping.

Data-centric mode additionally streams the weight blocks *compacted* by
the runtime frontier (``compact``, default on for data mode): only blocks
whose source tile is active for some query leave HBM; the rest are stood
in for by one VMEM-resident sentinel block (see
`repro.kernels.frontier.ops`). On the Pallas/interpret paths the
compaction runs on-device inside the `while_loop` with static shapes; on
the jnp/CPU path static shapes cannot shrink, so the fixpoint is driven
from the host instead (`_fixpoint_host`) and each step runs a
power-of-two-bucketed compacted relax -- the step cost tracks the live
frontier, O(active·T²), instead of O(nb·T²). Compaction is exact (the
⊕-identity annihilates ⊗), so results and step counts are bit-for-bit
the dense-streaming ones.

The algorithm is any registered `VertexAlgebra` (bfs, sssp, wcc,
pagerank, widest, reach, ...): the engine itself only threads the
algebra's scatter/carry/post-step hooks around the semiring relax kernel,
so a new algebra runs here unchanged.

Execution is batched over independent queries: the state is
(B, ntiles, T) -- B sources relaxing against one shared block structure
inside one `jax.lax.while_loop` fixpoint. `FlipEngine.execute` is the
single entry point (scalar source = the B=1 view; `distributed=True`
switches to the shard_map fixpoint; `warm=` resumes a prior result) --
the legacy `run`/`run_batch`/`run_distributed`/`run_updated` methods are
deprecated shims over it, and `repro.api` ( `flip.compile(graph,
program, plan).query(srcs)` ) is the intended front door. Queries whose
frontier has emptied are frozen by a per-query convergence mask, so a
long-tail query never perturbs finished ones and batched results are
bit-for-bit the per-source results.

Both paths can execute distributed via `shard_map`: destination tiles
are partitioned over a mesh axis (devices = PE clusters), queries stay
replicated, each device relaxes its local blocks, and the updated
attribute vector is re-assembled with an all-gather -- the collective is
the NoC, and its cost amortizes over the whole batch.
"""
from __future__ import annotations

import dataclasses
import functools
import time
import warnings

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro.algebra import VertexAlgebra
from repro.core.mapping import Mapping
from repro.graphs.csr import Graph
from repro.kernels.frontier.ops import (BlockedGraph, UpdateDelta,
                                        build_blocks, frontier_relax,
                                        resolve_relax_mode, tile_activity)
from repro.obs.telemetry import DispatchTelemetry, StepTrace
from repro.resilience.errors import InvalidRequest

# default per-step trace row capacity (`execute(trace=True)`): enough for
# any realistic fixpoint (diameters are O(100) even on road networks)
# while keeping the traced stat buffers a few hundred KB. Pass an int as
# `trace` to override; steps beyond the capacity still execute exactly
# (only their trace rows are dropped, flagged `truncated`).
TRACE_CAP_DEFAULT = 4096


@dataclasses.dataclass
class WarmStart:
    """Resume state for delta-driven incremental recompute.

    `attrs` is the converged result of a prior run on the pre-update
    engine, in original vertex order: `(n,)` (applied to every query of
    the batch) or `(B, n)` matching the batch. `seeds` holds the original
    ids of the vertices whose out-edge ⊗ operands changed
    (`UpdateDelta.affected_src`): they form the initial frontier, so the
    fixpoint relaxes only what the update batch can actually improve and
    converges in O(delta) steps instead of O(graph). Sound only for
    monotone algebras under a `Semiring.monotone_under` update batch --
    `FlipEngine.run_updated` applies that dispatch automatically.
    """
    attrs: np.ndarray
    seeds: np.ndarray


@dataclasses.dataclass
class ExecutionDetail:
    """Everything one `execute(detail=True)` dispatch knows about its
    outcome, beyond the bare ``(out, steps)`` tuple:

    `converged` is the engine's per-query convergence mask read at the
    fixpoint's end: True iff that query's frontier emptied (the fixpoint
    was *reached*), False iff it was frozen by a step budget, a
    deadline, or the session-wide `max_steps` valve -- in which case
    `attrs` is a valid partial relaxation, flagged, never silently
    truncated. `deadline_expired` marks which queries the deadline (not
    the step budget) stopped. Shapes follow the query: scalar source ->
    scalar flags, batch -> (B,) arrays."""
    attrs: np.ndarray
    steps: int | np.ndarray
    converged: bool | np.ndarray
    deadline_expired: bool | np.ndarray
    telemetry: DispatchTelemetry | None = None


def mapping_order(mapping: Mapping) -> np.ndarray:
    """Vertex ordering induced by the FLIP placement: vertices co-located
    on a (copy, PE) become adjacent tile positions, so the compiled
    placement's locality becomes block-sparsity."""
    keys = [(int(mapping.copy_of[v]), int(mapping.pe_of[v]), v)
            for v in range(mapping.graph.n)]
    return np.asarray([v for _, _, v in sorted(keys)], dtype=np.int64)


@dataclasses.dataclass
class FlipEngine:
    """Compiled graph + algorithm, ready to run on CPU or a device mesh."""

    bg: BlockedGraph
    algo: str
    mode: str = "data"          # 'data' (FLIP) or 'op' (classic CGRA)
    relax_mode: str = "auto"    # kernel dispatch: auto/pallas/interpret/jnp
    compact: bool | str = "auto"  # frontier-compacted block streaming:
                                  # 'auto' = on for data mode, off for op
    max_steps: int = 100_000
    feature_dim: int = 1        # feature width d of the vertex state:
                                # d > 1 runs the (T, T) x (T, d) vector
                                # relax ((B, ntiles, T, d) state)

    # -------------------------------------------------------------- #
    @staticmethod
    def build(graph: Graph, algo: str | VertexAlgebra,
              mapping: Mapping | None = None,
              tile: int = 128, mode: str = "data",
              relax_mode: str = "auto",
              compact: bool | str = "auto",
              feature_dim: int | None = None) -> "FlipEngine":
        order = mapping_order(mapping) if mapping is not None else None
        bg = build_blocks(graph, algo=algo, tile=tile, order=order)
        d = bg.algebra.feature_dim if feature_dim is None else feature_dim
        if bg.algebra.feature_dim > 1 and d != bg.algebra.feature_dim:
            raise ValueError(
                f"{bg.algebra.name} natively carries feature_dim "
                f"{bg.algebra.feature_dim}; cannot run it at "
                f"feature_dim {d}")
        return FlipEngine(bg=bg, algo=bg.algebra.name, mode=mode,
                          relax_mode=relax_mode, compact=compact,
                          feature_dim=d)

    @property
    def algebra(self) -> VertexAlgebra:
        return self.bg.algebra

    @property
    def _features(self) -> bool:
        return self.feature_dim > 1

    @property
    def _use_compact(self) -> bool:
        """Resolve the compaction policy: op-mode sweeps relax everything
        by definition, so only data mode compacts by default."""
        if self.compact == "auto":
            return self.mode == "data"
        return bool(self.compact)

    def _resolved_relax_mode(self) -> str:
        return resolve_relax_mode(self.relax_mode)

    # -------------------------------------------------------------- #
    def initial_state(self, srcs, warm: WarmStart | None = None):
        """(attrs, aux, frontier) as (B, ntiles, T) arrays for a batch of
        sources; padded lanes hold the ⊕-identity so they never activate
        or contribute.

        With `warm`, the fixpoint resumes from a prior converged result
        instead of the algebra's initial state: attrs come from
        `warm.attrs` and only `warm.seeds` start active, so relaxation
        propagates exactly the update batch's improvements."""
        bg, alg = self.bg, self.algebra
        d, features = self.feature_dim, self._features
        srcs = np.atleast_1d(np.asarray(srcs, dtype=np.int64))
        b = srcs.shape[0]
        if warm is not None:
            if alg.kind != "monotone":
                raise ValueError(
                    f"warm start needs a monotone algebra; {alg.name} is "
                    f"{alg.kind!r} -- recompute from scratch instead")
            prev = np.asarray(warm.attrs, dtype=np.float32)
            want = (b, bg.n, d) if features else (b, bg.n)
            if features and (prev.ndim < 2 or prev.shape[-1] != d):
                wd = prev.shape[-1] if prev.ndim >= 2 else 1
                raise ValueError(
                    f"warm attrs carry feature_dim {wd} but this "
                    f"engine runs {alg.name} at feature_dim {d}; "
                    f"warm state shape {prev.shape} != {want}")
            if prev.ndim == len(want) - 1:   # shared across the batch
                prev = np.broadcast_to(prev, want)
            if prev.shape != want:
                raise ValueError(
                    f"warm attrs shape {prev.shape} does not match "
                    f"{want} (B={b}, n={bg.n}"
                    + (f", d={d})" if features else ")"))
            attrs = bg.to_tiled(prev, features=features)
            frontier = np.zeros((b, bg.padded_n), dtype=bool)
            seeds = np.asarray(warm.seeds, dtype=np.int64)
            frontier[:, bg.perm[seeds]] = True
        else:
            attrs = bg.to_tiled(
                alg.initial_attrs(bg.n, srcs, feature_dim=d),
                features=features)
            frontier = np.zeros((b, bg.padded_n), dtype=bool)
            frontier[:, bg.perm] = alg.initial_frontier(bg.n, srcs,
                                                        feature_dim=d)
        aux_shape = (b, bg.n, d) if features else (b, bg.n)
        aux = bg.to_tiled(np.zeros(aux_shape, dtype=np.float32), fill=0.0,
                          features=features)
        return attrs, aux, jnp.asarray(
            frontier.reshape(b, bg.ntiles, bg.tile))

    def _step(self, attrs, aux, frontier, with_stats: bool = False):
        alg, features = self.algebra, self._features
        sv, carry = alg.scatter_carry_jnp(attrs, frontier,
                                          op_mode=(self.mode == "op"),
                                          features=features)
        new = frontier_relax(sv, carry, self.bg, mode=self.relax_mode,
                             compact=self._use_compact,
                             feature_dim=self.feature_dim)
        out = alg.post_step_jnp(attrs, aux, sv, new, features=features)
        if not with_stats:
            return out
        return out, self._step_stats_jit()(sv, frontier)

    def _step_stats(self, sv, frontier):
        """One trace row's worth of per-step stats, computed from the
        exact quantities the compaction machinery derives anyway: the
        frontier entering the step, the per-tile activity of the
        scattered source values (the kernel's packet-trigger condition),
        and the resulting active-block count. Pure extra outputs -- the
        step math never reads them, so traced runs stay bit-identical.

        Returns ``(active_vertices (B,), active_tiles (), fetched ())``
        as i32; `fetched` is the blocks streamed from HBM this step
        (active blocks under compaction, all blocks under dense)."""
        bg = self.bg
        act = tile_activity(sv, bg.semiring, self._features)  # (ntiles,)
        active_tiles = jnp.sum(act.astype(jnp.int32))
        nb = bg.bsrc.shape[0]
        if self._use_compact:
            fetched = jnp.sum(jnp.take(act, bg.bsrc).astype(jnp.int32))
        else:
            fetched = jnp.int32(nb)
        active_v = jnp.sum(frontier, axis=(1, 2)).astype(jnp.int32)
        return active_v, active_tiles, fetched

    def _step_stats_jit(self):
        """`_step_stats` as one cached jitted dispatch: the host-driven
        fixpoint runs its step eagerly (it must read concrete frontiers),
        so fusing the half-dozen stat reductions into a single call keeps
        traced host steps within the overhead bound. Inside the jitted
        while_loop body the same tracing inlines and the wrapper is
        free."""
        fn = self.__dict__.get("_step_stats_fn")
        if fn is None:
            fn = self.__dict__["_step_stats_fn"] = jax.jit(self._step_stats)
        return fn

    def _masked_step(self, attrs, aux, frontier, live,
                     with_stats: bool = False):
        """One relax step with the per-query freeze applied: queries not
        in `live` ((B,) bool -- frontier emptied, or step/deadline budget
        exhausted) keep their state *and their frontier* untouched, so a
        budget-frozen query still reads as non-converged (frontier
        non-empty) while a finished one stays finished (its frontier
        emptied naturally). The single body behind both fixpoint
        drivers, so host-driven and while_loop runs stay bit-for-bit
        identical."""
        stepped = self._step(attrs, aux, frontier, with_stats=with_stats)
        (attrs_n, aux_n, frontier_n), stats = \
            stepped if with_stats else (stepped, None)
        # live broadcasts from the query axis over every trailing state
        # axis: (B, 1, 1) against (B, ntiles, T), one more 1 at d > 1
        ms = live.reshape(live.shape + (1,) * (attrs.ndim - 1))
        out = (jnp.where(ms, attrs_n, attrs),
               jnp.where(ms, aux_n, aux),
               jnp.where(live[:, None, None], frontier_n, frontier))
        return (out, stats) if with_stats else out

    def _fixpoint(self, attrs0, aux0, frontier0, trace_cap: int = 0,
                  budgets=None, deadlines_t=None):
        """Shared (B, ntiles, T) while_loop with per-query convergence
        masking: a query whose frontier emptied is frozen, so late
        queries in the batch cannot perturb finished ones (op-mode
        sweeps and residual aux accumulation would otherwise keep
        touching them) and per-query step counts match solo runs.

        `budgets` ((B,) i32, default: `max_steps` everywhere) is the
        per-query step cap: a query that reaches its budget with a
        non-empty frontier is frozen exactly like a converged one but
        keeps its frontier, so the final per-query convergence mask
        (returned as the 5th element) reads False for it -- a partial
        result is always *flagged*, never silently truncated. Budgets
        are a traced argument of the one compiled while_loop, so
        varying them never retraces.

        `deadlines_t` ((B,) absolute `time.monotonic` deadlines, +inf =
        none) needs host-observable step boundaries, so any finite
        deadline routes the fixpoint through the host driver (same
        body, bit-for-bit results). Compacted jnp streaming routes
        there too (concrete frontiers pick the bucket sizes).

        `trace_cap > 0` additionally records one per-step stats row into
        fixed-shape (trace_cap, ...) buffers riding the carry (see
        `_step_stats`). Returns ``(attrs, aux, frontier, steps, trace,
        converged, expired)`` where `trace` is a `(StepTrace, truncated)`
        pair or None, `converged` is the (B,) bool end-of-run mask, and
        `expired` marks deadline-stopped queries. The final frontier is
        part of the return so a bounded-budget run is *resumable*: the
        continuous-batching scheduler (`repro.serving`) re-enters with
        the same state to run the next segment. The stat buffers are
        write-only extra outputs, so attrs and step counts are
        bit-identical either way."""
        b = attrs0.shape[0]
        if budgets is None:
            budgets = jnp.full((b,), self.max_steps, dtype=jnp.int32)
        else:
            budgets = jnp.asarray(np.broadcast_to(
                np.asarray(budgets, dtype=np.int32), (b,)))
        deadlined = (deadlines_t is not None
                     and bool(np.isfinite(deadlines_t).any()))
        if deadlined or (self._use_compact
                         and self._resolved_relax_mode() == "jnp"):
            return self._fixpoint_host(attrs0, aux0, frontier0, trace_cap,
                                       budgets=budgets,
                                       deadlines_t=deadlines_t)
        out = self._dense_fixpoint_jit(trace_cap)(attrs0, aux0, frontier0,
                                                  budgets)
        attrs, aux, frontier, steps = out[0], out[1], out[2], out[3]
        converged = ~np.asarray(frontier.any(axis=(1, 2)))
        expired = np.zeros(b, dtype=bool)
        if not trace_cap:
            return attrs, aux, frontier, steps, None, converged, expired
        n_iter = int(out[5])
        rows = min(n_iter, trace_cap)
        b_av, b_at, b_bf, b_cv = (np.asarray(x)[:rows] for x in out[6])
        nb = int(self.bg.bsrc.shape[0])
        trace = StepTrace(active_vertices=b_av, active_tiles=b_at,
                          blocks_fetched=b_bf,
                          blocks_skipped=np.int32(nb) - b_bf,
                          converged=b_cv)
        return (attrs, aux, frontier, steps,
                (trace, n_iter > trace_cap), converged, expired)

    def _dense_fixpoint_jit(self, trace_cap: int):
        """The whole dense while_loop compiled as ONE jitted program per
        (engine, trace_cap), cached on the instance: eager per-call
        dispatch of the loop would otherwise dominate the step cost (and
        blow the traced/untraced overhead bound). The traced variant only
        adds fixed-shape stat-buffer writes to the carry, so both compile
        to the same fused step with tracing as a few extra reductions."""
        cache = self.__dict__.setdefault("_fixpoint_cache", {})
        fn = cache.get(trace_cap)
        if fn is not None:
            return fn

        def live_mask(frontier, steps, budgets):
            """(B,) per-query liveness: frontier still active AND the
            step budget not yet exhausted. Budget-capped queries drop
            out of the loop but keep their (non-empty) frontier, which
            is exactly how the final convergence mask spots them."""
            return jnp.logical_and(frontier.any(axis=(1, 2)),
                                   steps < budgets)

        def cond(state):
            frontier, steps, budgets = state[2], state[3], state[4]
            return live_mask(frontier, steps, budgets).any()

        def body(state):
            attrs, aux, frontier, steps, budgets = state[:5]
            live = live_mask(frontier, steps, budgets)
            if not trace_cap:
                attrs, aux, frontier = self._masked_step(attrs, aux,
                                                         frontier, live)
                return (attrs, aux, frontier,
                        steps + live.astype(jnp.int32), budgets)
            it, (b_av, b_at, b_bf, b_cv) = state[5], state[6]
            (attrs, aux, frontier), (av, at, bf) = self._masked_step(
                attrs, aux, frontier, live, with_stats=True)
            # rows past the capacity are dropped, not wrapped: the trace
            # stays a prefix of the run and `truncated` flags the cut
            bufs = (b_av.at[it].set(av, mode="drop"),
                    b_at.at[it].set(at, mode="drop"),
                    b_bf.at[it].set(bf, mode="drop"),
                    b_cv.at[it].set(~live, mode="drop"))
            return (attrs, aux, frontier, steps + live.astype(jnp.int32),
                    budgets, it + 1, bufs)

        @jax.jit
        def run(attrs0, aux0, frontier0, budgets):
            b = attrs0.shape[0]
            state0 = (attrs0, aux0, frontier0, jnp.zeros(b, jnp.int32),
                      budgets)
            if trace_cap:
                bufs0 = (jnp.zeros((trace_cap, b), jnp.int32),
                         jnp.zeros((trace_cap,), jnp.int32),
                         jnp.zeros((trace_cap,), jnp.int32),
                         jnp.zeros((trace_cap, b), bool))
                state0 = state0 + (jnp.int32(0), bufs0)
            return jax.lax.while_loop(cond, body, state0)

        cache[trace_cap] = run
        return run

    def _fixpoint_host(self, attrs, aux, frontier, trace_cap: int = 0,
                       budgets=None, deadlines_t=None):
        """Host-driven fixpoint for compacted jnp streaming and for
        deadline-budgeted queries: identical body semantics to the
        while_loop above (same live-mask freezing, same step accounting
        -- bit-for-bit results), but each step reads the concrete
        frontier so `frontier_relax` can bucket the compacted block list
        -- and, because every step boundary is host-observable, this is
        where per-query deadlines are enforced: a query whose
        `deadlines_t` entry has passed is frozen (kept frontier, so it
        reads non-converged) before the next step starts; work already
        done is returned as a flagged partial result.

        With `trace_cap`, stats rows are recorded host-side -- and since
        this loop observes every step from the host anyway, it also
        records real per-step wall times (`StepTrace.step_wall_s`),
        which the on-device while_loop cannot."""
        b = int(attrs.shape[0])
        if budgets is None:
            budgets = np.full(b, self.max_steps, dtype=np.int32)
        budgets = np.asarray(budgets)
        deadlines = (None if deadlines_t is None
                     or not np.isfinite(deadlines_t).any()
                     else np.broadcast_to(np.asarray(deadlines_t,
                                                     dtype=np.float64),
                                          (b,)))
        expired = np.zeros(b, dtype=bool)
        steps = np.zeros(b, np.int32)
        rows: list[tuple] = []
        walls: list[float] = []
        n_iter = 0
        t0 = time.perf_counter()
        while True:
            # this concrete read is the loop's natural per-step sync: it
            # also closes the previous traced step's wall measurement, so
            # tracing adds no extra host<->device round trips
            active = np.asarray(frontier.any(axis=(1, 2)))
            if len(walls) < len(rows):
                walls.append(time.perf_counter() - t0)
            if deadlines is not None:
                # a deadline only *expires* a query that still has work
                # left: converged queries met their deadline by definition
                expired |= active & (deadlines <= time.monotonic())
            live = active & ~expired & (steps < budgets)
            if not live.any():
                break
            t0 = time.perf_counter()
            if trace_cap:
                (attrs, aux, frontier), st = self._masked_step(
                    attrs, aux, frontier, jnp.asarray(live),
                    with_stats=True)
                if n_iter < trace_cap:
                    # stats stay on device until after the loop: only
                    # the row tuple is kept per step
                    av, at, bf = st
                    rows.append((av, at, bf, ~live))
            else:
                attrs, aux, frontier = self._masked_step(
                    attrs, aux, frontier, jnp.asarray(live))
            steps = steps + live.astype(np.int32)
            n_iter += 1
        converged = ~np.asarray(frontier.any(axis=(1, 2)))
        if not trace_cap:
            return (attrs, aux, frontier, jnp.asarray(steps), None,
                    converged, expired)
        nb = int(self.bg.bsrc.shape[0])
        bf = np.asarray([int(r[2]) for r in rows], dtype=np.int32)
        trace = StepTrace(
            active_vertices=(np.stack([np.asarray(r[0]) for r in rows])
                             .astype(np.int32) if rows
                             else np.zeros((0, b), np.int32)),
            active_tiles=np.asarray([int(r[1]) for r in rows],
                                    dtype=np.int32),
            blocks_fetched=bf,
            blocks_skipped=np.int32(nb) - bf,
            converged=(np.stack([r[3] for r in rows]) if rows
                       else np.zeros((0, b), bool)),
            step_wall_s=np.asarray(walls, dtype=np.float64))
        return (attrs, aux, frontier, jnp.asarray(steps),
                (trace, n_iter > trace_cap), converged, expired)

    # -------------------------------------------------------------- #
    # the one plan-driven executor
    # -------------------------------------------------------------- #
    def execute(self, srcs, *, warm: WarmStart | None = None,
                distributed: bool = False, mesh: Mesh | None = None,
                axis: str = "data", trace: bool | int = False,
                max_steps=None, deadline_s=None, detail: bool = False):
        """The single execution entry point every layer drives.

        One call uniformly covers what used to be four methods: a scalar
        `srcs` is a solo query (`(n,)` result, int steps), a sequence is
        a batch (`(B, n)` / `(B,)`), `warm` resumes from a prior
        converged result (incremental recompute, see `WarmStart` /
        `resolve_warm`), and `distributed=True` runs the shard_map
        fixpoint over `mesh` (default: all local devices) instead of the
        local one. Results are bit-for-bit identical across all of these
        axes -- batching, distribution, and warm starts never change the
        fixpoint, only how it is reached.

        `trace` turns on per-step frontier tracing (True = the default
        `TRACE_CAP_DEFAULT` row capacity, an int = that capacity) and
        makes the call return ``(out, steps, DispatchTelemetry)``
        instead of ``(out, steps)``; results and step counts are
        bit-identical with tracing on. Tracing the shard_map fixpoint is
        not supported yet.

        `max_steps` (int or (B,) per-query ints) caps each query's
        relaxation steps below the session-wide `self.max_steps` valve;
        `deadline_s` (relative seconds, scalar or (B,) per query) stops
        a query at the first host-observable step boundary past its
        deadline. Either budget can leave a query short of its fixpoint
        -- the partial result is *flagged* via the per-query convergence
        mask, which `detail=True` exposes: the call then returns an
        `ExecutionDetail` (attrs / steps / converged / deadline_expired
        / telemetry) instead of the bare tuple. Deadlines are a local
        (host-driven) mechanism; a distributed plan rejects them.

        `repro.api.CompiledQuery` is the intended driver: it resolves an
        `ExecutionPlan` into these arguments. The legacy `run*` methods
        are deprecated shims over this method.
        """
        batched = bool(np.ndim(srcs))
        srcs = np.atleast_1d(np.asarray(srcs, dtype=np.int64))
        budgets = self._resolve_budgets(max_steps, len(srcs))
        deadlines_t = self._resolve_deadlines(deadline_s, len(srcs))
        if distributed:
            if trace:
                raise ValueError(
                    "per-step tracing is not supported on the "
                    "distributed (shard_map) fixpoint yet; run the "
                    "trace on a local plan")
            if deadlines_t is not None:
                raise InvalidRequest(
                    "deadline_s is not supported on the distributed "
                    "(shard_map) fixpoint: deadlines are enforced at "
                    "host-observable step boundaries -- use max_steps, "
                    "or run on a local plan")
            out, steps, conv = self._execute_distributed(
                srcs, warm=warm, mesh=mesh, axis=axis, budgets=budgets)
            tele, expired = None, np.zeros(len(srcs), dtype=bool)
        else:
            out, steps, tele, conv, expired = self._execute_local(
                srcs, warm=warm, trace_cap=self._trace_cap(trace),
                budgets=budgets, deadlines_t=deadlines_t)
        if detail:
            if batched:
                return ExecutionDetail(attrs=out, steps=steps,
                                       converged=conv,
                                       deadline_expired=expired,
                                       telemetry=tele)
            return ExecutionDetail(attrs=out[0], steps=int(steps[0]),
                                   converged=bool(conv[0]),
                                   deadline_expired=bool(expired[0]),
                                   telemetry=tele)
        r = (out, steps) if batched else (out[0], int(steps[0]))
        return r + (tele,) if trace else r

    def _resolve_budgets(self, max_steps, b: int):
        """Per-query step budgets ((B,) i32) from a caller cap: None
        keeps the session valve; an int or (B,) sequence is validated
        (>= 1) and clipped to `self.max_steps`."""
        if max_steps is None:
            return None
        budgets = np.atleast_1d(np.asarray(max_steps))
        if not np.issubdtype(budgets.dtype, np.integer):
            raise InvalidRequest(
                f"max_steps must be an int or a sequence of ints, got "
                f"dtype {budgets.dtype}", value=max_steps)
        if budgets.shape not in ((1,), (b,)):
            raise InvalidRequest(
                f"max_steps shape {budgets.shape} does not match the "
                f"{b} queries (scalar or one budget per query)",
                value=max_steps)
        if (budgets < 1).any():
            bad = int(budgets[budgets < 1][0])
            raise InvalidRequest(
                f"max_steps must be >= 1, got {bad}", value=bad)
        return np.minimum(
            np.broadcast_to(budgets, (b,)), self.max_steps
        ).astype(np.int32)

    def _resolve_deadlines(self, deadline_s, b: int):
        """Absolute per-query `time.monotonic` deadlines ((B,) f64) from
        relative seconds (scalar or per query; None / non-finite entries
        mean no deadline)."""
        if deadline_s is None:
            return None
        now = time.monotonic()
        rel = np.atleast_1d(np.asarray(
            [np.inf if d is None else float(d)
             for d in np.atleast_1d(deadline_s)], dtype=np.float64))
        if rel.shape not in ((1,), (b,)):
            raise InvalidRequest(
                f"deadline_s shape {rel.shape} does not match the "
                f"{b} queries (scalar or one deadline per query)",
                value=deadline_s)
        # rel <= 0 is legal here: a bucketed query's later chunks may
        # arrive with their deadline already spent -- they come back
        # immediately as flagged partials (the session validates that
        # *caller-supplied* deadlines are positive)
        if not np.isfinite(rel).any():
            return None
        return np.broadcast_to(now + rel, (b,)).copy()

    def _trace_cap(self, trace: bool | int) -> int:
        """0 (off) or the per-step trace row capacity."""
        if not trace:
            return 0
        cap = TRACE_CAP_DEFAULT if trace is True else int(trace)
        return max(1, min(cap, self.max_steps))

    def resolve_warm(self, prev, delta: UpdateDelta) -> WarmStart | None:
        """Warm-start dispatch after `apply_updates`: a `delta.monotone`
        batch on a monotone algebra may resume from `prev` with only
        `delta.affected_src` seeded active; anything else must recompute
        from scratch (returns None)."""
        if delta.monotone and self.algebra.kind == "monotone":
            return WarmStart(attrs=np.asarray(prev, dtype=np.float32),
                             seeds=delta.affected_src)
        return None

    def _execute_local(self, srcs, warm: WarmStart | None = None,
                       trace_cap: int = 0, budgets=None,
                       deadlines_t=None):
        """Local fixpoint over a (B,) source array; always batched.
        Returns ``(out, steps, DispatchTelemetry | None, converged,
        deadline_expired)`` -- the last two are (B,) bool masks."""
        attrs0, aux0, frontier0 = self.initial_state(srcs, warm=warm)
        t0 = time.perf_counter()
        attrs, aux, _, steps, rec, converged, expired = self._fixpoint(
            attrs0, aux0, frontier0, trace_cap, budgets=budgets,
            deadlines_t=deadlines_t)
        out = self.bg.to_orig(self.algebra.finalize(attrs, aux),
                              features=self._features)
        steps = np.asarray(steps)
        tele = None
        if rec is not None:
            trace, truncated = rec
            tele = DispatchTelemetry(
                backend=self._resolved_relax_mode(), mode=self.mode,
                compact=self._use_compact, batch=int(steps.shape[0]),
                n=self.bg.n, ntiles=self.bg.ntiles,
                n_blocks=int(self.bg.bsrc.shape[0]), steps=steps,
                trace=trace, wall_s=time.perf_counter() - t0,
                truncated=truncated, tile=self.bg.tile,
                feature_dim=self.feature_dim)
        return out, steps, tele, converged, expired

    # -------------------------------------------------------------- #
    # bounded-segment stepping: the continuous-batching yield surface
    # -------------------------------------------------------------- #
    def idle_state(self, b: int):
        """(B, ntiles, T[, d]) state with every query lane *inert*:
        ⊕-identity attrs, zero aux, empty frontier. An inert lane is
        frozen by the per-query live mask (its frontier never fills), so
        it costs nothing and cannot perturb the other lanes -- the
        rotating batch's empty slots live in this state until a queued
        query is admitted into them (`write_slot`)."""
        bg = self.bg
        zero = np.float32(self.algebra.semiring.zero)
        shape = (b, bg.ntiles, bg.tile)
        if self._features:
            shape = shape + (self.feature_dim,)
        return (jnp.full(shape, zero, dtype=jnp.float32),
                jnp.zeros(shape, dtype=jnp.float32),
                jnp.zeros((b, bg.ntiles, bg.tile), dtype=bool))

    def write_slot(self, state, b: int, src: int,
                   warm: WarmStart | None = None):
        """Admit one query into lane `b` of a rotating-batch state:
        lane `b` of (attrs, aux, frontier) is overwritten with the
        freshly initialized (or warm-resumed) solo state of `src`, all
        other lanes are untouched. Because every fixpoint operation is
        independent along the batch axis (the PR-2 bit-exactness
        contract), the admitted lane then evolves exactly as a solo run
        of `src` would -- regardless of what the other lanes are doing."""
        attrs, aux, frontier = state
        a1, x1, f1 = self.initial_state([int(src)], warm=warm)
        return (jnp.asarray(attrs).at[b].set(jnp.asarray(a1)[0]),
                jnp.asarray(aux).at[b].set(jnp.asarray(x1)[0]),
                jnp.asarray(frontier).at[b].set(jnp.asarray(f1)[0]))

    def run_segment(self, state, budgets):
        """Advance a (B, ...) fixpoint state by a bounded segment: lane
        `b` runs at most ``budgets[b]`` further steps (0 = frozen) and
        stops early the moment its frontier empties. This is the
        step-boundary yield hook the continuous-batching scheduler
        (`repro.serving`) is built on: between segments the host can
        retire converged lanes, admit queued queries into idle lanes,
        and enforce deadlines -- then re-enter with the same state.

        Returns ``(state, steps, converged)``: the advanced (attrs, aux,
        frontier) triple, the (B,) i32 steps actually taken this
        segment, and the (B,) bool end-of-segment convergence mask
        (True = frontier empty; inert/idle lanes read True).

        Segmenting is exact: the per-step body is `_masked_step` -- the
        same body both fixpoint drivers run -- so K-step segments
        compose into bit-for-bit the single-call fixpoint, per lane
        (budgets only partition the step sequence; they never change
        it). The dense while_loop path takes budgets as a traced
        argument, so varying segment lengths never retrace."""
        attrs, aux, frontier = state
        budgets = jnp.asarray(np.asarray(budgets, dtype=np.int32))
        attrs, aux, frontier, steps, _, converged, _ = self._fixpoint(
            attrs, aux, frontier, 0, budgets=budgets)
        return ((attrs, aux, frontier), np.asarray(steps),
                np.asarray(converged))

    def finalize_state(self, attrs, aux) -> np.ndarray:
        """Finalize a (tiled) fixpoint state into original-vertex-order
        results: (B, ntiles, T[, d]) -> (B, n[, d]). Lane-independent,
        so a rotating batch can finalize just the retiring lane by
        slicing ``attrs[b:b+1]``."""
        return self.bg.to_orig(self.algebra.finalize(attrs, aux),
                               features=self._features)

    # -------------------------------------------------------------- #
    # streaming graph mutations: delta-driven incremental recompute
    # -------------------------------------------------------------- #
    def apply_updates(self, new_graph: Graph,
                      updates) -> tuple["FlipEngine", "UpdateDelta"]:
        """Incremental re-block after a mutation batch: `new_graph` is
        the post-update Graph (``graph.apply_updates(updates)``). Only
        the touched tiles are rebuilt (`BlockedGraph.apply_updates`);
        value-only rebuilds keep every array shape, so the returned
        engine hits the same compiled executables. Returns
        ``(new_engine, delta)`` -- this engine is left untouched."""
        bg2, delta = self.bg.apply_updates(new_graph, updates)
        return dataclasses.replace(self, bg=bg2), delta

    # -------------------------------------------------------------- #
    def _execute_distributed(self, srcs, warm: WarmStart | None = None,
                             mesh: Mesh | None = None, axis: str = "data",
                             budgets=None):
        """shard_map fixpoint over a (B,) source array; always batched:
        destination tiles sharded over `axis`, queries replicated.
        `warm` resumes from a prior converged result (see `WarmStart`),
        so incremental recompute after a monotone update batch works
        distributed too.

        Each device owns a contiguous slab of destination tiles and the
        blocks that write them; per step it computes its slab's new attrs
        for every query in the batch and the global attribute vector is
        re-formed with an all-gather (the TPU analogue of FLIP's NoC
        scatter) -- one collective per step regardless of B, so the NoC
        cost amortizes over the batch. Works for every registered algebra
        in both 'data' and 'op' modes; a device whose slab holds only
        padded tiles owns zero real blocks and runs identity no-op blocks.

        Because blocks are bdst-sorted, each device's slab is one
        contiguous range of the block list, sliced directly from the
        precomputed per-destination layout (`bg.dst_start`). In data mode
        the per-device frontier compaction is the degenerate exact form:
        a device none of whose local blocks has an active source returns
        its carry without touching the weight slab (`lax.cond`), so
        frontier locality idles whole devices just like FLIP's inactive
        PE clusters.
        """
        if mesh is None:
            devs = np.array(jax.devices())
            mesh = Mesh(devs, (axis,))
        ndev = mesh.shape[axis]
        bg, alg = self.bg, self.algebra
        sr = alg.semiring
        zero = np.float32(sr.zero)

        # pad tiles to a multiple of ndev, then slice each device's block
        # slab straight out of the bdst-sorted list via the precomputed
        # per-destination layout (no per-block Python loop)
        ntiles_p = -(-bg.ntiles // ndev) * ndev
        bsrc, bdst = np.asarray(bg.bsrc), np.asarray(bg.bdst)
        tiles_per_dev = ntiles_p // ndev
        bounds = np.minimum(np.arange(0, ntiles_p + 1, tiles_per_dev),
                            bg.ntiles)
        starts = np.asarray(bg.dst_start)[bounds]        # (ndev+1,)
        # >= 1 so a device owning zero blocks still gets a (1, T, T)
        # all-identity slab (exact no-op) instead of a zero-size array
        max_nb = max(1, int(np.diff(starts).max()))
        t = bg.tile
        blocks_sh = np.full((ndev, max_nb, t, t), zero, dtype=np.float32)
        bsrc_sh = np.zeros((ndev, max_nb), dtype=np.int32)
        bdst_sh = np.zeros((ndev, max_nb), dtype=np.int32)
        valid_sh = np.zeros((ndev, max_nb), dtype=bool)
        blocks_np = np.asarray(bg.blocks)
        for dev in range(ndev):
            s, e = int(starts[dev]), int(starts[dev + 1])
            blocks_sh[dev, :e - s] = blocks_np[s:e]
            bsrc_sh[dev, :e - s] = bsrc[s:e]
            # destination indices local to the device slab
            bdst_sh[dev, :e - s] = bdst[s:e] - dev * tiles_per_dev
            valid_sh[dev, :e - s] = True
            # padding blocks (and the whole slab of a block-less device)
            # keep bsrc/bdst 0 and all ⊕-identity entries = exact no-op;
            # valid=False keeps them out of the idle-skip predicate (a
            # padding slot's bsrc points at global tile 0, whose activity
            # must not keep this device awake)

        features = self._features
        attrs0, aux0, frontier0 = self.initial_state(srcs, warm=warm)
        pad = ntiles_p - bg.ntiles
        if pad:
            widths = ((0, 0), (0, pad)) + ((0, 0),) * (attrs0.ndim - 2)
            attrs0 = jnp.pad(attrs0, widths, constant_values=zero)
            aux0 = jnp.pad(aux0, widths)
            frontier0 = jnp.pad(frontier0, ((0, 0), (0, pad), (0, 0)))
        op_mode = self.mode == "op"
        skip_idle = self._use_compact
        if budgets is None:
            budgets0 = np.full(srcs.shape[0], self.max_steps,
                               dtype=np.int32)
        else:
            budgets0 = np.asarray(budgets, dtype=np.int32)

        @functools.partial(
            shard_map, mesh=mesh,
            in_specs=(P(axis), P(axis), P(axis), P(axis),
                      P(None), P(None), P(None), P(None)),
            out_specs=(P(None), P(None), P(None), P(None)),
            check_rep=False)
        def dist_fix(blocks, bsrc_l, bdst_l, valid_l, attrs, aux, frontier,
                     budgets):
            blocks, bsrc_l, bdst_l, valid_l = (blocks[0], bsrc_l[0],
                                               bdst_l[0], valid_l[0])

            def cond(state):
                _, _, frontier, steps = state
                return jnp.logical_and(frontier.any(axis=(1, 2)),
                                       steps < budgets).any()

            def relax_local(args):
                svb, carry_local = args
                if features:        # (B, nb, T, d) x (nb, T, T) contraction
                    cand = sr.contract_jnp(svb, blocks)
                else:
                    cand = sr.add_reduce_jnp(
                        sr.mul_jnp(svb[..., :, None], blocks), axis=-2)
                best = jax.vmap(lambda c: sr.segment_reduce_jnp(
                    c, bdst_l, tiles_per_dev))(cand)
                return sr.add_jnp(carry_local, best)

            def body(state):
                attrs, aux, frontier, steps = state
                live = jnp.logical_and(frontier.any(axis=(1, 2)),
                                       steps < budgets)
                sv, carry = alg.scatter_carry_jnp(attrs, frontier, op_mode,
                                                  features=features)
                carry_local = jax.lax.dynamic_slice_in_dim(
                    carry, jax.lax.axis_index(axis) * tiles_per_dev,
                    tiles_per_dev, axis=1)
                svb = sv[:, bsrc_l]                        # (B, nb, T[, d])
                valid_b = valid_l.reshape(
                    (1, -1) + (1,) * (svb.ndim - 2))
                if skip_idle:
                    # per-device frontier compaction, degenerate exact
                    # form: no active source among the local *real*
                    # blocks (any query) => the local relax is pure
                    # ⊕-identity, so return the carry without touching
                    # the weight slab. Padding slots are masked out --
                    # their bsrc points at global tile 0, whose activity
                    # must not keep an otherwise idle device awake.
                    new_local = jax.lax.cond(
                        jnp.any(jnp.logical_and(svb != zero, valid_b)),
                        relax_local, lambda args: args[1],
                        (svb, carry_local))
                else:
                    new_local = relax_local((svb, carry_local))
                new = jax.lax.all_gather(new_local, axis, axis=1,
                                         tiled=True)
                attrs_n, aux_n, frontier_n = alg.post_step_jnp(
                    attrs, aux, sv, new, features=features)
                ms = live.reshape(live.shape + (1,) * (attrs.ndim - 1))
                return (jnp.where(ms, attrs_n, attrs),
                        jnp.where(ms, aux_n, aux),
                        jnp.where(live[:, None, None], frontier_n,
                                  frontier),
                        steps + live.astype(jnp.int32))

            steps0 = jnp.zeros(attrs.shape[0], jnp.int32)
            attrs_f, aux_f, frontier_f, steps = jax.lax.while_loop(
                cond, body, (attrs, aux, frontier, steps0))
            conv = jnp.logical_not(frontier_f.any(axis=(1, 2)))
            return attrs_f, aux_f, steps, conv

        blocks_sh = jnp.asarray(blocks_sh)
        attrs_f, aux_f, steps, conv = jax.jit(dist_fix)(
            blocks_sh, jnp.asarray(bsrc_sh), jnp.asarray(bdst_sh),
            jnp.asarray(valid_sh), attrs0, aux0, frontier0,
            jnp.asarray(budgets0))
        out = self.algebra.finalize(attrs_f, aux_f)
        out = self.bg.to_orig(out[:, :bg.ntiles], features=features)
        return out, np.asarray(steps), np.asarray(conv)

    # -------------------------------------------------------------- #
    # deprecated pre-api entry points: thin shims over `execute`
    # -------------------------------------------------------------- #
    @staticmethod
    def _warn_legacy(name: str) -> None:
        warnings.warn(
            f"FlipEngine.{name} is deprecated; compile a session with "
            "flip.compile(graph, program, plan) (repro.api) and call "
            ".query(...), or drive FlipEngine.execute directly",
            DeprecationWarning, stacklevel=3)

    def run(self, src: int = 0, warm: WarmStart | None = None):
        """Deprecated: `execute(src)`. Single-query fixpoint; returns
        the algebra's result vector in original vertex order plus the
        number of relaxation steps taken."""
        self._warn_legacy("run")
        return self.execute(int(src), warm=warm)

    def run_batch(self, srcs, warm: WarmStart | None = None):
        """Deprecated: `execute(srcs)` with a sequence. Batched fixpoint
        over B independent sources sharing one weight-block stream;
        returns ((B, n) results, (B,) per-query step counts), each row
        bit-for-bit the corresponding solo result."""
        self._warn_legacy("run_batch")
        return self.execute(np.atleast_1d(np.asarray(srcs)), warm=warm)

    def run_distributed(self, src=0, mesh: Mesh | None = None,
                        axis: str = "data", warm: WarmStart | None = None):
        """Deprecated: `execute(src, distributed=True)`. shard_map
        fixpoint with destination tiles sharded over `axis`; shapes
        follow `src` like `execute`."""
        self._warn_legacy("run_distributed")
        return self.execute(src, warm=warm, distributed=True,
                            mesh=mesh, axis=axis)

    def run_updated(self, src, prev, delta: UpdateDelta):
        """Deprecated: `execute(src, warm=resolve_warm(prev, delta))`.
        Recompute after `apply_updates`, incrementally when sound (see
        `resolve_warm`); the result is bit-for-bit the from-scratch
        fixpoint on the updated graph either way."""
        self._warn_legacy("run_updated")
        return self.execute(src, warm=self.resolve_warm(prev, delta))
