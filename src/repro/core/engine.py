"""FLIP JAX engine: the TPU-native data-centric execution layer.

Two execution modes, matching the paper's dual-mode fabric (Sec. 3.4):

  * data-centric  -- frontier-driven: each step relaxes only blocks with
    active sources (the Pallas kernel skips inactive tiles), and the new
    frontier is the set of vertices whose attribute improved. This is
    FLIP's packet-triggered execution, vectorized.
  * op-centric    -- classic CGRA analogue: a full (unmasked) relaxation
    sweep every step (Bellman-Ford style), no data-driven skipping.

Both run inside one `jax.lax.while_loop` fixpoint and can execute
distributed via `shard_map`: destination tiles are partitioned over a mesh
axis (devices = PE clusters), each device relaxes its local blocks, and the
updated attribute vector is re-assembled with an all-gather -- the
collective is the NoC.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro.core.mapping import Mapping
from repro.core.vertex_program import VertexProgram
from repro.graphs.csr import Graph
from repro.kernels.frontier.ops import BlockedGraph, build_blocks, frontier_relax

INF = jnp.inf


def mapping_order(mapping: Mapping) -> np.ndarray:
    """Vertex ordering induced by the FLIP placement: vertices co-located
    on a (copy, PE) become adjacent tile positions, so the compiled
    placement's locality becomes block-sparsity."""
    keys = [(int(mapping.copy_of[v]), int(mapping.pe_of[v]), v)
            for v in range(mapping.graph.n)]
    return np.asarray([v for _, _, v in sorted(keys)], dtype=np.int64)


@dataclasses.dataclass
class FlipEngine:
    """Compiled graph + algorithm, ready to run on CPU or a device mesh."""

    bg: BlockedGraph
    algo: str
    mode: str = "data"          # 'data' (FLIP) or 'op' (classic CGRA)
    relax_mode: str = "auto"    # kernel dispatch: auto/pallas/interpret/jnp
    max_steps: int = 100_000

    # -------------------------------------------------------------- #
    @staticmethod
    def build(graph: Graph, algo: str, mapping: Mapping | None = None,
              tile: int = 128, mode: str = "data",
              relax_mode: str = "auto") -> "FlipEngine":
        order = mapping_order(mapping) if mapping is not None else None
        bg = build_blocks(graph, algo=algo, tile=tile, order=order)
        return FlipEngine(bg=bg, algo=algo, mode=mode, relax_mode=relax_mode)

    # -------------------------------------------------------------- #
    def initial_state(self, src: int):
        bg = self.bg
        if self.algo == "wcc":
            attrs = np.full(bg.padded_n, np.inf, dtype=np.float32)
            attrs[bg.perm] = np.arange(bg.n, dtype=np.float32)
            frontier = np.zeros(bg.padded_n, dtype=bool)
            frontier[bg.perm] = True
        else:
            attrs = np.full(bg.padded_n, np.inf, dtype=np.float32)
            attrs[bg.perm[src]] = 0.0
            frontier = np.zeros(bg.padded_n, dtype=bool)
            frontier[bg.perm[src]] = True
        shape = (bg.ntiles, bg.tile)
        return jnp.asarray(attrs.reshape(shape)), jnp.asarray(
            frontier.reshape(shape))

    def _step(self, attrs, frontier):
        if self.mode == "op":
            src_vals = attrs                      # full sweep, no skipping
        else:
            src_vals = jnp.where(frontier, attrs, INF)
        new = frontier_relax(src_vals, attrs, self.bg, mode=self.relax_mode)
        return new, new < attrs

    # -------------------------------------------------------------- #
    def run(self, src: int = 0):
        """Single-device fixpoint; returns attrs in original vertex order
        plus the number of relaxation steps taken."""
        attrs0, frontier0 = self.initial_state(src)

        def cond(state):
            _, frontier, steps = state
            return jnp.logical_and(frontier.any(), steps < self.max_steps)

        def body(state):
            attrs, frontier, steps = state
            new, nf = self._step(attrs, frontier)
            return new, nf, steps + 1

        attrs, _, steps = jax.lax.while_loop(
            cond, body, (attrs0, frontier0, jnp.int32(0)))
        return self.bg.to_orig(attrs), int(steps)

    # -------------------------------------------------------------- #
    def run_distributed(self, src: int = 0, mesh: Mesh | None = None,
                        axis: str = "data"):
        """shard_map fixpoint: destination tiles sharded over `axis`.

        Each device owns a contiguous slab of destination tiles and the
        blocks that write them; per step it computes its slab's new attrs
        and the global attribute vector is re-formed with an all-gather
        (the TPU analogue of FLIP's NoC scatter).
        """
        if mesh is None:
            devs = np.array(jax.devices())
            mesh = Mesh(devs, (axis,))
        ndev = mesh.shape[axis]
        bg = self.bg

        # pad tiles to a multiple of ndev, then partition blocks by owner
        ntiles_p = -(-bg.ntiles // ndev) * ndev
        bsrc, bdst = np.asarray(bg.bsrc), np.asarray(bg.bdst)
        per_dev_blocks: list[list[int]] = [[] for _ in range(ndev)]
        tiles_per_dev = ntiles_p // ndev
        for i, d in enumerate(bdst):
            per_dev_blocks[d // tiles_per_dev].append(i)
        max_nb = max(len(b) for b in per_dev_blocks)
        t = bg.tile
        blocks_sh = np.zeros((ndev, max_nb, t, t), dtype=np.float32) + np.inf
        bsrc_sh = np.zeros((ndev, max_nb), dtype=np.int32)
        bdst_sh = np.zeros((ndev, max_nb), dtype=np.int32)
        blocks_np = np.asarray(bg.blocks)
        for dev, idxs in enumerate(per_dev_blocks):
            for j, i in enumerate(idxs):
                blocks_sh[dev, j] = blocks_np[i]
                bsrc_sh[dev, j] = bsrc[i]
                # destination indices local to the device slab
                bdst_sh[dev, j] = bdst[i] - dev * tiles_per_dev
            for j in range(len(idxs), max_nb):
                # padding blocks: write slab-local tile 0 with +inf = no-op
                bsrc_sh[dev, j] = 0
                bdst_sh[dev, j] = 0

        attrs0, frontier0 = self.initial_state(src)
        pad = ntiles_p - bg.ntiles
        if pad:
            attrs0 = jnp.pad(attrs0, ((0, pad), (0, 0)),
                             constant_values=np.inf)
            frontier0 = jnp.pad(frontier0, ((0, pad), (0, 0)))

        @functools.partial(
            shard_map, mesh=mesh,
            in_specs=(P(axis), P(axis), P(axis), P(None), P(None)),
            out_specs=P(None),
            check_rep=False)
        def dist_fix(blocks, bsrc_l, bdst_l, attrs, frontier):
            blocks, bsrc_l, bdst_l = blocks[0], bsrc_l[0], bdst_l[0]

            def cond(state):
                _, frontier, steps = state
                return jnp.logical_and(frontier.any(),
                                       steps < self.max_steps)

            def body(state):
                attrs, frontier, steps = state
                src_vals = attrs if self.mode == "op" else jnp.where(
                    frontier, attrs, INF)
                local_attrs = jax.lax.dynamic_slice_in_dim(
                    attrs, jax.lax.axis_index(axis) * tiles_per_dev,
                    tiles_per_dev, axis=0)
                sv = src_vals[bsrc_l]                          # (nb, T)
                cand = jnp.min(sv[:, :, None] + blocks, axis=1)
                best = jax.ops.segment_min(cand, bdst_l,
                                           num_segments=tiles_per_dev)
                new_local = jnp.minimum(local_attrs, best)
                new = jax.lax.all_gather(new_local, axis, tiled=True)
                return new, new < attrs, steps + 1

            attrs_f, _, steps = jax.lax.while_loop(
                cond, body, (attrs, frontier, jnp.int32(0)))
            return attrs_f

        blocks_sh = jnp.asarray(blocks_sh)
        out = jax.jit(dist_fix)(blocks_sh, jnp.asarray(bsrc_sh),
                                jnp.asarray(bdst_sh), attrs0, frontier0)
        return self.bg.to_orig(out[:bg.ntiles])
