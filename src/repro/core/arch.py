"""FLIP fabric description + timing constants (paper Sec. 3, Sec. 5.1).

The prototype in the paper: 8x8 PE array @100MHz, 4 vertices per PE (DRF has
4 registers), 2x2 PE clusters as the data-swap unit, 16KB distributed PE
memory + 16KB SPM, 256KB off-chip backing store, YX dimension-ordered
routing with credit-based flow control.

Timing model (derived from the paper's motivating example, Sec. 1.2 and
Sec. 3.2):
  * vertex program execution: 4/5/5 instructions (WCC/BFS/SSSP) on update,
    2/4/4 when the attribute does not change (one instruction/cycle).
  * scatter issue: ALUout injects one packet per cycle.
  * one-hop NoC latency `t_hop` is "close to the computation time of one
    packet" (Sec. 4.1) -- we use 5 cycles; links are pipelined (a link
    accepts a new packet every cycle, credit permitting).
  * Intra-Table search: hashed linked list, avg < 2 cycles -> t_tab = 2.
  * slice swap: load/store of a 2x2-cluster slice through the SPM
    (~260B/PE * 4 PEs at 4B/cycle) + fixed control overhead.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class FlipArch:
    width: int = 8                 # PE columns
    height: int = 8                # PE rows
    pe_capacity: int = 4           # vertices per PE (DRF registers)
    cluster: int = 2               # data-swap unit is cluster x cluster PEs
    input_buffer_depth: int = 8    # packets per input port (credit window)
    t_hop: int = 5                 # cycles per NoC hop (latency)
    t_tab: int = 2                 # Intra-Table search cycles
    t_swap: int = 300              # cycles to swap one slice in/out
    freq_mhz: float = 100.0

    @property
    def num_pes(self) -> int:
        return self.width * self.height

    @property
    def capacity(self) -> int:
        """Total vertices resident on-chip."""
        return self.num_pes * self.pe_capacity

    @property
    def clusters_per_row(self) -> int:
        return self.width // self.cluster

    def pe_xy(self, pe: int) -> tuple[int, int]:
        return pe % self.width, pe // self.width

    def pe_id(self, x: int, y: int) -> int:
        return y * self.width + x

    def cluster_of(self, pe: int) -> int:
        x, y = self.pe_xy(pe)
        return (y // self.cluster) * self.clusters_per_row + (x // self.cluster)

    def manhattan(self, pe_a: int, pe_b: int) -> int:
        ax, ay = self.pe_xy(pe_a)
        bx, by = self.pe_xy(pe_b)
        return abs(ax - bx) + abs(ay - by)

    def pe_neighbors(self, pe: int) -> list[int]:
        x, y = self.pe_xy(pe)
        out = []
        for dx, dy in ((1, 0), (-1, 0), (0, 1), (0, -1)):
            nx, ny = x + dx, y + dy
            if 0 <= nx < self.width and 0 <= ny < self.height:
                out.append(self.pe_id(nx, ny))
        return out

    def yx_route(self, src: int, dst: int) -> list[int]:
        """YX dimension-ordered route: move along Y first, then X.

        Returns the sequence of PEs visited after `src` (ending at `dst`).
        """
        sx, sy = self.pe_xy(src)
        dx, dy = self.pe_xy(dst)
        hops = []
        y = sy
        while y != dy:
            y += 1 if dy > y else -1
            hops.append(self.pe_id(sx, y))
        x = sx
        while x != dx:
            x += 1 if dx > x else -1
            hops.append(self.pe_id(x, dy))
        return hops


DEFAULT_ARCH = FlipArch()
