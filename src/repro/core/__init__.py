from repro.core.arch import FlipArch, DEFAULT_ARCH
from repro.core.vertex_program import (BFS, SSSP, WCC, WIDEST, REACH,
                                       PAGERANK, PROGRAMS, VertexProgram,
                                       get_algebra, register_algebra)
from repro.core.mapping import Mapping, RuntimeEstimator, compile_mapping
from repro.core.tables import RoutingTables, build_tables, scatter_graph
from repro.core.sim import SimResult, simulate
from repro.core import baselines

__all__ = [
    "FlipArch", "DEFAULT_ARCH",
    "BFS", "SSSP", "WCC", "WIDEST", "REACH", "PAGERANK",
    "PROGRAMS", "VertexProgram", "get_algebra", "register_algebra",
    "Mapping", "RuntimeEstimator", "compile_mapping",
    "RoutingTables", "build_tables", "scatter_graph",
    "SimResult", "simulate", "baselines",
]
