"""FLIP mapping compiler (paper Sec. 4, Algorithms 1 & 2).

Maps graph vertices onto the (possibly replicated, for data swapping) PE
array, minimizing total YX routing length while avoiding sequentialization
(two co-located vertices sharing an in-neighbor must execute serially).

Phase 1: beam search (k = 10) seeded with the graph center at the array
center, scoring partial mappings by total Manhattan routing length over
fully-mapped edges.
Phase 2: local pairwise swaps between a random PE and its neighbors,
accepted when the partial-runtime estimation model (Algorithm 2) predicts
an improvement; stops when stable.
"""
from __future__ import annotations

import dataclasses
import numpy as np

from repro.core.arch import FlipArch, DEFAULT_ARCH
from repro.core.vertex_program import VertexProgram, SSSP
from repro.graphs.csr import Graph


@dataclasses.dataclass
class Mapping:
    """Many-to-one vertex -> (replica copy, physical PE) assignment."""

    arch: FlipArch
    graph: Graph
    pe_of: np.ndarray      # (n,) int32: physical PE id of each vertex
    copy_of: np.ndarray    # (n,) int32: replica (slice) index of each vertex

    # ------------------------------------------------------------------ #
    def slice_of(self, v: int) -> int:
        """Slice id = replica copy (slices are per 2x2 cluster, one copy
        of a cluster's vertices per replica)."""
        return int(self.copy_of[v])

    def cluster_of(self, v: int) -> int:
        return self.arch.cluster_of(int(self.pe_of[v]))

    def vertices_on(self, pe: int, copy: int | None = None) -> list[int]:
        sel = self.pe_of == pe
        if copy is not None:
            sel &= self.copy_of == copy
        return list(np.nonzero(sel)[0])

    def register_index(self) -> np.ndarray:
        """DRF register slot of each vertex within its (copy, PE)."""
        reg = np.zeros(self.graph.n, dtype=np.int32)
        seen: dict[tuple[int, int], int] = {}
        for v in range(self.graph.n):
            key = (int(self.copy_of[v]), int(self.pe_of[v]))
            reg[v] = seen.get(key, 0)
            seen[key] = reg[v] + 1
        return reg

    def route_length(self, u: int, v: int) -> int:
        return self.arch.manhattan(int(self.pe_of[u]), int(self.pe_of[v]))

    def total_routing_length(self) -> int:
        return sum(self.route_length(u, v) for u, v, _ in self.graph.edge_list())

    def avg_routing_length(self) -> float:
        m = self.graph.m
        return self.total_routing_length() / max(m, 1)

    def num_copies(self) -> int:
        return int(self.copy_of.max()) + 1 if self.graph.n else 1

    def validate(self) -> None:
        """Invariants: every vertex mapped, capacity respected."""
        assert self.pe_of.shape == (self.graph.n,)
        assert (self.pe_of >= 0).all() and (self.pe_of < self.arch.num_pes).all()
        counts: dict[tuple[int, int], int] = {}
        for v in range(self.graph.n):
            key = (int(self.copy_of[v]), int(self.pe_of[v]))
            counts[key] = counts.get(key, 0) + 1
            assert counts[key] <= self.arch.pe_capacity, (
                f"PE {key} over capacity")

    # ------------------------------------------------------------------ #
    def collision_sets(self) -> dict[tuple[int, int], list[int]]:
        """Sequentialization barriers (Sec. 4.1): vertices co-located on one
        (copy, PE) that share an in-neighbor. Key: (pe, src_vertex)."""
        out: dict[tuple[int, int], list[int]] = {}
        for u in range(self.graph.n):
            targets: dict[int, list[int]] = {}
            for v in self.graph.neighbors(u):
                key = (int(self.copy_of[v]), int(self.pe_of[v]))
                targets.setdefault(key[1], []).append(int(v))
            for pe, vs in targets.items():
                if len(vs) > 1:
                    out[(pe, u)] = vs
        return out


# ====================================================================== #
# Algorithm 2: partial run-time estimation model
# ====================================================================== #
class RuntimeEstimator:
    """Estimates the time for updates to pass through the one-hop
    neighborhood of a vertex pair (paper Algorithm 2)."""

    def __init__(self, arch: FlipArch, graph: Graph,
                 program: VertexProgram = SSSP,
                 epsilon: int | None = None):
        self.arch = arch
        self.graph = graph
        self.program = program
        self.epsilon = arch.t_swap if epsilon is None else epsilon
        self.in_map = graph.in_neighbors_map()

    def _edges_of(self, v: int):
        """Incoming and outgoing edges of v as (src, dst) pairs."""
        out = [(v, int(w)) for w in self.graph.neighbors(v)]
        inc = [(int(u), v) for u, _ in self.in_map[v]]
        return out + inc

    def edge_time(self, pe_of, copy_of, src: int, dst: int) -> float:
        arch = self.arch
        hops = arch.manhattan(int(pe_of[src]), int(pe_of[dst]))
        t_trans = hops * arch.t_hop
        # same physical cluster but different slice -> swap overhead
        if (arch.cluster_of(int(pe_of[src])) == arch.cluster_of(int(pe_of[dst]))
                and copy_of[src] != copy_of[dst]):
            t_trans += self.epsilon
        # congestion: siblings of dst on the same PE sharing the source
        siblings = [v for v in self.graph.neighbors(src)
                    if pe_of[v] == pe_of[dst] and copy_of[v] == copy_of[dst]]
        t_proc = self.arch.t_tab + self.program.exe_update
        if len(siblings) > 1:
            # worst case: dst is the last vertex in sequential processing
            return t_trans + len(siblings) * t_proc
        return t_trans + t_proc

    def partial_runtime(self, pe_of, copy_of, u: int, v: int) -> float:
        t = 0.0
        for s, d in set(self._edges_of(u)) | set(self._edges_of(v)):
            t += self.edge_time(pe_of, copy_of, s, d)
        return t

    def swap_benefit(self, mapping: Mapping, u: int, v: int) -> float:
        """Benefit (>0 is good) of swapping the placements of u and v."""
        pe_of, copy_of = mapping.pe_of, mapping.copy_of
        before = self.partial_runtime(pe_of, copy_of, u, v)
        pe2, cp2 = pe_of.copy(), copy_of.copy()
        pe2[u], pe2[v] = pe_of[v], pe_of[u]
        cp2[u], cp2[v] = copy_of[v], copy_of[u]
        after = self.partial_runtime(pe2, cp2, u, v)
        return before - after


def _weighted_adjacency(graph: Graph, weighted: bool = False):
    """Per-vertex (neighbor ids, edge weights) arrays over the undirected
    closure. The paper's placement objective is UNWEIGHTED routing length
    (weighted=False: every edge counts 1 per direction); the MoE placement
    bridge passes weighted=True to use affinity weights."""
    acc: list[dict[int, float]] = [dict() for _ in range(graph.n)]
    for u, v, w in graph.edge_list():
        ww = w if weighted else 1.0
        acc[u][v] = acc[u].get(v, 0.0) + ww
        acc[v][u] = acc[v].get(u, 0.0) + ww
    out = []
    for d in acc:
        ns = np.asarray(sorted(d), dtype=np.int64)
        ws = np.asarray([d[k] for k in sorted(d)], dtype=np.float64)
        out.append((ns, ws))
    return out


# ====================================================================== #
# Algorithm 1: two-phase mapping
# ====================================================================== #
def _beam_search(graph: Graph, arch: FlipArch, num_copies: int,
                 beam_width: int, rng: np.random.Generator,
                 weighted: bool = False):
    """Phase 1: routing-length-driven placement.

    State: (cost, pe_of, copy_of, free list) with incremental cost updates.
    Candidate vertices are the frontier (unmapped neighbors of mapped
    vertices); candidate PEs are slots adjacent to used PEs (plus used PEs
    with spare capacity), across all replica copies.
    """
    n = graph.n
    adj = graph.undirected_adjacency()
    wadj = _weighted_adjacency(graph, weighted)
    center_v = graph.center_vertex()
    center_pe = arch.pe_id(arch.width // 2, arch.height // 2)

    # A slot is (copy, pe). Capacity per slot = arch.pe_capacity.
    def new_state():
        pe_of = np.full(n, -1, dtype=np.int32)
        copy_of = np.full(n, -1, dtype=np.int32)
        used = np.zeros((num_copies, arch.num_pes), dtype=np.int32)
        return [0.0, pe_of, copy_of, used]

    root = new_state()
    root[1][center_v] = center_pe
    root[2][center_v] = 0
    root[3][0, center_pe] = 1
    beams = [root]

    # order of placement: BFS from the center (matches the frontier-like
    # candidate set of the paper and guarantees every vertex gets placed,
    # including vertices unreachable from the center)
    order = []
    seen = {center_v}
    queue = [center_v]
    while queue:
        u = queue.pop(0)
        order.append(u)
        for w in sorted(adj[u]):
            if w not in seen:
                seen.add(w)
                queue.append(w)
    for v in range(n):
        if v not in seen:
            order.append(v)

    xs = np.array([arch.pe_xy(p)[0] for p in range(arch.num_pes)])
    ys = np.array([arch.pe_xy(p)[1] for p in range(arch.num_pes)])

    for v in order[1:]:
        nbrs, nbr_ws = wadj[v]
        candidates = []  # (total_cost, beam_idx, pe, copy)
        for bi, (cost, pe_of, copy_of, used) in enumerate(beams):
            sel = pe_of[nbrs] >= 0
            mapped_nbrs = nbrs[sel]
            # incremental (weighted) routing length to each physical PE
            if len(mapped_nbrs):
                delta = np.zeros(arch.num_pes)
                for w, ew in zip(mapped_nbrs, nbr_ws[sel]):
                    wx, wy = arch.pe_xy(int(pe_of[w]))
                    delta += ew * (np.abs(xs - wx) + np.abs(ys - wy))
            else:
                delta = np.zeros(arch.num_pes)
            # candidate PEs: any slot with capacity left, preferring ones
            # near used PEs; scan copies in order (earlier copies first)
            free = used < arch.pe_capacity
            for copy in range(num_copies):
                pes = np.nonzero(free[copy])[0]
                if len(pes) == 0:
                    continue
                costs = cost + delta[pes]
                top = np.argsort(costs, kind="stable")[:beam_width]
                for t in top:
                    candidates.append((float(costs[t]), bi, int(pes[t]), copy))
                break_after = len(mapped_nbrs) > 0
                if break_after and len(pes) > 0:
                    # with mapped neighbors the best physical PE dominates;
                    # still allow later copies only when this copy is full
                    break
        candidates.sort(key=lambda c: c[0])
        next_beams = []
        sig_seen = set()
        for tot, bi, pe, copy in candidates:
            if len(next_beams) >= beam_width:
                break
            sig = (bi, pe, copy)
            if sig in sig_seen:
                continue
            sig_seen.add(sig)
            cost, pe_of, copy_of, used = beams[bi]
            pe2, cp2, used2 = pe_of.copy(), copy_of.copy(), used.copy()
            pe2[v] = pe
            cp2[v] = copy
            used2[copy, pe] += 1
            next_beams.append([tot, pe2, cp2, used2])
        beams = next_beams
    best = min(beams, key=lambda b: b[0])
    return best[1], best[2]


def _sa_refine(graph: Graph, arch: FlipArch, pe_of, copy_of,
               rng: np.random.Generator, sweeps: int = 10,
               t0: float = 2.0, cooling: float = 0.85,
               t_min: float = 0.02, slice_pen: float = 6.0,
               weighted: bool = False):
    """Routing-length refinement with the paper's local-swap move set plus
    occasional uphill acceptance (simulated annealing). Same objective as
    beam search (total routing length) with the Sec. 4.4 cross-slice
    penalty; Algorithm 2's estimator-guided pass runs afterwards to handle
    sequentialization.
    """
    n = graph.n
    wadj = _weighted_adjacency(graph, weighted)
    if weighted:
        mean_w = np.mean([w.mean() for _, w in wadj if len(w)]) or 1.0
        t0, t_min = t0 * mean_w, t_min * mean_w
    xs = np.array([arch.pe_xy(p)[0] for p in range(arch.num_pes)])
    ys = np.array([arch.pe_xy(p)[1] for p in range(arch.num_pes)])
    cl = np.array([arch.cluster_of(p) for p in range(arch.num_pes)])
    pe_of = pe_of.astype(np.int64)
    copy_of = copy_of.astype(np.int64)

    def vcost(v: int, pe: int, cp: int) -> float:
        ns, ws = wadj[v]
        if len(ns) == 0:
            return 0.0
        pn = pe_of[ns]
        c = float((ws * (np.abs(xs[pn] - xs[pe])
                         + np.abs(ys[pn] - ys[pe]))).sum())
        if slice_pen:
            c += slice_pen * float(np.sum((cl[pn] == cl[pe])
                                          & (copy_of[ns] != cp)))
        return c

    temp = t0
    iters_per_t = max(1000, 12 * n)
    while temp > t_min:
        for _ in range(iters_per_t):
            u = int(rng.integers(0, n))
            v = int(rng.integers(0, n))
            pu, pv = int(pe_of[u]), int(pe_of[v])
            cu, cv = int(copy_of[u]), int(copy_of[v])
            if u == v or (pu == pv and cu == cv):
                continue
            before = vcost(u, pu, cu) + vcost(v, pv, cv)
            pe_of[u], pe_of[v] = pv, pu
            copy_of[u], copy_of[v] = cv, cu
            after = vcost(u, pv, cv) + vcost(v, pu, cu)
            d = after - before
            if d < 0 or rng.random() < np.exp(-d / temp):
                pass
            else:
                pe_of[u], pe_of[v] = pu, pv
                copy_of[u], copy_of[v] = cu, cv
        temp *= cooling
    return pe_of.astype(np.int32), copy_of.astype(np.int32)


def compile_mapping(graph: Graph, arch: FlipArch = DEFAULT_ARCH,
                    program: VertexProgram = SSSP,
                    beam_width: int = 10,
                    opt_iters: int | None = None,
                    stable_after: int = 60,
                    effort: int = 1,
                    weighted: bool = False,
                    seed: int = 0) -> Mapping:
    """Full Algorithm 1: beam-search init + local-swap refinement +
    estimator-guided sequentialization polish.

    effort: 0 = beam search only (fastest), 1 = default (+SA refinement),
    2 = heavy (longer anneal; for offline/Table-8-quality mappings).
    """
    rng = np.random.default_rng(seed)
    num_copies = max(1, -(-graph.n // arch.capacity))   # ceil
    pe_of, copy_of = _beam_search(graph, arch, num_copies, beam_width,
                                  rng, weighted=weighted)
    if effort >= 1:
        pe_of, copy_of = _sa_refine(
            graph, arch, pe_of, copy_of, rng,
            t0=2.0 if effort == 1 else 3.0,
            cooling=0.85 if effort == 1 else 0.92, weighted=weighted)
    mapping = Mapping(arch=arch, graph=graph, pe_of=pe_of, copy_of=copy_of)
    mapping.validate()

    est = RuntimeEstimator(arch, graph, program)
    if opt_iters is None:
        opt_iters = 4 * arch.num_pes * num_copies
    since_improved = 0
    it = 0
    while it < opt_iters and since_improved < stable_after:
        it += 1
        p = int(rng.integers(0, arch.num_pes))
        cp = int(rng.integers(0, num_copies))
        vs_here = mapping.vertices_on(p, cp)
        if not vs_here:
            since_improved += 1
            continue
        nbr_pes = mapping.arch.pe_neighbors(p)
        vs_nbr = [v for q in nbr_pes for v in mapping.vertices_on(q)]
        if not vs_nbr:
            since_improved += 1
            continue
        best_pair, best_c = None, 0.0
        for u in vs_here:
            for v in vs_nbr:
                c = est.swap_benefit(mapping, int(u), int(v))
                if c > best_c:
                    best_pair, best_c = (int(u), int(v)), c
        if best_pair is not None:
            u, v = best_pair
            mapping.pe_of[u], mapping.pe_of[v] = mapping.pe_of[v], mapping.pe_of[u]
            mapping.copy_of[u], mapping.copy_of[v] = (mapping.copy_of[v],
                                                      mapping.copy_of[u])
            since_improved = 0
        else:
            since_improved += 1
    mapping.validate()
    return mapping
