"""Data-centric expert placement: the FLIP mapping compiler applied to MoE.

FLIP's insight is that *data* should be pinned to compute sites and the
dynamic traffic routed between them, with placement compiled to minimize
expected routing cost. MoE expert-parallel dispatch is the same problem:

  vertices  = experts                (pinned to devices, like DRF slots)
  edges     = co-activation affinity (tokens routed to expert i AND j pay
                                      cross-device hops if i, j are far)
  PE array  = the "model" mesh axis laid out as a virtual grid
              (TPU ICI is a torus; neighboring devices are 1 hop)

`place_experts` reuses `compile_mapping` verbatim on the affinity graph and
returns an expert permutation: experts that co-fire land on the same or
adjacent devices, shrinking the all-to-all fan-out per token. This is the
paper-technique bridge used by repro.models.moe (DESIGN.md Sec. 3).
"""
from __future__ import annotations

import dataclasses
import numpy as np

from repro.core.arch import FlipArch
from repro.core.mapping import compile_mapping
from repro.graphs.csr import Graph


def expert_affinity(topk_indices: np.ndarray, num_experts: int) -> np.ndarray:
    """Co-activation counts from router decisions.

    topk_indices: (tokens, k) int array of routed expert ids.
    Returns (E, E) symmetric affinity: #tokens routed to both i and j.
    """
    aff = np.zeros((num_experts, num_experts), dtype=np.float64)
    for row in topk_indices:
        row = np.unique(row)
        for a in range(len(row)):
            for b in range(a + 1, len(row)):
                aff[row[a], row[b]] += 1
                aff[row[b], row[a]] += 1
    return aff


@dataclasses.dataclass
class ExpertPlacement:
    perm: np.ndarray          # new order: perm[k] = original expert id at
                              # slot k (slots are contiguous per device)
    device_of: np.ndarray     # (E,) device index of each original expert
    est_cost: float           # affinity-weighted routing length
    baseline_cost: float      # same metric for the identity placement


def _grid_dims(n: int) -> tuple[int, int]:
    h = int(np.sqrt(n))
    while n % h:
        h -= 1
    return n // h, h


def place_experts(affinity: np.ndarray, num_devices: int,
                  seed: int = 0, effort: int = 1) -> ExpertPlacement:
    """Map experts onto `num_devices` devices (laid out as a virtual grid)
    minimizing affinity-weighted routing length via the FLIP compiler."""
    num_experts = affinity.shape[0]
    assert num_experts % num_devices == 0, "experts must divide devices"
    cap = num_experts // num_devices
    gw, gh = _grid_dims(num_devices)
    arch = FlipArch(width=gw, height=gh, pe_capacity=cap, cluster=1,
                    t_swap=0)

    # affinity graph: keep edges above the mean to bound compile cost
    edges, weights = [], []
    thresh = affinity[affinity > 0].mean() if (affinity > 0).any() else 0.0
    for i in range(num_experts):
        for j in range(i + 1, num_experts):
            if affinity[i, j] > thresh:
                edges.append((i, j))
                weights.append(float(affinity[i, j]))
    g = Graph.from_edges(num_experts, edges, weights, directed=False) \
        if edges else Graph.from_edges(
            num_experts, [(i, (i + 1) % num_experts)
                          for i in range(num_experts)], directed=False)

    mapping = compile_mapping(g, arch=arch, effort=effort, seed=seed,
                              weighted=True)

    # routing cost weighted by full affinity (not just kept edges)
    def cost(device_of):
        xs = np.array([arch.pe_xy(p)[0] for p in range(arch.num_pes)])
        ys = np.array([arch.pe_xy(p)[1] for p in range(arch.num_pes)])
        c = 0.0
        for i in range(num_experts):
            for j in range(i + 1, num_experts):
                if affinity[i, j]:
                    pi, pj = device_of[i], device_of[j]
                    c += affinity[i, j] * (abs(xs[pi] - xs[pj])
                                           + abs(ys[pi] - ys[pj]))
        return c

    device_of = mapping.pe_of.astype(np.int64)
    ident = np.arange(num_experts) // cap
    # perm: experts sorted by (device, register) -> contiguous device slots
    order = np.asarray(
        [v for _, v in sorted((int(device_of[e]), e)
                              for e in range(num_experts))])
    return ExpertPlacement(perm=order, device_of=device_of,
                           est_cost=cost(device_of),
                           baseline_cost=cost(ident))
