"""Degradation ladder: validated fallback plans + failure classification.

When a dispatch raises (pallas off-TPU, retrace failure, OOM) the
serving layer does not lose the bucket -- it retries once per rung down
a *validated* chain of simpler execution plans:

    rung 0   the session's own plan (e.g. pallas kernel, compacted)
    rung 1   relax_mode -> 'jnp'   (pure-XLA kernel body)
    rung 2   compact    -> False   (dense block streaming)

Every rung is EXACT: the jnp kernel body computes the same semiring
relaxation as the Pallas kernel, and dense streaming only stops skipping
⊕-identity blocks -- so a degraded response is bit-for-bit the primary
response (echoing NEURA's retargetability: the program is the fixpoint,
not the backend). The chain is built by `fallback_chain` and each rung
is `resolve()`d up front, so a rung can never itself be an invalid plan.

`classify` maps an arbitrary dispatch exception onto the typed taxonomy
(`repro.resilience.errors`), and `finite_guard` is the cheap per-dispatch
result check: a NaN anywhere in the attrs means a poisoned weight block
or a broken kernel, never a legitimate algebra value (the semirings use
±inf sentinels, not NaN), so it trips a retryable `BackendFailure`.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.resilience.errors import BackendFailure, FlipError


def fallback_chain(plan, algebra=None) -> list:
    """The validated degradation ladder for `plan`: rung 0 is the plan
    itself, each later rung swaps one knob for its simplest exact
    equivalent (pallas/interpret -> jnp, then compact -> dense). Rungs
    that would equal an earlier rung are dropped, so a plan already at
    the bottom (jnp + dense) gets a one-rung chain. Every rung resolves
    cleanly or is skipped -- the ladder can never trade one failure for
    a plan-validation error."""
    rungs = [plan]
    cur = plan
    if cur.relax_mode != "jnp":
        cur = dataclasses.replace(cur, relax_mode="jnp")
        rungs.append(cur)
    if cur.compact is not False:
        # compact=True is invalid for op mode, but then it is already
        # False-resolved; replace() keeps the rest of the plan intact
        rungs.append(dataclasses.replace(cur, compact=False))
    out, seen = [], set()
    for r in rungs:
        try:
            r = r.resolve(algebra)
        except ValueError:
            continue                      # never ladder onto a bad plan
        if r.key() not in seen:
            seen.add(r.key())
            out.append(r)
    return out


def classify(exc: BaseException, rung: int = 0) -> FlipError:
    """Map a dispatch-time exception to its typed form. Exceptions that
    already carry a type (a `FlipError`) pass through; everything else a
    backend can raise mid-dispatch -- XLA runtime errors, OOM, retrace
    failures -- becomes a retryable `BackendFailure` with the original
    exception chained as `cause`."""
    if isinstance(exc, FlipError):
        return exc
    return BackendFailure(
        f"dispatch failed on rung {rung}: {type(exc).__name__}: {exc}",
        rung=rung, cause=exc)


def finite_guard(attrs) -> None:
    """Cheap per-dispatch sanity check on a result block: raise a
    retryable `BackendFailure` if any entry is NaN. ±inf is legitimate
    (the ⊕-identity sentinel of min_plus/max_min marks unreachable
    vertices); NaN is not a member of any registered semiring's domain,
    so it can only mean corrupted weights or a broken kernel. One
    `np.isnan().any()` pass over the (B, n[, d]) result -- O(output),
    far below the fixpoint's O(steps · blocks · T²)."""
    a = np.asarray(attrs)
    if np.isnan(a).any():
        bad = int(np.isnan(a).sum())
        raise BackendFailure(
            f"finite guard: {bad} NaN entr{'y' if bad == 1 else 'ies'} "
            f"in a {a.shape} result block (poisoned weights or kernel "
            "fault)", cause=None)
