"""Deterministic fault injection for the serving layer.

Chaos testing only works if the chaos replays: every fault here is a
`FaultSpec` pinned to an exact (dispatch ordinal, ladder rung) pair, and
the random generator (`FaultInjector.random`) is seeded -- the same seed
always produces the same fault schedule against the same request stream,
so a failing chaos run reduces to one reproducible command line.

Three injection points, matching the real failure modes they stand in
for (`kind`):

  'raise'   the backend raises mid-dispatch (retrace failure, OOM,
            pallas off-TPU) -- `before_dispatch` raises `InjectedFault`,
            which `classify` wraps as a retryable `BackendFailure`, so
            the degradation ladder takes over;
  'nan'     a weight block was silently corrupted -- `after_dispatch`
            NaN-poisons a seeded subset of the result, which the
            per-dispatch finite guard must catch before the garbage
            reaches a caller;
  'stall'   a hung collective / dead host -- `before_dispatch` sleeps
            past the server's `HeartbeatMonitor` timeout, which must
            flag the stall (and re-arm for the next one).

Faults are one-shot: a spec fires on its pinned (dispatch, rung) and
never again, so a ladder retry of the same bucket sees a healthy
backend -- exactly the transient-fault model the ladder exists for.
Persistent faults are expressed as several specs on consecutive rungs.
"""
from __future__ import annotations

import dataclasses
import time

import numpy as np

KINDS = ("raise", "nan", "stall")


class InjectedFault(RuntimeError):
    """The artificial backend failure. Deliberately NOT a FlipError:
    the taxonomy must classify it like any foreign backend exception."""


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """One pinned fault: fire `kind` on dispatch ordinal `dispatch`
    (the server's lifetime bucket-dispatch counter), ladder rung `rung`,
    optionally restricted to one algebra."""
    kind: str
    dispatch: int
    rung: int = 0
    algo: str | None = None
    stall_s: float = 0.0          # 'stall' only: injected sleep
    nan_frac: float = 0.25        # 'nan' only: fraction of entries hit

    def __post_init__(self):
        if self.kind not in KINDS:
            raise ValueError(f"fault kind must be one of {KINDS}, got "
                             f"{self.kind!r}")

    def matches(self, algo: str, dispatch: int, rung: int) -> bool:
        return (self.dispatch == dispatch and self.rung == rung
                and (self.algo is None or self.algo == algo))


@dataclasses.dataclass
class FaultInjector:
    """Seeded, replayable fault schedule the server consults around
    every dispatch. `fired` records what actually triggered (spec +
    where), so tests assert the schedule really executed."""

    specs: list = dataclasses.field(default_factory=list)
    seed: int = 0
    fired: list = dataclasses.field(default_factory=list)

    def __post_init__(self):
        self._rng = np.random.default_rng(self.seed)
        self._spent: set[int] = set()      # indices of one-shot specs

    # ------------------------------------------------------------ #
    @classmethod
    def random(cls, seed: int, dispatches: int, algos=None,
               rate: float = 0.25, stall_s: float = 0.0) -> "FaultInjector":
        """A seeded random schedule over `dispatches` upcoming bucket
        dispatches: each ordinal independently gets a fault with
        probability `rate`, kind drawn uniformly ('stall' only when a
        positive `stall_s` is supplied -- stalls cost wall time).
        Deterministic: (seed, dispatches, algos, rate, stall_s) fully
        decide the schedule."""
        rng = np.random.default_rng(seed)
        kinds = ["raise", "nan"] + (["stall"] if stall_s > 0 else [])
        specs = []
        for d in range(dispatches):
            if rng.random() >= rate:
                continue
            kind = kinds[int(rng.integers(len(kinds)))]
            algo = (None if algos is None
                    else algos[int(rng.integers(len(algos)))])
            specs.append(FaultSpec(kind=kind, dispatch=d, rung=0,
                                   algo=algo, stall_s=stall_s))
        return cls(specs=specs, seed=seed)

    # ------------------------------------------------------------ #
    def _take(self, algo: str, dispatch: int, rung: int, kinds) -> \
            FaultSpec | None:
        for i, spec in enumerate(self.specs):
            if i in self._spent or spec.kind not in kinds:
                continue
            if spec.matches(algo, dispatch, rung):
                self._spent.add(i)
                self.fired.append({"kind": spec.kind, "algo": algo,
                                   "dispatch": dispatch, "rung": rung})
                return spec
        return None

    def before_dispatch(self, algo: str, dispatch: int, rung: int) -> None:
        """Called just before the engine runs: may sleep (stall) and/or
        raise (backend fault). A 'stall' spec sleeps first, so one
        dispatch can both trip the heartbeat and then fail."""
        spec = self._take(algo, dispatch, rung, ("stall",))
        if spec is not None:
            time.sleep(spec.stall_s)
        spec = self._take(algo, dispatch, rung, ("raise",))
        if spec is not None:
            raise InjectedFault(
                f"injected backend fault (dispatch {dispatch} rung "
                f"{rung} algo {algo})")

    def after_dispatch(self, algo: str, dispatch: int, rung: int,
                       attrs: np.ndarray) -> np.ndarray:
        """Called on the raw result before the finite guard: a 'nan'
        spec returns a poisoned copy (seeded entry subset -> NaN); the
        caller's guard must refuse to serve it."""
        spec = self._take(algo, dispatch, rung, ("nan",))
        if spec is None:
            return attrs
        out = np.array(attrs, dtype=np.float32, copy=True)
        k = max(1, int(out.size * spec.nan_frac))
        idx = self._rng.choice(out.size, size=k, replace=False)
        # .flat assigns through any memory order; reshape(-1) would
        # silently copy (and drop the poison) on F-ordered results
        out.flat[idx] = np.nan
        return out
