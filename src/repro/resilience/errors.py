"""Typed error taxonomy for the serving layer.

Every failure a request can experience maps to exactly one `FlipError`
subclass, so the serving front-end can (a) attach the failure to the
request that caused it instead of losing the whole bucket, (b) decide
mechanically whether a retry down the degradation ladder can help
(`retryable`), and (c) export failure counts per `code` without string
matching. The taxonomy (see docs/RESILIENCE.md):

  FlipError
  ├─ InvalidRequest       caller error (bad source, bad budget); also a
  │                       ValueError so pre-taxonomy `except ValueError`
  │                       call sites keep working
  ├─ CapacityExceeded     admission control shed the request (queue
  │                       depth / per-algo quota) — retry later
  ├─ DeadlineExceeded     the request's deadline expired (in queue, or
  │                       mid-fixpoint with a partial result attached)
  ├─ ConvergenceFailure   the fixpoint hit its step budget without
  │                       converging — the result is a flagged partial,
  │                       never silently-truncated garbage
  └─ BackendFailure       the execution backend raised (pallas off-TPU,
                          retrace failure, OOM, non-finite guard trip):
                          retryable down the degradation ladder

`code` is the stable machine-readable identifier (metric names, JSON
exports); the message is for humans.
"""
from __future__ import annotations


class FlipError(Exception):
    """Base of every typed serving-layer failure."""

    code = "flip_error"
    #: a retry on a degraded rung (jnp / dense streaming) may succeed
    retryable = False

    def describe(self) -> dict:
        """JSON-ready view: stable code, class name, human message."""
        return {"code": self.code, "type": type(self).__name__,
                "message": str(self)}


class InvalidRequest(FlipError, ValueError):
    """The request itself is malformed: out-of-range source, negative
    budget, unknown algorithm. Never retried -- no backend can make an
    out-of-range vertex id valid."""

    code = "invalid_request"

    def __init__(self, message: str, *, value=None):
        super().__init__(message)
        self.value = value


class CapacityExceeded(FlipError):
    """Admission control rejected the request: the bounded queue (or the
    algebra's quota) is full. Shed at submit time -- reject-newest -- so
    accepted requests keep their latency instead of everyone timing
    out."""

    code = "capacity_exceeded"

    def __init__(self, message: str, *, depth: int = 0, limit: int = 0):
        super().__init__(message)
        self.depth = depth
        self.limit = limit


class DeadlineExceeded(FlipError):
    """The request's deadline budget expired: either before dispatch
    (still queued -- no work was done) or at a fixpoint step boundary
    (a partial, non-converged result is attached to the request)."""

    code = "deadline_exceeded"

    def __init__(self, message: str, *, deadline_s: float = 0.0,
                 elapsed_s: float = 0.0, where: str = ""):
        super().__init__(message)
        self.deadline_s = deadline_s
        self.elapsed_s = elapsed_s
        #: "" (bucket server), "queue" (expired before any work), or
        #: "fixpoint" (expired mid-relaxation, partial attached) --
        #: the scheduler's SLO accounting splits on this
        self.where = where

    def describe(self) -> dict:
        d = super().describe()
        if self.where:
            d["where"] = self.where
        return d


class ConvergenceFailure(FlipError):
    """The fixpoint stopped at its step budget with a non-empty
    frontier. The attrs are a valid partial relaxation (every relaxation
    performed is real), but NOT the fixpoint -- callers must see the
    flag, never mistake the partial for an answer."""

    code = "convergence_failure"

    def __init__(self, message: str, *, steps=None, max_steps=None):
        super().__init__(message)
        self.steps = steps
        self.max_steps = max_steps


class BackendFailure(FlipError):
    """The execution backend raised (or the per-dispatch finite guard
    tripped). Retryable: rung N+1 of the degradation ladder (pallas→jnp,
    compact→dense) runs the same exact fixpoint on a simpler path."""

    code = "backend_failure"
    retryable = True

    def __init__(self, message: str, *, rung: int = 0,
                 cause: BaseException | None = None):
        super().__init__(message)
        self.rung = rung
        self.cause = cause
