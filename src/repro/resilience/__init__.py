"""repro.resilience: the serving layer's failure model.

Flip's premise is graceful handling of dynamic, irregular workloads;
this package applies the same discipline to request/failure dynamics:

  * `errors`  -- the typed taxonomy (`FlipError` and its five
    subclasses) every failure maps onto; requests carry their error,
    buckets and streams never die with them;
  * `degrade` -- the validated degradation ladder (pallas→jnp,
    compact→dense; every rung exact), exception classification, and
    the per-dispatch NaN finite guard;
  * `faults`  -- deterministic, seeded fault injection (backend raise,
    NaN-poisoned results, step stalls) driving the chaos tests.

See docs/RESILIENCE.md for the taxonomy table, ladder semantics, shed
policy, and the fault-injection cookbook.
"""
from repro.resilience.degrade import classify, fallback_chain, finite_guard
from repro.resilience.errors import (BackendFailure, CapacityExceeded,
                                     ConvergenceFailure, DeadlineExceeded,
                                     FlipError, InvalidRequest)
from repro.resilience.faults import FaultInjector, FaultSpec, InjectedFault

__all__ = [
    "FlipError", "InvalidRequest", "CapacityExceeded", "DeadlineExceeded",
    "ConvergenceFailure", "BackendFailure",
    "fallback_chain", "classify", "finite_guard",
    "FaultInjector", "FaultSpec", "InjectedFault",
]
