"""Chrome-trace (`chrome://tracing` / Perfetto) span exporter.

Serializes query telemetry as the Trace Event Format JSON that Chrome's
tracing UI and https://ui.perfetto.dev load directly: one *query* span
containing one span per *dispatch* (engine fixpoint), each containing
one span per *step*, with the per-step frontier stats attached as span
``args`` so hovering a step shows its active vertices / tiles / blocks
fetched.

Timing semantics: the host-driven fixpoint records real per-step wall
times and those become the step span durations; the on-device
`lax.while_loop` paths expose no per-iteration clock, so their step
spans divide the dispatch wall evenly and are tagged
``"synthetic_timing": true`` -- the span *structure* and the attached
stats are exact either way, only the widths are approximate.
"""
from __future__ import annotations

import json

import numpy as np


class TraceBuilder:
    """Accumulates Trace Event Format events (timestamps in µs)."""

    def __init__(self, process: str = "flip"):
        self.events: list[dict] = [{
            "ph": "M", "pid": 1, "name": "process_name",
            "args": {"name": process},
        }]

    def thread(self, tid: int, name: str) -> None:
        self.events.append({"ph": "M", "pid": 1, "tid": tid,
                            "name": "thread_name", "args": {"name": name}})

    def span(self, name: str, ts_us: float, dur_us: float,
             tid: int = 0, args: dict | None = None) -> None:
        """One complete ('X') event."""
        ev = {"ph": "X", "pid": 1, "tid": tid, "name": name,
              "ts": float(ts_us), "dur": float(max(dur_us, 0.0))}
        if args:
            ev["args"] = args
        self.events.append(ev)

    def counter(self, name: str, ts_us: float, values: dict,
                tid: int = 0) -> None:
        """One counter ('C') event -- rendered as a stacked area track."""
        self.events.append({"ph": "C", "pid": 1, "tid": tid, "name": name,
                            "ts": float(ts_us),
                            "args": {k: float(v)
                                     for k, v in values.items()}})

    def to_chrome(self) -> dict:
        return {"traceEvents": self.events, "displayTimeUnit": "ms"}

    def write(self, path: str) -> str:
        with open(path, "w") as f:
            json.dump(self.to_chrome(), f)
        return path


# ------------------------------------------------------------------ #
def add_dispatch_spans(tb: TraceBuilder, disp, t0_us: float,
                       tid: int = 0, label: str = "dispatch") -> float:
    """Emit one dispatch span plus its step spans (and a frontier
    counter track) starting at `t0_us`; returns the dispatch end time."""
    tr = disp.trace
    nsteps = len(tr)
    dur_us = max(disp.wall_s * 1e6, 1e-3)
    tb.span(f"{label} [{disp.backend}/{disp.mode}"
            f"{' compact' if disp.compact else ''} B={disp.batch}]",
            t0_us, dur_us, tid=tid,
            args={"steps": [int(s) for s in np.atleast_1d(disp.steps)],
                  "n_blocks": disp.n_blocks, "truncated": disp.truncated,
                  **{k: v for k, v in disp.meta.items()}})
    if nsteps == 0:
        return t0_us + dur_us
    if tr.step_wall_s is not None:
        durs = np.maximum(np.asarray(tr.step_wall_s, dtype=np.float64),
                          0.0) * 1e6
        synthetic = False
    else:
        durs = np.full(nsteps, dur_us / nsteps)
        synthetic = True
    ts = t0_us
    for i in range(nsteps):
        args = {
            "active_vertices": int(tr.active_vertices[i].sum()),
            "active_tiles": int(tr.active_tiles[i]),
            "blocks_fetched": int(tr.blocks_fetched[i]),
            "blocks_skipped": int(tr.blocks_skipped[i]),
            "live_queries": int((~tr.converged[i]).sum()),
        }
        if synthetic:
            args["synthetic_timing"] = True
        tb.span(f"step {i}", ts, float(durs[i]), tid=tid, args=args)
        tb.counter("frontier", ts,
                   {"active_vertices": int(tr.active_vertices[i].sum()),
                    "active_tiles": int(tr.active_tiles[i])}, tid=tid)
        ts += float(durs[i])
    return max(ts, t0_us + dur_us)


def chrome_trace_from_telemetry(tele, name: str = "query",
                                process: str = "flip") -> dict:
    """query -> dispatch -> step span tree for one `QueryTelemetry`."""
    tb = TraceBuilder(process=process)
    tb.thread(0, "query")
    wall_us = max(tele.wall_s * 1e6, 1e-3)
    args = {"dispatches": len(tele.dispatches),
            "compile_s": tele.compile_s}
    tb.span(name, 0.0, wall_us, tid=0, args=args)
    if tele.compile_s:
        tb.span("compile", 0.0, tele.compile_s * 1e6, tid=0,
                args={"note": "first-dispatch jit trace share"})
    t = (tele.compile_s * 1e6) if tele.compile_s else 0.0
    for i, disp in enumerate(tele.dispatches):
        t = add_dispatch_spans(tb, disp, t, tid=0,
                               label=f"dispatch {i}")
    return tb.to_chrome()


def chrome_trace_from_result(result, name: str | None = None) -> dict:
    """Chrome trace for a traced `QueryResult` (its `.telemetry` must be
    set, i.e. the query ran with ``trace=``)."""
    if getattr(result, "telemetry", None) is None:
        raise ValueError(
            "QueryResult has no telemetry: run the query with "
            "trace=True (CompiledQuery.query(srcs, trace=True))")
    if name is None:
        prog = getattr(result, "program", None)
        name = f"query:{prog.name}" if prog is not None else "query"
    return chrome_trace_from_telemetry(result.telemetry, name=name)


def write_chrome_trace(path: str, result_or_telemetry,
                       name: str | None = None) -> str:
    """Write a Chrome-trace JSON file for a traced QueryResult or a bare
    QueryTelemetry; returns the path."""
    obj = result_or_telemetry
    # QueryResult also has an (int) `dispatches` field, so sniff for the
    # result-only `telemetry` attribute instead of `dispatches`
    if hasattr(obj, "telemetry"):           # QueryResult
        doc = chrome_trace_from_result(obj, name=name)
    else:                                   # bare QueryTelemetry
        doc = chrome_trace_from_telemetry(obj, name=name or "query")
    with open(path, "w") as f:
        json.dump(doc, f)
    return path
