"""repro.obs: query telemetry, metrics, and trace export.

The measurement layer under every execution path: per-step frontier
tracing inside the engine fixpoints (`telemetry`), a process-local
metrics registry with quantile histograms (`metrics`), and a
Chrome-trace/Perfetto span exporter (`trace`). Tracing is opt-in and
exact -- results and step counts are bit-identical with it on -- and
its step-cost overhead is CI-guarded at <=10%
(benchmarks/bench_telemetry_overhead.py). See docs/OBSERVABILITY.md.
"""
from repro.obs.metrics import Counter, Gauge, Histogram, MetricsRegistry
from repro.obs.telemetry import (DispatchTelemetry, QueryTelemetry,
                                 StepTrace, from_sim)
from repro.obs.trace import (TraceBuilder, chrome_trace_from_result,
                             chrome_trace_from_telemetry,
                             write_chrome_trace)

__all__ = [
    "Counter", "Gauge", "Histogram", "MetricsRegistry",
    "StepTrace", "DispatchTelemetry", "QueryTelemetry", "from_sim",
    "TraceBuilder", "chrome_trace_from_telemetry",
    "chrome_trace_from_result", "write_chrome_trace",
]
