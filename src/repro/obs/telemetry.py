"""Per-step query telemetry: the schema every execution layer emits.

FLIP's performance story is runtime-dependent -- step cost, HBM traffic,
and speedup all track the evolving frontier density -- so the stack
records, per fixpoint step, exactly the quantities the compaction
machinery already computes and would otherwise throw away:

  * ``active_vertices``  (steps, B) -- live frontier lanes per query;
  * ``active_tiles``     (steps,)   -- tiles with any active source lane
    (the kernel's packet-trigger condition, any query of the batch);
  * ``blocks_fetched``   (steps,)   -- weight blocks actually streamed
    from HBM this step (== active blocks under compaction, the full
    block count under dense streaming);
  * ``blocks_skipped``   (steps,)   -- blocks stood in for by the
    VMEM-resident sentinel (0 under dense streaming);
  * ``converged``        (steps, B) -- per-query convergence mask
    *entering* the step (a converged query is frozen by the engine);
  * ``step_wall_s``      (steps,)   -- host-measured per-step wall time;
    only the host-driven fixpoint can observe it (the on-device
    `lax.while_loop` exposes no per-iteration clock), so it is None on
    the device paths.

One engine fixpoint produces one `DispatchTelemetry`; a `QueryResult`
carries a `QueryTelemetry` aggregating the dispatches of that query
(one for a solo/batched run, several for bucketed serving dispatch).
Tracing is opt-in (``query(trace=True)``) and exact: the traced stat
buffers ride the fixpoint carry with fixed shapes, so attrs and step
counts stay bit-identical with tracing on (guarded by tests) and the
step-cost overhead stays within the CI bound (benchmarks/
bench_telemetry_overhead.py).

The cycle simulator re-emits its per-cycle parallelism trace through
the same schema (`from_sim`), so sim and JAX runs are comparable row
for row: busy PEs play the role of active vertices and one cycle plays
the role of one step.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class StepTrace:
    """Fixed-schema per-step record of one fixpoint (see module doc)."""
    active_vertices: np.ndarray          # (steps, B) i32
    active_tiles: np.ndarray             # (steps,)   i32
    blocks_fetched: np.ndarray           # (steps,)   i32
    blocks_skipped: np.ndarray           # (steps,)   i32
    converged: np.ndarray                # (steps, B) bool
    step_wall_s: np.ndarray | None = None   # (steps,) f64, host path only

    def __len__(self) -> int:
        return int(self.active_tiles.shape[0])

    def to_json(self) -> dict:
        d = {
            "active_vertices": self.active_vertices.tolist(),
            "active_tiles": self.active_tiles.tolist(),
            "blocks_fetched": self.blocks_fetched.tolist(),
            "blocks_skipped": self.blocks_skipped.tolist(),
            "converged": self.converged.tolist(),
        }
        if self.step_wall_s is not None:
            d["step_wall_s"] = [float(x) for x in self.step_wall_s]
        return d


@dataclasses.dataclass
class DispatchTelemetry:
    """One engine fixpoint's telemetry: where it ran, its static sizes,
    per-query step counts, and the per-step trace."""
    backend: str            # 'pallas' | 'interpret' | 'jnp' | 'sim'
    mode: str               # 'data' | 'op'
    compact: bool
    batch: int              # B of this dispatch (padded serving size)
    n: int                  # vertices
    ntiles: int
    n_blocks: int           # real weight blocks (sentinel excluded)
    steps: np.ndarray       # (B,) i32 per-query step counts
    trace: StepTrace
    wall_s: float = 0.0
    truncated: bool = False   # fixpoint outran the trace row capacity
    tile: int = 0           # T (0 when unknown, e.g. the sim bridge)
    feature_dim: int = 1    # feature width d of the vertex state
    meta: dict = dataclasses.field(default_factory=dict)

    def summary(self) -> dict:
        """Aggregates the autotuner's cost model and the benches consume.

        The HBM-bytes estimates scale with the feature width d: the
        weight stream is d-independent (each fetched block is (T, T)
        f32), while the per-step state stream -- the (B, ntiles, T, d)
        read + write every relax step performs -- carries a factor of d.
        That asymmetry IS the vector-state win: the same weight traffic
        feeds d feature lanes.
        """
        tr, nt = self.trace, max(self.ntiles, 1)
        nsteps = len(tr)
        t, d = self.tile, max(self.feature_dim, 1)
        state_lane_bytes = 2 * self.batch * nt * t * d * 4  # rd + wr
        return {
            "backend": self.backend,
            "mode": self.mode,
            "compact": self.compact,
            "batch": self.batch,
            "feature_dim": d,
            "steps_max": int(self.steps.max()) if self.steps.size else 0,
            "steps_mean": float(self.steps.mean()) if self.steps.size
            else 0.0,
            "traced_steps": nsteps,
            "truncated": self.truncated,
            "mean_active_vertices": (
                float(tr.active_vertices.sum(axis=1).mean())
                if nsteps else 0.0),
            "mean_active_tile_fraction": (
                float(tr.active_tiles.mean()) / nt if nsteps else 0.0),
            "blocks_fetched_total": int(tr.blocks_fetched.sum()),
            "blocks_skipped_total": int(tr.blocks_skipped.sum()),
            "hbm_weight_bytes_est": int(tr.blocks_fetched.sum()) * t * t
            * 4,
            "hbm_state_bytes_est": nsteps * state_lane_bytes,
            "wall_s": self.wall_s,
        }

    def to_json(self) -> dict:
        return {
            "backend": self.backend, "mode": self.mode,
            "compact": self.compact, "batch": self.batch,
            "n": self.n, "ntiles": self.ntiles,
            "n_blocks": self.n_blocks, "tile": self.tile,
            "feature_dim": self.feature_dim,
            "steps": [int(s) for s in np.atleast_1d(self.steps)],
            "wall_s": self.wall_s, "truncated": self.truncated,
            "meta": self.meta, "trace": self.trace.to_json(),
        }


@dataclasses.dataclass
class QueryTelemetry:
    """Everything one `query()` call did: its dispatches (each with a
    per-step trace), total wall, and the compile-attributed share."""
    dispatches: list[DispatchTelemetry]
    wall_s: float = 0.0
    compile_s: float = 0.0

    def summary(self) -> dict:
        """Cross-dispatch aggregate (weighted by traced steps)."""
        out = {
            "dispatches": len(self.dispatches),
            "wall_s": self.wall_s,
            "compile_s": self.compile_s,
            "steps_max": 0, "traced_steps": 0, "truncated": False,
            "mean_active_vertices": 0.0,
            "mean_active_tile_fraction": 0.0,
            "blocks_fetched_total": 0, "blocks_skipped_total": 0,
            "hbm_weight_bytes_est": 0, "hbm_state_bytes_est": 0,
        }
        w = 0
        for d in self.dispatches:
            s = d.summary()
            k = s["traced_steps"]
            out["steps_max"] = max(out["steps_max"], s["steps_max"])
            out["traced_steps"] += k
            out["truncated"] |= s["truncated"]
            out["blocks_fetched_total"] += s["blocks_fetched_total"]
            out["blocks_skipped_total"] += s["blocks_skipped_total"]
            out["hbm_weight_bytes_est"] += s["hbm_weight_bytes_est"]
            out["hbm_state_bytes_est"] += s["hbm_state_bytes_est"]
            if k:
                out["mean_active_vertices"] += s["mean_active_vertices"] * k
                out["mean_active_tile_fraction"] += \
                    s["mean_active_tile_fraction"] * k
                w += k
        if w:
            out["mean_active_vertices"] /= w
            out["mean_active_tile_fraction"] /= w
        return out

    def steps_histogram(self, edges=(1, 2, 4, 8, 16, 32, 64, 128)) -> dict:
        """Steps-to-converge histogram over every query of every
        dispatch: ``{"<=1": c, "<=2": c, ..., ">128": c}``."""
        steps = np.concatenate(
            [np.atleast_1d(d.steps) for d in self.dispatches]
        ) if self.dispatches else np.zeros(0, np.int32)
        hist, prev = {}, 0
        for e in edges:
            hist[f"<={e}"] = int(((steps > prev) & (steps <= e)).sum())
            prev = e
        hist[f">{edges[-1]}"] = int((steps > edges[-1]).sum())
        if steps.size:
            hist["<=1"] += int((steps <= 0).sum())   # 0-step queries
        return hist

    def to_json(self) -> dict:
        return {"wall_s": self.wall_s, "compile_s": self.compile_s,
                "summary": self.summary(),
                "dispatches": [d.to_json() for d in self.dispatches]}


# ------------------------------------------------------------------ #
# cycle-sim bridge: one schema for both evaluation vehicles
# ------------------------------------------------------------------ #
def from_sim(sim_result, freq_mhz: float = 100.0,
             mode: str = "data") -> QueryTelemetry:
    """Re-emit a `SimResult`'s per-cycle parallelism trace through the
    query-telemetry schema: one simulated cycle = one step, busy PEs =
    active vertices (the sim relaxes one vertex per busy PE per cycle),
    and wall time = simulated time at `freq_mhz`. Packet/swap counters
    ride in `meta`, so a sim row and a JAX row of BENCH_*.json carry
    the same keys."""
    trace = np.asarray(sim_result.parallelism_trace, dtype=np.int32)
    cycles = int(trace.shape[0])
    zeros = np.zeros(cycles, dtype=np.int32)
    steps = np.asarray([sim_result.cycles], dtype=np.int32)
    st = StepTrace(
        active_vertices=trace.reshape(cycles, 1),
        active_tiles=trace.copy(),           # busy PEs ~ active tiles
        blocks_fetched=zeros,
        blocks_skipped=zeros,
        converged=(trace == 0).reshape(cycles, 1),
        step_wall_s=np.full(cycles, 1e-6 / freq_mhz),
    )
    wall = sim_result.cycles * 1e-6 / freq_mhz
    disp = DispatchTelemetry(
        backend="sim", mode=mode, compact=True, batch=1,
        n=int(np.asarray(sim_result.attrs).shape[0]), ntiles=0,
        n_blocks=0, steps=steps, trace=st, wall_s=wall,
        meta={"cycles": sim_result.cycles,
              "packets_delivered": sim_result.packets_delivered,
              "edges_relaxed": sim_result.edges_relaxed,
              "avg_parallelism": sim_result.avg_parallelism,
              "max_parallelism": sim_result.max_parallelism,
              "swaps": sim_result.swaps,
              "freq_mhz": freq_mhz})
    return QueryTelemetry(dispatches=[disp], wall_s=wall)
