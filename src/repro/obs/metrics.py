"""Metrics registry: counters, gauges, and quantile histograms.

The runtime layers (engine, serving front-end, benchmarks) record what
they actually did -- requests, cache hits, latencies, rebuild timings --
into one `MetricsRegistry`, and everything downstream (server `stats()`,
`BENCH_*.json` summaries, the future plan autotuner's measured cost
model) reads the same snapshot schema instead of scraping prints.

Design constraints, in order:

  * **cheap on the hot path** -- `Counter.inc` / `Histogram.observe`
    are one attribute update; nothing is formatted or flushed until a
    snapshot or export is requested;
  * **bounded memory** -- histograms keep a fixed-capacity reservoir
    (uniform per-observation replacement once full), so a server that
    lives for millions of requests never grows an unbounded value list
    while p50/p95/p99 stay representative; exact count/sum/min/max are
    always maintained besides the reservoir;
  * **JSON all the way down** -- `snapshot()` returns plain
    dict/list/float structures that `json.dump` accepts unmodified, and
    `write_events_jsonl` appends one JSON object per line (the format
    log scrapers and the autotuner's history loader expect).
"""
from __future__ import annotations

import dataclasses
import json
import random
import threading
import time


@dataclasses.dataclass
class Counter:
    """Monotone event count. `inc()` only ever adds a non-negative n."""
    name: str
    value: int = 0

    def inc(self, n: int = 1) -> None:
        if n < 0:
            raise ValueError(f"counter {self.name} cannot decrease "
                             f"(inc({n}))")
        self.value += n

    def snapshot(self):
        return self.value


@dataclasses.dataclass
class Gauge:
    """Last-written value of a quantity that moves both ways."""
    name: str
    value: float = 0.0

    def set(self, v: float) -> None:
        self.value = float(v)

    def add(self, delta: float) -> None:
        """Relative move (either direction) -- queue depths and
        occupancy counts adjust by deltas at admission/retirement
        instead of recomputing the absolute level."""
        self.value += float(delta)

    def snapshot(self):
        return self.value


class Histogram:
    """Streaming distribution with exact count/sum/min/max plus a
    fixed-capacity uniform reservoir for the quantile estimates, so a
    long-lived server's latency histogram costs O(capacity) memory
    regardless of traffic."""

    def __init__(self, name: str, capacity: int = 2048,
                 seed: int = 0x5EED):
        self.name = name
        self.capacity = int(capacity)
        self.count = 0
        self.total = 0.0
        self.min = None
        self.max = None
        self._reservoir: list[float] = []
        self._rng = random.Random(seed)

    def observe(self, v: float) -> None:
        v = float(v)
        self.count += 1
        self.total += v
        self.min = v if self.min is None else min(self.min, v)
        self.max = v if self.max is None else max(self.max, v)
        if len(self._reservoir) < self.capacity:
            self._reservoir.append(v)
        else:                      # uniform replacement (Algorithm R)
            j = self._rng.randrange(self.count)
            if j < self.capacity:
                self._reservoir[j] = v

    def quantile(self, q: float) -> float:
        """Nearest-rank quantile over the reservoir (exact while fewer
        than `capacity` observations have been made)."""
        if not self._reservoir:
            return 0.0
        vals = sorted(self._reservoir)
        i = min(len(vals) - 1, max(0, round(q * (len(vals) - 1))))
        return vals[i]

    def snapshot(self) -> dict:
        return {
            "count": self.count,
            "sum": self.total,
            "min": 0.0 if self.min is None else self.min,
            "max": 0.0 if self.max is None else self.max,
            "mean": (self.total / self.count) if self.count else 0.0,
            "p50": self.quantile(0.50),
            "p95": self.quantile(0.95),
            "p99": self.quantile(0.99),
        }


class MetricsRegistry:
    """Get-or-create store of named metrics plus a JSONL event log.

    Metric names are free-form dotted strings (``latency_s.bfs``); the
    registry never interprets them. Access is thread-safe at the
    metric-creation level (the serving front-end may grow async later);
    individual observations rely on the GIL like the rest of the stack.
    """

    def __init__(self):
        self._lock = threading.Lock()
        self._counters: dict[str, Counter] = {}
        self._gauges: dict[str, Gauge] = {}
        self._histograms: dict[str, Histogram] = {}
        self._events: list[dict] = []

    # ------------------------------------------------------------ #
    def counter(self, name: str) -> Counter:
        with self._lock:
            if name not in self._counters:
                self._counters[name] = Counter(name)
            return self._counters[name]

    def gauge(self, name: str) -> Gauge:
        with self._lock:
            if name not in self._gauges:
                self._gauges[name] = Gauge(name)
            return self._gauges[name]

    def histogram(self, name: str, capacity: int = 2048) -> Histogram:
        with self._lock:
            if name not in self._histograms:
                self._histograms[name] = Histogram(name, capacity)
            return self._histograms[name]

    def sum_counters(self, prefix: str) -> int:
        """Total across every counter whose name starts with `prefix` --
        e.g. ``sum_counters("fallback.")`` for the degradation-ladder
        total or ``sum_counters("shed.")`` for requests shed across
        algebras."""
        with self._lock:
            return sum(c.value for n, c in self._counters.items()
                       if n.startswith(prefix))

    # ------------------------------------------------------------ #
    def emit(self, kind: str, **fields) -> dict:
        """Append one structured event (returned for reuse); exported
        verbatim by `write_events_jsonl`."""
        ev = {"ts": time.time(), "kind": kind, **fields}
        self._events.append(ev)
        return ev

    @property
    def events(self) -> list[dict]:
        return self._events

    # ------------------------------------------------------------ #
    def snapshot(self) -> dict:
        """One JSON-ready view of every metric."""
        return {
            "counters": {n: c.snapshot()
                         for n, c in sorted(self._counters.items())},
            "gauges": {n: g.snapshot()
                       for n, g in sorted(self._gauges.items())},
            "histograms": {n: h.snapshot()
                           for n, h in sorted(self._histograms.items())},
        }

    def write_snapshot_json(self, path: str) -> str:
        with open(path, "w") as f:
            json.dump(self.snapshot(), f, indent=1)
        return path

    def write_events_jsonl(self, path: str, append: bool = True) -> str:
        with open(path, "a" if append else "w") as f:
            for ev in self._events:
                f.write(json.dumps(ev) + "\n")
        return path
