"""Pure-jnp oracle for the frontier relaxation step.

One step of FLIP's data-centric execution in dense form: every vertex in
the frontier scatters `attr[u] ⊗ W[u, v]` along its out-edges;
destinations merge with the semiring's ⊕. W encodes the algorithm
(DESIGN.md Sec. 2):

    BFS     : (min,+),  W[u,v] = 1 on edges      (hop levels)
    SSSP    : (min,+),  W[u,v] = weight          (shortest path)
    WCC     : (min,+),  W[u,v] = 0 on both half-edges (min-label prop.)
    widest  : (max,min) W[u,v] = weight          (bottleneck bandwidth)
    reach   : (or,and)  W[u,v] = 1 on edges      (reachability)

Absent edges hold the ⊕-identity. Returns (new_attrs, new_frontier): the
new frontier is exactly the set of vertices whose attribute strictly
⊕-improved -- FLIP's "scatter only on update" rule. (Delta-PageRank's
residual step is not a monotone merge; see `FlipEngine` for its carry
form.)
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.algebra import MIN_PLUS, Semiring


def relax_step_ref(attrs: jnp.ndarray, frontier: jnp.ndarray,
                   w_dense: jnp.ndarray, semiring: Semiring = MIN_PLUS):
    """attrs: (n,) f32; frontier: (n,) bool; w_dense: (n, n) f32
    (⊕-identity = no edge). Returns (new_attrs (n,), new_frontier (n,))."""
    src_vals = jnp.where(frontier, attrs, semiring.zero)    # (n,)
    best = semiring.add_reduce_jnp(
        semiring.mul_jnp(src_vals[:, None], w_dense), axis=0)  # (n,)
    new_attrs = semiring.add_jnp(attrs, best)
    new_frontier = jnp.logical_and(
        semiring.add_jnp(new_attrs, attrs) == new_attrs,
        new_attrs != attrs)
    return new_attrs, new_frontier


def run_to_fixpoint_ref(attrs, frontier, w_dense, max_steps: int = 10_000,
                        semiring: Semiring = MIN_PLUS):
    """Host-side loop for small oracles (tests only)."""
    import numpy as np
    attrs = jnp.asarray(attrs)
    frontier = jnp.asarray(frontier)
    for _ in range(max_steps):
        if not bool(frontier.any()):
            break
        attrs, frontier = relax_step_ref(attrs, frontier, w_dense, semiring)
    return np.asarray(attrs)
