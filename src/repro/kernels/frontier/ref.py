"""Pure-jnp oracle for the frontier relaxation step.

One step of FLIP's data-centric execution in dense form: every vertex in
the frontier scatters `attr[u] + W[u, v]` along its out-edges; destinations
merge with tropical min. W encodes the algorithm (DESIGN.md Sec. 2):

    BFS : W[u,v] = 1 on edges           (hop levels)
    SSSP: W[u,v] = weight               (shortest path)
    WCC : W[u,v] = 0 on both half-edges (min-label propagation)

Absent edges are +inf. Returns (new_attrs, new_frontier): the new frontier
is exactly the set of vertices whose attribute improved -- FLIP's
"scatter only on update" rule.
"""
from __future__ import annotations

import jax.numpy as jnp

INF = jnp.inf


def relax_step_ref(attrs: jnp.ndarray, frontier: jnp.ndarray,
                   w_dense: jnp.ndarray):
    """attrs: (n,) f32; frontier: (n,) bool; w_dense: (n, n) f32 (+inf = no
    edge). Returns (new_attrs (n,), new_frontier (n,))."""
    src_vals = jnp.where(frontier, attrs, INF)              # (n,)
    msgs = src_vals[:, None] + w_dense                      # (n, n)
    best = jnp.min(msgs, axis=0)                            # (n,)
    new_attrs = jnp.minimum(attrs, best)
    new_frontier = new_attrs < attrs
    return new_attrs, new_frontier


def run_to_fixpoint_ref(attrs, frontier, w_dense, max_steps: int = 10_000):
    """Host-side loop for small oracles (tests only)."""
    import numpy as np
    attrs = jnp.asarray(attrs)
    frontier = jnp.asarray(frontier)
    for _ in range(max_steps):
        if not bool(frontier.any()):
            break
        attrs, frontier = relax_step_ref(attrs, frontier, w_dense)
    return np.asarray(attrs)
