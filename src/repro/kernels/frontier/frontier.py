"""Pallas TPU kernel: frontier-masked tropical (min,+) relaxation.

TPU-native form of FLIP's data-centric PE array (DESIGN.md Sec. 2): graph
vertices are tiled onto the 8x128 VPU lane grid; one grid step relaxes all
edges between a source tile and a destination tile held as a dense weight
block in VMEM (absent edge = +inf). The frontier bitmask plays FLIP's
packet-trigger role: a block whose source tile has no active vertex is
skipped entirely (`pl.when`), so inactive regions cost (almost) nothing --
the kernel preserves the paper's "only active vertices scatter" property.

Block-sparsity replaces the Inter-/Intra-Tables: `bsrc/bdst` (scalar-
prefetched, so index maps can read them) name the tile pair of each block;
position inside the block is the DRF register. Blocks are sorted by
destination tile so a destination's partial min accumulates in VMEM across
consecutive grid steps (revisit-friendly "arbitrary" dimension semantics).

Layout: tile size T is a multiple of 128 (lane width). VMEM working set
per step = T*T*4 B (block) + 3*T*4 B (src vals, dst init, out) -- e.g.
64.5 KiB for T=128, well inside the ~16 MiB VMEM budget; larger T=256/512
trades fewer grid steps against VMEM (ops.py picks T).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

INF = float("inf")   # python literal: safe to close over inside the kernel


def _relax_kernel(bsrc_ref, bdst_ref, src_vals_ref, attrs_dst_ref,
                  block_ref, out_ref):
    i = pl.program_id(0)
    prev = bdst_ref[jnp.maximum(i - 1, 0)]
    is_first = jnp.logical_or(i == 0, bdst_ref[i] != prev)

    # First visit of this destination tile: seed with current attributes
    # (the merge is min, so seeding with attrs folds "no update" in).
    @pl.when(is_first)
    def _init():
        out_ref[...] = attrs_dst_ref[...]

    src_vals = src_vals_ref[...]          # (1, T) -- +inf where inactive
    # FLIP trigger rule: skip the whole block if no source is active.
    @pl.when(jnp.any(src_vals < INF))
    def _relax():
        w = block_ref[0]                   # (T, T): w[s, d]
        cand = jnp.min(src_vals[0][:, None] + w, axis=0)   # (T,)
        out_ref[...] = jnp.minimum(out_ref[...], cand[None, :])


@functools.partial(jax.jit, static_argnames=("interpret",))
def frontier_relax_pallas(src_vals: jnp.ndarray,    # (ntiles, T) f32
                          attrs: jnp.ndarray,       # (ntiles, T) f32
                          blocks: jnp.ndarray,      # (nb, T, T) f32
                          bsrc: jnp.ndarray,        # (nb,) i32, sorted by
                          bdst: jnp.ndarray,        # (nb,) i32  (bdst, bsrc)
                          interpret: bool = False) -> jnp.ndarray:
    """One relaxation step. Returns new_attrs (ntiles, T).

    Destination tiles with no incident block keep their attrs (callers
    ensure every tile has at least one block, or accept identity via the
    input_output_aliasing below).
    """
    nb, t, _ = blocks.shape
    ntiles = attrs.shape[0]

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((1, t), lambda i, bs, bd: (bs[i], 0)),   # src vals
            pl.BlockSpec((1, t), lambda i, bs, bd: (bd[i], 0)),   # dst attrs
            pl.BlockSpec((1, t, t), lambda i, bs, bd: (i, 0, 0)),  # block
        ],
        out_specs=pl.BlockSpec((1, t), lambda i, bs, bd: (bd[i], 0)),
    )
    kwargs = {}
    if not interpret:
        kwargs["compiler_params"] = pltpu.CompilerParams(
            dimension_semantics=("arbitrary",))
    out = pl.pallas_call(
        _relax_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((ntiles, t), jnp.float32),
        input_output_aliases={3: 0},   # alias attrs -> out: untouched tiles
        interpret=interpret,           # keep their current attributes
        **kwargs,
    )(bsrc, bdst, src_vals, attrs, blocks)
    return out
