"""Pallas TPU kernel: frontier-masked semiring relaxation, batched.

TPU-native form of FLIP's data-centric PE array (DESIGN.md Sec. 2): graph
vertices are tiled onto the 8x128 VPU lane grid; one grid step relaxes all
edges between a source tile and a destination tile held as a dense weight
block in VMEM (absent edge = the semiring's ⊕-identity). One kernel body
serves every registered algebra: the merge ⊕, combine ⊗, and reduction
are closed over as static ops, so each (semiring, tile) pair specializes
to its own executable at trace time -- tropical (min,+) for BFS/SSSP/WCC,
(max,min) for widest-path, (or,and) for reachability, (+,x) for
delta-PageRank.

The frontier bitmask plays FLIP's packet-trigger role: a block whose
source tile holds only ⊕-identity lanes is skipped entirely (`pl.when`),
so inactive regions cost (almost) no *compute* -- the kernel preserves
the paper's "only active vertices scatter" property. Because the
⊕-identity annihilates ⊗, skipping such a block is exact, not
approximate.

Compacted block streaming extends that skip to the *memory system*, where
a memory-bound relax kernel actually spends its time: the block stream is
indexed through a scalar-prefetched selection list ``bsel`` (see
`ops.compact_block_stream`), whose active prefix names real blocks in
(bdst, bsrc) order and whose inactive tail repeats one all-identity
sentinel block index. The weight BlockSpec's index map reads ``bsel[i]``,
so consecutive sentinel slots produce identical indices and the Pallas
pipeline skips their copies -- the sentinel is fetched into VMEM once and
every dead weight block stays in HBM. Per-step HBM traffic is therefore
(active + 1)·T²·4 B instead of nb·T²·4 B; the sentinel slots still run
the (T, T) VPU combine, but that compute is free under the memory bound.
The dense path is the special case ``bsel = arange(nb)``.

Block-sparsity replaces the Inter-/Intra-Tables: `bsrc/bdst` (scalar-
prefetched, so index maps can read them) name the tile pair of each block;
position inside the block is the DRF register. Blocks are sorted by
destination tile so a destination's partial ⊕ accumulates in VMEM across
consecutive grid steps (revisit-friendly "arbitrary" dimension semantics);
a compacted stream preserves that order because the compaction is stable.

Batched execution (serving-style multi-query workloads): the state is
(B, ntiles, T) -- B independent queries over one shared block structure --
and the grid gains a trailing query dimension, grid = (nb, B). The weight
block's index map ignores the query index, so each block is fetched into
VMEM once and stays resident while all B queries relax against it (the
whole point of batching: amortize the block stream over the batch). The
output/carry specs cover the full (B, 1, T) destination slab and also
ignore the query index, so every visit to one output slab is consecutive
and the single-query accumulation semantics carry over unchanged. The
packet trigger is per query: block i is skipped for query b exactly when
that query's source tile holds only ⊕-identity lanes.

Vector-valued vertex state (feature_dim d > 1): the state blocks grow a
trailing feature axis -- (B, ntiles, T, d) -- and one grid step becomes a
(T, T) × (T, d) tile contraction via `Semiring.contract_jnp`: a true MXU
matmul (`W.T @ sv`) for (+, ×), a d-slab-swept broadcast-⊕-reduce on the
VPU for the tropical/boolean pairs. The weight block stays resident in
VMEM while the B query visits spin against it, so each streamed block is
amortized over B·d lanes instead of B -- the same HBM traffic now feeds
d× the math, which is exactly the memory-bound regime's win.

Layout: tile size T is a multiple of 128 (lane width). VMEM working set
per step at feature width d (d = 1 is the scalar layout) =
T*T*4 B (current block) + T*T*4 B (sentinel block, resident for the
whole step when streaming compacted) + (2B+1)*T*d*4 B (per-query src
slabs, plus the B-row dst init and out slabs), plus the generic
contraction's transient T*T*min(d, 8)*4 B broadcast slab (the in-kernel
d-sweep is bounded at 8 lanes per sweep; the (+, ×) matmul needs no
intermediate). Examples: 161 KiB for T=128, B=32, d=1; 2.7 MiB for
T=128, B=32, d=8; d=128 solo (B=1) is 833 KiB -- all inside the ~16 MiB
VMEM budget. ops.py picks T; plan.resolve validates d.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.algebra import MIN_PLUS, Semiring


@functools.lru_cache(maxsize=None)
def _make_relax_kernel(semiring: Semiring, feature_dim: int = 1):
    """Specialize the kernel body for one contraction shape.

    The cache key is the full (semiring, feature_dim) pair -- the d = 1
    body indexes (1, 1, T) state slabs while d > 1 bodies contract
    (1, 1, T, d) slabs through `semiring.contract_jnp`, so per-d
    specializations must not collide on the semiring alone.
    """
    zero = float(semiring.zero)        # python literal: safe to close over
    add, mul = semiring.add_jnp, semiring.mul_jnp
    add_reduce = semiring.add_reduce_jnp
    contract = semiring.contract_jnp

    def _relax_kernel(bsrc_ref, bdst_ref, bsel_ref, src_vals_ref, carry_ref,
                      block_ref, out_ref):
        del bsel_ref                   # consumed by the block index map
        i = pl.program_id(0)           # weight block (outer: stays resident
        b = pl.program_id(1)           # query in the batch    while b spins)
        prev = bdst_ref[jnp.maximum(i - 1, 0)]
        is_first = jnp.logical_or(i == 0, bdst_ref[i] != prev)

        # First visit of this destination slab: seed all B rows with the
        # carry values (current attrs for monotone algebras -- the ⊕-merge
        # folds "no update" in; the un-absorbed residual for delta-PR).
        @pl.when(jnp.logical_and(is_first, b == 0))
        def _init():
            out_ref[...] = carry_ref[...]

        src_vals = src_vals_ref[0]     # (1, T[, d]) query b's source tile,
        # FLIP trigger rule, per query:  ⊕-identity where inactive
        # skip the block if none of this query's sources is active.
        # (sentinel slots may still fire -- their all-identity block makes
        # the merge an exact no-op, and the compute is free under the
        # memory bound.)
        @pl.when(jnp.any(src_vals != zero))
        def _relax():
            w = block_ref[0]           # (T, T): w[s, d]
            if feature_dim > 1:
                cand = contract(src_vals[0], w)           # (T, d)
                cur = out_ref[pl.ds(b, 1), 0, :, :]       # (1, T, d)
                out_ref[pl.ds(b, 1), 0, :, :] = add(cur, cand[None])
            else:
                cand = add_reduce(mul(src_vals[0][:, None], w),
                                  axis=0)                 # (T,)
                cur = out_ref[pl.ds(b, 1), 0, :]          # (1, T)
                out_ref[pl.ds(b, 1), 0, :] = add(cur, cand[None, :])

    return _relax_kernel


@functools.partial(jax.jit,
                   static_argnames=("semiring", "interpret", "feature_dim"))
def frontier_relax_pallas(src_vals: jnp.ndarray,  # (B?, ntiles, T[, d]) f32
                          carry: jnp.ndarray,     # (B?, ntiles, T[, d]) f32
                          blocks: jnp.ndarray,    # (nb[+1], T, T) f32
                          bsrc: jnp.ndarray,      # (nslots,) i32, sorted by
                          bdst: jnp.ndarray,      # (nslots,) i32 (bdst, bsrc)
                          semiring: Semiring = MIN_PLUS,
                          interpret: bool = False,
                          bsel: jnp.ndarray | None = None,
                          feature_dim: int = 1) -> jnp.ndarray:
    """One relaxation step: new[b, d] = carry[b, d] ⊕ (⊕_s sv[b, s] ⊗ W[s, d]).

    `src_vals`/`carry` are (ntiles, T) for one query or (B, ntiles, T) for
    a batch of B independent queries sharing the block structure; the
    result has the same shape. Destination tiles with no incident block
    keep their carry (callers ensure every tile has at least one block, or
    accept identity via the input_output_aliasing below).

    `feature_dim` d > 1 switches to vector-valued vertex state: the state
    arrays carry a trailing feature axis ((ntiles, T, d) solo /
    (B, ntiles, T, d) batched) and each grid step runs the (T, T) × (T, d)
    tile contraction instead of the scalar broadcast-reduce. `feature_dim`
    is an explicit static argument (not inferred from ndim) because
    (ntiles, T, d) and (B, ntiles, T) are indistinguishable by rank alone.

    `bsel` (optional, (nslots,) i32) streams the weight blocks through an
    indirection: grid slot i fetches ``blocks[bsel[i]]``. Dense streaming
    is ``bsel = None`` (identity). Compacted streaming passes the output
    of `ops.compact_block_stream` together with the sentinel-extended
    block array and the compacted `bsrc`/`bdst` slot coordinates.
    """
    features = feature_dim > 1
    if src_vals.shape != carry.shape:
        raise ValueError(f"src_vals {src_vals.shape} / carry "
                         f"{carry.shape} state shapes disagree")
    if features and src_vals.shape[-1] != feature_dim:
        raise ValueError(
            f"state carries feature_dim {src_vals.shape[-1]} but the "
            f"kernel was asked for feature_dim {feature_dim}")
    squeeze = src_vals.ndim == 2 + features
    if squeeze:
        src_vals, carry = src_vals[None], carry[None]
    t = blocks.shape[-1]
    nslots = bsrc.shape[0]
    if bsel is None:
        bsel = jnp.arange(nslots, dtype=jnp.int32)
    batch, ntiles = carry.shape[0], carry.shape[1]

    if features:
        d = feature_dim
        in_specs = [
            pl.BlockSpec((1, 1, t, d),
                         lambda i, b, bs, bd, sel: (b, bs[i], 0, 0)),
            pl.BlockSpec((batch, 1, t, d),
                         lambda i, b, bs, bd, sel: (0, bd[i], 0, 0)),
            pl.BlockSpec((1, t, t),
                         lambda i, b, bs, bd, sel: (sel[i], 0, 0)),
        ]
        out_spec = pl.BlockSpec((batch, 1, t, d),
                                lambda i, b, bs, bd, sel: (0, bd[i], 0, 0))
        out_shape = jax.ShapeDtypeStruct((batch, ntiles, t, d), jnp.float32)
    else:
        in_specs = [
            pl.BlockSpec((1, 1, t),
                         lambda i, b, bs, bd, sel: (b, bs[i], 0)),  # src vals
            pl.BlockSpec((batch, 1, t),
                         lambda i, b, bs, bd, sel: (0, bd[i], 0)),  # carry
            pl.BlockSpec((1, t, t),
                         lambda i, b, bs, bd, sel: (sel[i], 0, 0)),  # block
        ]
        out_spec = pl.BlockSpec((batch, 1, t),
                                lambda i, b, bs, bd, sel: (0, bd[i], 0))
        out_shape = jax.ShapeDtypeStruct((batch, ntiles, t), jnp.float32)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(nslots, batch),
        in_specs=in_specs,
        out_specs=out_spec,
    )
    kwargs = {}
    if not interpret:
        kwargs["compiler_params"] = pltpu.CompilerParams(
            dimension_semantics=("arbitrary", "arbitrary"))
    out = pl.pallas_call(
        _make_relax_kernel(semiring, feature_dim),
        grid_spec=grid_spec,
        out_shape=out_shape,
        input_output_aliases={4: 0},   # alias carry -> out: untouched tiles
        interpret=interpret,           # keep their carry values
        **kwargs,
    )(bsrc, bdst, bsel, src_vals, carry, blocks)
    return out[0] if squeeze else out
