from repro.kernels.frontier.ops import (
    frontier_relax,
    build_blocks,
    BlockedGraph,
)
from repro.kernels.frontier import ref

__all__ = ["frontier_relax", "build_blocks", "BlockedGraph", "ref"]
