from repro.kernels.frontier.ops import (
    frontier_relax,
    build_blocks,
    compact_block_stream,
    tile_activity,
    BlockedGraph,
    UpdateDelta,
)
from repro.kernels.frontier import ref

__all__ = ["frontier_relax", "build_blocks", "compact_block_stream",
           "tile_activity", "BlockedGraph", "UpdateDelta", "ref"]
