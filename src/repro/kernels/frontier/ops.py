"""Public ops for the frontier relaxation kernel.

`build_blocks` converts a CSR graph (+ optional FLIP mapping, whose
vertex->PE placement becomes the vertex->tile permutation: the compiled
placement minimizes cross-tile edges exactly like it minimizes NoC hops)
into the block-sparse tile form the kernel consumes. The algorithm's
`VertexAlgebra` decides the stored ⊗ operand per edge (`edge_value`) and
the fill for absent edges (the semiring's ⊕-identity, so empty lanes drop
out of every reduction). The build is fully vectorized: one numpy
key-sort + `ufunc.at` semiring scatter, no per-edge Python loop.

`frontier_relax` dispatches: Pallas on TPU, Pallas-interpret when forced
(tests), and a vectorized segment-reduce jnp fallback elsewhere (CPU).

Frontier-compacted block streaming (``compact=True``): FLIP's headline
win is that *inactive vertices cost nothing*, and on a memory-bound relax
kernel that has to include the memory system, not just the ALUs. Each
step we derive per-tile activity from the source values (a tile is active
iff any lane differs from the ⊕-identity -- exactly the kernel's
packet-trigger condition), map it onto the block list, and compact the
active blocks to the front of a *fixed-size* index list with a masked
cumsum + scatter (the list is pre-sorted by ``bdst``, so a stable
compaction preserves the consecutive-visit accumulation order -- no sort
at runtime). Inactive slots all point at one designated all-identity
sentinel block (`BlockedGraph.blocks_ext`), so the Pallas index map
re-fetches one tiny VMEM-resident block instead of streaming dead weight
blocks: HBM traffic drops from O(nb·T²) to O(active·T²) + ε per step
while every shape stays static (no recompiles). Because the ⊕-identity
annihilates ⊗, the sentinel relax is an exact no-op, so compacted results
are bit-for-bit the dense-streaming results.

On the jnp/CPU path the same activity mask drives a gather of only the
active blocks before the segment-⊕. Static shapes under `jit` cannot
shrink, so when called with concrete (non-traced) arrays the active list
is padded to the next power-of-two bucket -- at most log2(nb) specialized
executables -- which is where the CPU fallback's asymptotic win comes
from (`FlipEngine` drives its jnp fixpoint through this path).
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.algebra import MIN_PLUS, Semiring, VertexAlgebra, get_algebra
from repro.graphs.csr import Graph
from repro.kernels.frontier.frontier import frontier_relax_pallas


@dataclasses.dataclass
class BlockedGraph:
    """Block-sparse tiled adjacency over one algebra's semiring."""
    n: int                      # true vertex count
    tile: int                   # T
    ntiles: int
    blocks: jnp.ndarray         # (nb, T, T) f32, ⊕-identity = no edge
    bsrc: jnp.ndarray           # (nb,) i32, sorted by (bdst, bsrc)
    bdst: jnp.ndarray           # (nb,) i32
    perm: np.ndarray            # original vertex id -> tiled position
    inv_perm: np.ndarray        # tiled position -> original vertex id
    algebra: VertexAlgebra = None
    # (nb+1, T, T): `blocks` plus one trailing all-⊕-identity sentinel
    # block. Compacted streaming points every inactive slot at index nb,
    # so the sentinel is fetched once and stays VMEM-resident while the
    # dead blocks it stands in for never leave HBM.
    blocks_ext: jnp.ndarray = None
    # (ntiles+1,) i32 per-destination segment layout: the blocks writing
    # destination tile d occupy bdst-sorted positions
    # dst_start[d]:dst_start[d+1]. Precomputed so runtime compaction is a
    # masked cumsum/scatter (never a sort) and the distributed engine can
    # slice per-device block slabs directly.
    dst_start: np.ndarray = None
    bsrc_np: np.ndarray = None  # host copy of bsrc for the per-step
                                # bucketing path (avoids a device->host
                                # conversion every fixpoint step)
    version: int = 0            # Graph.version this layout was built from
    graph_fp: str = None        # Graph.fingerprint() of that graph, so
                                # engine caches can detect stale layouts

    def __post_init__(self):
        # precompute eagerly (construction always happens on the host):
        # materializing these lazily inside a trace would cache tracers
        if self.blocks_ext is None and self.algebra is not None:
            sentinel = jnp.full((1, self.tile, self.tile),
                                np.float32(self.semiring.zero), jnp.float32)
            self.blocks_ext = jnp.concatenate([self.blocks, sentinel],
                                              axis=0)
        if self.dst_start is None:
            self.dst_start = np.searchsorted(
                np.asarray(self.bdst),
                np.arange(self.ntiles + 1)).astype(np.int32)
        if self.bsrc_np is None:
            self.bsrc_np = np.asarray(self.bsrc)

    @property
    def padded_n(self) -> int:
        return self.ntiles * self.tile

    @property
    def semiring(self) -> Semiring:
        if self.algebra is None:
            raise ValueError("BlockedGraph built without an algebra; "
                             "construct it via build_blocks(graph, algo)")
        return self.algebra.semiring

    def to_tiled(self, attrs_orig: np.ndarray, fill=None,
                 features: bool = False) -> jnp.ndarray:
        """(n,) -> (ntiles, T), or batched (B, n) -> (B, ntiles, T);
        padded lanes hold `fill` (default: the ⊕-identity).
        `features=True` treats the trailing axis as the feature width d:
        (n, d) -> (ntiles, T, d), (B, n, d) -> (B, ntiles, T, d)."""
        if fill is None:
            fill = np.float32(self.semiring.zero)
        attrs_orig = np.asarray(attrs_orig)
        if features:
            lead, d = attrs_orig.shape[:-2], attrs_orig.shape[-1]
            out = np.full(lead + (self.padded_n, d), fill, dtype=np.float32)
            out[..., self.perm, :] = attrs_orig
            return jnp.asarray(
                out.reshape(lead + (self.ntiles, self.tile, d)))
        lead = attrs_orig.shape[:-1]
        out = np.full(lead + (self.padded_n,), fill, dtype=np.float32)
        out[..., self.perm] = attrs_orig
        return jnp.asarray(out.reshape(lead + (self.ntiles, self.tile)))

    def to_orig(self, attrs_tiled, features: bool = False) -> np.ndarray:
        """(ntiles, T) -> (n,), or batched (B, ntiles, T) -> (B, n);
        with `features=True` the trailing feature axis rides along:
        (…, ntiles, T, d) -> (…, n, d)."""
        flat = np.asarray(attrs_tiled)
        if features:
            d = flat.shape[-1]
            flat = flat.reshape(flat.shape[:-3] + (-1, d))
            return flat[..., self.perm, :]
        flat = flat.reshape(flat.shape[:-2] + (-1,))
        return flat[..., self.perm]

    # ------------------------------------------------------------------ #
    # streaming mutations: rebuild only the touched tiles
    # ------------------------------------------------------------------ #
    def apply_updates(self, new_graph: Graph,
                      updates) -> tuple["BlockedGraph", "UpdateDelta"]:
        """Incremental re-block against `new_graph` (the post-update
        Graph, i.e. ``graph.apply_updates(updates)``), reusing this
        layout's vertex permutation and tiling.

        Only the tile pairs touched by `updates` are recomputed, through
        the same vectorized semiring `ufunc.at` scatter as `build_blocks`.
        When every touched pair keeps a (non-empty) block, the update is
        value-only: `bsrc`/`bdst` (and every shape) are reused unchanged,
        so compiled relax executables keyed on them stay hot. A batch
        that activates a previously empty tile pair appends blocks, and
        one that empties an off-diagonal block drops it (diagonal blocks
        always stay: they seed the carry); either way the key order is
        re-sorted and `shape_changed=True`. The resulting layout is
        always block-for-block identical to a from-scratch
        `build_blocks` over `new_graph`, so layouts never accumulate
        cruft across long mutation streams.

        Returns ``(new_bg, delta)``; `delta` carries the per-algebra
        warm-start verdict (`Semiring.monotone_under` over the changed
        cells) and the affected source vertices that seed the resumed
        frontier.
        """
        alg, sr, t, ntiles = self.algebra, self.semiring, self.tile, \
            self.ntiles
        if alg is None:
            raise ValueError("BlockedGraph built without an algebra")
        if new_graph.n != self.n:
            raise ValueError(
                f"apply_updates keeps the vertex set fixed: layout has "
                f"n={self.n}, updated graph has n={new_graph.n}")
        perm = self.perm

        # dirty (u, v) endpoint pairs in every stored direction: the
        # graph's own mirroring (undirected CSR) and the algebra's
        # both-half-edges rule (WCC) each add the reverse pair
        uu, vv = [], []
        for upd in updates:
            u, v = int(upd[0]), int(upd[1])
            uu.append(u), vv.append(v)
            if not new_graph.directed or alg.undirected:
                uu.append(v), vv.append(u)
        # degree-dependent ⊗ operands (delta-PageRank): a changed
        # out-degree re-values every surviving out-edge of the source,
        # so all of its tiles are dirty, not just the updated cell
        if alg.weight_rule == "degree_damped":
            for s in sorted(set(uu)):
                for x in new_graph.neighbors(s):
                    uu.append(s), vv.append(int(x))
        u_arr = np.asarray(uu, dtype=np.int64)
        v_arr = np.asarray(vv, dtype=np.int64)
        pu, pv = perm[u_arr], perm[v_arr]
        dkeys = np.unique((pv // t) * ntiles + (pu // t))
        if dkeys.size == 0:                    # empty batch: version-only
            new_bg = dataclasses.replace(
                self, version=new_graph.version,
                graph_fp=new_graph.fingerprint())
            return new_bg, UpdateDelta(
                monotone=sr.monotone_under([], []), shape_changed=False,
                affected_src=np.zeros(0, dtype=np.int64),
                n_blocks_rebuilt=0, version=new_graph.version)

        # rebuild the dirty tiles from the new graph's edges -- the same
        # key-sort + semiring-scatter path as build_blocks, restricted to
        # edges that land in a dirty tile pair
        eu = new_graph.edge_sources()
        ev = new_graph.indices.astype(np.int64)
        w = alg.edge_values(eu, ev, new_graph.weights,
                            new_graph.out_degree())
        if alg.undirected:
            eu, ev = np.concatenate([eu, ev]), np.concatenate([ev, eu])
            w = np.concatenate([w, w])
        peu, pev = perm[eu], perm[ev]
        ekey = (pev // t) * ntiles + (peu // t)
        kpos = np.searchsorted(dkeys, ekey)
        sel = np.flatnonzero(
            (kpos < dkeys.size)
            & (dkeys[np.minimum(kpos, dkeys.size - 1)] == ekey))
        fresh = np.full((dkeys.size, t, t), np.float32(sr.zero),
                        dtype=np.float32)
        lin = (kpos[sel] * t + peu[sel] % t) * t + pev[sel] % t
        _scatter_edges(sr, fresh.reshape(-1), lin,
                       w[sel].astype(np.float32))

        # old values of the same cells (⊕-identity where no block exists
        # yet) drive the monotonicity verdict and the frontier seeds;
        # only the dirty blocks are gathered from the device array --
        # the full block tensor never round-trips through the host on
        # the (common) value-only path
        old_keys = (np.asarray(self.bdst, dtype=np.int64) * ntiles
                    + np.asarray(self.bsrc, dtype=np.int64))
        nb = old_keys.size
        opos = np.searchsorted(old_keys, dkeys)
        exists = ((opos < nb)
                  & (old_keys[np.minimum(opos, nb - 1)] == dkeys))
        opos_e = opos[exists]
        old = np.full_like(fresh, np.float32(sr.zero))
        if opos_e.size:
            old[exists] = np.asarray(self.blocks[opos_e])
        monotone = sr.monotone_under(old, fresh)

        # affected sources: original ids of the lanes whose out-edge
        # cells changed -- the warm-start frontier seed
        changed_rows = (old != fresh).any(axis=2)        # (ndirty, t)
        blk, row = np.nonzero(changed_rows)
        pos = (dkeys[blk] % ntiles) * t + row            # tiled positions
        pos = pos[pos < self.n]                          # drop padding
        affected = np.unique(self.inv_perm[pos]).astype(np.int64)

        fp = new_graph.fingerprint()
        # keep the layout identical to a from-scratch build: a missing
        # tile pair only grows the list if it actually gained edges (a
        # delete of an absent edge stays a no-op), and an off-diagonal
        # block emptied by deletions is dropped (diagonal blocks always
        # stay -- they initialize the carry for their destination tile)
        empty = ~(fresh != np.float32(sr.zero)).any(axis=(1, 2))
        diag = (dkeys // ntiles) == (dkeys % ntiles)
        grow = ~exists & ~empty
        drop = exists & empty & ~diag
        if not grow.any() and not drop.any():
            upd = self.blocks
            if opos_e.size:                # dirty tiles patched on device
                upd = upd.at[opos_e].set(jnp.asarray(fresh[exists]))
            new_bg = BlockedGraph(
                n=self.n, tile=t, ntiles=ntiles,
                blocks=upd, bsrc=self.bsrc, bdst=self.bdst,
                perm=perm, inv_perm=self.inv_perm, algebra=alg,
                dst_start=self.dst_start, bsrc_np=self.bsrc_np,
                version=new_graph.version, graph_fp=fp)
            shape_changed = False
        else:
            blocks = np.asarray(self.blocks).copy()
            blocks[opos_e] = fresh[exists]
            keep = np.ones(nb, dtype=bool)
            keep[opos[drop]] = False
            keys2 = np.concatenate([old_keys[keep], dkeys[grow]])
            blocks2 = np.concatenate([blocks[keep], fresh[grow]])
            order2 = np.argsort(keys2, kind="stable")
            keys2 = keys2[order2]
            new_bg = BlockedGraph(
                n=self.n, tile=t, ntiles=ntiles,
                blocks=jnp.asarray(blocks2[order2]),
                bsrc=jnp.asarray((keys2 % ntiles).astype(np.int32)),
                bdst=jnp.asarray((keys2 // ntiles).astype(np.int32)),
                perm=perm, inv_perm=self.inv_perm, algebra=alg,
                version=new_graph.version, graph_fp=fp)
            shape_changed = True
        delta = UpdateDelta(monotone=monotone, shape_changed=shape_changed,
                            affected_src=affected,
                            n_blocks_rebuilt=int(dkeys.size),
                            version=new_graph.version)
        return new_bg, delta


@dataclasses.dataclass(frozen=True)
class UpdateDelta:
    """What one `BlockedGraph.apply_updates` batch did, and whether the
    previous fixpoint may warm-start the recompute."""
    monotone: bool            # every changed cell ⊕-improved under an
                              # idempotent ⊕: resume from the old fixpoint
    shape_changed: bool       # block list grew (empty tile pair
                              # activated) or shrank (off-diagonal block
                              # emptied): compiled fns keyed on the block
                              # shapes will retrace
    affected_src: np.ndarray  # original ids of sources whose out-edge
                              # cells changed -- the warm frontier seed
    n_blocks_rebuilt: int     # dirty tiles recomputed by this batch
    version: int              # Graph.version the new layout tracks


def _scatter_edges(sr: Semiring, flat: np.ndarray, lin: np.ndarray,
                   w: np.ndarray) -> None:
    """⊕-combine edge values into flattened block storage in place
    (parallel edges merge through the semiring). Shared by the full
    build and the incremental tile rebuild so the two can never drift:
    the ufunc `.at` fast path, with a slow exact fallback for
    non-ufunc ⊕."""
    if hasattr(sr.add_np, "at"):
        sr.add_np.at(flat, lin, w)
    else:
        for j, x in zip(lin, w):
            flat[j] = sr.add_np(flat[j], x)


def build_blocks(graph: Graph, algo: str | VertexAlgebra = "sssp",
                 tile: int = 128,
                 order: np.ndarray | None = None) -> BlockedGraph:
    """Block-sparse semiring adjacency for any registered algebra.

    algo: a registered algorithm name ('bfs', 'sssp', 'wcc', 'pagerank',
    'widest', 'reach', ...) or a `VertexAlgebra` directly. `order`:
    optional vertex ordering (e.g. from the FLIP mapping compiler);
    order[k] = original id of the vertex at tiled position k.

    Fully vectorized: edges come straight out of the CSR arrays, the ⊗
    operands from the algebra's vectorized `edge_values`, block ids from
    one `np.unique` over (bdst, bsrc) keys (already the required sort
    order), and parallel edges ⊕-combine through the semiring ufunc's
    `.at` scatter -- no per-edge Python loop.
    """
    alg = algo if isinstance(algo, VertexAlgebra) else get_algebra(algo)
    sr = alg.semiring
    n = graph.n
    if order is None:
        order = np.arange(n)
    perm = np.empty(n, dtype=np.int64)     # original -> position
    perm[order] = np.arange(n)

    ntiles = max(1, -(-n // tile))
    outdeg = graph.out_degree()
    u = graph.edge_sources()
    v = graph.indices.astype(np.int64)
    w = alg.edge_values(u, v, graph.weights, outdeg)
    if alg.undirected:
        u, v = np.concatenate([u, v]), np.concatenate([v, u])
        w = np.concatenate([w, w])
    pu, pv = perm[u], perm[v]

    # block key = bdst * ntiles + bsrc: np.unique sorts by (bdst, bsrc),
    # exactly the consecutive-destination-visit order the kernel needs.
    # every destination tile must appear at least once so its output block
    # is initialized from the carry (all-identity blocks act as identity):
    # the diagonal keys guarantee that.
    key = (pv // tile) * ntiles + (pu // tile)
    diag = np.arange(ntiles, dtype=np.int64) * (ntiles + 1)
    uniq, inv = np.unique(np.concatenate([key, diag]), return_inverse=True)
    nb = uniq.size
    bdst = (uniq // ntiles).astype(np.int32)
    bsrc = (uniq % ntiles).astype(np.int32)

    blocks = np.full((nb, tile, tile), np.float32(sr.zero), dtype=np.float32)
    lin = (inv[:key.size] * tile + pu % tile) * tile + pv % tile
    _scatter_edges(sr, blocks.reshape(-1), lin, w.astype(np.float32))
    return BlockedGraph(n=n, tile=tile, ntiles=ntiles,
                        blocks=jnp.asarray(blocks),
                        bsrc=jnp.asarray(bsrc), bdst=jnp.asarray(bdst),
                        perm=perm, inv_perm=np.asarray(order),
                        algebra=alg, version=graph.version,
                        graph_fp=graph.fingerprint())


# --------------------------------------------------------------------- #
# frontier compaction: per-tile activity -> compacted block stream
# --------------------------------------------------------------------- #
def tile_activity(src_vals, semiring: Semiring, features: bool = False):
    """(…, ntiles, T[, d]) source values -> (ntiles,) bool per-tile
    activity.

    A tile is active iff any of its lanes (for any query of the batch,
    any feature lane when `features=True`) differs from the ⊕-identity --
    the same condition as the kernel's packet trigger, so a block whose
    source tile is inactive contributes exactly nothing (the ⊕-identity
    annihilates ⊗) and may be dropped from the stream without changing a
    single bit of the result.
    """
    axes = (-2, -1) if features else (-1,)
    act = jnp.any(src_vals != np.float32(semiring.zero), axis=axes)
    if act.ndim > 1:                       # batched: active for any query
        act = jnp.any(act, axis=tuple(range(act.ndim - 1)))
    return act


@jax.jit
def compact_block_stream(tile_act, bsrc, bdst):
    """Stable compaction of the active blocks to the front of a fixed-size
    index list (masked cumsum + scatter -- never a sort: the list is
    already (bdst, bsrc)-sorted and stability preserves that, keeping the
    kernel's consecutive-destination accumulation semantics intact).

    Returns ``(bsel, bsrc_c, bdst_c, n_active)``:
      * bsel   (nb,) i32 -- slot i's index into ``blocks_ext``; slots
        ``>= n_active`` hold the sentinel index nb.
      * bsrc_c/bdst_c (nb,) i32 -- slot tile coordinates; inactive slots
        repeat the last active block's pair (or block nb-1 when nothing is
        active) so consecutive grid steps keep identical index-map
        outputs and Pallas skips the re-fetch entirely.
      * n_active -- traced active-block count.
    """
    nb = bsrc.shape[0]
    act = jnp.take(tile_act, bsrc)
    pos = jnp.cumsum(act.astype(jnp.int32)) - 1
    n_active = jnp.sum(act.astype(jnp.int32))
    sel = jnp.full((nb,), nb, dtype=jnp.int32)
    sel = sel.at[jnp.where(act, pos, nb)].set(
        jnp.arange(nb, dtype=jnp.int32), mode="drop")
    last = jnp.minimum(sel[jnp.maximum(n_active - 1, 0)], nb - 1)
    fill = jnp.where(jnp.arange(nb) < n_active, sel, last)
    return (sel, jnp.take(bsrc, fill), jnp.take(bdst, fill), n_active)


@functools.partial(jax.jit, static_argnames=("semiring", "features"))
def _relax_jnp(src_vals, carry, blocks, bsrc, bdst,
               semiring: Semiring = MIN_PLUS, features: bool = False):
    """Vectorized fallback: per-block ⊗-combine + segment-⊕ by bdst.

    Accepts (ntiles, T) state or batched (B, ntiles, T): the combine
    broadcasts the shared blocks over the query axis (XLA fuses the
    ⊗+reduce, so the (B, nb, T, T) product is never materialized) and the
    segment-⊕ maps over queries. `features=True` switches to vector state
    ((…, ntiles, T, d)): the combine becomes the semiring's (T, T) × (T, d)
    tile contraction (a matmul for (+, ×)) and the segment-⊕ carries the
    feature axis along.
    """
    tax = -3 if features else -2
    ntiles = carry.shape[tax]
    sv = jnp.take(src_vals, bsrc, axis=tax)          # (..., nb, T[, d])
    if features:
        cand = semiring.contract_jnp(sv, blocks)     # (..., nb, T, d)
    else:
        cand = semiring.add_reduce_jnp(
            semiring.mul_jnp(sv[..., :, None], blocks), axis=-2)
    def seg(x):
        return semiring.segment_reduce_jnp(x, bdst, ntiles)
    batched = cand.ndim == (4 if features else 3)
    best = jax.vmap(seg)(cand) if batched else seg(cand)
    return semiring.add_jnp(carry, best)


@functools.partial(jax.jit, static_argnames=("semiring", "features"))
def _relax_jnp_compact(src_vals, carry, blocks_ext, bsrc, bdst, bsel,
                       semiring: Semiring = MIN_PLUS,
                       features: bool = False):
    """Compacted jnp relax: ⊗-combine + segment-⊕ over only the blocks
    named by ``bsel`` (a prefix of active block ids padded with the
    sentinel index nb). Sentinel rows gather the all-identity block, so
    they contribute the ⊕-identity to their segment: bit-for-bit the
    dense result, at O(len(bsel)·T²) instead of O(nb·T²). Vector state
    (`features=True`) contracts each gathered block over its (T, d) slab.
    """
    tax = -3 if features else -2
    ntiles = carry.shape[tax]
    src_ix = jnp.take(bsrc, bsel, mode="clip")      # sentinel -> last block
    seg_ix = jnp.take(bdst, bsel, mode="clip")
    sv = jnp.take(src_vals, src_ix, axis=tax)            # (..., k, T[, d])
    w = jnp.take(blocks_ext, bsel, axis=0)               # (k, T, T)
    if features:
        cand = semiring.contract_jnp(sv, w)              # (..., k, T, d)
    else:
        cand = semiring.add_reduce_jnp(
            semiring.mul_jnp(sv[..., :, None], w), axis=-2)
    def seg(x):
        return semiring.segment_reduce_jnp(x, seg_ix, ntiles)
    batched = cand.ndim == (4 if features else 3)
    best = jax.vmap(seg)(cand) if batched else seg(cand)
    return semiring.add_jnp(carry, best)


_BUCKET_MIN = 8     # smallest compacted-list size: bounds executables at
                    # ~log2(nb) buckets per (semiring, state shape)


def _relax_jnp_bucketed(src_vals, carry, bg: "BlockedGraph",
                        features: bool = False):
    """Host-side compacted jnp step for concrete (non-traced) inputs: read
    the active count, round it up to a power-of-two bucket, and run the
    bucket-sized compacted relax. Falls back to the dense step when the
    bucket would not be smaller than the full list."""
    sr = bg.semiring
    nb = int(bg.bsrc.shape[0])
    act = np.asarray(tile_activity(src_vals, sr, features))[bg.bsrc_np]
    idx = np.flatnonzero(act).astype(np.int32)
    bucket = max(_BUCKET_MIN,
                 1 << int(idx.size - 1).bit_length() if idx.size else 0)
    if bucket >= nb:
        return _relax_jnp(src_vals, carry, bg.blocks, bg.bsrc, bg.bdst,
                          semiring=sr, features=features)
    bsel = np.full(bucket, nb, dtype=np.int32)
    bsel[:idx.size] = idx
    return _relax_jnp_compact(src_vals, carry, bg.blocks_ext, bg.bsrc,
                              bg.bdst, jnp.asarray(bsel), semiring=sr,
                              features=features)


def resolve_relax_mode(mode: str) -> str:
    """The single 'auto' dispatch rule: Pallas on TPU, jnp elsewhere.
    Shared with `FlipEngine` so the engine's host-fixpoint redirect can
    never disagree with the kernel dispatch below."""
    if mode == "auto":
        return "pallas" if jax.default_backend() == "tpu" else "jnp"
    return mode


def frontier_relax(src_vals, carry, bg: BlockedGraph, mode: str = "auto",
                   compact: bool = False, feature_dim: int = 1):
    """One frontier relaxation step over a BlockedGraph.

    src_vals: (ntiles, T) f32 -- attrs where active, ⊕-identity where
              not -- or (B, ntiles, T) for a batch of B queries. At
              feature_dim d > 1 the state grows a trailing feature axis:
              (ntiles, T, d) / (B, ntiles, T, d).
    carry:    same shape; values merged into every destination.
    mode: 'auto' | 'pallas' | 'interpret' | 'jnp'.
    compact: frontier-compacted block streaming -- stream only blocks
             with an active source tile (any query); exact (bit-for-bit
             the dense result). On the pallas/interpret path the
             compaction runs on-device with static shapes; on the jnp
             path it buckets host-side, so under a trace (e.g. inside
             `lax.while_loop`) it falls back to the dense step.
    feature_dim: static feature width d; must match the state's trailing
             axis when > 1 (explicit, because (ntiles, T, d) and
             (B, ntiles, T) are rank-ambiguous).
    """
    sr = bg.semiring
    features = feature_dim > 1
    if features and src_vals.shape[-1] != feature_dim:
        raise ValueError(
            f"frontier_relax: state trailing axis {src_vals.shape[-1]} "
            f"!= feature_dim {feature_dim} (state shape "
            f"{tuple(src_vals.shape)})")
    mode = resolve_relax_mode(mode)
    if mode == "pallas" and jax.default_backend() != "tpu":
        raise ValueError(
            f"frontier_relax(mode='pallas') needs a TPU backend, but "
            f"jax.default_backend() is {jax.default_backend()!r}; use "
            "mode='interpret' (Pallas interpreter, exact but slow) or "
            "mode='jnp' (vectorized fallback)")
    if mode == "jnp":
        if not compact:
            return _relax_jnp(src_vals, carry, bg.blocks, bg.bsrc, bg.bdst,
                              semiring=sr, features=features)
        if isinstance(src_vals, jax.core.Tracer):
            # traced shapes cannot shrink: the dense step *is* the
            # compacted stream's fixed-size upper bound, and it avoids a
            # pointless full-width gather of blocks_ext
            return _relax_jnp(src_vals, carry, bg.blocks, bg.bsrc, bg.bdst,
                              semiring=sr, features=features)
        return _relax_jnp_bucketed(src_vals, carry, bg, features=features)
    interpret = mode == "interpret"
    if not compact:
        return frontier_relax_pallas(src_vals, carry, bg.blocks, bg.bsrc,
                                     bg.bdst, semiring=sr,
                                     interpret=interpret,
                                     feature_dim=feature_dim)
    bsel, bsrc_c, bdst_c, _ = compact_block_stream(
        tile_activity(src_vals, sr, features), bg.bsrc, bg.bdst)
    return frontier_relax_pallas(src_vals, carry, bg.blocks_ext, bsrc_c,
                                 bdst_c, semiring=sr, interpret=interpret,
                                 bsel=bsel, feature_dim=feature_dim)
