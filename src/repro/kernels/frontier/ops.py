"""Public ops for the frontier relaxation kernel.

`build_blocks` converts a CSR graph (+ optional FLIP mapping, whose
vertex->PE placement becomes the vertex->tile permutation: the compiled
placement minimizes cross-tile edges exactly like it minimizes NoC hops)
into the block-sparse tile form the kernel consumes.

`frontier_relax` dispatches: Pallas on TPU, Pallas-interpret when forced
(tests), and a vectorized segment-min jnp fallback elsewhere (CPU).
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.graphs.csr import Graph
from repro.kernels.frontier.frontier import frontier_relax_pallas

INF = np.float32(np.inf)


@dataclasses.dataclass
class BlockedGraph:
    """Block-sparse tiled adjacency in (min,+) form."""
    n: int                      # true vertex count
    tile: int                   # T
    ntiles: int
    blocks: jnp.ndarray         # (nb, T, T) f32, +inf = no edge
    bsrc: jnp.ndarray           # (nb,) i32, sorted by (bdst, bsrc)
    bdst: jnp.ndarray           # (nb,) i32
    perm: np.ndarray            # original vertex id -> tiled position
    inv_perm: np.ndarray        # tiled position -> original vertex id

    @property
    def padded_n(self) -> int:
        return self.ntiles * self.tile

    def to_tiled(self, attrs_orig: np.ndarray, fill=INF) -> jnp.ndarray:
        out = np.full(self.padded_n, fill, dtype=np.float32)
        out[self.perm] = attrs_orig
        return jnp.asarray(out.reshape(self.ntiles, self.tile))

    def to_orig(self, attrs_tiled) -> np.ndarray:
        flat = np.asarray(attrs_tiled).reshape(-1)
        return flat[self.perm]


def build_blocks(graph: Graph, algo: str = "sssp", tile: int = 128,
                 order: np.ndarray | None = None) -> BlockedGraph:
    """Block-sparse (min,+) adjacency.

    algo: 'bfs' (unit weights), 'sssp' (edge weights), 'wcc' (zero weights,
    symmetrized). `order`: optional vertex ordering (e.g. from the FLIP
    mapping compiler); order[k] = original id of the vertex at tiled
    position k.
    """
    n = graph.n
    if order is None:
        order = np.arange(n)
    perm = np.empty(n, dtype=np.int64)     # original -> position
    perm[order] = np.arange(n)

    ntiles = max(1, -(-n // tile))
    edges = []
    for u, v, w in graph.edge_list():
        if algo == "bfs":
            wval = 1.0
        elif algo == "wcc":
            wval = 0.0
        else:
            wval = w
        edges.append((perm[u], perm[v], wval))
        if algo == "wcc":
            edges.append((perm[v], perm[u], wval))

    by_block: dict[tuple[int, int], list[tuple[int, int, float]]] = {}
    for pu, pv, w in edges:
        key = (pv // tile, pu // tile)     # (dst, src) for the sort
        by_block.setdefault(key, []).append((pu % tile, pv % tile, w))

    # every destination tile must appear at least once so its output block
    # is initialized from attrs (blocks of all-inf act as identity)
    for d in range(ntiles):
        by_block.setdefault((d, d), [])

    keys = sorted(by_block)
    nb = len(keys)
    blocks = np.full((nb, tile, tile), INF, dtype=np.float32)
    bsrc = np.empty(nb, dtype=np.int32)
    bdst = np.empty(nb, dtype=np.int32)
    for i, (d, s) in enumerate(keys):
        bdst[i], bsrc[i] = d, s
        for su, dv, w in by_block[(d, s)]:
            blocks[i, su, dv] = min(blocks[i, su, dv], np.float32(w))
    return BlockedGraph(n=n, tile=tile, ntiles=ntiles,
                        blocks=jnp.asarray(blocks),
                        bsrc=jnp.asarray(bsrc), bdst=jnp.asarray(bdst),
                        perm=perm, inv_perm=np.asarray(order))


# --------------------------------------------------------------------- #
# dispatching step op
# --------------------------------------------------------------------- #
@jax.jit
def _relax_jnp(src_vals, attrs, blocks, bsrc, bdst):
    """Vectorized fallback: per-block candidate + segment-min by bdst."""
    ntiles, t = attrs.shape
    sv = src_vals[bsrc]                                  # (nb, T)
    cand = jnp.min(sv[:, :, None] + blocks, axis=1)      # (nb, T)
    best = jax.ops.segment_min(cand, bdst, num_segments=ntiles)
    return jnp.minimum(attrs, best)


def frontier_relax(src_vals, attrs, bg: BlockedGraph, mode: str = "auto"):
    """One frontier relaxation step over a BlockedGraph.

    src_vals: (ntiles, T) f32 -- attrs where active, +inf where not.
    attrs:    (ntiles, T) f32 current attributes.
    mode: 'auto' | 'pallas' | 'interpret' | 'jnp'.
    """
    if mode == "auto":
        mode = "pallas" if jax.default_backend() == "tpu" else "jnp"
    if mode == "jnp":
        return _relax_jnp(src_vals, attrs, bg.blocks, bg.bsrc, bg.bdst)
    return frontier_relax_pallas(src_vals, attrs, bg.blocks, bg.bsrc,
                                 bg.bdst, interpret=(mode == "interpret"))
