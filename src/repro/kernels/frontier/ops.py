"""Public ops for the frontier relaxation kernel.

`build_blocks` converts a CSR graph (+ optional FLIP mapping, whose
vertex->PE placement becomes the vertex->tile permutation: the compiled
placement minimizes cross-tile edges exactly like it minimizes NoC hops)
into the block-sparse tile form the kernel consumes. The algorithm's
`VertexAlgebra` decides the stored ⊗ operand per edge (`edge_value`) and
the fill for absent edges (the semiring's ⊕-identity, so empty lanes drop
out of every reduction).

`frontier_relax` dispatches: Pallas on TPU, Pallas-interpret when forced
(tests), and a vectorized segment-reduce jnp fallback elsewhere (CPU).
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.algebra import MIN_PLUS, Semiring, VertexAlgebra, get_algebra
from repro.graphs.csr import Graph
from repro.kernels.frontier.frontier import frontier_relax_pallas


@dataclasses.dataclass
class BlockedGraph:
    """Block-sparse tiled adjacency over one algebra's semiring."""
    n: int                      # true vertex count
    tile: int                   # T
    ntiles: int
    blocks: jnp.ndarray         # (nb, T, T) f32, ⊕-identity = no edge
    bsrc: jnp.ndarray           # (nb,) i32, sorted by (bdst, bsrc)
    bdst: jnp.ndarray           # (nb,) i32
    perm: np.ndarray            # original vertex id -> tiled position
    inv_perm: np.ndarray        # tiled position -> original vertex id
    algebra: VertexAlgebra = None

    @property
    def padded_n(self) -> int:
        return self.ntiles * self.tile

    @property
    def semiring(self) -> Semiring:
        if self.algebra is None:
            raise ValueError("BlockedGraph built without an algebra; "
                             "construct it via build_blocks(graph, algo)")
        return self.algebra.semiring

    def to_tiled(self, attrs_orig: np.ndarray, fill=None) -> jnp.ndarray:
        """(n,) -> (ntiles, T), or batched (B, n) -> (B, ntiles, T);
        padded lanes hold `fill` (default: the ⊕-identity)."""
        if fill is None:
            fill = np.float32(self.semiring.zero)
        attrs_orig = np.asarray(attrs_orig)
        lead = attrs_orig.shape[:-1]
        out = np.full(lead + (self.padded_n,), fill, dtype=np.float32)
        out[..., self.perm] = attrs_orig
        return jnp.asarray(out.reshape(lead + (self.ntiles, self.tile)))

    def to_orig(self, attrs_tiled) -> np.ndarray:
        """(ntiles, T) -> (n,), or batched (B, ntiles, T) -> (B, n)."""
        flat = np.asarray(attrs_tiled)
        flat = flat.reshape(flat.shape[:-2] + (-1,))
        return flat[..., self.perm]


def build_blocks(graph: Graph, algo: str | VertexAlgebra = "sssp",
                 tile: int = 128,
                 order: np.ndarray | None = None) -> BlockedGraph:
    """Block-sparse semiring adjacency for any registered algebra.

    algo: a registered algorithm name ('bfs', 'sssp', 'wcc', 'pagerank',
    'widest', 'reach', ...) or a `VertexAlgebra` directly. `order`:
    optional vertex ordering (e.g. from the FLIP mapping compiler);
    order[k] = original id of the vertex at tiled position k.
    """
    alg = algo if isinstance(algo, VertexAlgebra) else get_algebra(algo)
    sr = alg.semiring
    n = graph.n
    if order is None:
        order = np.arange(n)
    perm = np.empty(n, dtype=np.int64)     # original -> position
    perm[order] = np.arange(n)

    ntiles = max(1, -(-n // tile))
    outdeg = graph.out_degree()
    edges = []
    for u, v, w in graph.edge_list():
        wval = alg.edge_value(u, v, w, outdeg)
        edges.append((perm[u], perm[v], wval))
        if alg.undirected:
            edges.append((perm[v], perm[u], wval))

    by_block: dict[tuple[int, int], list[tuple[int, int, float]]] = {}
    for pu, pv, w in edges:
        key = (pv // tile, pu // tile)     # (dst, src) for the sort
        by_block.setdefault(key, []).append((pu % tile, pv % tile, w))

    # every destination tile must appear at least once so its output block
    # is initialized from the carry (all-identity blocks act as identity)
    for d in range(ntiles):
        by_block.setdefault((d, d), [])

    keys = sorted(by_block)
    nb = len(keys)
    blocks = np.full((nb, tile, tile), np.float32(sr.zero), dtype=np.float32)
    bsrc = np.empty(nb, dtype=np.int32)
    bdst = np.empty(nb, dtype=np.int32)
    for i, (d, s) in enumerate(keys):
        bdst[i], bsrc[i] = d, s
        for su, dv, w in by_block[(d, s)]:
            # parallel edges ⊕-combine (min for tropical, + for PageRank)
            blocks[i, su, dv] = sr.add_np(blocks[i, su, dv], np.float32(w))
    return BlockedGraph(n=n, tile=tile, ntiles=ntiles,
                        blocks=jnp.asarray(blocks),
                        bsrc=jnp.asarray(bsrc), bdst=jnp.asarray(bdst),
                        perm=perm, inv_perm=np.asarray(order),
                        algebra=alg)


# --------------------------------------------------------------------- #
# dispatching step op
# --------------------------------------------------------------------- #
@functools.partial(jax.jit, static_argnames=("semiring",))
def _relax_jnp(src_vals, carry, blocks, bsrc, bdst,
               semiring: Semiring = MIN_PLUS):
    """Vectorized fallback: per-block ⊗-combine + segment-⊕ by bdst.

    Accepts (ntiles, T) state or batched (B, ntiles, T): the combine
    broadcasts the shared blocks over the query axis (XLA fuses the
    ⊗+reduce, so the (B, nb, T, T) product is never materialized) and the
    segment-⊕ maps over queries.
    """
    ntiles = carry.shape[-2]
    sv = jnp.take(src_vals, bsrc, axis=-2)               # (..., nb, T)
    cand = semiring.add_reduce_jnp(
        semiring.mul_jnp(sv[..., :, None], blocks), axis=-2)  # (..., nb, T)
    def seg(x):
        return semiring.segment_reduce_jnp(x, bdst, ntiles)
    best = jax.vmap(seg)(cand) if cand.ndim == 3 else seg(cand)
    return semiring.add_jnp(carry, best)


def frontier_relax(src_vals, carry, bg: BlockedGraph, mode: str = "auto"):
    """One frontier relaxation step over a BlockedGraph.

    src_vals: (ntiles, T) f32 -- attrs where active, ⊕-identity where
              not -- or (B, ntiles, T) for a batch of B queries.
    carry:    same shape; values merged into every destination.
    mode: 'auto' | 'pallas' | 'interpret' | 'jnp'.
    """
    sr = bg.semiring
    if mode == "auto":
        mode = "pallas" if jax.default_backend() == "tpu" else "jnp"
    if mode == "jnp":
        return _relax_jnp(src_vals, carry, bg.blocks, bg.bsrc, bg.bdst,
                          semiring=sr)
    return frontier_relax_pallas(src_vals, carry, bg.blocks, bg.bsrc,
                                 bg.bdst, semiring=sr,
                                 interpret=(mode == "interpret"))
