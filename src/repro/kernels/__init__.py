"""Pallas TPU kernels for the perf-critical compute layers.

frontier/  -- the paper's hot loop: frontier-masked tropical (min,+)
              relaxation over block-sparse adjacency tiles (TPU-native
              form of FLIP's data-centric PE array, DESIGN.md Sec. 2).
attention/ -- causal + sliding-window flash attention (train/prefill).
ssd/       -- Mamba-2 state-space-duality chunked scan.

Each kernel directory ships <name>.py (pl.pallas_call + BlockSpec),
ops.py (jit'd public wrapper with platform dispatch) and ref.py (pure-jnp
oracle used by the tests).
"""
