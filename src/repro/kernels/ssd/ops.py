"""Public op for the SSD layer: platform dispatch.

On TPU the intra-chunk quadratic form runs in the Pallas kernel
(ssd.py); elsewhere (CPU smoke tests, dry-run lowering) the pure-jnp
chunked form from ref.py is used -- same math, same chunk structure, so
HLO FLOPs are representative.
"""
from __future__ import annotations

import jax

from repro.kernels.ssd.ref import ssd_ref


def ssd_chunked(x, dt, Bm, Cm, A_log, D, chunk: int = 64, h0=None,
                impl: str = "auto"):
    if impl == "auto":
        impl = "pallas" if jax.default_backend() == "tpu" else "jnp"
    if impl in ("pallas", "pallas_interpret"):
        from repro.kernels.ssd.ssd import ssd_pallas
        return ssd_pallas(x, dt, Bm, Cm, A_log, D, chunk=chunk, h0=h0,
                          interpret=(impl == "pallas_interpret"))
    return ssd_ref(x, dt, Bm, Cm, A_log, D, chunk=chunk, h0=h0)
