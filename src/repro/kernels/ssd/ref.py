"""Pure-jnp oracle: Mamba-2 SSD (state-space duality) chunked scan.

Semantics (per head h, state size N, head dim P):
    h_t = a_t * h_{t-1} + dt_t * B_t (x) x_t          a_t = exp(-exp(A_log) dt_t)
    y_t = C_t . h_t + D * x_t

Chunked O(L*Q) evaluation (arXiv:2405.21060): within a chunk the quadratic
"attention" form with decay mask; across chunks a sequential state carry.

Shapes: x (B,L,H,P); dt (B,L,H); Bm/Cm (B,L,N); A_log (H,); D (H,).
Also exposes `ssd_step_ref` for single-token decode.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def ssd_ref(x, dt, Bm, Cm, A_log, D, chunk: int = 64, h0=None):
    """Returns (y (B,L,H,P), h_final (B,H,N,P))."""
    b, l, h, p = x.shape
    n = Bm.shape[-1]
    assert l % chunk == 0, (l, chunk)
    nc, q = l // chunk, chunk
    f32 = jnp.float32

    la = (-jnp.exp(A_log.astype(f32))[None, None, :]
          * dt.astype(f32))                    # (B,L,H) log decay
    dtx = x.astype(f32) * dt.astype(f32)[..., None]    # (B,L,H,P)

    # chunked views
    la_c = la.reshape(b, nc, q, h)
    x_c = dtx.reshape(b, nc, q, h, p)
    B_c = Bm.astype(f32).reshape(b, nc, q, n)
    C_c = Cm.astype(f32).reshape(b, nc, q, n)
    cums = jnp.cumsum(la_c, axis=2)                    # inclusive
    last = cums[:, :, -1:, :]                          # (B,nc,1,H)

    # intra-chunk quadratic form
    G = jnp.einsum("bcin,bcjn->bcij", C_c, B_c)        # (B,nc,Q,Q)
    diff = cums[:, :, :, None, :] - cums[:, :, None, :, :]   # (B,nc,Q,Q,H)
    mask = jnp.tril(jnp.ones((q, q), bool))
    # mask BEFORE exp: for i<j diff is large-positive; exp would overflow
    # and its cotangent would be inf*0=NaN through the where
    decay = jnp.exp(jnp.where(mask[None, None, :, :, None], diff, -jnp.inf))
    att = G[..., None] * decay                         # (B,nc,Q,Q,H)
    y_intra = jnp.einsum("bcijh,bcjhp->bcihp", att, x_c)

    # per-chunk outgoing state
    dec_out = jnp.exp(last - cums)                     # (B,nc,Q,H)
    S = jnp.einsum("bcjh,bcjn,bcjhp->bchnp", dec_out, B_c, x_c)

    # sequential inter-chunk recurrence
    chunk_decay = jnp.exp(last[:, :, 0, :])            # (B,nc,H)
    if h0 is None:
        h0 = jnp.zeros((b, h, n, p), f32)

    def step(hprev, inputs):
        s_c, cd = inputs                               # (B,H,N,P),(B,H)
        hnew = cd[:, :, None, None] * hprev + s_c
        return hnew, hprev

    hfin, hprevs = jax.lax.scan(
        step, h0, (S.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(1, 0, 2)))
    hprevs = hprevs.transpose(1, 0, 2, 3, 4)           # (B,nc,H,N,P)

    dec_in = jnp.exp(cums)                             # (B,nc,Q,H)
    y_inter = jnp.einsum("bcin,bchnp,bcih->bcihp", C_c, hprevs, dec_in)
    y = (y_intra + y_inter).reshape(b, l, h, p)
    y = y + x.astype(f32) * D.astype(f32)[None, None, :, None]
    return y.astype(x.dtype), hfin


def ssd_step_ref(x, dt, Bm, Cm, A_log, D, hprev):
    """Single decode step. x (B,H,P); dt (B,H); Bm/Cm (B,N);
    hprev (B,H,N,P). Returns (y (B,H,P), h)."""
    f32 = jnp.float32
    a = jnp.exp(-jnp.exp(A_log.astype(f32))[None, :] * dt.astype(f32))
    dtx = x.astype(f32) * dt.astype(f32)[..., None]    # (B,H,P)
    h = a[:, :, None, None] * hprev \
        + jnp.einsum("bn,bhp->bhnp", Bm.astype(f32), dtx)
    y = jnp.einsum("bn,bhnp->bhp", Cm.astype(f32), h)
    y = y + x.astype(f32) * D.astype(f32)[None, :, None]
    return y.astype(x.dtype), h
