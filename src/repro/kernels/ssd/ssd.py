"""Pallas TPU kernel for the SSD intra-chunk quadratic form.

Per (batch, chunk, head) grid cell, in VMEM:
    G     = C_c B_c^T                      (Q x Q "attention" scores)
    att   = G * exp(cums_i - cums_j) * tril
    y     = att @ (dt*x)                   intra-chunk output
    S     = (B_c * exp(last - cums))^T (dt*x)   outgoing chunk state
The O(L) inter-chunk recurrence (tiny, sequential) and the y_inter
correction stay in jax.lax.scan in ops.py -- the quadratic part is
>95% of the FLOPs and is what the MXU should run.

VMEM working set (Q=256, N=128, P=64 fp32): ~1 MiB.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(c_ref, b_ref, x_ref, cums_ref, y_ref, s_ref):
    C = c_ref[0, 0].astype(jnp.float32)           # (Q, N)
    B = b_ref[0, 0].astype(jnp.float32)           # (Q, N)
    x = x_ref[0, 0, :, 0].astype(jnp.float32)     # (Q, P)
    cums = cums_ref[0, 0, :, 0].astype(jnp.float32)   # (Q,)

    q = C.shape[0]
    G = jax.lax.dot_general(C, B, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (Q,Q)
    diff = cums[:, None] - cums[None, :]
    ii = jax.lax.broadcasted_iota(jnp.int32, (q, q), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (q, q), 1)
    # mask before exp (overflow + NaN-cotangent safety, same as ref.py)
    att = G * jnp.exp(jnp.where(ii >= jj, diff, -jnp.inf))
    y = jax.lax.dot_general(att, x, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (Q,P)
    y_ref[0, 0, :, 0] = y.astype(y_ref.dtype)

    dec_out = jnp.exp(cums[-1] - cums)            # (Q,)
    bw = B * dec_out[:, None]                     # (Q,N)
    s = jax.lax.dot_general(bw, x, (((0,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (N,P)
    s_ref[0, 0, 0] = s.astype(s_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def ssd_intra_pallas(C, B, dtx, cums, interpret: bool = False):
    """C/B: (b,nc,Q,N); dtx: (b,nc,Q,H,P); cums: (b,nc,Q,H).
    Returns (y_intra (b,nc,Q,H,P) f32, S (b,nc,H,N,P) f32)."""
    b, nc, q, n = C.shape
    h, p = dtx.shape[3], dtx.shape[4]
    kwargs = {}
    if not interpret:
        kwargs["compiler_params"] = pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel"))
    y, s = pl.pallas_call(
        _ssd_kernel,
        grid=(b, nc, h),
        in_specs=[
            pl.BlockSpec((1, 1, q, n), lambda bb, cc, hh: (bb, cc, 0, 0)),
            pl.BlockSpec((1, 1, q, n), lambda bb, cc, hh: (bb, cc, 0, 0)),
            pl.BlockSpec((1, 1, q, 1, p),
                         lambda bb, cc, hh: (bb, cc, 0, hh, 0)),
            pl.BlockSpec((1, 1, q, 1), lambda bb, cc, hh: (bb, cc, 0, hh)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, q, 1, p),
                         lambda bb, cc, hh: (bb, cc, 0, hh, 0)),
            pl.BlockSpec((1, 1, 1, n, p),
                         lambda bb, cc, hh: (bb, cc, hh, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, nc, q, h, p), jnp.float32),
            jax.ShapeDtypeStruct((b, nc, h, n, p), jnp.float32),
        ],
        interpret=interpret,
        **kwargs,
    )(C, B, dtx, cums)
    return y, s


def ssd_pallas(x, dt, Bm, Cm, A_log, D, chunk: int = 64, h0=None,
               interpret: bool = False):
    """Full SSD with the Pallas intra-chunk kernel (same contract as
    ref.ssd_ref)."""
    b, l, h, p = x.shape
    n = Bm.shape[-1]
    assert l % chunk == 0
    nc, q = l // chunk, chunk
    f32 = jnp.float32

    la = -jnp.exp(A_log.astype(f32))[None, None, :] * dt.astype(f32)
    dtx = (x.astype(f32) * dt.astype(f32)[..., None]).reshape(
        b, nc, q, h, p)
    la_c = la.reshape(b, nc, q, h)
    cums = jnp.cumsum(la_c, axis=2)
    last = cums[:, :, -1:, :]
    B_c = Bm.astype(f32).reshape(b, nc, q, n)
    C_c = Cm.astype(f32).reshape(b, nc, q, n)

    y_intra, S = ssd_intra_pallas(C_c, B_c, dtx, cums, interpret=interpret)

    chunk_decay = jnp.exp(last[:, :, 0, :])
    if h0 is None:
        h0 = jnp.zeros((b, h, n, p), f32)

    def step(hprev, inputs):
        s_c, cd = inputs
        return cd[:, :, None, None] * hprev + s_c, hprev

    hfin, hprevs = jax.lax.scan(
        step, h0, (S.transpose(1, 0, 2, 3, 4),
                   chunk_decay.transpose(1, 0, 2)))
    hprevs = hprevs.transpose(1, 0, 2, 3, 4)
    dec_in = jnp.exp(cums)
    y_inter = jnp.einsum("bcin,bchnp,bcih->bcihp", C_c, hprevs, dec_in)
    y = (y_intra + y_inter).reshape(b, l, h, p)
    y = y + x.astype(f32) * D.astype(f32)[None, None, :, None]
    return y.astype(x.dtype), hfin
