from repro.kernels.ssd.ops import ssd_chunked
from repro.kernels.ssd import ref

__all__ = ["ssd_chunked", "ref"]
