"""Public flash attention op with platform dispatch."""
from __future__ import annotations

import jax

from repro.kernels.attention.flash import flash_attention_pallas
from repro.kernels.attention.ref import attention_ref


def flash_attention(q, k, v, causal: bool = True, window: int | None = None,
                    interpret: bool | None = None, **kw):
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    return flash_attention_pallas(q, k, v, causal=causal, window=window,
                                  interpret=interpret, **kw)
