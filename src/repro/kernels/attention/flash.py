"""Pallas TPU flash attention (GQA, causal, sliding-window).

Grid: (batch, q_heads, q_blocks, kv_blocks) with the kv dimension
innermost and "arbitrary" (sequential) so the online-softmax running
state (m, l, acc) lives in VMEM scratch across kv steps.

Causal/window block skipping is structural: fully-masked (q_blk, kv_blk)
pairs are skipped with pl.when, so HLO-level work matches ~S^2/2 for
causal and ~S*W for sliding windows -- the same property the lax_flash
fallback has, and the TPU analogue of FLIP's "inactive PEs don't fire".

Block sizes: bq x bkv tiles of the score matrix; defaults 512x512 keep
the VMEM working set (q blk + k blk + v blk + scores + acc) under ~2.5
MiB for hd <= 256.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref,
                  m_sc, l_sc, acc_sc,
                  *, bq, bkv, nkv, causal, window, scale):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    # valid kv-block range for this q block
    last = qi * bq // bkv if causal else nkv - 1
    first = 0
    if window is not None:
        first = jnp.maximum(0, (qi * bq - window) // bkv)

    @pl.when(ki == 0)
    def _init():
        m_sc[...] = jnp.full_like(m_sc, NEG_INF)
        l_sc[...] = jnp.zeros_like(l_sc)
        acc_sc[...] = jnp.zeros_like(acc_sc)

    in_range = jnp.logical_and(ki >= first, ki <= last)

    @pl.when(in_range)
    def _block():
        q = q_ref[0, 0].astype(jnp.float32)      # (bq, hd)
        k = k_ref[0, 0].astype(jnp.float32)      # (bkv, hd)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        s = s * scale                             # (bq, bkv)
        q_pos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 0)
        k_pos = ki * bkv + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 1)
        ok = jnp.ones((bq, bkv), jnp.bool_)
        if causal:
            ok = jnp.logical_and(ok, k_pos <= q_pos)
        if window is not None:
            ok = jnp.logical_and(ok, k_pos > q_pos - window)
        s = jnp.where(ok, s, NEG_INF)

        m_prev = m_sc[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_sc[...] = l_sc[...] * corr + p.sum(axis=1)
        acc_sc[...] = acc_sc[...] * corr[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_sc[...] = m_new

    @pl.when(ki == nkv - 1)
    def _finish():
        l = jnp.maximum(l_sc[...], 1e-30)
        o_ref[0, 0] = (acc_sc[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "window", "bq",
                                             "bkv", "interpret"))
def flash_attention_pallas(q, k, v, causal: bool = True,
                           window: int | None = None,
                           bq: int = 512, bkv: int = 512,
                           interpret: bool = False):
    """q: (B,S,H,hd); k/v: (B,T,KH,hd). Returns (B,S,H,hd)."""
    b, s, h, hd = q.shape
    t, kh = k.shape[1], k.shape[2]
    g = h // kh
    bq = min(bq, s)
    bkv = min(bkv, t)
    assert s % bq == 0 and t % bkv == 0
    nq, nkv = s // bq, t // bkv
    scale = 1.0 / np.sqrt(hd)

    # layout: (B, H, S, hd) blocks
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)

    kernel = functools.partial(_flash_kernel, bq=bq, bkv=bkv, nkv=nkv,
                               causal=causal, window=window, scale=scale)
    kwargs = {}
    if not interpret:
        kwargs["compiler_params"] = pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary"))
    out = pl.pallas_call(
        kernel,
        grid=(b, h, nq, nkv),
        in_specs=[
            pl.BlockSpec((1, 1, bq, hd),
                         lambda bb, hh, qi, ki: (bb, hh, qi, 0)),
            pl.BlockSpec((1, 1, bkv, hd),
                         lambda bb, hh, qi, ki, g=g: (bb, hh // g, ki, 0)),
            pl.BlockSpec((1, 1, bkv, hd),
                         lambda bb, hh, qi, ki, g=g: (bb, hh // g, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, hd),
                               lambda bb, hh, qi, ki: (bb, hh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, s, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq, hd), jnp.float32),
        ],
        interpret=interpret,
        **kwargs,
    )(qt, kt, vt)
    return out.transpose(0, 2, 1, 3)
