from repro.kernels.attention.ops import flash_attention
from repro.kernels.attention import ref

__all__ = ["flash_attention", "ref"]
