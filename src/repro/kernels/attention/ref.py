"""Pure-jnp oracle for flash attention (GQA, causal, sliding window)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

NEG_INF = -1e30


def attention_ref(q, k, v, causal: bool = True, window: int | None = None):
    """q: (B,S,H,hd); k/v: (B,T,KH,hd) with H % KH == 0."""
    b, s, h, hd = q.shape
    t, kh = k.shape[1], k.shape[2]
    g = h // kh
    qr = q.reshape(b, s, kh, g, hd)
    scores = jnp.einsum("bskgd,btkd->bkgst", qr, k).astype(jnp.float32)
    scores = scores / np.sqrt(hd)
    q_pos = jnp.arange(s)[:, None]
    k_pos = jnp.arange(t)[None, :]
    ok = jnp.ones((s, t), bool)
    if causal:
        ok &= k_pos <= q_pos
    if window is not None:
        ok &= k_pos > q_pos - window
    scores = jnp.where(ok, scores, NEG_INF)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgst,btkd->bskgd", p.astype(v.dtype), v)
    return out.reshape(b, s, h, hd)
