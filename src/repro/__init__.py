"""repro: FLIP (data-centric edge CGRA) reproduced and scaled as a JAX framework.

Layers:
  repro.graphs   -- graph substrate (CSR, generators, references)
  repro.core     -- the paper's contribution (mapping compiler, cycle sim,
                    JAX frontier engine, data-centric dispatch)
  repro.kernels  -- Pallas TPU kernels (frontier relax, attention, SSD)
  repro.models   -- LM substrate for the assigned architectures
  repro.configs  -- one config per assigned architecture
  repro.distributed / repro.optim / repro.checkpoint / repro.data
  repro.launch   -- mesh, dryrun, train, serve, graph_run
  repro.api      -- the unified query surface: compile(graph, program,
                    plan) -> CompiledQuery sessions (alias: `import flip`)
"""

__version__ = "1.0.0"

_API_EXPORTS = ("compile", "Program", "ExecutionPlan", "CompiledQuery",
                "QueryResult", "WarmStart")


def __getattr__(name):
    # `repro.compile(...)` works without importing jax at package import
    # time (the api pulls in the whole engine stack lazily).
    if name in _API_EXPORTS:
        from repro import api
        return getattr(api, name)
    raise AttributeError(f"module 'repro' has no attribute {name!r}")
