"""repro: FLIP (data-centric edge CGRA) reproduced and scaled as a JAX framework.

Layers:
  repro.graphs   -- graph substrate (CSR, generators, references)
  repro.core     -- the paper's contribution (mapping compiler, cycle sim,
                    JAX frontier engine, data-centric dispatch)
  repro.kernels  -- Pallas TPU kernels (frontier relax, attention, SSD)
  repro.models   -- LM substrate for the assigned architectures
  repro.configs  -- one config per assigned architecture
  repro.distributed / repro.optim / repro.checkpoint / repro.data
  repro.launch   -- mesh, dryrun, train, serve, graph_run
"""

__version__ = "1.0.0"
