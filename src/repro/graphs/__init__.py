from repro.graphs.csr import Graph
from repro.graphs.generators import (
    make_road_network,
    make_tree,
    make_synthetic,
    make_power_law,
    make_dataset,
    DATASET_SPECS,
)
from repro.graphs import reference

__all__ = [
    "Graph",
    "make_road_network",
    "make_tree",
    "make_synthetic",
    "make_power_law",
    "make_dataset",
    "DATASET_SPECS",
    "reference",
]
