"""Reference (oracle) graph algorithms in plain numpy.

These are the functional ground truth for every FLIP execution layer
(cycle simulator, JAX frontier engine, Pallas kernel) and double as the
"MCU" algorithm implementations (the paper's MCU baseline runs the
textbook-optimal algorithms: BFS O(|V|+|E|), SSSP via binary-heap Dijkstra
O(|E|+|V|log|V|), WCC O(|V|+|E|)).

Each function also returns lightweight op counts that the MCU cycle model
(repro.core.baselines) converts into cycles.
"""
from __future__ import annotations

import heapq
import numpy as np

from repro.graphs.csr import Graph

INF = np.float32(np.inf)


def bfs(g: Graph, src: int):
    """Hop levels from src. Returns (levels f32 (n,), stats)."""
    level = np.full(g.n, INF, dtype=np.float32)
    level[src] = 0.0
    frontier = [src]
    edges_relaxed = 0
    while frontier:
        nxt = []
        for u in frontier:
            for v in g.neighbors(u):
                edges_relaxed += 1
                if level[v] == INF:
                    level[v] = level[u] + 1.0
                    nxt.append(int(v))
        frontier = nxt
    return level, {"edges_relaxed": edges_relaxed}


def sssp(g: Graph, src: int):
    """Dijkstra with a binary heap. Returns (dist f32 (n,), stats)."""
    dist = np.full(g.n, INF, dtype=np.float32)
    dist[src] = 0.0
    heap = [(0.0, src)]
    edges_relaxed = 0
    pops = 0
    while heap:
        d, u = heapq.heappop(heap)
        pops += 1
        if d > dist[u]:
            continue
        base = g.indptr[u]
        for k in range(base, g.indptr[u + 1]):
            v = int(g.indices[k])
            w = float(g.weights[k])
            edges_relaxed += 1
            nd = d + w
            if nd < dist[v]:
                dist[v] = np.float32(nd)
                heapq.heappush(heap, (nd, v))
    return dist, {"edges_relaxed": edges_relaxed, "heap_pops": pops}


def wcc(g: Graph):
    """Weakly connected components by min-label propagation.

    Returns (labels f32 (n,) — min vertex id in the component, stats).
    """
    adj = g.undirected_adjacency()
    label = np.arange(g.n, dtype=np.float32)
    edges_relaxed = 0
    changed = True
    while changed:
        changed = False
        for u in range(g.n):
            for v in adj[u]:
                edges_relaxed += 1
                if label[v] < label[u]:
                    label[u] = label[v]
                    changed = True
                elif label[u] < label[v]:
                    label[v] = label[u]
                    changed = True
    return label, {"edges_relaxed": edges_relaxed}


def run(algo: str, g: Graph, src: int = 0):
    if algo == "bfs":
        return bfs(g, src)
    if algo == "sssp":
        return sssp(g, src)
    if algo == "wcc":
        return wcc(g)
    raise ValueError(f"unknown algorithm {algo!r}")
