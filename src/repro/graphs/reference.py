"""Reference (oracle) graph algorithms in plain numpy.

These are the functional ground truth for every FLIP execution layer
(cycle simulator, JAX frontier engine, Pallas kernel) and double as the
"MCU" algorithm implementations (the paper's MCU baseline runs the
textbook-optimal algorithms: BFS O(|V|+|E|), SSSP via binary-heap Dijkstra
O(|E|+|V|log|V|), WCC O(|V|+|E|)).

Each function also returns lightweight op counts that the MCU cycle model
(repro.core.baselines) converts into cycles.
"""
from __future__ import annotations

import heapq
import numpy as np

from repro.graphs.csr import Graph

INF = np.float32(np.inf)


def bfs(g: Graph, src: int):
    """Hop levels from src. Returns (levels f32 (n,), stats)."""
    level = np.full(g.n, INF, dtype=np.float32)
    level[src] = 0.0
    frontier = [src]
    edges_relaxed = 0
    while frontier:
        nxt = []
        for u in frontier:
            for v in g.neighbors(u):
                edges_relaxed += 1
                if level[v] == INF:
                    level[v] = level[u] + 1.0
                    nxt.append(int(v))
        frontier = nxt
    return level, {"edges_relaxed": edges_relaxed}


def sssp(g: Graph, src: int):
    """Dijkstra with a binary heap. Returns (dist f32 (n,), stats)."""
    dist = np.full(g.n, INF, dtype=np.float32)
    dist[src] = 0.0
    heap = [(0.0, src)]
    edges_relaxed = 0
    pops = 0
    while heap:
        d, u = heapq.heappop(heap)
        pops += 1
        if d > dist[u]:
            continue
        base = g.indptr[u]
        for k in range(base, g.indptr[u + 1]):
            v = int(g.indices[k])
            w = float(g.weights[k])
            edges_relaxed += 1
            nd = d + w
            if nd < dist[v]:
                dist[v] = np.float32(nd)
                heapq.heappush(heap, (nd, v))
    return dist, {"edges_relaxed": edges_relaxed, "heap_pops": pops}


def wcc(g: Graph):
    """Weakly connected components by min-label propagation.

    Returns (labels f32 (n,) — min vertex id in the component, stats).
    """
    adj = g.undirected_adjacency()
    label = np.arange(g.n, dtype=np.float32)
    edges_relaxed = 0
    changed = True
    while changed:
        changed = False
        for u in range(g.n):
            for v in adj[u]:
                edges_relaxed += 1
                if label[v] < label[u]:
                    label[u] = label[v]
                    changed = True
                elif label[u] < label[v]:
                    label[v] = label[u]
                    changed = True
    return label, {"edges_relaxed": edges_relaxed}


def pagerank(g: Graph, damping: float = 0.85, tol: float = 1e-12,
             max_iters: int = 10_000):
    """PageRank without dangling-mass redistribution: the fixpoint of

        p = (1-d)/n + d * sum_{u -> v} p[u] / outdeg(u)

    solved by Jacobi iteration in float64 (the power series sum_k M^k b,
    which is exactly what the engine's delta-push accumulates).
    Returns (rank f32 (n,), stats).
    """
    n = g.n
    deg = g.out_degree().astype(np.float64)
    b = (1.0 - damping) / n
    p = np.zeros(n, dtype=np.float64)
    iters = 0
    edges_relaxed = 0
    for iters in range(1, max_iters + 1):
        contrib = np.where(deg > 0, p / np.maximum(deg, 1), 0.0)
        new = np.full(n, b)
        for u in range(n):
            lo, hi = g.indptr[u], g.indptr[u + 1]
            if contrib[u]:
                new[g.indices[lo:hi]] += damping * contrib[u]
            edges_relaxed += hi - lo
        delta = np.abs(new - p).max()
        p = new
        if delta < tol:
            break
    return p.astype(np.float32), {"edges_relaxed": edges_relaxed,
                                  "iterations": iters}


def widest(g: Graph, src: int):
    """Widest (maximum-bottleneck) path via max-heap Dijkstra.

    width(src) = +inf; unreachable vertices stay -inf.
    Returns (width f32 (n,), stats).
    """
    width = np.full(g.n, -np.inf, dtype=np.float32)
    width[src] = np.inf
    heap = [(-np.inf, src)]           # max-heap via negated widths
    edges_relaxed = 0
    pops = 0
    while heap:
        negw, u = heapq.heappop(heap)
        pops += 1
        if -negw < width[u]:
            continue
        for k in range(g.indptr[u], g.indptr[u + 1]):
            v = int(g.indices[k])
            w = float(g.weights[k])
            edges_relaxed += 1
            cand = min(float(width[u]), w)
            if cand > width[v]:
                width[v] = np.float32(cand)
                heapq.heappush(heap, (-cand, v))
    return width, {"edges_relaxed": edges_relaxed, "heap_pops": pops}


def reach(g: Graph, src: int):
    """Directed reachability from src as {0.0, 1.0} floats.
    Returns (reachable f32 (n,), stats)."""
    seen = np.zeros(g.n, dtype=bool)
    seen[src] = True
    frontier = [src]
    edges_relaxed = 0
    while frontier:
        nxt = []
        for u in frontier:
            for v in g.neighbors(u):
                edges_relaxed += 1
                if not seen[v]:
                    seen[v] = True
                    nxt.append(int(v))
        frontier = nxt
    return seen.astype(np.float32), {"edges_relaxed": edges_relaxed}


# ---------------------------------------------------------------------- #
# vector-state oracles: (n, d) feature blocks, column f seeded from
# landmark f of `landmarks(n, src, d)` (landmark 0 == src). Shared with
# the algebras through the same landmark convention, so the engine and
# the oracle agree on seeding by construction.
# ---------------------------------------------------------------------- #
def multi_bfs(g: Graph, src: int, d: int = 8):
    """Multi-landmark BFS embedding: column f is the hop-level vector
    from landmark f. Returns (levels f32 (n, d), stats)."""
    from repro.algebra.programs import landmarks
    lm = landmarks(g.n, src, d)
    cols, edges = [], 0
    for f in range(d):
        lev, st = bfs(g, int(lm[f]))
        cols.append(lev)
        edges += st["edges_relaxed"]
    return np.stack(cols, axis=1), {"edges_relaxed": edges}


def labelprop(g: Graph, src: int, d: int = 8, damping: float = 0.85,
              tol: float = 1e-12, max_iters: int = 10_000):
    """Seeded label spreading under the damped-walk (+, x) operator:
    column f is the fixpoint of

        p_f = b_f + damping * sum_{u -> v} p_f[u] / outdeg(u)

    with b_f = (1 - damping) * onehot(landmark f) -- the power series
    sum_k (damping M)^k b_f the engine's residual push accumulates.
    argmax over the feature axis is the propagated community label.
    Returns (masses f32 (n, d), stats)."""
    from repro.algebra.programs import landmarks
    n = g.n
    lm = landmarks(n, src, d)
    deg = g.out_degree().astype(np.float64)
    b = np.zeros((n, d), dtype=np.float64)
    b[lm, np.arange(d)] = 1.0 - damping
    p = np.zeros((n, d), dtype=np.float64)
    iters = 0
    edges_relaxed = 0
    for iters in range(1, max_iters + 1):
        contrib = np.where(deg[:, None] > 0,
                           p / np.maximum(deg, 1)[:, None], 0.0)
        new = b.copy()
        for u in range(n):
            lo, hi = g.indptr[u], g.indptr[u + 1]
            if contrib[u].any():
                new[g.indices[lo:hi]] += damping * contrib[u]
            edges_relaxed += hi - lo
        delta = np.abs(new - p).max()
        p = new
        if delta < tol:
            break
    return p.astype(np.float32), {"edges_relaxed": edges_relaxed,
                                  "iterations": iters}


# ---------------------------------------------------------------------- #
# oracle registry: one entry per registered algorithm, so `run` dispatch
# and `repro.api.Program` registration share a single table. Every oracle
# is normalized to the `(graph, src) -> (result, stats)` signature
# (src-free algorithms ignore src; stats may be empty).
# ---------------------------------------------------------------------- #
ORACLES = {
    "bfs": bfs,
    "sssp": sssp,
    "wcc": lambda g, src=0: wcc(g),
    "pagerank": lambda g, src=0: pagerank(g),
    "widest": widest,
    "reach": reach,
    "multi_bfs": multi_bfs,
    "labelprop": labelprop,
}


def register_oracle(name: str, fn) -> None:
    """Register `fn(graph, src)` as the ground truth for algorithm
    `name`. `fn` may return just the result vector or `(result, stats)`;
    `run` normalizes either form. `repro.api.Program` calls this
    atomically with the `VertexAlgebra` registration."""
    ORACLES[name] = fn


def get_oracle(name: str):
    """The registered oracle callable, or None if the algorithm has no
    numpy ground truth (engine-only algebras)."""
    return ORACLES.get(name)


def run(algo: str, g: Graph, src: int = 0):
    fn = ORACLES.get(algo)
    if fn is None:
        raise ValueError(f"unknown algorithm {algo!r}")
    out = fn(g, src)
    if isinstance(out, tuple) and len(out) == 2 and isinstance(out[1],
                                                               dict):
        return out
    return np.asarray(out), {}
