"""Dataset generators matching Table 4 of the FLIP paper.

| Group    | Type       | Diameter | #Graphs | |V|       | |E|        |
| Tree     | Directed   | High     | 100     | 256      | 255        |
| SRN      | Undirected | High     | 100     | [64,107] | [146,278]  |
| LRN      | Undirected | High     | 100     | 256      | [584,898]  |
| Syn.     | Directed   | Low      | 100     | 256      | 768        |
| Ext. LRN | Undirected | High     | 10      | 16k      | [44k,50k]  |

The paper builds SRN/LRN by BFS-sampling the SNAP California / San Francisco
road networks with random seeds. SNAP data is not available offline, so we
generate *structurally equivalent* road networks: near-planar grid graphs
with random edge deletions (degree ~2..4, high diameter), which match the
published |V|/|E| ranges exactly. |E| counts directed half-edges for
undirected groups (that is how Table 4's road-network counts are consistent
with degree ~2.5 road graphs).
"""
from __future__ import annotations

import math
import numpy as np

from repro.graphs.csr import Graph


def _grid_road_network(n: int, rng: np.random.Generator,
                       delete_frac: float, max_weight: int = 8) -> Graph:
    """Near-planar road-like network: grid skeleton + random deletions.

    A random spanning tree of the kept edges is protected so the graph stays
    connected (the paper's BFS-sampled subgraphs are connected by
    construction).
    """
    side = int(math.ceil(math.sqrt(n)))
    # Vertex ids: first n cells of the grid in row-major "serpentine" order
    # (keeps the induced subgraph connected).
    coords = []
    for r in range(side):
        cols = range(side) if r % 2 == 0 else range(side - 1, -1, -1)
        for c in cols:
            coords.append((r, c))
            if len(coords) == n:
                break
        if len(coords) == n:
            break
    idx = {rc: i for i, rc in enumerate(coords)}

    edges = []
    for (r, c), i in idx.items():
        for dr, dc in ((0, 1), (1, 0)):
            j = idx.get((r + dr, c + dc))
            if j is not None:
                edges.append((i, j))
    edges = np.asarray(edges)

    # Protected spanning tree via randomized union-find over shuffled edges.
    order = rng.permutation(len(edges))
    parent = np.arange(n)

    def find(x):
        while parent[x] != x:
            parent[x] = parent[parent[x]]
            x = parent[x]
        return x

    protected = np.zeros(len(edges), dtype=bool)
    for k in order:
        u, v = edges[k]
        ru, rv = find(u), find(v)
        if ru != rv:
            parent[ru] = rv
            protected[k] = True

    keep = protected | (rng.random(len(edges)) > delete_frac)
    kept = edges[keep]
    weights = rng.integers(1, max_weight + 1, size=len(kept)).astype(np.float32)
    return Graph.from_edges(n, [tuple(e) for e in kept], weights, directed=False)


def make_road_network(n: int, seed: int = 0, delete_frac: float = 0.35) -> Graph:
    rng = np.random.default_rng(seed)
    return _grid_road_network(n, rng, delete_frac)


def make_tree(n: int = 256, seed: int = 0, max_children: int = 4,
              max_weight: int = 8) -> Graph:
    """Random directed tree rooted at vertex 0 (|E| = n - 1)."""
    rng = np.random.default_rng(seed)
    edges = []
    # attach each vertex i>0 to a random earlier vertex with bounded fanout
    child_count = np.zeros(n, dtype=np.int64)
    for i in range(1, n):
        while True:
            p = int(rng.integers(0, i))
            if child_count[p] < max_children:
                break
        child_count[p] += 1
        edges.append((p, i))
    weights = rng.integers(1, max_weight + 1, size=len(edges)).astype(np.float32)
    return Graph.from_edges(n, edges, weights, directed=True)


def make_power_law(n: int = 128, m: int = 384, seed: int = 0,
                   exponent: float = 2.5, max_weight: int = 8) -> Graph:
    """Chung-Lu style directed power-law graph (hub-dominated degrees).

    Endpoint i is drawn with probability ~ (i+1)^(-1/(exponent-1)) under a
    random vertex relabeling, giving an expected degree sequence with tail
    exponent ~`exponent`. A spanning arborescence from vertex 0 keeps the
    graph reachable, like `make_synthetic`.
    """
    rng = np.random.default_rng(seed)
    w = (np.arange(1, n + 1, dtype=np.float64)) ** (-1.0 / (exponent - 1.0))
    w = rng.permutation(w)
    p = w / w.sum()
    edges = set()
    perm = rng.permutation(n)
    order = [0] + [int(v) for v in perm if v != 0]
    for i in range(1, n):
        edges.add((order[int(rng.integers(0, i))], order[i]))
    tries = 0
    while len(edges) < m and tries < 50 * m:
        u = int(rng.choice(n, p=p))
        v = int(rng.choice(n, p=p))
        tries += 1
        if u != v:
            edges.add((u, v))
    weights = rng.integers(1, max_weight + 1,
                           size=len(edges)).astype(np.float32)
    return Graph.from_edges(n, sorted(edges), weights, directed=True)


def make_synthetic(n: int = 256, m: int = 768, seed: int = 0,
                   max_weight: int = 8) -> Graph:
    """Low-diameter random directed graph: m distinct random edges."""
    rng = np.random.default_rng(seed)
    edges = set()
    # spanning arborescence from 0 keeps most vertices reachable
    perm = rng.permutation(n)
    order = [0] + [int(v) for v in perm if v != 0]
    for i in range(1, n):
        edges.add((order[int(rng.integers(0, i))], order[i]))
    while len(edges) < m:
        u, v = int(rng.integers(0, n)), int(rng.integers(0, n))
        if u != v:
            edges.add((u, v))
    weights = rng.integers(1, max_weight + 1, size=len(edges)).astype(np.float32)
    return Graph.from_edges(n, sorted(edges), weights, directed=True)


# --------------------------------------------------------------------- #
# Table-4 dataset groups
# --------------------------------------------------------------------- #
DATASET_SPECS = {
    # group: (builder, default count)
    "Tree":    (lambda seed: make_tree(256, seed=seed), 100),
    "SRN":     (lambda seed: make_road_network(
        int(np.random.default_rng(seed).integers(64, 108)), seed=seed,
        delete_frac=0.70), 100),
    "LRN":     (lambda seed: make_road_network(256, seed=seed), 100),
    "Syn":     (lambda seed: make_synthetic(256, 768, seed=seed), 100),
    "ExtLRN":  (lambda seed: make_road_network(16384, seed=seed,
                                               delete_frac=0.56), 10),
}


def make_dataset(group: str, count: int | None = None, seed0: int = 0):
    """Yield `count` graphs of a Table-4 group."""
    builder, default_count = DATASET_SPECS[group]
    count = default_count if count is None else count
    for s in range(count):
        yield builder(seed0 + s)
