"""CSR graph container used by every FLIP layer (compiler, simulator, engine).

The paper's graphs (Table 4) are small (64..16k vertices) with low, balanced
in/out degree, so a plain numpy CSR is the right host-side representation.
The JAX engine re-blocks this into dense tile-pairs (see repro.core.engine).
"""
from __future__ import annotations

import dataclasses
import hashlib
import numpy as np


@dataclasses.dataclass
class Graph:
    """Directed weighted graph in CSR form.

    Undirected graphs are stored with both half-edges present (matching the
    paper's edge counts for road networks, which count directed half-edges).

    Instances are treated as immutable: streaming mutations go through
    `apply_updates`, which returns a NEW Graph with `version` bumped, so
    downstream caches (blocked layouts, compiled engines) can tell graph
    generations apart via `version` / `fingerprint()`.
    """

    indptr: np.ndarray   # (n+1,) int32
    indices: np.ndarray  # (m,)   int32  -- destination vertex of each edge
    weights: np.ndarray  # (m,)   float32
    directed: bool = True
    version: int = 0     # bumped by every apply_updates
    _fp: str | None = dataclasses.field(default=None, init=False,
                                        repr=False, compare=False)

    # ------------------------------------------------------------------ #
    # construction
    # ------------------------------------------------------------------ #
    @staticmethod
    def from_edges(n: int, edges, weights=None, directed: bool = True) -> "Graph":
        """Build from an iterable of (u, v) pairs. Deduplicates."""
        pairs = [(int(u), int(v)) for u, v in edges]
        if weights is None:
            wmap = {e: 1.0 for e in pairs}
        else:
            wmap = {}
            for (u, v), w in zip(pairs, weights):    # pre-sort alignment
                wmap[(u, v)] = min(float(w), wmap.get((u, v), np.inf))
        edges = sorted(set(pairs))
        if not directed:
            full = {}
            for (u, v), w in wmap.items():
                full[(u, v)] = w
                full[(v, u)] = w
            wmap = full
            edges = sorted(wmap)
        indptr = np.zeros(n + 1, dtype=np.int32)
        for u, _ in edges:
            indptr[u + 1] += 1
        indptr = np.cumsum(indptr).astype(np.int32)
        indices = np.asarray([v for _, v in edges], dtype=np.int32)
        w = np.asarray([wmap[e] for e in edges], dtype=np.float32)
        return Graph(indptr=indptr, indices=indices, weights=w, directed=directed)

    # ------------------------------------------------------------------ #
    # streaming mutations (versioned: always returns a new Graph)
    # ------------------------------------------------------------------ #
    def apply_updates(self, updates) -> "Graph":
        """Apply a batch of edge mutations; returns a NEW Graph (this one
        is never modified) with `version` bumped by one.

        `updates` is an iterable of `(u, v, w)` triples: any float `w`
        upserts the edge (inserts it if absent, overwrites its weight
        otherwise), `w = None` deletes it (deleting an absent edge is a
        no-op, so idempotent streams replay safely). `(u, v)` pairs are
        accepted as shorthand for `(u, v, 1.0)`. Within one batch, later
        entries win for the same `(u, v)`. Undirected graphs keep both
        half-edges in sync automatically. The vertex set is fixed: an
        endpoint outside `[0, n)` raises (grow the graph by building a
        new one from edges).

        Pass a *sequence*, not a one-shot iterator, when the same batch
        is then replayed into `BlockedGraph`/`FlipEngine.apply_updates`
        -- each call consumes the iterable once.
        """
        n = self.n
        ops: dict[tuple[int, int], float | None] = {}
        for upd in updates:
            if len(upd) == 2:
                (u, v), w = upd, 1.0
            else:
                u, v, w = upd
            u, v = int(u), int(v)
            if not (0 <= u < n and 0 <= v < n):
                raise ValueError(
                    f"edge update ({u}, {v}) is outside the fixed vertex "
                    f"set [0, {n}); apply_updates cannot grow the graph")
            w = None if w is None else float(w)
            ops[(u, v)] = w
            if not self.directed:
                ops[(v, u)] = w

        eu = self.edge_sources()
        ev = self.indices.astype(np.int64)
        if ops:
            # drop every existing edge named by the batch, then append the
            # surviving upserts and re-sort -- one vectorized pass, no
            # per-edge Python over the untouched edges
            ukey = np.asarray([u * n + v for (u, v) in ops],
                              dtype=np.int64)
            keep = ~np.isin(eu * n + ev, ukey)
            ins = [(u, v, w) for (u, v), w in ops.items() if w is not None]
            au = np.concatenate([eu[keep], np.asarray(
                [e[0] for e in ins], dtype=np.int64)])
            av = np.concatenate([ev[keep], np.asarray(
                [e[1] for e in ins], dtype=np.int64)])
            aw = np.concatenate([self.weights[keep], np.asarray(
                [e[2] for e in ins], dtype=np.float32)])
        else:
            au, av, aw = eu, ev, self.weights
        order = np.argsort(au * n + av, kind="stable")
        au, av, aw = au[order], av[order], aw[order]
        indptr = np.concatenate(
            [[0], np.cumsum(np.bincount(au, minlength=n))]).astype(np.int32)
        return Graph(indptr=indptr, indices=av.astype(np.int32),
                     weights=aw.astype(np.float32), directed=self.directed,
                     version=self.version + 1)

    def fingerprint(self) -> str:
        """Cheap content hash of the CSR arrays (+ version), cached on
        first use. Because Graph instances are treated as immutable
        (`apply_updates` returns a new one), the cache never goes stale;
        engine caches key on this to detect graph swaps."""
        if self._fp is None:
            h = hashlib.blake2b(digest_size=16)
            h.update(f"{self.n}|{self.version}|{self.directed}".encode())
            for a in (self.indptr, self.indices, self.weights):
                h.update(np.ascontiguousarray(a).tobytes())
            self._fp = h.hexdigest()
        return self._fp

    # ------------------------------------------------------------------ #
    # basic accessors
    # ------------------------------------------------------------------ #
    @property
    def n(self) -> int:
        return len(self.indptr) - 1

    @property
    def m(self) -> int:
        return len(self.indices)

    def neighbors(self, u: int) -> np.ndarray:
        return self.indices[self.indptr[u]:self.indptr[u + 1]]

    def edge_weights(self, u: int) -> np.ndarray:
        return self.weights[self.indptr[u]:self.indptr[u + 1]]

    def out_degree(self) -> np.ndarray:
        return np.diff(self.indptr)

    def edge_sources(self) -> np.ndarray:
        """(m,) int64 source vertex of each CSR edge (the expansion of
        `indptr`, pairing with `indices`/`weights` positionally)."""
        return np.repeat(np.arange(self.n, dtype=np.int64),
                         np.diff(self.indptr))

    def edge_list(self):
        """Yield (u, v, w) triples."""
        for u in range(self.n):
            for k in range(self.indptr[u], self.indptr[u + 1]):
                yield u, int(self.indices[k]), float(self.weights[k])

    def reverse(self) -> "Graph":
        """Graph with all edges flipped (used for in-neighbor queries)."""
        edges = [(v, u) for u, v, _ in self.edge_list()]
        ws = [w for _, _, w in self.edge_list()]
        return Graph.from_edges(self.n, edges, ws, directed=True)

    def in_neighbors_map(self):
        """dict: v -> list of (u, w) over incoming edges. Host-side helper."""
        inc = {v: [] for v in range(self.n)}
        for u, v, w in self.edge_list():
            inc[v].append((u, w))
        return inc

    # ------------------------------------------------------------------ #
    # dense forms for the JAX engine / reference oracles
    # ------------------------------------------------------------------ #
    def dense_weights(self, inf: float = np.inf) -> np.ndarray:
        """(n, n) matrix W[u, v] = weight of edge u->v, `inf` if absent."""
        W = np.full((self.n, self.n), inf, dtype=np.float32)
        for u, v, w in self.edge_list():
            W[u, v] = min(W[u, v], w)
        return W

    def permuted(self, perm: np.ndarray) -> "Graph":
        """Relabel vertices: new id of old vertex i is perm[i]."""
        perm = np.asarray(perm)
        edges = [(perm[u], perm[v]) for u, v, _ in self.edge_list()]
        ws = [w for _, _, w in self.edge_list()]
        return Graph.from_edges(self.n, edges, ws, directed=True)

    # ------------------------------------------------------------------ #
    # structure metrics used by the mapping compiler
    # ------------------------------------------------------------------ #
    def undirected_adjacency(self):
        adj = {v: set() for v in range(self.n)}
        for u, v, _ in self.edge_list():
            adj[u].add(v)
            adj[v].add(u)
        return adj

    def bfs_levels_from(self, src: int) -> np.ndarray:
        """Unweighted hop distance from src over the undirected skeleton."""
        adj = self.undirected_adjacency()
        dist = np.full(self.n, -1, dtype=np.int64)
        dist[src] = 0
        frontier = [src]
        d = 0
        while frontier:
            d += 1
            nxt = []
            for u in frontier:
                for v in adj[u]:
                    if dist[v] < 0:
                        dist[v] = d
                        nxt.append(v)
            frontier = nxt
        return dist

    def center_vertex(self, sample: int = 32, seed: int = 0) -> int:
        """Vertex with (approximately) minimum eccentricity.

        Exact for n <= sample; sampled double-sweep otherwise. The paper
        seeds beam search from the graph center (Sec. 4.2.1).
        """
        rng = np.random.default_rng(seed)
        if self.n <= sample:
            cands = np.arange(self.n)
        else:
            cands = rng.choice(self.n, size=sample, replace=False)
        best, best_ecc = int(cands[0]), np.iinfo(np.int64).max
        for c in cands:
            lv = self.bfs_levels_from(int(c))
            ecc = lv.max() if (lv >= 0).all() else lv[lv >= 0].max() + self.n
            if ecc < best_ecc:
                best, best_ecc = int(c), ecc
        return best

    def is_connected(self) -> bool:
        return bool((self.bfs_levels_from(0) >= 0).all())
