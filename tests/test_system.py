"""End-to-end behaviour tests: the paper's pipeline + the train/serve
drivers (resume-after-kill included)."""
import json
import os
import subprocess
import sys

import numpy as np
import pytest


def _run(args, timeout=900, **kw):
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    return subprocess.run([sys.executable, "-m"] + args,
                          capture_output=True, text=True, cwd="/root/repo",
                          timeout=timeout, env=env, **kw)


def test_graph_pipeline_end_to_end():
    """compile -> simulate -> verify + baseline speedups (paper pipeline)."""
    from repro.core import BFS, compile_mapping, simulate, baselines
    from repro.graphs import make_road_network, reference
    g = make_road_network(96, seed=0, delete_frac=0.7)
    m = compile_mapping(g, effort=1, seed=0)
    r = simulate(m, BFS, src=1)
    ref, _ = reference.bfs(g, 1)
    assert np.allclose(np.where(np.isinf(r.attrs), -1, r.attrs),
                       np.where(np.isinf(ref), -1, ref))
    t_flip = r.cycles / m.arch.freq_mhz
    mcu = baselines.mcu_cycles("bfs", g, 1)
    cgra = baselines.cgra_cycles("bfs", g, 1)
    # paper Fig. 10: FLIP beats both baselines by large factors
    assert mcu.time_us / t_flip > 10
    assert cgra.time_us / t_flip > 3


def test_graph_run_cli():
    out = _run(["repro.launch.graph_run", "--algo", "bfs", "--dataset",
                "SRN", "--engine", "jax", "--effort", "0"])
    assert out.returncode == 0, out.stderr[-2000:]
    assert "correct vs reference: True" in out.stdout


def test_train_cli_and_resume():
    """Train 8 steps, kill, resume to 12: checkpoint-restart works and the
    loss curve continues (fault-tolerance path)."""
    import shutil
    ckpt = "/tmp/test_ckpt_resume"
    shutil.rmtree(ckpt, ignore_errors=True)
    base = ["repro.launch.train", "--arch", "qwen3_0_6b", "--preset",
            "tiny", "--seq", "64", "--batch", "4", "--ckpt-dir", ckpt,
            "--ckpt-every", "4", "--log-every", "4"]
    out = _run(base + ["--steps", "8"])
    assert out.returncode == 0, out.stderr[-2000:]
    assert "step=8" in out.stdout
    out2 = _run(base + ["--steps", "12", "--resume"])
    assert out2.returncode == 0, out2.stderr[-2000:]
    assert "resumed from step 8" in out2.stdout
    assert "step=12" in out2.stdout


def test_serve_cli():
    out = _run(["repro.launch.serve", "--arch", "qwen3_0_6b", "--preset",
                "tiny", "--slots", "4", "--requests", "6",
                "--max-new", "8"])
    assert out.returncode == 0, out.stderr[-2000:]
    assert "requests" in out.stdout


def test_expert_placement_reduces_traffic():
    from repro.core.placement import expert_affinity, place_experts
    rng = np.random.default_rng(0)
    E, k = 32, 4
    gperm = rng.permutation(E).reshape(8, 4)
    topk = np.stack([rng.permuted(gperm[rng.integers(0, 8)])[:k]
                     for _ in range(1500)])
    pl = place_experts(expert_affinity(topk, E), num_devices=8, seed=0)
    assert pl.est_cost < pl.baseline_cost * 0.7   # >30% traffic cut
    assert sorted(pl.perm.tolist()) == list(range(E))
