"""FLIP mapping compiler: Algorithm 1 & 2 invariants + quality."""
import numpy as np
import pytest

from repro.core import (DEFAULT_ARCH, FlipArch, Mapping, RuntimeEstimator,
                        SSSP, compile_mapping)
from repro.graphs import make_road_network, make_synthetic, make_tree

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYP = True
except ImportError:
    HAVE_HYP = False


def test_mapping_validates():
    g = make_road_network(128, seed=0)
    m = compile_mapping(g, effort=0)
    m.validate()
    assert m.num_copies() == 1


def test_capacity_respected_small_arch():
    g = make_synthetic(64, 128, seed=1)
    arch = FlipArch(width=4, height=4, pe_capacity=4)
    m = compile_mapping(g, arch=arch, effort=0)
    m.validate()       # 64 vertices exactly fill 4x4x4


def test_replication_for_large_graphs():
    g = make_road_network(600, seed=0)
    m = compile_mapping(g, effort=0)
    assert m.num_copies() == -(-600 // DEFAULT_ARCH.capacity)
    m.validate()


def test_local_opt_improves_routing_length():
    g = make_road_network(256, seed=1)
    m0 = compile_mapping(g, effort=0, seed=0)
    m1 = compile_mapping(g, effort=1, seed=0)
    assert m1.avg_routing_length() <= m0.avg_routing_length() + 1e-9


def test_table8_quality_road_networks():
    """Paper Table 8: avg routing length < ~1 for road networks."""
    g = make_road_network(96, seed=2, delete_frac=0.70)
    m = compile_mapping(g, effort=1, seed=0)
    assert m.avg_routing_length() < 1.2


def test_estimator_swap_benefit_antisymmetric_sign():
    g = make_road_network(64, seed=0)
    m = compile_mapping(g, effort=0)
    est = RuntimeEstimator(DEFAULT_ARCH, g, SSSP)
    u, v = 3, 40
    c = est.swap_benefit(m, u, v)
    # swapping back must undo the benefit
    m.pe_of[u], m.pe_of[v] = m.pe_of[v], m.pe_of[u]
    c_back = est.swap_benefit(m, u, v)
    assert np.isclose(c, -c_back, atol=1e-6)


def test_collision_sets_are_real():
    g = make_synthetic(64, 256, seed=0)
    m = compile_mapping(g, effort=0)
    for (pe, src), vs in m.collision_sets().items():
        assert len(vs) > 1
        for v in vs:
            assert m.pe_of[v] == pe
            assert v in list(g.neighbors(src))


def test_yx_route_length_matches_manhattan():
    arch = DEFAULT_ARCH
    for a in range(0, arch.num_pes, 7):
        for b in range(0, arch.num_pes, 11):
            assert len(arch.yx_route(a, b)) == arch.manhattan(a, b)


if HAVE_HYP:
    @given(st.integers(12, 60), st.integers(0, 500))
    @settings(max_examples=10, deadline=None)
    def test_mapping_total_and_capacity(n, seed):
        g = make_synthetic(n, 2 * n, seed=seed)
        arch = FlipArch(width=4, height=4, pe_capacity=4)
        m = compile_mapping(g, arch=arch, effort=0, seed=seed)
        m.validate()
        assert len(np.unique(np.stack([m.pe_of, m.copy_of]), axis=1).T) <= \
            arch.num_pes * m.num_copies()
