"""Streaming graph mutations: delta-driven incremental recompute.

The contract under test:

  * `Graph.apply_updates` / `BlockedGraph.apply_updates` rebuild exactly
    the touched tiles -- block-for-block equal to a from-scratch
    `build_blocks` over the mutated graph, for every registered algebra,
    including delete-then-reinsert, updates into carry-only destination
    tiles, and batches that activate a previously empty tile pair
    (shape-changing rebuilds);
  * after a `Semiring.monotone_under` batch, `FlipEngine.run_updated`
    resumes from the previous fixpoint with only the affected sources
    seeded, and the result is **bit-for-bit** the from-scratch run --
    across all registered algebras x {jnp, interpret} x {solo, B=8};
  * non-monotone batches (deletes, ⊕-worsening reweights, non-idempotent
    ⊕) fall back to a full recompute through the same entry point;
  * `GraphServer` interleaves updates with queries, reuses value-only
    rebuilt engines, and never serves a stale graph (fingerprint-keyed
    engine cache).
"""
import numpy as np
import pytest
from conftest import ALGOS, SRCS8, oracle

from repro.algebra import ALGEBRAS, MAX_MIN, MIN_PLUS, OR_AND, PLUS_TIMES
from repro.core.engine import FlipEngine, WarmStart
from repro.graphs import Graph, make_power_law, make_synthetic, reference
from repro.kernels.frontier import build_blocks
from repro.launch.serve_graph import GraphServer


def _edge_array(g):
    """(m, 2) int array of (u, v) edge endpoints."""
    return np.stack([g.edge_sources(),
                     g.indices.astype(np.int64)], axis=1)


def _improving_weight(algo, w):
    """A raw weight moved in the algebra's ⊕-improving direction (for
    weight rules that ignore the raw weight, any value is improving:
    the stored ⊗ operand does not change)."""
    sr = ALGEBRAS[algo].semiring
    if ALGEBRAS[algo].weight_rule != "graph":
        return w + 1.0
    for cand in (w * 0.5, w * 2.0):
        if float(sr.add_np(np.float32(cand), np.float32(w))) == \
                np.float32(cand):
            return cand
    return w


def _monotone_batch(g, algo, rng, k=3):
    """Update batch that is ⊕-improving under the algebra: inserts of
    absent edges plus ⊕-improving reweights of existing ones."""
    edges = _edge_array(g)
    have = set(map(tuple, edges.tolist()))
    batch = []
    for i in rng.choice(g.m, size=min(k, g.m), replace=False):
        u, v = map(int, edges[i])
        batch.append((u, v, _improving_weight(algo, float(g.weights[i]))))
    inserts = 0
    while inserts < k:
        u, v = int(rng.integers(g.n)), int(rng.integers(g.n))
        if (u, v) not in have and (not g.directed or u != v):
            batch.append((u, v, float(rng.integers(1, 9))))
            have.add((u, v))
            if not g.directed:
                have.add((v, u))
            inserts += 1
    return batch


def _mixed_batch(g, rng, k=3):
    """Adversarial batch: inserts + deletes + reweights both directions."""
    edges = _edge_array(g)
    idx = rng.choice(g.m, size=min(3 * k, g.m), replace=False)
    batch = [(int(edges[i][0]), int(edges[i][1]), None) for i in idx[:k]]
    batch += [(int(edges[i][0]), int(edges[i][1]),
               float(rng.integers(1, 17))) for i in idx[k:2 * k]]
    batch += [(int(rng.integers(g.n)), int(rng.integers(g.n)),
               float(rng.integers(1, 9))) for _ in range(k)]
    return batch


# --------------------------------------------------------------------- #
# blocked-layout rebuild: incremental == from-scratch, block for block
# --------------------------------------------------------------------- #
@pytest.mark.parametrize("algo", ALGOS)
def test_apply_updates_matches_full_rebuild(algo):
    g = make_power_law(70, 210, seed=42)
    rng = np.random.default_rng(0)
    for order in (None, rng.permutation(g.n)):
        bg = build_blocks(g, algo, tile=16, order=order)
        g_cur = g
        for trial in range(3):                 # a mutation *sequence*
            batch = _mixed_batch(g_cur, rng)
            g_cur = g_cur.apply_updates(batch)
            bg, delta = bg.apply_updates(g_cur, batch)
            full = build_blocks(g_cur, algo, tile=16, order=order)
            np.testing.assert_array_equal(np.asarray(bg.bsrc),
                                          np.asarray(full.bsrc))
            np.testing.assert_array_equal(np.asarray(bg.bdst),
                                          np.asarray(full.bdst))
            np.testing.assert_array_equal(np.asarray(bg.blocks),
                                          np.asarray(full.blocks), )
            assert bg.version == g_cur.version == trial + 1
            assert bg.graph_fp == g_cur.fingerprint()


def test_apply_updates_undirected_graph_mirrors():
    """Undirected CSR: one (u, v, w) update must land in both half-edge
    tiles of the rebuilt layout."""
    from repro.graphs import make_road_network
    g = make_road_network(64, seed=2, delete_frac=0.5)
    assert not g.directed
    batch = [(0, int(g.neighbors(0)[0]), 0.25)]
    g2 = g.apply_updates(batch)
    np.testing.assert_array_equal(g2.dense_weights(),
                                  g2.dense_weights().T)
    bg = build_blocks(g, "sssp", tile=16)
    bg2, _ = bg.apply_updates(g2, batch)
    full = build_blocks(g2, "sssp", tile=16)
    np.testing.assert_array_equal(np.asarray(bg2.blocks),
                                  np.asarray(full.blocks))


def test_empty_update_batch_is_noop():
    """An empty batch (e.g. a drained stream tick) rolls the version
    forward and changes nothing else, end to end."""
    g = make_synthetic(40, 110, seed=3)
    eng = FlipEngine.build(g, "sssp", tile=16, relax_mode="jnp")
    prev, _ = eng.run(2)
    g2 = g.apply_updates([])
    assert g2.version == g.version + 1 and g2.m == g.m
    eng2, delta = eng.apply_updates(g2, [])
    assert (delta.monotone and not delta.shape_changed
            and delta.affected_src.size == 0)
    assert eng2.bg.graph_fp == g2.fingerprint()
    out, steps = eng2.run_updated(2, prev, delta)
    assert steps == 0
    np.testing.assert_array_equal(out, prev)


def test_graph_apply_updates_semantics():
    g = make_synthetic(20, 40, seed=0)
    v0 = g.version
    # delete of an absent edge is a no-op; last write wins in a batch
    g2 = g.apply_updates([(0, 19, None), (0, 19, 5.0), (0, 19, 3.0)])
    assert g2.version == v0 + 1 and g.version == v0
    W = g2.dense_weights()
    assert W[0, 19] == 3.0
    g3 = g2.apply_updates([(0, 19, None)])
    assert g3.dense_weights()[0, 19] == np.inf
    assert g3.m == g.m                     # insert + delete round-trips
    with pytest.raises(ValueError, match="outside the fixed vertex set"):
        g.apply_updates([(0, 99, 1.0)])
    # fingerprints separate versions even with identical structure
    assert g.fingerprint() != g3.fingerprint()


# --------------------------------------------------------------------- #
# monotonicity detection (Semiring.monotone_under)
# --------------------------------------------------------------------- #
def test_monotone_under_per_semiring():
    # insert: ⊕-identity -> value is always improving (idempotent ⊕)
    assert MIN_PLUS.monotone_under([MIN_PLUS.zero], [3.0])
    assert MAX_MIN.monotone_under([MAX_MIN.zero], [3.0])
    assert OR_AND.monotone_under([OR_AND.zero], [1.0])
    # delete: value -> ⊕-identity never is
    assert not MIN_PLUS.monotone_under([3.0], [MIN_PLUS.zero])
    assert not MAX_MIN.monotone_under([3.0], [MAX_MIN.zero])
    assert not OR_AND.monotone_under([1.0], [OR_AND.zero])
    # reweight direction flips between min- and max-flavoured ⊕
    assert MIN_PLUS.monotone_under([4.0], [2.0])
    assert not MIN_PLUS.monotone_under([2.0], [4.0])
    assert MAX_MIN.monotone_under([2.0], [4.0])
    assert not MAX_MIN.monotone_under([4.0], [2.0])
    # no-op is monotone; non-idempotent ⊕ never warm-starts
    assert MIN_PLUS.monotone_under([2.0], [2.0])
    assert not PLUS_TIMES.monotone_under([0.0], [3.0])


# --------------------------------------------------------------------- #
# incremental recompute: bit-exact vs from-scratch
# --------------------------------------------------------------------- #
def _check_incremental(g, algo, relax_mode, tile, srcs, rng):
    eng = FlipEngine.build(g, algo, tile=tile, relax_mode=relax_mode)
    prev, _ = eng.run_batch(srcs)
    batch = _monotone_batch(g, algo, rng)
    g2 = g.apply_updates(batch)
    eng2, delta = eng.apply_updates(g2, batch)
    assert delta.monotone == ALGEBRAS[algo].semiring.idempotent
    inc, inc_steps = eng2.run_updated(srcs, prev, delta)
    scr, scr_steps = eng2.run_batch(srcs)
    np.testing.assert_array_equal(inc, scr)     # bit-exact, every query
    for b, s in enumerate(srcs):
        assert ALGEBRAS[algo].results_match(inc[b],
                                            oracle(algo, g2, int(s)))
    if delta.monotone:
        # the whole point: the delta fixpoint is shorter than scratch
        assert inc_steps.max() <= scr_steps.max()
    return g2, eng2, delta


@pytest.mark.parametrize("batching", ["solo", "b8"])
@pytest.mark.parametrize("algo", ALGOS)
def test_incremental_bitexact_jnp(algo, batching):
    g = make_power_law(48, 140, seed=6)
    srcs = np.array([3]) if batching == "solo" else SRCS8 % g.n
    _check_incremental(g, algo, "jnp", 16, srcs,
                       np.random.default_rng(1))


@pytest.mark.parametrize("batching", ["solo", "b8"])
@pytest.mark.parametrize("algo", ALGOS)
def test_incremental_bitexact_interpret(algo, batching):
    """Same contract through the Pallas kernel body (interpret mode)."""
    g = make_synthetic(24, 70, seed=2)
    srcs = np.array([5]) if batching == "solo" else SRCS8 % g.n
    _check_incremental(g, algo, "interpret", 8, srcs,
                       np.random.default_rng(2))


@pytest.mark.parametrize("algo", ALGOS)
def test_delete_then_reinsert(algo):
    """Delete forces a full recompute; reinserting the same edge is
    monotone again and the warm rerun lands bit-for-bit on the original
    fixpoint (the graph round-tripped)."""
    g = make_power_law(48, 150, seed=9)
    eng = FlipEngine.build(g, algo, tile=16, relax_mode="jnp")
    src = 3
    base, _ = eng.run(src)
    u = int(g.edge_sources()[7])
    v, w = int(g.indices[7]), float(g.weights[7])

    g_del = g.apply_updates([(u, v, None)])
    eng_del, d1 = eng.apply_updates(g_del, [(u, v, None)])
    assert not d1.monotone                      # delete is never monotone
    mid, _ = eng_del.run_updated(src, base, d1)  # falls back to scratch
    np.testing.assert_array_equal(mid, eng_del.run(src)[0])
    assert ALGEBRAS[algo].results_match(mid, oracle(algo, g_del, src))

    g_re = g_del.apply_updates([(u, v, w)])
    eng_re, d2 = eng_del.apply_updates(g_re, [(u, v, w)])
    assert d2.monotone == ALGEBRAS[algo].semiring.idempotent
    fin, _ = eng_re.run_updated(src, mid, d2)
    np.testing.assert_array_equal(fin, base)    # graph round-tripped
    # and the layout did too
    np.testing.assert_array_equal(np.asarray(eng_re.bg.blocks),
                                  np.asarray(eng.bg.blocks))


@pytest.mark.parametrize("mode", ["jnp", "interpret"])
def test_update_into_carry_only_destination_tile(mode):
    """An update whose destination tile previously had no active inbound
    block (output = pure carry) must re-derive that tile's values."""
    edges = [(0, 1), (1, 2), (2, 3), (16, 8), (17, 9), (0, 17)]
    g = Graph.from_edges(24, edges, weights=[2.0] * len(edges),
                         directed=True)
    eng = FlipEngine.build(g, "sssp", tile=8, relax_mode=mode)
    prev, _ = eng.run(0)
    assert prev[8] == np.inf                    # tile 1 unreachable from 0
    batch = [(0, 9, 1.5)]                       # open a path into tile 1
    g2 = g.apply_updates(batch)
    eng2, delta = eng.apply_updates(g2, batch)
    assert delta.monotone
    inc, _ = eng2.run_updated(0, prev, delta)
    np.testing.assert_array_equal(inc, eng2.run(0)[0])
    assert ALGEBRAS["sssp"].results_match(inc, oracle("sssp", g2, 0))
    assert inc[9] == 1.5


def test_update_activates_empty_tile_pair():
    """A batch inserting edges between tiles with no existing block grows
    the block list (shape-changing rebuild) and still matches a full
    rebuild + from-scratch run."""
    edges = [(0, 1), (1, 2), (8, 9), (17, 18)]  # no tile-0 -> tile-2 block
    g = Graph.from_edges(24, edges, weights=[1.0] * len(edges),
                         directed=True)
    bg = build_blocks(g, "sssp", tile=8)
    nb0 = np.asarray(bg.bsrc).size
    batch = [(1, 17, 4.0)]                      # tile 0 -> tile 2
    g2 = g.apply_updates(batch)
    bg2, delta = bg.apply_updates(g2, batch)
    assert delta.shape_changed and delta.monotone
    assert np.asarray(bg2.bsrc).size == nb0 + 1
    full = build_blocks(g2, "sssp", tile=8)
    np.testing.assert_array_equal(np.asarray(bg2.blocks),
                                  np.asarray(full.blocks))
    eng = FlipEngine.build(g, "sssp", tile=8, relax_mode="jnp")
    prev, _ = eng.run(0)
    eng2, delta = eng.apply_updates(g2, batch)
    inc, _ = eng2.run_updated(0, prev, delta)
    np.testing.assert_array_equal(inc, eng2.run(0)[0])
    assert inc[17] == 5.0 and inc[18] == 6.0


def test_value_only_update_keeps_layout_arrays():
    """A reweight touching only existing blocks must reuse the layout
    arrays (bsrc/bdst identity) so compiled executables stay hot."""
    g = make_power_law(48, 140, seed=3)
    bg = build_blocks(g, "sssp", tile=16)
    u = int(g.edge_sources()[0])
    batch = [(u, int(g.indices[0]), float(g.weights[0]) * 0.5)]
    g2 = g.apply_updates(batch)
    bg2, delta = bg.apply_updates(g2, batch)
    assert not delta.shape_changed
    assert bg2.bsrc is bg.bsrc and bg2.bdst is bg.bdst
    assert bg2.dst_start is bg.dst_start


# --------------------------------------------------------------------- #
# warm-start plumbing
# --------------------------------------------------------------------- #
def test_warm_start_validation_and_noop():
    g = make_synthetic(40, 110, seed=1)
    eng = FlipEngine.build(g, "pagerank", tile=16, relax_mode="jnp")
    with pytest.raises(ValueError, match="monotone algebra"):
        eng.run(0, warm=WarmStart(np.zeros(g.n, np.float32),
                                  np.array([0])))
    eng = FlipEngine.build(g, "sssp", tile=16, relax_mode="jnp")
    base, _ = eng.run(2)
    # empty seed set: nothing to relax, zero steps, result untouched
    out, steps = eng.run(2, warm=WarmStart(base, np.array([], np.int64)))
    assert steps == 0
    np.testing.assert_array_equal(out, base)


def test_run_distributed_warm_start():
    """The warm-start path through the shard_map engine (1-device mesh
    on CPU CI; real meshes shard the same code)."""
    g = make_power_law(48, 140, seed=5)
    eng = FlipEngine.build(g, "sssp", tile=16)
    prev, _ = eng.run(3)
    rng = np.random.default_rng(4)
    batch = _monotone_batch(g, "sssp", rng)
    g2 = g.apply_updates(batch)
    eng2, delta = eng.apply_updates(g2, batch)
    assert delta.monotone
    warm = WarmStart(prev, delta.affected_src)
    got, _ = eng2.run_distributed(3, warm=warm)
    np.testing.assert_array_equal(got, eng2.run(3)[0])


# --------------------------------------------------------------------- #
# serving front-end: interleaved updates + stale-cache regression
# --------------------------------------------------------------------- #
def test_graph_server_update_interleaved_with_queries():
    g = make_power_law(48, 140, seed=4)
    srv = GraphServer(g, batch=4, tile=16, relax_mode="jnp")
    rng = np.random.default_rng(0)
    batch1 = _monotone_batch(g, "sssp", rng)
    g2 = g.apply_updates(batch1)
    batch2 = [(int(g2.edge_sources()[5]),
               int(g2.indices[5]), None)]       # delete: non-monotone
    g3 = g2.apply_updates(batch2)
    stream = ([("sssp", 3), ("bfs", 7), ("update", batch1),
               ("sssp", 3), ("bfs", 7), ("update", batch2),
               ("sssp", 3)])
    reqs = srv.serve(stream)
    assert srv.updates_applied == 2
    graphs = [g, g, g2, g2, g3]
    for r, gg in zip(reqs, graphs):
        assert ALGEBRAS[r.algo].results_match(
            r.result, oracle(r.algo, gg, r.src)), (r.algo, r.src)


def test_graph_server_value_only_update_reuses_engine():
    """A value-only mutation must patch the cached engine in place (same
    layout arrays -> same compiled executables), not rebuild it."""
    g = make_power_law(48, 140, seed=8)
    srv = GraphServer(g, batch=2, tile=16, relax_mode="jnp")
    srv.serve([("sssp", 1), ("sssp", 2)])
    bg_before = srv._engines["sssp"].bg
    u = int(g.edge_sources()[0])
    deltas = srv.update([(u, int(g.indices[0]),
                          float(g.weights[0]) * 0.5)])
    assert not deltas["sssp"].shape_changed
    bg_after = srv._engines["sssp"].bg
    assert bg_after.bsrc is bg_before.bsrc      # layout reused, not rebuilt
    assert bg_after.graph_fp == srv.graph.fingerprint()
    r = srv.serve([("sssp", 1)])[0]             # engine() must not rebuild
    assert srv._engines["sssp"].bg is bg_after
    assert ALGEBRAS["sssp"].results_match(
        r.result, oracle("sssp", srv.graph, 1))


def test_graph_server_update_accepts_one_shot_iterator():
    """Regression: `update()` consumes the batch once per cached engine
    plus once for the graph -- a generator-typed batch must not leave
    engines rebuilt from an exhausted (empty) iterator."""
    g = make_synthetic(40, 110, seed=7)
    srv = GraphServer(g, batch=1, tile=16, relax_mode="jnp")
    srv.serve([("sssp", 3)])
    srv.update(iter([(3, 10, 0.001)]))
    r = srv.serve([("sssp", 3)])[0]
    assert ALGEBRAS["sssp"].results_match(
        r.result, oracle("sssp", srv.graph, 3))
    assert r.result[10] == np.float32(0.001)


def test_graph_server_stale_cache_regression():
    """Regression (pre-fix: engines keyed only by algo): a wholesale
    graph swap must invalidate the cached engine, not silently serve the
    old graph's results."""
    g = make_synthetic(40, 110, seed=5)
    srv = GraphServer(g, batch=1, tile=16, relax_mode="jnp")
    r1 = srv.serve([("sssp", 3)])[0]
    assert ALGEBRAS["sssp"].results_match(r1.result, oracle("sssp", g, 3))
    g2 = make_synthetic(40, 110, seed=6)        # same shape, new content
    srv.graph = g2
    r2 = srv.serve([("sssp", 3)])[0]
    assert ALGEBRAS["sssp"].results_match(r2.result,
                                          oracle("sssp", g2, 3))
    assert not np.array_equal(r1.result, r2.result)
