"""Semiring algebra subsystem: randomized cross-layer equivalence.

Per registered algebra, the same algorithm runs through every execution
layer and must agree with the plain-numpy oracle:

  * reference oracle                   (repro.graphs.reference)
  * FlipEngine data mode               (frontier-driven, jnp kernel path)
  * FlipEngine op mode                 (full-sweep, classic-CGRA analogue)
  * Pallas kernel in interpret mode    (at least one non-tropical algebra)
  * cycle simulator                    (where the program is expressible)

Graphs are small fixed-seed Erdős–Rényi (`make_synthetic`) and power-law
(`make_power_law`) instances. Engine tests use a single 64-lane tile so
jit caches one executable per (algebra, mode) across all 20 graphs; a
separate multi-tile case exercises the block-sparse bsrc/bdst path.
"""
import jax
import numpy as np
import pytest
from conftest import ALGOS, SIM_ALGOS, assert_close as _assert_close, \
    tpu_only

from repro.algebra import ALGEBRAS, get_algebra
from repro.core import PROGRAMS, compile_mapping, simulate
from repro.core.engine import FlipEngine
from repro.graphs import (make_power_law, make_road_network, make_synthetic,
                          reference)


def _graphs20():
    """20 fixed-seed graphs: 10 Erdős–Rényi + 10 power-law, one size so
    the engine's jit cache is shared across all of them."""
    for seed in range(10):
        yield make_synthetic(48, 140, seed=seed), 3 + seed % 5
        yield make_power_law(48, 140, seed=seed), 3 + seed % 5


@pytest.mark.parametrize("algo", ALGOS)
def test_engine_matches_oracle_20_graphs(algo):
    for g, src in _graphs20():
        ref, _ = reference.run(algo, g, src)
        for mode in ("data", "op"):
            eng = FlipEngine.build(g, algo, tile=64, mode=mode,
                                   relax_mode="jnp")
            got, steps = eng.run(src)
            assert steps > 0
            _assert_close(got, ref, algo, f"mode={mode}")


@pytest.mark.parametrize("algo", ALGOS)
def test_engine_multitile_blocksparse(algo):
    """ntiles > 1: exercises bsrc/bdst block bookkeeping + segment ⊕."""
    g = make_power_law(70, 210, seed=42)
    ref, _ = reference.run(algo, g, 1)
    for mode in ("data", "op"):
        eng = FlipEngine.build(g, algo, tile=16, mode=mode,
                               relax_mode="jnp")
        got, _ = eng.run(1)
        _assert_close(got, ref, algo, f"multitile mode={mode}")


@pytest.mark.parametrize("algo", ["widest", "reach", "pagerank"])
def test_interpret_kernel_non_tropical(algo):
    """The Pallas kernel body (interpret mode) on non-(min,+) semirings."""
    g = make_synthetic(40, 110, seed=7)
    ref, _ = reference.run(algo, g, 2)
    eng = FlipEngine.build(g, algo, tile=16, mode="data",
                           relax_mode="interpret")
    got, _ = eng.run(2)
    _assert_close(got, ref, algo, "interpret")


@pytest.mark.parametrize("algo", SIM_ALGOS)
def test_sim_cross_layer(algo):
    """Cycle simulator vs oracle vs engine on ER + road graphs."""
    for g, src in [(make_synthetic(48, 140, seed=11), 2),
                   (make_road_network(64, seed=2, delete_frac=0.5), 5)]:
        m = compile_mapping(g, effort=0, seed=0)
        r = simulate(m, PROGRAMS[algo], src=src)
        ref, _ = reference.run(algo, g, src)
        _assert_close(r.attrs, ref, algo, "sim")
        got, _ = FlipEngine.build(g, algo, tile=64,
                                  relax_mode="jnp").run(src)
        _assert_close(got, ref, algo, "engine-vs-sim graph")


def test_pagerank_not_expressible_on_sim():
    g = make_synthetic(32, 80, seed=0)
    m = compile_mapping(g, effort=0, seed=0)
    with pytest.raises(ValueError, match="not expressible"):
        simulate(m, PROGRAMS["pagerank"], src=0)


def test_pagerank_mass_conservation():
    """Rank sums to (1 - leaked dangling mass) <= 1, never more."""
    g = make_power_law(48, 140, seed=3)
    got, _ = FlipEngine.build(g, "pagerank", tile=64,
                              relax_mode="jnp").run(0)
    assert 0.0 < float(np.sum(got)) <= 1.0 + 1e-4


@tpu_only
@pytest.mark.parametrize("algo", ALGOS)
def test_pallas_compiled_matches_oracle(algo):
    g = make_synthetic(120, 360, seed=1)
    ref, _ = reference.run(algo, g, 0)
    eng = FlipEngine.build(g, algo, tile=128, mode="data",
                           relax_mode="pallas")
    got, _ = eng.run(0)
    _assert_close(got, ref, algo, "pallas-compiled")


@pytest.mark.skipif(len(jax.devices()) < 2,
                    reason="needs a real multi-device platform; the "
                           "single-device CPU CI covers run_distributed "
                           "via the forced-host subprocess tests")
@pytest.mark.parametrize("algo", ["sssp", "pagerank"])
def test_run_distributed_real_devices(algo):
    g = make_synthetic(96, 280, seed=4)
    ref, _ = reference.run(algo, g, 0)
    got, steps = FlipEngine.build(g, algo, tile=32).run_distributed(0)
    assert steps > 0
    _assert_close(got, ref, algo, "distributed")


def test_register_custom_algebra_end_to_end():
    """The registry contract: one VertexAlgebra entry opens a new
    algorithm on every layer. Minimax path = (min, max) semiring."""
    import jax
    import jax.numpy as jnp
    from repro.algebra import Semiring, VertexAlgebra, register_algebra

    min_max = Semiring(
        name="min_max", zero=float("inf"), one=float("-inf"),
        add_np=np.minimum, mul_np=np.maximum,
        add_jnp=jnp.minimum, mul_jnp=jnp.maximum,
        add_reduce_jnp=jnp.min,
        segment_reduce_jnp=lambda x, s, n: jax.ops.segment_min(
            x, s, num_segments=n),
        idempotent=True,
    )
    minimax = register_algebra(VertexAlgebra(
        "minimax_test", min_max, weight_rule="graph"))
    try:
        g = make_synthetic(40, 120, seed=9)
        # oracle: Dijkstra minimizing the max edge weight along the path
        import heapq
        best = np.full(g.n, np.inf, dtype=np.float32)
        best[2] = -np.inf
        heap = [(-np.inf, 2)]
        while heap:
            d, u = heapq.heappop(heap)
            if d > best[u]:
                continue
            for k in range(g.indptr[u], g.indptr[u + 1]):
                v = int(g.indices[k])
                cand = max(d, float(g.weights[k]))
                if cand < best[v]:
                    best[v] = np.float32(cand)
                    heapq.heappush(heap, (cand, v))
        for mode in ("data", "op"):
            got, _ = FlipEngine.build(g, minimax, tile=64, mode=mode,
                                      relax_mode="jnp").run(2)
            _assert_close(got, best, "minimax", f"mode={mode}")
        # and on the cycle simulator, unchanged
        m = compile_mapping(g, effort=0, seed=0)
        r = simulate(m, get_algebra("minimax_test"), src=2)
        _assert_close(r.attrs, best, "minimax", "sim")
    finally:
        ALGEBRAS.pop("minimax_test", None)
