"""Shared test scaffolding for the whole suite.

Environment setup (CPU platform pin, `src/` on the path) plus the
graph/algebra helpers that used to be copy-pasted across
`test_algebra.py`, `test_batched.py`, and `test_compaction.py`:
oracle-comparison assertions, tiled-state builders, the batched
bit-exactness checker, and the TPU/CPU skip markers for the Pallas
paths. Import them with ``from conftest import ...`` (pytest puts the
tests directory on `sys.path` while collecting).
"""
import os
import sys

# tests must see the real (1-device) platform; the 512-device override is
# dryrun.py-only. Some tests spawn subprocesses that set their own flags.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax                     # noqa: E402
import jax.numpy as jnp        # noqa: E402
import numpy as np             # noqa: E402
import pytest                  # noqa: E402

from repro.algebra import ALGEBRAS, VertexAlgebra   # noqa: E402
from repro.graphs import reference                  # noqa: E402

# scalar programs only: the shape-sensitive suites ((B, n) results,
# sim parity, solo-vs-batch bit-exactness) run over these; the vector
# programs (feature_dim > 1) have their own (n, d) suites in
# test_features.py / test_fuzz_differential.py.
ALGOS = sorted(a for a in ALGEBRAS if ALGEBRAS[a].feature_dim == 1)
VEC_ALGOS = sorted(a for a in ALGEBRAS if ALGEBRAS[a].feature_dim > 1)
SIM_ALGOS = [a for a in ALGOS if ALGEBRAS[a].sim_ok]
SRCS8 = np.array([3, 11, 0, 27, 42, 8, 19, 33])     # B=8 fixed sources

ON_TPU = jax.default_backend() == "tpu"
tpu_only = pytest.mark.skipif(
    not ON_TPU, reason="compiled Pallas path is TPU-only; CPU covers the "
                       "same kernel body via interpret mode")
cpu_only = pytest.mark.skipif(
    ON_TPU, reason="pallas mode is the real path on TPU")

finite = VertexAlgebra.finite   # shared ±inf-sentinel mapping


def assert_close(got, ref, algo, msg=""):
    """Oracle comparison at the algebra's tolerance, ±inf-safe."""
    alg = ALGEBRAS.get(algo)
    atol = alg.atol if alg is not None else 1e-6
    assert np.allclose(finite(got), finite(ref), atol=atol), \
        f"{algo} {msg}: max|d|=" \
        f"{np.abs(finite(got) - finite(ref)).max()}"


def oracle(algo, g, src):
    """The numpy reference result alone (stats dropped)."""
    out, _ = reference.run(algo, g, src)
    return out


def tiled_state(bg, rng, batch=0):
    """Random mid-run attribute state in tiled (B?, ntiles, T) layout."""
    shape = (batch, bg.n) if batch else (bg.n,)
    vals = rng.uniform(0.5, 9, shape).astype(np.float32)
    return bg.to_tiled(vals)


def masked_src_vals(bg, attrs, rng, density):
    """Frontier-masked source values at a named or numeric density:
    'none' / 'all' / 'tile0' (one active source tile) / a float
    per-lane activation probability."""
    if density == "none":
        mask = np.zeros(attrs.shape, dtype=bool)
    elif density == "all":
        mask = np.ones(attrs.shape, dtype=bool)
    elif density == "tile0":
        mask = np.zeros(attrs.shape, dtype=bool)
        mask[..., 0, :] = True
    else:
        mask = rng.random(attrs.shape) < density
    return jnp.where(jnp.asarray(mask), attrs,
                     np.float32(bg.semiring.zero))


def np_contract(sr, sv, w):
    """Plain-numpy feature contraction oracle for the vector-state
    kernels: out[D, f] = ⊕_s sv[s, f] ⊗ w[s, D], built from the
    semiring's numpy ops (independent of `Semiring.contract_jnp`)."""
    vals = sr.mul_np(sv[:, None, :], w[:, :, None])      # (S, D, d)
    out = vals[0]
    for s in range(1, vals.shape[0]):
        out = sr.add_np(out, vals[s])
    return out


def check_batch(eng, g, srcs, algo):
    """run_batch rows must be bit-for-bit the solo runs and match the
    oracle (the batched-execution contract)."""
    outs, steps = eng.run_batch(srcs)
    assert outs.shape == (len(srcs), g.n)
    assert steps.shape == (len(srcs),)
    for b, s in enumerate(srcs):
        solo_out, solo_steps = eng.run(int(s))
        np.testing.assert_array_equal(outs[b], solo_out)
        assert steps[b] == solo_steps
        assert ALGEBRAS[algo].results_match(outs[b], oracle(algo, g,
                                                            int(s))), \
            (algo, b)
