import os
import sys

# tests must see the real (1-device) platform; the 512-device override is
# dryrun.py-only. Some tests spawn subprocesses that set their own flags.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
