"""Vector-valued vertex state: (T, d) feature blocks.

Covers the feature-dim contract end to end:

  * `Semiring.contract_jnp` vs a plain-numpy per-tile oracle for every
    semiring (MXU matmul for (+, x), slab-swept broadcast-⊕-reduce for
    the idempotent ones, including d that is not a slab multiple);
  * d = 1 stays bit-exact with the scalar path (explicit
    plan.feature_dim=1 == default plan, for every scalar algebra x
    {jnp, interpret} x {solo, batched});
  * scalar programs forced to d > 1 run d broadcast lanes (idempotent
    algebras column-for-column bit-exact with the scalar run);
  * the vector programs (multi_bfs, labelprop) match their (n, d) numpy
    oracles through solo, batched, bucketed-serving, warm-start and
    distributed execution;
  * shape/plan validation fails loudly: d-inconsistent kernel inputs,
    warm states of the wrong width, plans forcing a vector program off
    its native width;
  * `_make_relax_kernel`'s cache keys on (semiring, feature_dim).
"""
import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from conftest import ALGOS, VEC_ALGOS, SRCS8, np_contract, oracle
from repro import api as flip
from repro.algebra import (ALGEBRAS, MAX_MIN, MIN_PLUS, OR_AND,
                           PLUS_TIMES, landmarks)
from repro.graphs import make_synthetic, reference
from repro.kernels.frontier import build_blocks, frontier_relax
from repro.kernels.frontier.frontier import (_make_relax_kernel,
                                             frontier_relax_pallas)

SEMIRINGS = [MIN_PLUS, MAX_MIN, OR_AND, PLUS_TIMES]


def _state(rng, sr, shape):
    """Random finite state values inside each semiring's domain."""
    if sr is OR_AND:
        return (rng.random(shape) < 0.5).astype(np.float32)
    return rng.uniform(0.5, 4.0, shape).astype(np.float32)


# ------------------------------------------------------------------ #
# contract_jnp semantics
# ------------------------------------------------------------------ #
@pytest.mark.parametrize("sr", SEMIRINGS, ids=lambda s: s.name)
@pytest.mark.parametrize("d", [1, 3, 8, 20])   # 20 spans 3 slab sweeps
def test_contract_matches_numpy_oracle(sr, d):
    rng = np.random.default_rng(7)
    sv = _state(rng, sr, (16, d))
    w = _state(rng, sr, (16, 12))
    got = np.asarray(sr.contract_jnp(jnp.asarray(sv), jnp.asarray(w)))
    want = np_contract(sr, sv, w)
    assert got.shape == (12, d)
    if sr.idempotent:
        np.testing.assert_array_equal(got, want)
    else:
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_contract_batched_leading_axes():
    rng = np.random.default_rng(1)
    sv = rng.uniform(0, 2, (2, 5, 16, 3)).astype(np.float32)
    w = rng.uniform(0, 2, (2, 5, 16, 16)).astype(np.float32)
    got = np.asarray(MIN_PLUS.contract_jnp(jnp.asarray(sv),
                                           jnp.asarray(w)))
    assert got.shape == (2, 5, 16, 3)
    for b in range(2):
        for k in range(5):
            np.testing.assert_array_equal(
                got[b, k], np_contract(MIN_PLUS, sv[b, k], w[b, k]))


# ------------------------------------------------------------------ #
# kernel layer: frontier_relax with feature blocks
# ------------------------------------------------------------------ #
@pytest.mark.parametrize("sr", SEMIRINGS, ids=lambda s: s.name)
@pytest.mark.parametrize("mode", ["jnp", "interpret"])
@pytest.mark.parametrize("batched", [False, True])
def test_relax_features_matches_dense_oracle(sr, mode, batched):
    """One relax step on (ntiles, T, d) state vs the per-tile numpy
    contraction oracle, through the real BlockGraph dispatch."""
    g = make_synthetic(60, 180, seed=5)
    algo = {MIN_PLUS: "sssp", MAX_MIN: "widest", OR_AND: "reach",
            PLUS_TIMES: "pagerank"}[sr]
    bg = build_blocks(g, algo=algo, tile=16)
    d = 4
    rng = np.random.default_rng(3)
    shape = ((2,) if batched else ()) + (bg.ntiles, bg.tile, d)
    sv = _state(rng, sr, shape)
    carry = _state(rng, sr, shape)
    out = np.asarray(frontier_relax(
        jnp.asarray(sv), jnp.asarray(carry), bg, mode=mode,
        feature_dim=d))
    blocks = np.asarray(bg.blocks)
    bsrc, bdst = np.asarray(bg.bsrc), np.asarray(bg.bdst)

    # oracle: cand[dst] accumulated over blocks, then carry ⊕ cand
    def one(svb, carryb):
        new = carryb.copy()
        for i in range(len(bsrc)):
            c = np_contract(sr, svb[bsrc[i]], blocks[i])
            new[bdst[i]] = sr.add_np(new[bdst[i]], c)
        return new
    if batched:
        want = np.stack([one(sv[b], carry[b]) for b in range(2)])
    else:
        want = one(sv, carry)
    if sr.idempotent:
        np.testing.assert_array_equal(out, want)
    else:
        np.testing.assert_allclose(out, want, rtol=1e-4, atol=1e-4)


def test_relax_feature_dim_mismatch_raises():
    g = make_synthetic(40, 100, seed=0)
    bg = build_blocks(g, algo="sssp", tile=16)
    sv = jnp.zeros((bg.ntiles, bg.tile, 4), jnp.float32)
    with pytest.raises(ValueError, match="feature_dim"):
        frontier_relax(sv, sv, bg, mode="jnp", feature_dim=8)


def test_relax_kernel_cache_keys_on_feature_dim():
    k1 = _make_relax_kernel(MIN_PLUS, 1)
    k8 = _make_relax_kernel(MIN_PLUS, 8)
    assert k1 is not k8
    assert _make_relax_kernel(MIN_PLUS, 8) is k8
    assert _make_relax_kernel(PLUS_TIMES, 8) is not k8


def test_pallas_interpret_features_matches_jnp():
    g = make_synthetic(60, 180, seed=5)
    bg = build_blocks(g, algo="sssp", tile=16)
    rng = np.random.default_rng(9)
    sv = rng.uniform(0.5, 4, (bg.ntiles, bg.tile, 4)).astype(np.float32)
    carry = rng.uniform(0.5, 4, sv.shape).astype(np.float32)
    a = frontier_relax(jnp.asarray(sv), jnp.asarray(carry), bg,
                       mode="interpret", feature_dim=4)
    b = frontier_relax(jnp.asarray(sv), jnp.asarray(carry), bg,
                       mode="jnp", feature_dim=4)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ------------------------------------------------------------------ #
# d = 1 bit-exactness with the scalar path
# ------------------------------------------------------------------ #
@pytest.mark.parametrize("relax_mode", ["jnp", "interpret"])
@pytest.mark.parametrize("algo", ALGOS)
def test_d1_bit_exact_with_scalar_path(algo, relax_mode):
    """plan.feature_dim=1 must be the *same* execution as the default
    plan, bit for bit, solo and batched -- d=1 routes through the
    untouched scalar kernel bodies."""
    g = make_synthetic(70, 200, seed=2)
    base = flip.ExecutionPlan(relax_mode=relax_mode)
    forced = flip.ExecutionPlan(relax_mode=relax_mode, feature_dim=1)
    r0 = flip.compile(g, algo, base).query(3)
    r1 = flip.compile(g, algo, forced).query(3)
    np.testing.assert_array_equal(r0.attrs, r1.attrs)
    assert r0.steps == r1.steps
    b0 = flip.compile(g, algo, base).query(SRCS8[:4] % g.n)
    b1 = flip.compile(g, algo, forced).query(SRCS8[:4] % g.n)
    np.testing.assert_array_equal(b0.attrs, b1.attrs)
    np.testing.assert_array_equal(b0.steps, b1.steps)


# ------------------------------------------------------------------ #
# scalar programs at d > 1: broadcast feature lanes
# ------------------------------------------------------------------ #
@pytest.mark.parametrize("algo", ["bfs", "sssp", "widest", "reach"])
def test_broadcast_lanes_match_scalar_columnwise(algo):
    """Idempotent algebras at forced d: every feature column is the
    scalar run, bit for bit (same elementwise ops per lane)."""
    g = make_synthetic(70, 200, seed=2)
    scalar = flip.compile(g, algo).query(5).attrs
    vec = flip.compile(g, algo,
                       flip.ExecutionPlan(feature_dim=4)).query(5).attrs
    assert vec.shape == (g.n, 4)
    for f in range(4):
        np.testing.assert_array_equal(vec[:, f], scalar)


def test_broadcast_lanes_pagerank_close():
    g = make_synthetic(70, 200, seed=2)
    scalar = flip.compile(g, "pagerank").query(0).attrs
    vec = flip.compile(g, "pagerank",
                       flip.ExecutionPlan(feature_dim=3)).query(0).attrs
    assert vec.shape == (g.n, 3)
    for f in range(3):
        np.testing.assert_allclose(vec[:, f], scalar, rtol=1e-4,
                                   atol=1e-5)


# ------------------------------------------------------------------ #
# vector programs vs their (n, d) oracles
# ------------------------------------------------------------------ #
@pytest.mark.parametrize("relax_mode", ["jnp", "interpret"])
@pytest.mark.parametrize("algo", VEC_ALGOS)
def test_vector_programs_match_oracle(algo, relax_mode):
    g = make_synthetic(80, 240, seed=4)
    d = ALGEBRAS[algo].feature_dim
    res = flip.compile(g, algo,
                       flip.ExecutionPlan(relax_mode=relax_mode)).query(3)
    assert res.attrs.shape == (g.n, d)
    ref = oracle(algo, g, 3)
    assert ref.shape == (g.n, d)
    assert ALGEBRAS[algo].results_match(res.attrs, ref)
    assert res.check()


@pytest.mark.parametrize("algo", VEC_ALGOS)
def test_vector_programs_batched(algo):
    g = make_synthetic(80, 240, seed=4)
    d = ALGEBRAS[algo].feature_dim
    srcs = SRCS8[:3] % g.n
    res = flip.compile(g, algo).query(srcs)
    assert res.attrs.shape == (len(srcs), g.n, d)
    for b, s in enumerate(srcs):
        assert ALGEBRAS[algo].results_match(res.attrs[b],
                                            oracle(algo, g, int(s))), b


def test_vector_bucketed_serving():
    from repro.launch.serve_graph import GraphServer
    g = make_synthetic(80, 240, seed=4)
    srcs = [0, 5, 9, 13, 21]
    srv = GraphServer(g, plan=flip.ExecutionPlan(batch=4))
    reqs = srv.serve([("multi_bfs", s) for s in srcs])
    for r, s in zip(reqs, srcs):
        assert r.result.shape == (g.n, 8)
        assert ALGEBRAS["multi_bfs"].results_match(
            r.result, oracle("multi_bfs", g, s)), s


def test_vector_distributed():
    g = make_synthetic(80, 240, seed=4)
    res = flip.compile(g, "multi_bfs",
                       flip.ExecutionPlan(distributed=True)).query(3)
    assert ALGEBRAS["multi_bfs"].results_match(res.attrs,
                                               oracle("multi_bfs", g, 3))


def test_labelprop_labels_are_argmax_communities():
    """The point of labelprop: argmax over the feature axis assigns
    every reachable vertex the label of its dominant landmark, and each
    landmark claims itself."""
    g = make_synthetic(80, 240, seed=4)
    res = flip.compile(g, "labelprop").query(3)
    lm = landmarks(g.n, 3, 8)
    labels = np.argmax(res.attrs, axis=1)
    np.testing.assert_array_equal(labels[lm], np.arange(8))


# ------------------------------------------------------------------ #
# warm starts with vector state
# ------------------------------------------------------------------ #
def test_vector_warm_start_matches_recompute():
    g = make_synthetic(80, 240, seed=4)
    cq = flip.compile(g, "multi_bfs")
    r0 = cq.query(3)
    cq2, delta = cq.update([(3, 60, 1.0)])
    warm = cq2.query(3, warm=r0)
    cold = cq2.query(3)
    np.testing.assert_array_equal(warm.attrs, cold.attrs)
    assert ALGEBRAS["multi_bfs"].results_match(
        warm.attrs, oracle("multi_bfs", cq2.graph, 3))


def test_vector_warm_width_mismatch_raises():
    g = make_synthetic(80, 240, seed=4)
    cq = flip.compile(g, "multi_bfs")
    r0 = cq.query(3)
    cq2, _ = cq.update([(3, 60, 1.0)])
    bad = dataclasses.replace(r0, attrs=r0.attrs[..., 0])   # (n,) into d=8
    with pytest.raises(ValueError, match="feature_dim"):
        cq2.query(3, warm=bad)


# ------------------------------------------------------------------ #
# plan / engine validation
# ------------------------------------------------------------------ #
def test_plan_rejects_bad_feature_dim():
    with pytest.raises(ValueError, match="feature_dim"):
        flip.ExecutionPlan(feature_dim=-1).validate()
    with pytest.raises(ValueError, match="feature_dim"):
        flip.ExecutionPlan(feature_dim="8").validate()


def test_plan_rejects_off_native_width_for_vector_program():
    g = make_synthetic(40, 100, seed=0)
    with pytest.raises(ValueError, match="native"):
        flip.compile(g, "multi_bfs", flip.ExecutionPlan(feature_dim=4))
    # feature_dim=0 (auto) and the native width both resolve fine
    assert flip.compile(g, "multi_bfs").plan.feature_dim == 8
    assert flip.compile(
        g, "multi_bfs",
        flip.ExecutionPlan(feature_dim=8)).plan.feature_dim == 8


def test_plan_auto_adopts_native_width():
    p = flip.ExecutionPlan().resolve(ALGEBRAS["labelprop"])
    assert p.feature_dim == 8
    p = flip.ExecutionPlan().resolve(ALGEBRAS["sssp"])
    assert p.feature_dim == 1


def test_plan_key_includes_feature_dim():
    a = flip.ExecutionPlan(feature_dim=0).key()
    b = flip.ExecutionPlan(feature_dim=8).key()
    assert a != b


# ------------------------------------------------------------------ #
# telemetry: HBM estimates scale with d on the state stream only
# ------------------------------------------------------------------ #
def test_telemetry_state_bytes_scale_with_d():
    g = make_synthetic(80, 240, seed=4)
    r1 = flip.compile(g, "sssp").query(3, trace=True)
    r8 = flip.compile(g, "sssp",
                      flip.ExecutionPlan(feature_dim=8)).query(
                          3, trace=True)
    s1, s8 = (r.telemetry.dispatches[0].summary() for r in (r1, r8))
    assert s1["feature_dim"] == 1 and s8["feature_dim"] == 8
    # identical fixpoint trajectory per lane -> same steps, same weight
    # traffic; the state stream carries the factor of d
    assert s8["hbm_weight_bytes_est"] == s1["hbm_weight_bytes_est"]
    assert s8["hbm_state_bytes_est"] == 8 * s1["hbm_state_bytes_est"]
