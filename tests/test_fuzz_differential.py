"""Seeded differential fuzz harness.

Each seed generates one random graph -- alternating between an
adversarial uniform family (self-loops, parallel edges that the CSR
builder ⊕-dedupes, isolated vertices, n never tile-aligned) and
`make_power_law` hubs -- and pushes **every registered algebra** through
the execution layers against the numpy reference oracles:

  * FlipEngine data mode, jnp relax path (frontier-compacted fixpoint)
  * FlipEngine op mode, jnp relax path (full-sweep classic-CGRA)
  * Pallas kernel body in interpret mode (rotated: one algebra per seed,
    so the slow path still covers every algebra across the seed corpus)
  * the asynchronous cycle simulator (rotated over the expressible
    algebras, on the self-loop-free power-law family)

then drives a random mutation sequence (inserts / deletes / reweights,
including self-loop and parallel-edge upserts) through the incremental
engines: after every batch the delta-driven `run_updated` result must be
bit-for-bit the from-scratch run on the mutated graph and match the
oracle, and the incrementally rebuilt block layout must equal a full
rebuild.

Failures print a minimal repro: the seed, the generated graph's
parameters, and the exact pytest command that replays the case.

Seed count: 50 by default (~ISSUE spec); `FUZZ_SEEDS=5` is the CI smoke
setting, and any larger value soaks further.
"""
import os

import jax.numpy as jnp
import numpy as np
import pytest
from conftest import ALGOS, SIM_ALGOS, VEC_ALGOS, np_contract, oracle

from repro.algebra import ALGEBRAS
from repro.core import PROGRAMS, compile_mapping, simulate
from repro.core.engine import FlipEngine
from repro.graphs import Graph, make_power_law, reference
from repro.kernels.frontier import build_blocks, frontier_relax

SEEDS = range(int(os.environ.get("FUZZ_SEEDS", "50")))
TILE = 16
# vertex counts are drawn from a small fixed set (never tile-aligned) so
# the jit cache sees a bounded family of shapes across the whole corpus
NS_UNIFORM = (17, 23, 33, 41)
NS_POWER = (19, 27, 35, 45)


def _random_uniform_graph(rng):
    """Adversarial uniform-random graph: endpoints drawn with
    replacement, so self-loops and parallel edges (⊕-deduped by
    `Graph.from_edges`) occur, and nothing guarantees connectivity --
    isolated vertices and unreachable components stay in."""
    n = int(rng.choice(NS_UNIFORM))
    m = int(rng.integers(n, 4 * n))
    u = rng.integers(0, n, size=m)
    v = rng.integers(0, n, size=m)
    w = rng.integers(1, 9, size=m).astype(float)
    directed = bool(rng.integers(2))
    return Graph.from_edges(n, list(zip(u, v)), list(w),
                            directed=directed)


def _random_batch(g, rng, k=4):
    """Random mutation batch: inserts (self-loops allowed), deletes of
    existing edges, reweights of existing edges -- all dyadic weights so
    bit-exact warm-vs-scratch comparison is meaningful."""
    eu = g.edge_sources()
    batch = []
    for _ in range(k):
        kind = int(rng.integers(3)) if g.m else 0
        if kind == 0:
            batch.append((int(rng.integers(g.n)), int(rng.integers(g.n)),
                          float(rng.integers(1, 9))))
        else:
            i = int(rng.integers(g.m))
            u, v = int(eu[i]), int(g.indices[i])
            batch.append((u, v, None) if kind == 1
                         else (u, v, float(rng.integers(1, 9))))
    return batch


@pytest.mark.parametrize("seed", SEEDS)
def test_fuzz_differential(seed):
    rng = np.random.default_rng(seed)
    if seed % 2:
        g = _random_uniform_graph(rng)
    else:
        n = int(rng.choice(NS_POWER))
        g = make_power_law(n, int(rng.integers(2 * n, 4 * n)), seed=seed)
    src = int(rng.integers(g.n))
    repro = (f"repro: FUZZ_SEEDS={seed + 1} python -m pytest "
             f"'tests/test_fuzz_differential.py::test_fuzz_differential"
             f"[{seed}]' | graph: n={g.n} m={g.m} "
             f"directed={g.directed} family="
             f"{'uniform' if seed % 2 else 'power_law'} src={src}")

    interp_algo = ALGOS[seed % len(ALGOS)]
    engines, results = {}, {}
    for algo in ALGOS:
        ref = oracle(algo, g, src)
        for mode in ("data", "op"):
            eng = FlipEngine.build(g, algo, tile=TILE, mode=mode,
                                   relax_mode="jnp")
            got, steps = eng.run(src)
            assert ALGEBRAS[algo].results_match(got, ref), \
                f"{algo} {mode}/jnp diverged from oracle; {repro}"
            if mode == "data":
                engines[algo], results[algo] = eng, got
        if algo == interp_algo:
            got, _ = FlipEngine.build(g, algo, tile=8, mode="data",
                                      relax_mode="interpret").run(src)
            assert ALGEBRAS[algo].results_match(got, ref), \
                f"{algo} data/interpret diverged from oracle; {repro}"

    # cycle simulator: self-loop-free family only (the packet model, like
    # the paper's fabric, assumes simple edges)
    if seed % 2 == 0 and SIM_ALGOS:
        algo = SIM_ALGOS[seed % len(SIM_ALGOS)]
        m = compile_mapping(g, effort=0, seed=0)
        r = simulate(m, PROGRAMS[algo], src=src)
        assert ALGEBRAS[algo].results_match(r.attrs, oracle(algo, g, src)), \
            f"{algo} sim diverged from oracle; {repro}"

    # random mutation sequence through the incremental engines
    g_cur = g
    for step in range(2):
        batch = _random_batch(g_cur, rng)
        g_next = g_cur.apply_updates(batch)
        for algo in ALGOS:
            eng2, delta = engines[algo].apply_updates(g_next, batch)
            inc, _ = eng2.run_updated(src, results[algo], delta)
            scr, _ = eng2.run(src)
            np.testing.assert_array_equal(
                inc, scr,
                err_msg=f"{algo} incremental != scratch after mutation "
                        f"batch {step} {batch}; {repro}")
            assert ALGEBRAS[algo].results_match(
                inc, oracle(algo, g_next, src)), \
                f"{algo} diverged from oracle after mutation batch " \
                f"{step} {batch}; {repro}"
            engines[algo], results[algo] = eng2, inc
        # structural spot-check (rotated algebra): incremental layout ==
        # full rebuild, covering delete/reinsert/shape-change paths
        full = build_blocks(g_next, interp_algo, tile=TILE)
        np.testing.assert_array_equal(
            np.asarray(engines[interp_algo].bg.blocks),
            np.asarray(full.blocks),
            err_msg=f"{interp_algo} incremental layout != rebuild after "
                    f"batch {step} {batch}; {repro}")
        g_cur = g_next


# ------------------------------------------------------------------ #
# vector-state fuzz: random (T, d) feature blocks through every algebra
# ------------------------------------------------------------------ #
def _random_features(rng, sr, shape, family):
    """Random feature state inside the semiring's domain: a bounded
    uniform family and a heavy-tailed power-law family (Pareto), the
    latter stressing the ⊕-reduce with magnitudes spanning decades."""
    if sr.name == "or_and":
        return (rng.random(shape) < 0.5).astype(np.float32)
    if family == "uniform":
        vals = rng.uniform(0.25, 8.0, shape)
    else:
        vals = 0.25 + rng.pareto(1.5, shape)
    return vals.astype(np.float32)


@pytest.mark.parametrize("seed", SEEDS)
def test_fuzz_features(seed):
    """d > 1 differential: one frontier_relax step on random feature
    state vs the per-tile numpy contraction oracle for **every**
    algebra, plus the vector programs' full fixpoints vs their (n, d)
    oracles, on the same alternating graph families as the scalar
    fuzz."""
    rng = np.random.default_rng(10_000 + seed)
    family = "uniform" if seed % 2 else "power_law"
    if seed % 2:
        g = _random_uniform_graph(rng)
    else:
        n = int(rng.choice(NS_POWER))
        g = make_power_law(n, int(rng.integers(2 * n, 4 * n)), seed=seed)
    src = int(rng.integers(g.n))
    d = int(rng.choice((2, 4, 8)))
    repro = (f"repro: FUZZ_SEEDS={seed + 1} python -m pytest "
             f"'tests/test_fuzz_differential.py::test_fuzz_features"
             f"[{seed}]' | graph: n={g.n} m={g.m} "
             f"directed={g.directed} family={family} src={src} d={d}")

    interp_algo = ALGOS[seed % len(ALGOS)]
    for algo in ALGOS:
        sr = ALGEBRAS[algo].semiring
        bg = build_blocks(g, algo=algo, tile=TILE)
        shape = (bg.ntiles, bg.tile, d)
        sv = _random_features(rng, sr, shape, family)
        carry = _random_features(rng, sr, shape, family)
        blocks = np.asarray(bg.blocks)
        bsrc, bdst = np.asarray(bg.bsrc), np.asarray(bg.bdst)
        want = carry.copy()
        for i in range(len(bsrc)):
            c = np_contract(sr, sv[bsrc[i]], blocks[i])
            want[bdst[i]] = sr.add_np(want[bdst[i]], c)
        modes = ("jnp", "interpret") if algo == interp_algo else ("jnp",)
        for mode in modes:
            got = np.asarray(frontier_relax(
                jnp.asarray(sv), jnp.asarray(carry), bg, mode=mode,
                feature_dim=d))
            if sr.idempotent:
                np.testing.assert_array_equal(
                    got, want,
                    err_msg=f"{algo} {mode} d={d} feature relax diverged "
                            f"from numpy oracle; {repro}")
            else:
                np.testing.assert_allclose(
                    got, want, rtol=1e-4, atol=1e-4,
                    err_msg=f"{algo} {mode} d={d} feature relax diverged "
                            f"from numpy oracle; {repro}")

    # vector programs: full engine fixpoint vs the (n, d) oracle,
    # rotated so each seed runs one of them (labelprop fixpoints are
    # long) and the corpus covers both
    algo = VEC_ALGOS[seed % len(VEC_ALGOS)]
    eng = FlipEngine.build(g, algo, tile=TILE, relax_mode="jnp")
    got, _ = eng.run(src)
    assert ALGEBRAS[algo].results_match(got, oracle(algo, g, src)), \
        f"{algo} engine diverged from (n, d) oracle; {repro}"


# ------------------------------------------------------------------ #
# fault-injection fuzz: seeded chaos schedules against the server
# ------------------------------------------------------------------ #
CHAOS_SEEDS = range(int(os.environ.get("FUZZ_CHAOS_SEEDS", "8")))


@pytest.mark.parametrize("seed", CHAOS_SEEDS)
def test_fuzz_chaos_serving(seed):
    """Seeded fault schedules (backend raise + NaN poison at random
    (dispatch, rung) ordinals) against a serving stream with
    interleaved updates: zero lost requests, typed errors on every
    failure, oracle-exact results on every success. Each seed draws its
    own graph, request stream, and fault schedule; `FUZZ_CHAOS_SEEDS`
    scales the corpus (CI smoke uses a smaller value)."""
    from repro.launch.serve_graph import GraphServer
    from repro.resilience import FaultInjector, FlipError

    rng = np.random.default_rng(20_000 + seed)
    n = int(rng.choice(NS_POWER))
    g = make_power_law(n, int(rng.integers(2 * n, 4 * n)), seed=seed)
    algos = ["bfs", "sssp"]
    n_req = 16
    repro = (f"repro: FUZZ_CHAOS_SEEDS={seed + 1} python -m pytest "
             f"'tests/test_fuzz_differential.py::test_fuzz_chaos_serving"
             f"[{seed}]' | graph: n={g.n} m={g.m}")

    inj = FaultInjector.random(seed=30_000 + seed, dispatches=12,
                               rate=0.4)
    srv = GraphServer(g, batch=4, tile=TILE, fault_injector=inj)
    g_cur, reqs, snaps = g, [], []
    for i in range(n_req):
        if i == n_req // 2 and g_cur.m:       # one mid-stream mutation
            eu = g_cur.edge_sources()
            j = int(rng.integers(g_cur.m))
            batch = [(int(eu[j]), int(g_cur.indices[j]),
                      float(g_cur.weights[j]) * 0.5)]
            srv.update(batch)
            g_cur = g_cur.apply_updates(batch)
        reqs.append(srv.submit(algos[int(rng.integers(len(algos)))],
                               int(rng.integers(g.n))))
        snaps.append(g_cur)
    srv.drain()

    assert all(r.done for r in reqs), f"server lost requests; {repro}"
    for r, g_snap in zip(reqs, snaps):
        if r.error is not None:
            assert isinstance(r.error, FlipError), \
                f"untyped failure {r.error!r}; {repro}"
        if r.ok:
            assert ALGEBRAS[r.algo].results_match(
                r.result, oracle(r.algo, g_snap, r.src)), \
                f"{r.algo} src={r.src} rung={r.rung} diverged; {repro}"


# ------------------------------------------------------------------ #
# continuous-batching traffic fuzz: Zipf sources, mixed algebras,
# interleaved mutations, deterministic replay
# ------------------------------------------------------------------ #
TRAFFIC_SEEDS = range(int(os.environ.get("FUZZ_TRAFFIC_SEEDS", "8")))


def _zipf_src(rng, n):
    """Zipf-distributed source id (clipped to the vertex set): the
    serving-traffic shape -- a few hot sources dominate, exercising
    the result cache and warm-start reuse."""
    return int(min(rng.zipf(1.4) - 1, n - 1))


@pytest.mark.parametrize("seed", TRAFFIC_SEEDS)
def test_fuzz_traffic(seed):
    """Seeded Zipf traffic through the continuous-batching scheduler:
    mixed algebras (scalar + vector state), hot repeated sources, and
    interleaved monotone mutation batches, all on a virtual clock.
    Every served result -- cold, cache hit, or warm-started -- must
    match the numpy oracle for the graph version current at its
    submission, zero requests may be lost, and the full transcript
    must replay bit-for-bit on a second identically-seeded server.
    `FUZZ_TRAFFIC_SEEDS` scales the corpus (CI smoke uses fewer)."""
    from repro.serving import AsyncGraphServer, VirtualClock

    rng0 = np.random.default_rng(40_000 + seed)
    n = int(rng0.choice(NS_POWER))
    g = make_power_law(n, int(rng0.integers(2 * n, 4 * n)), seed=seed)
    algos = ["bfs", "sssp", "wcc", "pagerank", "multi_bfs"]
    n_req = 20
    repro = (f"repro: FUZZ_TRAFFIC_SEEDS={seed + 1} python -m pytest "
             f"'tests/test_fuzz_differential.py::test_fuzz_traffic"
             f"[{seed}]' | graph: n={g.n} m={g.m}")

    def run():
        rng = np.random.default_rng(50_000 + seed)
        srv = AsyncGraphServer(
            g, batch=3, tile=TILE, relax_mode="jnp",
            segment_steps=int(rng.integers(1, 5)), cache_capacity=16,
            clock=VirtualClock())
        g_cur, reqs, snaps = g, [], []
        for i in range(n_req):
            if i and i % 7 == 0 and g_cur.m:
                # ⊕-improving reweights + one insert: monotone, so
                # warm-start reuse stays in play across versions
                eu = g_cur.edge_sources()
                idx = rng.choice(g_cur.m, size=min(3, g_cur.m),
                                 replace=False)
                batch = [(int(eu[j]), int(g_cur.indices[j]),
                          float(g_cur.weights[j]) * 0.5) for j in idx]
                batch.append((int(rng.integers(n)),
                              int(rng.integers(n)), 1.0))
                srv.update(batch)
                g_cur = g_cur.apply_updates(batch)
            reqs.append(srv.submit(
                algos[int(rng.integers(len(algos)))], _zipf_src(rng, n)))
            snaps.append(g_cur)
            if rng.random() < 0.3:    # partial progress between submits
                srv.pump()
        srv.drain()
        return srv, reqs, snaps

    srv, reqs, snaps = run()
    assert all(r.done for r in reqs), f"scheduler lost requests; {repro}"
    for r, g_snap in zip(reqs, snaps):
        assert r.ok, f"{r.algo} src={r.src} failed: {r.error!r}; {repro}"
        if ALGEBRAS[r.algo].feature_dim == 1:
            ref = oracle(r.algo, g_snap, r.src)
        else:
            ref, _ = reference.run(r.algo, g_snap, r.src)
        assert ALGEBRAS[r.algo].results_match(r.result, ref), \
            (f"{r.algo} src={r.src} hit={r.cache_hit} "
             f"warm={r.warm_started} diverged; {repro}")

    # deterministic replay: a second identically-seeded run produces
    # the exact same transcript, scheduling decisions included
    _, reqs2, _ = run()
    t1 = [(r.req_id, r.algo, r.src, r.slot, r.admit_window,
           r.queue_wait_s, r.service_s, r.steps, r.cache_hit,
           r.warm_started,
           None if r.result is None else r.result.tobytes())
          for r in reqs]
    t2 = [(r.req_id, r.algo, r.src, r.slot, r.admit_window,
           r.queue_wait_s, r.service_s, r.steps, r.cache_hit,
           r.warm_started,
           None if r.result is None else r.result.tobytes())
          for r in reqs2]
    assert t1 == t2, f"transcript replay diverged; {repro}"
