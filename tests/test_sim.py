"""Cycle simulator: functional correctness vs oracles + metric sanity."""
import numpy as np
import pytest

from repro.core import BFS, SSSP, WCC, FlipArch, compile_mapping, simulate
from repro.graphs import make_road_network, make_synthetic, make_tree, reference


def _check(g, prog, name, src=0, **kw):
    m = compile_mapping(g, effort=0, seed=0)
    r = simulate(m, prog, src=src)
    ref, _ = reference.run(name, g, src)
    a = np.where(np.isinf(r.attrs), -1, r.attrs)
    b = np.where(np.isinf(ref), -1, ref)
    assert np.allclose(a, b), f"{name} mismatch"
    return r


@pytest.mark.parametrize("name,prog", [("bfs", BFS), ("sssp", SSSP),
                                       ("wcc", WCC)])
def test_sim_correct_road_network(name, prog):
    g = make_road_network(96, seed=0, delete_frac=0.7)
    r = _check(g, prog, name, src=5)
    assert r.cycles > 0
    assert r.max_parallelism >= 1


@pytest.mark.parametrize("name,prog", [("bfs", BFS), ("sssp", SSSP)])
def test_sim_correct_synthetic(name, prog):
    g = make_synthetic(128, 384, seed=2)
    _check(g, prog, name, src=7)


def test_sim_tree_root():
    g = make_tree(128, seed=1)
    r = _check(g, BFS, "bfs", src=0)
    # a tree relaxes each edge exactly once
    assert r.edges_relaxed == g.m


def test_sim_data_swapping_multi_slice():
    """Graph larger than on-chip capacity -> slices swap at runtime."""
    g = make_road_network(400, seed=0)       # > 256 capacity
    m = compile_mapping(g, effort=0, seed=0)
    assert m.num_copies() == 2
    r = simulate(m, BFS, src=3)
    ref, _ = reference.bfs(g, 3)
    a = np.where(np.isinf(r.attrs), -1, r.attrs)
    b = np.where(np.isinf(ref), -1, ref)
    assert np.allclose(a, b)
    assert r.swaps > 0                         # swapping actually happened


def test_sim_parallelism_exceeds_one_on_dense_frontier():
    g = make_synthetic(256, 768, seed=0)
    m = compile_mapping(g, effort=0, seed=0)
    r = simulate(m, BFS, src=0)
    assert r.avg_parallelism > 2.0             # data-level parallelism


def test_sim_unreached_vertices_stay_inf():
    # vertex 3 unreachable from 0
    from repro.graphs import Graph
    g = Graph.from_edges(4, [(0, 1), (1, 2), (3, 2)])
    m = compile_mapping(g, effort=0)
    r = simulate(m, BFS, src=0)
    assert np.isinf(r.attrs[3])


def test_farthest_first_layout_no_worse():
    from repro.core import build_tables
    g = make_road_network(128, seed=4)
    m = compile_mapping(g, effort=0, seed=0)
    r_sorted = simulate(m, SSSP, src=2,
                        tables=build_tables(m, SSSP, farthest_first=True))
    r_unsorted = simulate(m, SSSP, src=2,
                          tables=build_tables(m, SSSP,
                                              farthest_first=False))
    ref, _ = reference.sssp(g, 2)
    for r in (r_sorted, r_unsorted):
        a = np.where(np.isinf(r.attrs), -1, r.attrs)
        assert np.allclose(a, np.where(np.isinf(ref), -1, ref))
