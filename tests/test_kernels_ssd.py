"""SSD kernel: chunked vs sequential oracle; Pallas interpret vs ref."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.ssd.ref import ssd_ref, ssd_step_ref
from repro.kernels.ssd.ssd import ssd_pallas

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYP = True
except ImportError:
    HAVE_HYP = False


def _inputs(B, L, H, P, N, seed=0):
    rng = np.random.default_rng(seed)
    return (jnp.asarray(rng.normal(size=(B, L, H, P)), jnp.float32),
            jnp.asarray(rng.uniform(0.01, 0.2, (B, L, H)), jnp.float32),
            jnp.asarray(rng.normal(size=(B, L, N)), jnp.float32),
            jnp.asarray(rng.normal(size=(B, L, N)), jnp.float32),
            jnp.asarray(rng.normal(size=(H,)), jnp.float32),
            jnp.asarray(rng.normal(size=(H,)), jnp.float32))


def _sequential(x, dt, Bm, Cm, Al, D):
    B, L, H, P = x.shape
    h = jnp.zeros((B, H, Bm.shape[-1], P))
    ys = []
    for t in range(L):
        y, h = ssd_step_ref(x[:, t], dt[:, t], Bm[:, t], Cm[:, t], Al, D, h)
        ys.append(y)
    return jnp.stack(ys, axis=1), h


@pytest.mark.parametrize("chunk", [4, 8, 32])
def test_chunked_matches_sequential(chunk):
    x, dt, Bm, Cm, Al, D = _inputs(2, 32, 2, 8, 4)
    y_seq, h_seq = _sequential(x, dt, Bm, Cm, Al, D)
    y, h = ssd_ref(x, dt, Bm, Cm, Al, D, chunk=chunk)
    np.testing.assert_allclose(y, y_seq, atol=1e-4)
    np.testing.assert_allclose(h, h_seq, atol=1e-4)


@pytest.mark.parametrize("shape", [(1, 32, 2, 8, 4), (2, 64, 4, 16, 8),
                                   (1, 128, 1, 32, 16)])
def test_pallas_matches_ref(shape):
    B, L, H, P, N = shape
    x, dt, Bm, Cm, Al, D = _inputs(B, L, H, P, N, seed=7)
    y_ref, h_ref = ssd_ref(x, dt, Bm, Cm, Al, D, chunk=16)
    y, h = ssd_pallas(x, dt, Bm, Cm, Al, D, chunk=16, interpret=True)
    np.testing.assert_allclose(y, y_ref, atol=1e-4)
    np.testing.assert_allclose(h, h_ref, atol=1e-4)


def test_initial_state_carried():
    x, dt, Bm, Cm, Al, D = _inputs(1, 16, 2, 4, 4, seed=3)
    _, h_mid = ssd_ref(x[:, :8], dt[:, :8], Bm[:, :8], Cm[:, :8], Al, D,
                       chunk=4)
    y2, h_end = ssd_ref(x[:, 8:], dt[:, 8:], Bm[:, 8:], Cm[:, 8:], Al, D,
                        chunk=4, h0=h_mid)
    y_full, h_full = ssd_ref(x, dt, Bm, Cm, Al, D, chunk=4)
    np.testing.assert_allclose(y2, y_full[:, 8:], atol=1e-4)
    np.testing.assert_allclose(h_end, h_full, atol=1e-4)


def test_decay_bounds_state():
    """With large dt*A the state forgets: y depends only on recent x."""
    x, dt, Bm, Cm, Al, D = _inputs(1, 32, 1, 4, 4, seed=9)
    Al_big = jnp.full_like(Al, 3.0)     # exp(3) ~ 20 -> strong decay
    dt_big = jnp.full_like(dt, 5.0)
    x2 = x.at[:, :16].set(123.0)        # perturb distant past
    y1, _ = ssd_ref(x, dt_big, Bm, Cm, Al_big, D, chunk=8)
    y2, _ = ssd_ref(x2, dt_big, Bm, Cm, Al_big, D, chunk=8)
    np.testing.assert_allclose(y1[:, -1], y2[:, -1], atol=1e-3)


if HAVE_HYP:
    @given(st.sampled_from([8, 16]), st.sampled_from([1, 2]),
           st.sampled_from([4, 8]), st.integers(0, 2**16))
    @settings(max_examples=10, deadline=None)
    def test_chunk_invariance(L, H, N, seed):
        x, dt, Bm, Cm, Al, D = _inputs(1, L, H, 8, N, seed=seed)
        y1, h1 = ssd_ref(x, dt, Bm, Cm, Al, D, chunk=L)
        y2, h2 = ssd_ref(x, dt, Bm, Cm, Al, D, chunk=max(L // 4, 1))
        np.testing.assert_allclose(y1, y2, atol=1e-4)
        np.testing.assert_allclose(h1, h2, atol=1e-4)
