"""Batched multi-query execution: (B, ntiles, T) equivalence.

`run_batch(srcs)` threads B independent queries through one shared
while_loop fixpoint; every row must be bit-for-bit the corresponding solo
`run(src)` (the per-query convergence mask freezes finished queries), and
must match the numpy oracle, on the jnp fallback and the Pallas-interpret
kernel, in both data and op modes. The serving front-end adds bucketed
dispatch + tail padding on top and must preserve the same guarantee.
"""
import numpy as np
import pytest
from conftest import ALGOS, SRCS8, check_batch as _check_batch

from repro.algebra import ALGEBRAS
from repro.core.engine import FlipEngine
from repro.graphs import make_power_law, make_synthetic, reference
from repro.launch.serve_graph import GraphServer


@pytest.mark.parametrize("mode", ["data", "op"])
@pytest.mark.parametrize("algo", ALGOS)
def test_run_batch_jnp_bitexact(algo, mode):
    g = make_power_law(48, 140, seed=6)
    eng = FlipEngine.build(g, algo, tile=64, mode=mode, relax_mode="jnp")
    _check_batch(eng, g, SRCS8, algo)


@pytest.mark.parametrize("mode", ["data", "op"])
@pytest.mark.parametrize("algo", ALGOS)
def test_run_batch_interpret_kernel_bitexact(algo, mode):
    """Same contract through the Pallas kernel body (interpret mode),
    multi-tile so the batched grid's block/slab bookkeeping is real."""
    g = make_synthetic(24, 70, seed=2)
    eng = FlipEngine.build(g, algo, tile=8, mode=mode,
                           relax_mode="interpret")
    _check_batch(eng, g, SRCS8 % g.n, algo)


def test_run_batch_heterogeneous_convergence():
    """Queries finishing at very different steps: the long-tail query
    keeps relaxing while finished ones stay frozen."""
    g = make_synthetic(60, 130, seed=9)
    eng = FlipEngine.build(g, "sssp", tile=32, relax_mode="jnp")
    outs, steps = eng.run_batch(np.arange(8))
    assert steps.min() >= 1 and len(set(steps.tolist())) > 1
    for b in range(8):
        ref, _ = reference.run("sssp", g, b)
        assert ALGEBRAS["sssp"].results_match(outs[b], ref)


def test_run_batch_single_source_matches_run():
    g = make_synthetic(40, 110, seed=1)
    eng = FlipEngine.build(g, "widest", tile=32, relax_mode="jnp")
    outs, steps = eng.run_batch([7])
    solo, s = eng.run(7)
    np.testing.assert_array_equal(outs[0], solo)
    assert steps[0] == s


# ----------------------------------------------------------------- #
# serving front-end
# ----------------------------------------------------------------- #
def test_graph_server_mixed_stream_matches_oracle():
    """Mixed-algebra stream, tail bucket not a multiple of B: bucketing,
    padding, and the per-algebra engine cache must all be transparent."""
    g = make_power_law(48, 140, seed=4)
    srv = GraphServer(g, batch=4, tile=32, relax_mode="jnp")
    rng = np.random.default_rng(0)
    algos = ["bfs", "pagerank", "widest"]
    stream = [(algos[int(rng.integers(3))], int(rng.integers(g.n)))
              for _ in range(22)]                   # 22 % 4 != 0
    reqs = srv.serve(stream)
    assert [(r.algo, r.src) for r in reqs] == stream    # order preserved
    assert srv.completed == 22
    assert len(srv._engines) == 3                   # one engine per algebra
    for r in reqs:
        assert r.done and r.steps >= 1
        ref, _ = reference.run(r.algo, g, r.src)
        assert ALGEBRAS[r.algo].results_match(r.result, ref), r.algo


def test_graph_server_padding_is_bitexact():
    """A padded tail dispatch returns exactly the solo-run results."""
    g = make_synthetic(40, 110, seed=5)
    srv = GraphServer(g, batch=8, tile=32, relax_mode="jnp")
    reqs = srv.serve([("bfs", 3), ("bfs", 17), ("bfs", 17)])
    assert srv.dispatches == 1
    eng = srv.engine("bfs")
    for r in reqs:
        solo, steps = eng.run(r.src)
        np.testing.assert_array_equal(r.result, solo)
        assert r.steps == steps


def test_graph_server_rejects_unknown_algo():
    g = make_synthetic(20, 40, seed=0)
    srv = GraphServer(g, batch=2)
    with pytest.raises(ValueError, match="unknown algorithm"):
        srv.submit("not_an_algo", 0)
