"""Pallas frontier kernel vs pure-jnp oracle: shape/dtype/tile sweeps."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.algebra import ALGEBRAS
from repro.graphs import Graph, make_road_network, make_synthetic, reference
from repro.kernels.frontier import build_blocks, frontier_relax
from repro.kernels.frontier.frontier import frontier_relax_pallas
from repro.kernels.frontier.ref import relax_step_ref

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYP = True
except ImportError:
    HAVE_HYP = False


def _run_fixpoint(g, algo, src, tile, mode):
    bg = build_blocks(g, algo=algo, tile=tile)
    if algo == "wcc":
        attrs0 = np.arange(g.n, dtype=np.float32)
        fr0 = np.ones(g.n, bool)
    else:
        attrs0 = np.full(g.n, np.inf, np.float32)
        attrs0[src] = 0
        fr0 = np.zeros(g.n, bool)
        fr0[src] = True
    attrs = bg.to_tiled(attrs0)
    fr = np.zeros(bg.padded_n, bool)
    fr[bg.perm[fr0.nonzero()[0]]] = True
    fr = jnp.asarray(fr.reshape(bg.ntiles, bg.tile))
    for _ in range(4 * g.n):
        if not bool(fr.any()):
            break
        sv = jnp.where(fr, attrs, jnp.inf)
        new = frontier_relax(sv, attrs, bg, mode=mode)
        fr = new < attrs
        attrs = new
    return bg.to_orig(attrs)


@pytest.mark.parametrize("algo", ["bfs", "sssp", "wcc"])
@pytest.mark.parametrize("tile", [16, 32, 128])
def test_kernel_interpret_matches_reference(algo, tile):
    g = make_road_network(90, seed=1, delete_frac=0.6)
    src = 4
    out = _run_fixpoint(g, algo, src, tile, mode="interpret")
    ref, _ = reference.run(algo, g, src)
    assert np.allclose(np.where(np.isinf(out), -1, out),
                       np.where(np.isinf(ref), -1, ref))


@pytest.mark.parametrize("algo", ["bfs", "sssp"])
def test_jnp_fallback_matches_interpret(algo):
    g = make_synthetic(70, 200, seed=3)
    a = _run_fixpoint(g, algo, 0, 32, mode="jnp")
    b = _run_fixpoint(g, algo, 0, 32, mode="interpret")
    assert np.allclose(np.where(np.isinf(a), -1, a),
                       np.where(np.isinf(b), -1, b))


def test_single_step_against_dense_oracle():
    g = make_synthetic(60, 180, seed=5)
    bg = build_blocks(g, algo="sssp", tile=16)
    rng = np.random.default_rng(0)
    attrs0 = rng.uniform(0, 10, g.n).astype(np.float32)
    fr0 = rng.random(g.n) < 0.3
    w = g.dense_weights()
    ref_new, _ = relax_step_ref(jnp.asarray(attrs0), jnp.asarray(fr0),
                                jnp.asarray(w))
    attrs = bg.to_tiled(attrs0)
    fr = np.zeros(bg.padded_n, bool)
    fr[bg.perm[fr0.nonzero()[0]]] = True
    sv = jnp.where(jnp.asarray(fr.reshape(bg.ntiles, bg.tile)), attrs,
                   jnp.inf)
    out = frontier_relax(sv, attrs, bg, mode="interpret")
    assert np.allclose(bg.to_orig(out), np.asarray(ref_new), atol=1e-5)


def test_mapping_order_improves_block_sparsity():
    from repro.core import compile_mapping
    from repro.core.engine import mapping_order
    g = make_road_network(256, seed=0)
    rng = np.random.default_rng(0)
    bg_rand = build_blocks(g, "bfs", tile=32,
                           order=rng.permutation(g.n))
    m = compile_mapping(g, effort=1, seed=0)
    bg_mapped = build_blocks(g, "bfs", tile=32, order=mapping_order(m))
    # the FLIP placement concentrates edges into fewer tile pairs than a
    # random vertex order (its routing-length objective == tile locality)
    assert bg_mapped.blocks.shape[0] < bg_rand.blocks.shape[0]


# ------------------------------------------------------------------ #
# edge cases: carry-only destinations and ragged vertex counts
# ------------------------------------------------------------------ #
@pytest.mark.parametrize("mode", ["jnp", "interpret"])
@pytest.mark.parametrize("batched", [False, True])
def test_destination_without_incident_block_keeps_carry(mode, batched):
    """A destination tile no block writes must return its carry verbatim
    (the input_output_aliases path in the Pallas kernel; the segment-⊕
    identity in the jnp fallback)."""
    from repro.algebra import MIN_PLUS
    t, ntiles = 8, 3
    rng = np.random.default_rng(0)
    # one block, writing only dst tile 0 from src tile 2: tiles 1 and 2
    # have no incident block at all
    blocks = jnp.asarray(rng.uniform(1, 5, (1, t, t)).astype(np.float32))
    bsrc = jnp.asarray([2], dtype=jnp.int32)
    bdst = jnp.asarray([0], dtype=jnp.int32)
    sv = rng.uniform(0, 10, (ntiles, t)).astype(np.float32)
    carry = rng.uniform(0, 10, (ntiles, t)).astype(np.float32)
    if batched:
        sv = np.stack([sv, sv + 1.0])
        carry = np.stack([carry, carry + 1.0])
    if mode == "jnp":
        from repro.kernels.frontier.ops import _relax_jnp
        out = _relax_jnp(jnp.asarray(sv), jnp.asarray(carry), blocks,
                         bsrc, bdst, semiring=MIN_PLUS)
    else:
        out = frontier_relax_pallas(jnp.asarray(sv), jnp.asarray(carry),
                                    blocks, bsrc, bdst, semiring=MIN_PLUS,
                                    interpret=True)
    out = np.asarray(out)
    # untouched destination tiles: carry, bit-for-bit
    np.testing.assert_array_equal(out[..., 1:, :], carry[..., 1:, :])
    # the written tile really relaxed
    want = np.minimum(carry[..., 0, :],
                      (sv[..., 2, :, None] + np.asarray(blocks)[0]).min(-2))
    np.testing.assert_allclose(out[..., 0, :], want, atol=1e-6)


@pytest.mark.parametrize("algo", sorted(ALGEBRAS))
def test_to_tiled_round_trip_ragged_n(algo):
    """Vertex counts that are not a multiple of the tile size survive
    to_tiled/to_orig for every registered algebra, solo and batched."""
    g = make_synthetic(37, 100, seed=8)           # 37 = 2*16 + 5
    bg = build_blocks(g, algo, tile=16)
    assert bg.padded_n > g.n                      # padding actually exists
    rng = np.random.default_rng(1)
    vec = rng.uniform(0.5, 9, g.n).astype(np.float32)
    np.testing.assert_array_equal(bg.to_orig(bg.to_tiled(vec)), vec)
    batch = rng.uniform(0.5, 9, (5, g.n)).astype(np.float32)
    tiled = bg.to_tiled(batch)
    assert tiled.shape == (5, bg.ntiles, bg.tile)
    np.testing.assert_array_equal(bg.to_orig(tiled), batch)
    # padded lanes hold the ⊕-identity, so they can never win a merge
    flat = np.asarray(tiled).reshape(5, -1)
    pad_lanes = np.setdiff1d(np.arange(bg.padded_n), bg.perm)
    assert np.all(flat[:, pad_lanes] ==
                  np.float32(ALGEBRAS[algo].semiring.zero))


if HAVE_HYP:
    @given(st.integers(8, 48), st.integers(0, 100),
           st.sampled_from([8, 16, 32]))
    @settings(max_examples=10, deadline=None)
    def test_step_invariants(n, seed, tile):
        """One relax step never increases any attribute (min-semiring)."""
        g = make_synthetic(n, 2 * n, seed=seed)
        bg = build_blocks(g, "sssp", tile=tile)
        rng = np.random.default_rng(seed)
        attrs0 = rng.uniform(0, 5, n).astype(np.float32)
        attrs = bg.to_tiled(attrs0)
        sv = attrs  # everything active
        out = frontier_relax(sv, attrs, bg, mode="jnp")
        assert bool((out <= attrs + 1e-6).all())
