"""Graph substrate: generators (Table 4 ranges), CSR invariants, oracles."""
import numpy as np
import pytest

from repro.graphs import (Graph, make_dataset, make_road_network, make_tree,
                          make_synthetic, reference)

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYP = True
except ImportError:
    HAVE_HYP = False


def test_table4_ranges():
    for g in make_dataset("Tree", 5):
        assert g.n == 256 and g.m == 255
    for g in make_dataset("SRN", 5):
        assert 64 <= g.n <= 107 and 146 <= g.m <= 278
    for g in make_dataset("LRN", 5):
        assert g.n == 256 and 584 <= g.m <= 898
    for g in make_dataset("Syn", 5):
        assert g.n == 256 and g.m == 768


def test_road_network_connected():
    for seed in range(5):
        assert make_road_network(128, seed=seed).is_connected()


def test_csr_roundtrip():
    g = Graph.from_edges(4, [(0, 1), (1, 2), (2, 3), (0, 3)],
                         [1.0, 2.0, 3.0, 4.0])
    assert g.n == 4 and g.m == 4
    assert list(g.neighbors(0)) == [1, 3]
    assert g.edge_weights(0).tolist() == [1.0, 4.0]
    rev = g.reverse()
    assert list(rev.neighbors(1)) == [0]


def test_undirected_half_edges():
    g = Graph.from_edges(3, [(0, 1), (1, 2)], directed=False)
    assert g.m == 4  # both half-edges stored


def test_bfs_oracle_line_graph():
    g = Graph.from_edges(4, [(0, 1), (1, 2), (2, 3)])
    lv, _ = reference.bfs(g, 0)
    assert lv.tolist() == [0, 1, 2, 3]


def test_sssp_oracle_vs_bfs_unit_weights():
    g = make_road_network(100, seed=3)
    g_unit = Graph.from_edges(g.n, [(u, v) for u, v, _ in g.edge_list()],
                              [1.0] * g.m)
    d, _ = reference.sssp(g_unit, 0)
    lv, _ = reference.bfs(g_unit, 0)
    assert np.allclose(d, lv)


def test_wcc_oracle_components():
    # two disjoint triangles
    g = Graph.from_edges(6, [(0, 1), (1, 2), (2, 0), (3, 4), (4, 5),
                             (5, 3)])
    lab, _ = reference.wcc(g)
    assert lab.tolist() == [0, 0, 0, 3, 3, 3]


def test_center_vertex_path_graph():
    g = Graph.from_edges(5, [(i, i + 1) for i in range(4)], directed=False)
    assert g.center_vertex() == 2


if HAVE_HYP:
    @given(st.integers(10, 80), st.integers(0, 10_000))
    @settings(max_examples=20, deadline=None)
    def test_generator_invariants(n, seed):
        g = make_synthetic(n, min(2 * n, n * (n - 1) // 2), seed=seed)
        assert g.indptr[0] == 0 and g.indptr[-1] == g.m
        assert (np.diff(g.indptr) >= 0).all()
        assert (g.indices >= 0).all() and (g.indices < g.n).all()
        assert (g.weights >= 1).all()

    @given(st.integers(16, 64), st.integers(0, 1000))
    @settings(max_examples=15, deadline=None)
    def test_sssp_triangle_inequality(n, seed):
        g = make_road_network(n, seed=seed)
        d, _ = reference.sssp(g, 0)
        for u, v, w in g.edge_list():
            if np.isfinite(d[u]):
                assert d[v] <= d[u] + w + 1e-5
