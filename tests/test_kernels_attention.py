"""Flash attention Pallas kernel vs oracle: shape/dtype/mask sweeps."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.attention.ops import flash_attention
from repro.kernels.attention.ref import attention_ref

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYP = True
except ImportError:
    HAVE_HYP = False


def _rand(shape, dtype, seed=0):
    return jnp.asarray(
        np.random.default_rng(seed).normal(size=shape), dtype)


@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("kh", [1, 2, 4])
def test_flash_gqa_sweep(causal, kh):
    B, S, H, hd = 2, 256, 4, 32
    q = _rand((B, S, H, hd), jnp.float32, 1)
    k = _rand((B, S, kh, hd), jnp.float32, 2)
    v = _rand((B, S, kh, hd), jnp.float32, 3)
    out = flash_attention(q, k, v, causal=causal, interpret=True,
                          bq=128, bkv=128)
    ref = attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(out, ref, atol=2e-5)


@pytest.mark.parametrize("window", [32, 64, 128])
def test_flash_sliding_window(window):
    B, S, H, hd = 1, 256, 2, 64
    q = _rand((B, S, H, hd), jnp.float32, 4)
    k = _rand((B, S, H, hd), jnp.float32, 5)
    v = _rand((B, S, H, hd), jnp.float32, 6)
    out = flash_attention(q, k, v, causal=True, window=window,
                          interpret=True, bq=64, bkv=64)
    ref = attention_ref(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(out, ref, atol=2e-5)


@pytest.mark.parametrize("dtype,atol", [(jnp.float32, 2e-5),
                                        (jnp.bfloat16, 2e-2)])
def test_flash_dtypes(dtype, atol):
    B, S, H, hd = 1, 128, 2, 64
    q, k, v = (_rand((B, S, H, hd), dtype, s) for s in (7, 8, 9))
    out = flash_attention(q, k, v, causal=True, interpret=True,
                          bq=64, bkv=64)
    ref = attention_ref(q.astype(jnp.float32), k.astype(jnp.float32),
                        v.astype(jnp.float32), causal=True)
    np.testing.assert_allclose(out.astype(jnp.float32), ref, atol=atol)


def test_flash_head_dims():
    for hd in (16, 128, 256):
        q, k, v = (_rand((1, 128, 2, hd), jnp.float32, s)
                   for s in (10, 11, 12))
        out = flash_attention(q, k, v, causal=True, interpret=True,
                              bq=64, bkv=64)
        ref = attention_ref(q, k, v, causal=True)
        np.testing.assert_allclose(out, ref, atol=3e-5)


def test_lax_flash_matches_plain_and_pallas():
    from repro.models.attention import attend
    q, k, v = (_rand((2, 512, 4, 32), jnp.float32, s) for s in (1, 2, 3))
    k = k[:, :, :2]
    v = v[:, :, :2]
    o_plain = attend(q, k, v, True, None, impl="plain")
    o_lax = attend(q, k, v, True, None, impl="lax_flash")
    o_pl = attend(q, k, v, True, None, impl="pallas_interpret")
    np.testing.assert_allclose(o_plain, o_lax, atol=2e-5)
    np.testing.assert_allclose(o_plain, o_pl, atol=2e-5)


if HAVE_HYP:
    @given(st.sampled_from([64, 128]), st.sampled_from([1, 2]),
           st.sampled_from([16, 32]), st.booleans(),
           st.integers(0, 2**16))
    @settings(max_examples=10, deadline=None)
    def test_flash_property(s, kh, hd, causal, seed):
        q = _rand((1, s, 2, hd), jnp.float32, seed)
        k = _rand((1, s, kh, hd), jnp.float32, seed + 1)
        v = _rand((1, s, kh, hd), jnp.float32, seed + 2)
        out = flash_attention(q, k, v, causal=causal, interpret=True,
                              bq=64, bkv=64)
        ref = attention_ref(q, k, v, causal=causal)
        np.testing.assert_allclose(out, ref, atol=3e-5)
