"""Plan-autotuner suite: determinism, legality, bit-exactness, store.

The tuner's contract has four load-bearing faces, each with its own
test group here:

  * determinism -- the same (graph, program, base, seed) tunes to the
    same plan in model-only mode, and the profile fingerprint is a
    stable content hash (same shape = same key, any change = new key);
  * legality -- every candidate the sweep can emit has already passed
    `ExecutionPlan.resolve()`, base plan first;
  * bit-exactness -- tuning is policy, never semantics: a tuned
    session's attrs are bit-for-bit the default session's, across all
    scalar algebras, a vector algebra, and both CPU kernel dispatches;
  * store -- entries round-trip, stale fingerprints / schema drift /
    corrupt files are all misses, writes are atomic.
"""
import dataclasses
import json
import os

import numpy as np
import pytest

from conftest import ALGOS, VEC_ALGOS, assert_close, oracle

import flip
from repro.api.plan import ExecutionPlan
from repro.autotune import (CostModel, Sample, TuningStore,
                            analytic_step_us, autotune, candidate_plans,
                            load_bench_samples, measure_plan,
                            price_candidate, profile_graph,
                            resolve_tuned)
from repro.autotune import store as store_mod
from repro.autotune.model import features_of
from repro.autotune.profile import DEGREE_BUCKETS, PROBE_STEPS
from repro.graphs import make_power_law, make_road_network


@pytest.fixture
def g():
    return make_power_law(256, 768, seed=0)


@pytest.fixture
def tmp_store(tmp_path):
    return TuningStore(str(tmp_path / "autotune.json"))


# ------------------------------------------------------------------ #
# profile
# ------------------------------------------------------------------ #
class TestProfile:
    def test_shape_fields(self, g):
        p = profile_graph(g, feature_dim=1, backend="cpu",
                          device_kind="cpu")
        assert (p.n, p.m) == (g.n, g.m)
        assert len(p.degree_hist) == DEGREE_BUCKETS
        assert sum(p.degree_hist) == g.n
        assert 0 < len(p.density_trajectory) <= PROBE_STEPS
        assert all(0.0 <= x <= 1.0 for x in p.density_trajectory)
        assert 0.0 < p.mean_density <= p.peak_density <= 1.0

    def test_fingerprint_stable_and_sensitive(self, g):
        kw = dict(feature_dim=1, backend="cpu", device_kind="cpu")
        fp = profile_graph(g, **kw).fingerprint()
        # same shape -> same key (profiled twice)
        assert profile_graph(g, **kw).fingerprint() == fp
        # any input change -> new key
        assert profile_graph(make_power_law(256, 768, seed=1),
                             **kw).fingerprint() != fp
        assert profile_graph(g, feature_dim=8, backend="cpu",
                             device_kind="cpu").fingerprint() != fp
        assert profile_graph(g, feature_dim=1, backend="tpu",
                             device_kind="TPU v4").fingerprint() != fp

    def test_trajectory_separates_topologies(self):
        """A hub-heavy power-law graph densifies faster than a road
        network -- that separation is the whole point of probing."""
        pl = profile_graph(make_power_law(512, 2048, seed=0),
                           backend="cpu", device_kind="cpu")
        rd = profile_graph(make_road_network(512, seed=0),
                           backend="cpu", device_kind="cpu")
        assert pl.peak_density > rd.peak_density

    def test_empty_graph(self):
        from repro.graphs.csr import Graph
        g0 = Graph.from_edges(0, [])
        p = profile_graph(g0, backend="cpu", device_kind="cpu")
        assert p.density_trajectory == ()
        assert p.mean_density == 1.0
        assert p.fingerprint()

    def test_to_json_roundtrips_fingerprint(self, g):
        p = profile_graph(g, backend="cpu", device_kind="cpu")
        j = json.loads(json.dumps(p.to_json()))
        assert j["fingerprint"] == p.fingerprint()
        assert j["n"] == g.n


# ------------------------------------------------------------------ #
# space
# ------------------------------------------------------------------ #
class TestSpace:
    def test_every_candidate_resolves(self, g):
        """The sweep's legality invariant: resolve() accepts every
        emitted candidate (resolve is idempotent on resolved plans)."""
        base = ExecutionPlan().resolve()
        for c in candidate_plans(base, backend="cpu"):
            r = c.plan.resolve()
            assert r.key() == c.plan.key()
            assert r.relax_mode != "auto" and r.compact in (True, False)
            assert not r.tuned

    def test_base_plan_leads(self, g):
        base = ExecutionPlan(tile=128).resolve()
        cands = candidate_plans(base, backend="cpu")
        assert cands[0].plan.key() == base.key()

    def test_illegal_combos_pruned(self):
        # op mode: compact=True is rejected by the validator, so the
        # space must only emit compact=False op candidates
        base = ExecutionPlan(mode="op", compact=False).resolve()
        cands = candidate_plans(base, backend="cpu")
        assert cands
        assert all(not c.plan.compact for c in cands)
        # pallas is TPU-only: never emitted for a cpu backend
        assert all(c.plan.relax_mode != "pallas" for c in cands)

    def test_semantic_knobs_never_vary(self):
        base = ExecutionPlan(mode="op", compact=False, warm="never",
                             feature_dim=4).resolve()
        for c in candidate_plans(base, backend="cpu"):
            assert c.plan.mode == "op"
            assert c.plan.warm == "never"
            assert c.plan.feature_dim == 4

    def test_non_idempotent_algebra_freezes_regrouping_knobs(self):
        """pagerank/labelprop's float + reassociates under re-tiling
        and dispatch swaps: for those algebras the sweep must hold
        tile/relax_mode at base and vary only compact/batch."""
        from repro.algebra import ALGEBRAS
        alg = ALGEBRAS["pagerank"]
        base = ExecutionPlan().resolve(alg)
        cands = candidate_plans(base, alg, backend="cpu")
        assert {c.plan.tile for c in cands} == {base.tile}
        assert {c.plan.relax_mode for c in cands} == {base.relax_mode}
        assert {c.plan.compact for c in cands} == {True, False}

    def test_interpret_is_analytic_only(self):
        cands = candidate_plans(ExecutionPlan().resolve(),
                                backend="cpu")
        by_mode = {c.plan.relax_mode: c.measure_ok for c in cands}
        assert by_mode["jnp"] is True
        assert by_mode["interpret"] is False

    def test_batch_candidates_follow_base(self):
        solo = candidate_plans(ExecutionPlan().resolve(), backend="cpu")
        assert {c.plan.batch for c in solo} == {0}
        served = candidate_plans(ExecutionPlan(batch=8).resolve(),
                                 backend="cpu")
        assert {c.plan.batch for c in served} == {4, 8, 16}


# ------------------------------------------------------------------ #
# measure + model
# ------------------------------------------------------------------ #
class TestPricing:
    def test_measured_sample(self, g):
        plan = ExecutionPlan(tile=64).resolve()
        s = measure_plan(g, "bfs", plan, seed=0, repeats=1,
                         segment_steps=4)
        assert s.source == "measured"
        assert s.step_us > 0 and s.steps > 0 and s.wall_s > 0
        assert s.to_json()["tile"] == 64

    def test_analytic_ordering(self, g):
        """The bridge's ordinal contract: interpret >> jnp, and dense
        streaming >= compacted at a sparse frontier."""
        p = dataclasses.replace(
            profile_graph(g, backend="cpu", device_kind="cpu"),
            density_trajectory=(0.01,))
        base = ExecutionPlan().resolve()
        jnp_c = analytic_step_us(p, base)
        interp = analytic_step_us(
            p, dataclasses.replace(base, relax_mode="interpret"))
        dense = analytic_step_us(
            p, dataclasses.replace(base, compact=False))
        assert interp > 100 * jnp_c
        assert dense >= jnp_c

    def test_budget_gate_falls_back_to_analytic(self, g):
        p = profile_graph(g, backend="cpu", device_kind="cpu")
        s = price_candidate(g, "bfs", ExecutionPlan().resolve(), p,
                            measure_ok=True, budget_s=0.0)
        assert s.source == "analytic"
        assert s.step_us == pytest.approx(
            analytic_step_us(p, ExecutionPlan().resolve()))

    def test_model_fit_and_predict(self, g):
        p = profile_graph(g, backend="cpu", device_kind="cpu")
        base = ExecutionPlan().resolve()
        # synthesize a perfectly linear backend so the fit is checkable
        plans = [dataclasses.replace(base, tile=t, compact=c)
                 for t in (64, 128, 256) for c in (True, False)]
        true_coef = np.array([5.0, 2.0, 1e-4])
        samples = [
            Sample(plan=pl,
                   step_us=float(features_of(p, pl) @ true_coef),
                   steps=4, wall_s=1e-3, source="measured")
            for pl in plans]
        model = CostModel.fit(samples, p)
        assert model.n_samples == len(samples)
        got = model.predict(p, plans[0])
        assert got == pytest.approx(samples[0].step_us, rel=1e-6)
        # a backend the fit never saw falls back to the analytic bridge
        interp = dataclasses.replace(base, relax_mode="interpret")
        assert model.predict(p, interp) == pytest.approx(
            analytic_step_us(p, interp))

    def test_fit_excludes_analytic_samples(self, g):
        p = profile_graph(g, backend="cpu", device_kind="cpu")
        base = ExecutionPlan().resolve()
        samples = [Sample(plan=base, step_us=1.0, steps=0, wall_s=0.0,
                          source="analytic")] * 5
        assert CostModel.fit(samples, p).coef == {}

    def test_load_bench_samples(self, tmp_path):
        path = tmp_path / "BENCH_x.json"
        path.write_text(json.dumps({"tag": "x", "runs": [{"rows": [
            {"name": "feature_step_min_plus_2k_d8", "us_per_call": 512.3,
             "derived": "power-law |V|=2048 blocks=519 d=8"},
            {"name": "frontier_step_dense_1pct", "us_per_call": 80.0,
             "derived": "power-law |V|=2048 blocks=519"},
            {"name": "not_a_step_row", "us_per_call": 3.0,
             "derived": "blocks=9"},
            {"name": "feature_step_no_blocks", "us_per_call": 3.0,
             "derived": "d=8"},
        ]}]}))
        samples = load_bench_samples([str(path)])
        assert len(samples) == 2
        assert all(s.source == "measured" and s.features is not None
                   for s in samples)
        assert samples[0].features[1] == 519
        # missing / corrupt files contribute nothing, never raise
        assert load_bench_samples([str(tmp_path / "nope.json")]) == []
        bad = tmp_path / "BENCH_bad.json"
        bad.write_text("{corrupt")
        assert load_bench_samples([str(bad)]) == []


# ------------------------------------------------------------------ #
# tuner: determinism + selection
# ------------------------------------------------------------------ #
class TestTuner:
    def test_model_only_tune_is_deterministic(self, g, tmp_path):
        """Same profile + same seed -> identical chosen plan. Two
        independent tunes, separate stores, no wall clocks anywhere."""
        reports = [
            autotune(g, "bfs", seed=7,
                     store=TuningStore(str(tmp_path / f"s{i}.json")),
                     measure=False, bench_history=False)
            for i in range(2)]
        assert reports[0].chosen.key() == reports[1].chosen.key()
        assert [s.to_json() for s in reports[0].samples] == \
               [s.to_json() for s in reports[1].samples]
        assert not reports[0].cached and reports[0].samples

    def test_chosen_is_argmin_with_default_tiebreak(self, g, tmp_store):
        rep = autotune(g, "bfs", store=tmp_store, measure=False,
                       bench_history=False)
        scores = list(rep.scores.values())
        best = min(scores)
        assert rep.scores[rep.chosen.key()] <= best * 1.02
        # every candidate in the table resolved (keys are resolved keys)
        assert len(rep.scores) == len(rep.samples)

    def test_store_hit_roundtrip(self, g, tmp_store):
        rep1 = autotune(g, "bfs", store=tmp_store, measure=False)
        rep2 = autotune(g, "bfs", store=tmp_store, measure=False)
        assert not rep1.cached and rep2.cached
        assert rep2.chosen.key() == rep1.chosen.key()
        assert rep2.samples == []
        # force re-sweeps anyway
        rep3 = autotune(g, "bfs", store=tmp_store, measure=False,
                        force=True)
        assert not rep3.cached and rep3.samples

    def test_tune_report_json(self, g, tmp_store):
        rep = autotune(g, "bfs", store=tmp_store, measure=False)
        j = json.loads(json.dumps(rep.to_json()))
        assert j["chosen"]["tile"] in (64, 128, 256)
        assert j["why"] and not j["cached"]

    def test_resolve_tuned_clears_flag(self, g, tmp_store):
        plan, rep = resolve_tuned(
            g, "bfs", ExecutionPlan.auto(tuned=True), store=tmp_store)
        assert not plan.tuned
        assert plan.key() == rep.chosen.key()
        assert plan.relax_mode in ("jnp", "interpret")


# ------------------------------------------------------------------ #
# bit-exactness: tuning is policy, never semantics
# ------------------------------------------------------------------ #
class TestBitExact:
    @pytest.mark.parametrize("algo", ALGOS)
    def test_scalar_algebras(self, algo, g, tmp_store):
        """Tuned session attrs == default session attrs, bit for bit,
        and both match the oracle."""
        cq_tuned = flip.compile(g, algo,
                                ExecutionPlan.auto(tuned=True),
                                store=tmp_store)
        cq_def = flip.compile(g, algo)
        rt = cq_tuned.query(3)
        rd = cq_def.query(3)
        np.testing.assert_array_equal(np.asarray(rt.attrs),
                                      np.asarray(rd.attrs))
        assert_close(rt.attrs, oracle(algo, g, 3), algo)

    @pytest.mark.parametrize("algo", VEC_ALGOS[:1])
    def test_vector_algebra(self, algo, g, tmp_store):
        cq_tuned = flip.compile(g, algo,
                                ExecutionPlan.auto(tuned=True),
                                store=tmp_store)
        rt = cq_tuned.query(3)
        rd = flip.compile(g, algo).query(3)
        np.testing.assert_array_equal(np.asarray(rt.attrs),
                                      np.asarray(rd.attrs))
        assert cq_tuned.plan.feature_dim > 1

    @pytest.mark.parametrize("relax", ["jnp", "interpret"])
    def test_every_candidate_matches_default(self, relax, g):
        """Not just the chosen plan: every plan the space can emit at
        this dispatch mode is bit-exact with the default."""
        r0 = flip.compile(g, "bfs").query(5)
        cands = [c for c in candidate_plans(ExecutionPlan().resolve(),
                                            backend="cpu")
                 if c.plan.relax_mode == relax]
        assert cands
        # interpret is ~1000x slower: one candidate proves the point
        for c in (cands if relax == "jnp" else cands[:1]):
            r = flip.compile(g, "bfs", c.plan).query(5)
            np.testing.assert_array_equal(
                np.asarray(r.attrs), np.asarray(r0.attrs),
                err_msg=str(c.plan.key()))

    def test_batched_tuned_session(self, g, tmp_store):
        base = ExecutionPlan.auto(tuned=True, batch=4)
        cq = flip.compile(g, "sssp", base, store=tmp_store)
        srcs = np.array([3, 11, 0, 27, 42, 8])
        rt = cq.query(srcs)
        rd = flip.compile(g, "sssp").query(srcs)
        np.testing.assert_array_equal(np.asarray(rt.attrs),
                                      np.asarray(rd.attrs))

    def test_telemetry_carries_tuner_provenance(self, g, tmp_store):
        cq = flip.compile(g, "bfs", ExecutionPlan.auto(tuned=True),
                          store=tmp_store)
        r = cq.query(3, trace=True)
        meta = r.telemetry.dispatches[0].meta["autotune"]
        assert meta["chosen"]["tile"] == cq.plan.tile
        assert meta["why"] == cq.tune.why
        assert meta["fingerprint"] == cq.tune.profile.fingerprint()
        # untuned sessions stamp nothing
        r0 = flip.compile(g, "bfs").query(3, trace=True)
        assert "autotune" not in r0.telemetry.dispatches[0].meta


# ------------------------------------------------------------------ #
# store
# ------------------------------------------------------------------ #
class TestStore:
    def test_roundtrip(self, tmp_store):
        e = tmp_store.put("fp1", "bfs", "cpu",
                          {"tile": 256, "relax_mode": "jnp",
                           "compact": True, "batch": 0},
                          score_us=12.5, seed=3, why="test")
        got = tmp_store.get("fp1", "bfs", "cpu")
        assert got["plan"]["tile"] == 256
        assert got["seed"] == 3 and got["why"] == "test"
        assert e["schema"] == store_mod.SCHEMA
        assert len(tmp_store) == 1

    def test_stale_fingerprint_rejected(self, tmp_store):
        tmp_store.put("fp1", "bfs", "cpu", {"tile": 64},
                      score_us=1.0, seed=0)
        assert tmp_store.get("fp2", "bfs", "cpu") is None
        assert tmp_store.get("fp1", "sssp", "cpu") is None
        assert tmp_store.get("fp1", "bfs", "tpu") is None

    def test_schema_drift_rejected(self, tmp_store):
        tmp_store.put("fp1", "bfs", "cpu", {"tile": 64},
                      score_us=1.0, seed=0)
        with open(tmp_store.path) as f:
            data = json.load(f)
        key = TuningStore.key("fp1", "bfs", "cpu")
        data["entries"][key]["schema"] = store_mod.SCHEMA + 1
        with open(tmp_store.path, "w") as f:
            json.dump(data, f)
        assert tmp_store.get("fp1", "bfs", "cpu") is None

    def test_corrupt_store_is_empty(self, tmp_path):
        p = tmp_path / "db.json"
        p.write_text("{not json")
        s = TuningStore(str(p))
        assert len(s) == 0
        assert s.get("fp", "bfs", "cpu") is None
        # and a put over the corpse rewrites cleanly
        s.put("fp", "bfs", "cpu", {"tile": 64}, score_us=1.0, seed=0)
        assert s.get("fp", "bfs", "cpu") is not None

    def test_default_path_env(self, tmp_path, monkeypatch):
        monkeypatch.setenv("FLIP_AUTOTUNE_DB", str(tmp_path / "e.json"))
        assert TuningStore().path == str(tmp_path / "e.json")
        monkeypatch.delenv("FLIP_AUTOTUNE_DB")
        assert TuningStore().path.endswith(
            os.path.join(".cache", "flip", "autotune.json"))

    def test_stored_knobs_cannot_change_semantics(self, g, tmp_store):
        """A stored entry rehydrates performance knobs only: a
        hand-edited entry with extra keys cannot flip mode/warm, and a
        combo that no longer resolves falls back to a fresh sweep."""
        p = profile_graph(g, backend="cpu", device_kind="cpu")
        fp = p.fingerprint()
        tmp_store.put(fp, "bfs", "cpu",
                      {"tile": 64, "relax_mode": "jnp", "compact": True,
                       "batch": 0, "mode": "op", "warm": "never"},
                      score_us=1.0, seed=0)
        rep = autotune(g, "bfs", store=tmp_store, measure=False)
        assert rep.cached
        assert rep.chosen.mode == "data"      # smuggled key ignored
        assert rep.chosen.warm == "auto"
        # a stored combo the validator now rejects = miss, fresh sweep
        tmp_store.put(fp, "bfs", "cpu",
                      {"tile": 64, "relax_mode": "pallas"},
                      score_us=1.0, seed=0)
        rep2 = autotune(g, "bfs", store=tmp_store, measure=False)
        assert not rep2.cached and rep2.samples


# ------------------------------------------------------------------ #
# plan surface
# ------------------------------------------------------------------ #
class TestPlanSurface:
    def test_tuned_flag_validation(self):
        with pytest.raises(ValueError, match="tuned"):
            ExecutionPlan(tuned="yes").validate()
        with pytest.raises(ValueError, match="distributed"):
            ExecutionPlan(tuned=True, distributed=True).validate()

    def test_resolve_leaves_tuned_in_place(self):
        # resolve() alone has no graph to tune against
        assert ExecutionPlan(tuned=True).resolve().tuned

    def test_tuned_in_key(self):
        assert ExecutionPlan(tuned=True).key() != ExecutionPlan().key()
