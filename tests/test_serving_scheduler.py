"""Continuous-batching scheduler: replayable, bit-exact, cache-coherent.

The contract under test (docs/SERVING.md):

  * ROTATION IS INVISIBLE: every query served through the rotating
    batch -- under any admission interleaving, any segment length K,
    retire-and-refill mid-fixpoint, idle lanes all around -- returns
    bit-for-bit the solo `query(src)` result (attrs AND step count),
    across every algebra, scalar and vector state, jnp and interpret
    relax paths;
  * SCHEDULING IS REPLAYABLE: under a `VirtualClock` the full request
    transcript (slots, admission windows, waits, latencies, outcomes)
    is a pure function of the submission sequence -- two runs agree
    exactly, no sleeps anywhere;
  * THE CACHE IS COHERENT: hits are bit-identical to the cold query
    they short-circuit, entries for superseded graph fingerprints are
    structurally unreachable, the LRU bound holds, and warm-start reuse
    across one update step is exact (and refused beyond one step);
  * SLOs ARE ENFORCED ON THE SCHEDULER'S CLOCK: queue wait consumes the
    deadline (expiry in queue = typed error, no work); mid-fixpoint
    expiry retires a flagged partial at a window boundary without
    disturbing the other lanes; admission control sheds newest with a
    typed error; zero requests are ever lost.
"""
import numpy as np
import pytest
from conftest import ALGOS, VEC_ALGOS, oracle

import flip
from repro.algebra import ALGEBRAS
from repro.api import ExecutionPlan
from repro.graphs import make_power_law
from repro.resilience import (CapacityExceeded, ConvergenceFailure,
                              DeadlineExceeded, InvalidRequest)
from repro.serving import (AsyncGraphServer, ResultCache, ServeRequest,
                           VirtualClock)

TILE = 16
SRCS = [3, 11, 0, 27, 42, 8, 19]


@pytest.fixture(scope="module")
def g():
    return make_power_law(60, 180, seed=3)


def server(g, **kw):
    kw.setdefault("tile", TILE)
    kw.setdefault("relax_mode", "jnp")
    kw.setdefault("clock", VirtualClock())
    return AsyncGraphServer(g, **kw)


_SOLO = {}


def solo(g, algo, src, **query_kw):
    """Reference solo query, sessions cached per (graph, algo)."""
    key = (g.fingerprint(), algo)
    cq = _SOLO.get(key)
    if cq is None:
        cq = _SOLO[key] = flip.compile(
            g, algo, ExecutionPlan(tile=TILE, relax_mode="jnp"))
    return cq.query(int(src), **query_kw)


def transcript(reqs):
    """The full observable outcome of a request sequence."""
    return [(r.req_id, r.algo, r.src, r.slot, r.admit_window,
             r.queue_wait_s, r.service_s, r.steps, r.cache_hit,
             r.warm_started, r.converged,
             None if r.error is None else r.error.code,
             None if r.result is None else r.result.tobytes())
            for r in reqs]


# ------------------------------------------------------------------ #
# rotation is invisible: bit-exact vs solo, everywhere
# ------------------------------------------------------------------ #
@pytest.mark.parametrize("algo", ALGOS + VEC_ALGOS)
def test_rotation_bit_exact(g, algo):
    """B=3 lanes serving 7 queries: four retire-and-refill rotations,
    every result and step count bit-for-bit the solo run (cache off, so
    every request crosses the rotating batch)."""
    srv = server(g, batch=3, segment_steps=2, cache_capacity=0)
    reqs = [srv.submit(algo, s) for s in SRCS]
    srv.drain()
    for r in reqs:
        assert r.ok, (algo, r.src, r.error)
        ref = solo(g, algo, r.src)
        np.testing.assert_array_equal(r.result, np.asarray(ref.attrs))
        assert r.steps == int(ref.steps)
        if ALGEBRAS[algo].feature_dim == 1:
            assert ALGEBRAS[algo].results_match(
                r.result, oracle(algo, g, r.src))


@pytest.mark.parametrize("algo", ["bfs", "sssp", "labelprop"])
def test_rotation_bit_exact_interpret(g, algo):
    """The interpret relax path rotates identically: same kernel body
    as the compiled Pallas path, same bit-exact contract."""
    srv = server(g, batch=2, segment_steps=3, cache_capacity=0,
                 relax_mode="interpret")
    reqs = [srv.submit(algo, s) for s in SRCS[:4]]
    srv.drain()
    for r in reqs:
        assert r.ok, (algo, r.src, r.error)
        ref = solo(g, algo, r.src)      # jnp reference: exact across
        np.testing.assert_array_equal(  # relax backends
            r.result, np.asarray(ref.attrs))
        assert r.steps == int(ref.steps)


def test_retire_and_refill_mid_fixpoint(g):
    """Fast queries retire out of lanes while a slow one keeps
    relaxing; refilled lanes join the warm batch mid-fixpoint and
    nobody's result is disturbed. Sources 3/11 converge in one step,
    27/42 take several -- with B=2 and K=1 the fast lane turns over
    multiple queries before the slow lane retires."""
    srv = server(g, batch=2, segment_steps=1, cache_capacity=0)
    reqs = [srv.submit("bfs", s) for s in [27, 3, 11, 0, 42]]
    srv.drain()
    for r in reqs:
        assert r.ok, (r.src, r.error)
        ref = solo(g, "bfs", r.src)
        np.testing.assert_array_equal(r.result, np.asarray(ref.attrs))
        assert r.steps == int(ref.steps)
    # the later queries were admitted into lanes mid-run (window > 0),
    # i.e. genuine rotation, not sequential buckets
    assert max(r.admit_window for r in reqs) > 0
    assert {r.slot for r in reqs} <= {0, 1}


def test_segment_length_is_policy_not_semantics(g):
    """K only decides WHEN retirement happens; the results, step
    counts, and outcomes are identical at every K."""
    outcomes = []
    for k in (1, 2, 3, 7):
        srv = server(g, batch=3, segment_steps=k, cache_capacity=0)
        reqs = [srv.submit("sssp", s) for s in SRCS]
        srv.drain()
        outcomes.append([(r.src, r.steps, r.converged,
                          r.result.tobytes()) for r in reqs])
    for other in outcomes[1:]:
        assert other == outcomes[0]


def test_empty_queue_idle(g):
    """An empty pump is a no-op: no windows run, the virtual clock does
    not move, and the scheduler reports zero pending."""
    clock = VirtualClock()
    srv = server(g, batch=2, clock=clock)
    assert srv.pump() == 0
    assert srv.pump() == 0
    assert clock.now() == 0.0
    assert srv.windows == 0
    srv.drain()                      # drain on empty is also a no-op
    # a single query amid idle lanes is still exact
    r = srv.submit("bfs", 27)
    srv.drain()
    np.testing.assert_array_equal(
        r.result, np.asarray(solo(g, "bfs", 27).attrs))
    assert srv.stats()["queue_depth"] == 0


def test_replay_determinism(g):
    """The whole transcript -- slots, admission windows, waits,
    service times, outcomes, result bytes -- replays bit-for-bit
    across independent server instances."""
    stream = [("bfs", 3), ("sssp", 9), ("bfs", 27), ("bfs", 3),
              ("sssp", 42), ("wcc", 0), ("bfs", 11), ("sssp", 3)]

    def run():
        srv = server(g, batch=3, segment_steps=2)
        reqs = [srv.submit(a, s) for a, s in stream]
        srv.drain()
        return transcript(reqs), srv.windows, srv.cache.stats()

    t1, w1, c1 = run()
    t2, w2, c2 = run()
    assert t1 == t2
    assert w1 == w2
    assert c1 == c2


# ------------------------------------------------------------------ #
# deadlines on the scheduler's clock
# ------------------------------------------------------------------ #
def test_deadline_expiry_inside_rotating_batch(g):
    """A deadline expires mid-fixpoint at a window boundary: the
    request retires as a flagged partial with a typed error locating
    the expiry ('fixpoint'), and its lane-mates are untouched."""
    srv = server(g, batch=2, segment_steps=2)
    slow = srv.submit("bfs", 27, deadline_s=3.0)   # needs ~7 steps
    fast = srv.submit("bfs", 3)                    # 1 step
    srv.drain()
    assert not slow.ok and slow.deadline_expired
    assert isinstance(slow.error, DeadlineExceeded)
    assert slow.error.where == "fixpoint"
    assert slow.error.describe()["where"] == "fixpoint"
    assert slow.result is not None and not slow.converged
    assert 0 < slow.steps < int(solo(g, "bfs", 27).steps)
    # the partial is the real prefix of the relaxation: bit-equal to a
    # solo run stopped at the same step
    part = solo(g, "bfs", 27, max_steps=slow.steps)
    np.testing.assert_array_equal(slow.result, np.asarray(part.attrs))
    assert fast.ok
    np.testing.assert_array_equal(
        fast.result, np.asarray(solo(g, "bfs", 3).attrs))


def test_deadline_expiry_in_queue(g):
    """Queue wait consumes the deadline: a request that expires before
    a lane frees up comes back typed, with NO partial (no work done)."""
    clock = VirtualClock()
    srv = server(g, batch=1, clock=clock)
    first = srv.submit("bfs", 27)                 # occupies the lane
    queued = srv.submit("bfs", 42, deadline_s=1.0)
    clock.advance(2.0)                            # expires while queued
    srv.drain()
    assert first.ok
    assert not queued.ok and queued.deadline_expired
    assert isinstance(queued.error, DeadlineExceeded)
    assert queued.error.where == "queue"
    assert queued.result is None
    assert queued.queue_wait_s >= 1.0


def test_step_budget_partial_is_exact_prefix(g):
    """max_steps exhaustion retires a flagged ConvergenceFailure whose
    partial equals the solo run under the same budget."""
    srv = server(g, batch=2, segment_steps=2)
    r = srv.submit("sssp", 27, max_steps=3)
    srv.drain()
    assert not r.ok and isinstance(r.error, ConvergenceFailure)
    assert not r.converged and r.steps == 3
    ref = solo(g, "sssp", 27, max_steps=3)
    np.testing.assert_array_equal(r.result, np.asarray(ref.attrs))


# ------------------------------------------------------------------ #
# admission control + zero lost requests
# ------------------------------------------------------------------ #
def test_shed_and_zero_lost(g):
    srv = server(g, batch=1, max_queue_depth=2)
    reqs = [srv.submit("bfs", i) for i in range(6)]
    shed = [r for r in reqs if isinstance(r.error, CapacityExceeded)]
    assert len(shed) == 4            # queue bound 2: newest 4 rejected
    srv.drain()
    assert all(r.done for r in reqs)
    assert sum(r.ok for r in reqs) == 2
    assert srv.shed == 4
    srv2 = server(g, batch=1, quotas={"bfs": 1})
    out = [srv2.submit("bfs", i) for i in range(3)]
    assert sum(isinstance(r.error, CapacityExceeded) for r in out) == 2


def test_invalid_requests_raise_synchronously(g):
    srv = server(g)
    with pytest.raises(InvalidRequest):
        srv.submit("nope", 0)
    with pytest.raises(InvalidRequest):
        srv.submit("bfs", g.n)
    with pytest.raises(InvalidRequest):
        srv.submit("bfs", -1)
    with pytest.raises(InvalidRequest):
        srv.submit("bfs", 0, max_steps=0)
    with pytest.raises(InvalidRequest):
        srv.submit("bfs", 0, deadline_s=-1.0)
    assert srv.pending == 0          # nothing malformed was queued


def test_distributed_plans_rejected(g):
    with pytest.raises(ValueError, match="bucket GraphServer"):
        AsyncGraphServer(g, tile=TILE,
                         plan=ExecutionPlan(distributed=True, tile=TILE))


# ------------------------------------------------------------------ #
# the shared result cache
# ------------------------------------------------------------------ #
def test_cache_hit_bit_identical_to_cold(g):
    srv = server(g, batch=2)
    cold = srv.submit("bfs", 27)
    srv.drain()
    hit = srv.submit("bfs", 27)
    assert hit.cache_hit and hit.done and hit.ok
    assert hit.steps == cold.steps
    np.testing.assert_array_equal(hit.result, cold.result)
    np.testing.assert_array_equal(
        hit.result, np.asarray(solo(g, "bfs", 27).attrs))
    assert srv.cache.stats()["hits"] == 1


def test_cache_property_randomized(g):
    """Property test over random submit/update/submit sequences: every
    served result (hit or cold) is bit-identical to the solo query on
    the graph version current at its submission; superseded versions
    are never served. Warm reuse is off so every cache entry traces to
    a cold run and hit step counts must equal cold step counts too
    (warm-start exactness has its own tests)."""
    rng = np.random.default_rng(7)
    srv = server(g, batch=3, segment_steps=2, warm_reuse=False)
    g_cur = g
    for _ in range(4):
        reqs = []
        # two waves per graph version: wave-2 repeats of wave-1
        # sources exercise cache hits (a repeat submitted before its
        # twin completes runs cold -- no coalescing -- so hits need a
        # drain in between)
        for _ in range(2):
            wave = []
            for _ in range(6):
                algo = ("bfs", "sssp", "wcc")[int(rng.integers(3))]
                src = int(rng.integers(8))   # small pool -> repeats
                wave.append((srv.submit(algo, src), algo, src))
            srv.drain()
            reqs.extend(wave)
        for r, algo, src in reqs:
            assert r.ok, (algo, src, r.error)
            ref = solo(g_cur, algo, src)
            np.testing.assert_array_equal(r.result,
                                          np.asarray(ref.attrs))
            assert r.steps == int(ref.steps), (algo, src, r.cache_hit)
        # mutate: improving reweights keep the stream monotone
        eu = g_cur.edge_sources()
        idx = rng.choice(g_cur.m, size=3, replace=False)
        batch = [(int(eu[i]), int(g_cur.indices[i]),
                  float(g_cur.weights[i]) * 0.5) for i in idx]
        batch.append((int(rng.integers(g.n)), int(rng.integers(g.n)),
                      1.0))
        srv.update(batch)
        g_cur = g_cur.apply_updates(batch)
        assert srv.graph.fingerprint() == g_cur.fingerprint()
    assert srv.cache.stats()["hits"] > 0     # Zipf-free but repeats land


def test_cache_lru_bound():
    c = ResultCache(capacity=3)
    for i in range(5):
        c.put("fp", "bfs", i, np.full(4, i, np.float32), i + 1)
    assert len(c) == 3 and c.evictions == 2
    assert c.get("fp", "bfs", 0) is None     # oldest two evicted
    assert c.get("fp", "bfs", 1) is None
    e = c.get("fp", "bfs", 2)                # survivor, promoted to MRU
    assert e is not None and e.steps == 3
    c.put("fp", "bfs", 9, np.zeros(4, np.float32), 1)
    assert c.get("fp", "bfs", 2) is not None   # MRU survived insertion
    assert c.get("fp", "bfs", 3) is None       # LRU paid for it
    with pytest.raises(ValueError):
        ResultCache(capacity=-1)
    # a served entry is frozen: callers cannot poison later hits
    with pytest.raises(ValueError):
        e.attrs[0] = 99.0


def test_cache_eviction_end_to_end(g):
    """Server-level LRU: with capacity 2, the first of three distinct
    sources is evicted -- re-querying it is a miss (recomputed, still
    exact), while the recent ones hit."""
    srv = server(g, batch=2, cache_capacity=2)
    for s in (3, 27, 42):
        srv.submit("bfs", s)
        srv.drain()
    assert len(srv.cache) == 2
    r3 = srv.submit("bfs", 3)
    srv.drain()
    assert not r3.cache_hit and r3.ok
    r42 = srv.submit("bfs", 42)
    assert r42.cache_hit


def test_cache_disabled(g):
    srv = server(g, batch=2, cache_capacity=0)
    a = srv.submit("bfs", 27)
    srv.drain()
    b = srv.submit("bfs", 27)
    srv.drain()
    assert not a.cache_hit and not b.cache_hit
    np.testing.assert_array_equal(a.result, b.result)
    assert srv.cache.stats() == {
        "capacity": 0, "entries": 0, "hits": 0, "misses": 0,
        "hit_rate": 0.0, "evictions": 0}


def test_superseded_fingerprint_never_served(g):
    """After an update, the old generation's entries are structurally
    unreachable: a repeated source recomputes on the new graph and the
    results genuinely differ (the mutation improves this path)."""
    srv = server(g, batch=2)
    before = srv.submit("sssp", 27)
    srv.drain()
    assert before.ok
    # a near-zero shortcut 27 -> its farthest reachable vertex: sssp
    # from 27 must improve, so stale-entry reuse would be visible
    far = int(np.argmax(np.where(
        np.isfinite(before.result) & (before.result > 0),
        before.result, -1.0)))
    assert before.result[far] > 0.001
    srv.update([(27, far, 0.001)])
    after = srv.submit("sssp", 27)
    srv.drain()
    assert after.ok and not after.cache_hit
    ref = solo(srv.graph, "sssp", 27)
    np.testing.assert_array_equal(after.result, np.asarray(ref.attrs))
    assert not np.array_equal(after.result, before.result)


# ------------------------------------------------------------------ #
# warm-start reuse across one update step
# ------------------------------------------------------------------ #
def test_warm_start_across_one_update(g):
    """Monotone algebra + improving batch: repeated sources resume from
    the superseded generation's cached fixpoints -- flagged
    `warm_started`, results bit-equal the scratch solo on the new
    graph."""
    srv = server(g, batch=2)
    for s in (3, 27):
        srv.submit("sssp", s)
    srv.drain()
    eu = g.edge_sources()
    batch = [(int(eu[i]), int(g.indices[i]), float(g.weights[i]) * 0.5)
             for i in (0, 7, 13)]
    srv.update(batch)
    g2 = g.apply_updates(batch)
    reqs = [srv.submit("sssp", s) for s in (3, 27)]
    srv.drain()
    for r in reqs:
        assert r.ok and r.warm_started, (r.src, r.error)
        ref = solo(g2, "sssp", r.src)
        np.testing.assert_array_equal(r.result, np.asarray(ref.attrs))
    # an uncached source admits cold alongside warm lanes, still exact
    cold = srv.submit("sssp", 42)
    srv.drain()
    assert cold.ok and not cold.warm_started
    np.testing.assert_array_equal(
        cold.result, np.asarray(solo(g2, "sssp", 42).attrs))


def test_warm_candidates_live_one_version_step(g):
    """PR-5 provenance: warm candidates come from the immediately
    preceding version only. Two back-to-back updates with no queries
    between leave nothing to resume from -- queries run cold and
    exact."""
    srv = server(g, batch=2)
    srv.submit("sssp", 3)
    srv.drain()
    b1 = [(3, 50, 0.5)]
    b2 = [(5, 59, 0.5)]
    srv.update(b1)
    srv.update(b2)                   # candidates from b1 now stale
    r = srv.submit("sssp", 3)
    srv.drain()
    assert r.ok and not r.warm_started
    g2 = g.apply_updates(b1).apply_updates(b2)
    np.testing.assert_array_equal(
        r.result, np.asarray(solo(g2, "sssp", 3).attrs))


def test_non_monotone_never_warm_starts(g):
    """pagerank (residual algebra): resolve_warm refuses, queries after
    an update run cold and exact."""
    srv = server(g, batch=2)
    srv.submit("pagerank", 3)
    srv.drain()
    srv.update([(3, 50, 0.5)])
    r = srv.submit("pagerank", 3)
    srv.drain()
    assert r.ok and not r.warm_started
    np.testing.assert_array_equal(
        r.result, np.asarray(solo(srv.graph, "pagerank", 3).attrs))


# ------------------------------------------------------------------ #
# serve() streams, metrics, stats
# ------------------------------------------------------------------ #
def test_serve_stream_graph_version_order(g):
    """An ("update", batch) stream item drains earlier queries against
    the pre-update graph; later ones see the new version -- submission
    order is graph-version order, matching the bucket server."""
    batch = [(3, 50, 0.001)]
    srv = server(g, batch=2)
    reqs = srv.serve([("sssp", 3), ("update", batch), ("sssp", 3)])
    g2 = g.apply_updates(batch)
    np.testing.assert_array_equal(
        reqs[0].result, np.asarray(solo(g, "sssp", 3).attrs))
    np.testing.assert_array_equal(
        reqs[1].result, np.asarray(solo(g2, "sssp", 3).attrs))
    assert srv.updates_applied == 1


def test_stats_and_metrics(g):
    import json
    srv = server(g, batch=2, segment_steps=2)
    for s in (3, 27, 3, 42):
        srv.submit("bfs", s)
    srv.drain()
    st = srv.stats()
    json.dumps(st)                   # JSON-ready end to end
    assert st["scheduler"] == "continuous"
    assert st["queue_depth"] == 0
    assert st["occupancy"] == 0.0    # drained
    assert st["windows"] == srv.windows > 0
    assert st["completed"] == 4
    assert 0.0 <= st["cache"]["hit_rate"] <= 1.0
    snap = st["metrics"]
    assert snap["counters"]["completed.bfs"] == 4
    assert "latency_s.bfs" in snap["histograms"]
    assert snap["gauges"]["queue_depth"] == 0.0
    # Gauge.add moves both ways (the scheduler's delta-adjust surface)
    gauge = srv.metrics.gauge("probe")
    gauge.add(2.5)
    gauge.add(-1.0)
    assert gauge.snapshot() == 1.5


def test_request_done_invariant(g):
    """Every ServeRequest path ends `done`: result, typed error, shed,
    expired, partial -- never neither."""
    r = ServeRequest(0, "bfs", 1)
    assert not r.done and not r.ok
    clock = VirtualClock()
    srv = server(g, batch=1, max_queue_depth=1, clock=clock)
    reqs = [srv.submit("bfs", 27, deadline_s=3.0),
            srv.submit("bfs", 42, deadline_s=0.5),
            srv.submit("bfs", 3)]            # shed (queue full)
    clock.advance(1.0)
    srv.drain()
    assert all(q.done for q in reqs)
    assert sum(q.ok for q in reqs) <= 1
