"""Per-arch smoke tests: reduced configs, forward + train step + decode,
output shapes, finite losses/grads (assignment deliverable f)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, SHAPES, cells, get, get_smoke, \
    shape_supported
from repro.models import model as M

KEY = jax.random.PRNGKey(0)


def _batch(cfg, B=2, S=32, seed=0):
    if cfg.frontend == "frames":
        rng = np.random.default_rng(seed)
        return {"frames": jnp.asarray(
                    rng.normal(size=(B, S, cfg.d_model)), jnp.float32),
                "labels": jnp.asarray(
                    rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)}
    rng = np.random.default_rng(seed)
    return {"tokens": jnp.asarray(
                rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32),
            "labels": jnp.asarray(
                rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)}


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_train_step(arch):
    cfg = get_smoke(arch)
    params = M.init_params(cfg, KEY)
    batch = _batch(cfg)
    loss, grads = jax.jit(jax.value_and_grad(
        lambda p: M.train_loss(p, batch, cfg)))(params)
    assert jnp.isfinite(loss), f"{arch} loss not finite"
    for leaf in jax.tree_util.tree_leaves(grads):
        assert bool(jnp.isfinite(leaf).all()), f"{arch} grad not finite"


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward_shapes(arch):
    cfg = get_smoke(arch)
    params = M.init_params(cfg, KEY)
    batch = _batch(cfg)
    x = M.embed_inputs(params, batch, cfg)
    hidden, aux = M.backbone(params, x, cfg, remat=False)
    assert hidden.shape == (2, 32, cfg.d_model)
    assert bool(jnp.isfinite(hidden).all())
    logits = M.prefill(params, batch, cfg)
    assert logits.shape == (2, 1, cfg.padded_vocab)


@pytest.mark.parametrize("arch",
                         [a for a in ARCH_IDS if get(a).has_decode])
def test_smoke_decode_matches_prefill(arch):
    """Decoding token-by-token must reproduce teacher-forced logits."""
    cfg = get_smoke(arch)
    params = M.init_params(cfg, KEY)
    B, S = 1, 8
    batch = _batch(cfg, B, S, seed=1)
    # full forward logits at last position
    full = M.prefill(params, batch, cfg, impl="plain")
    # token-by-token decode over the same prompt
    cache = M.init_cache(cfg, B, 16)
    step = jax.jit(lambda p, c, t, q: M.decode_step(p, c, t, q, cfg))
    for t in range(S):
        logits, cache = step(params, cache, batch["tokens"][:, t:t + 1],
                             jnp.full((B,), t, jnp.int32))
    np.testing.assert_allclose(np.asarray(logits),
                               np.asarray(full), rtol=2e-2, atol=2e-3)


def test_cell_matrix_counts():
    run, skipped = cells()
    assert len(run) == 32
    assert len(skipped) == 8
    # hubert has no decode cells
    assert ("hubert_xlarge", "decode_32k") not in run


def test_sliding_window_limits_cache():
    cfg = get_smoke("gemma3_12b")
    cache = M.abstract_cache(cfg, 2, 512)
    # local layers (block0..4): ring cache of window=16; global: 512
    assert cache["block0"]["k"].shape[2] == 16
    assert cache["block5"]["k"].shape[2] == 512


def test_param_counts_match_advertised():
    expect = {
        "qwen3_0_6b": 0.60e9, "phi3_medium_14b": 14.7e9,
        "mistral_nemo_12b": 12.2e9, "gemma3_12b": 11.8e9,
        "qwen3_moe_235b_a22b": 235e9, "jamba_1_5_large_398b": 398e9,
        "mamba2_370m": 0.37e9, "chameleon_34b": 34.3e9,
    }
    for arch, n in expect.items():
        got = get(arch).param_count()
        assert abs(got - n) / n < 0.05, (arch, got, n)


def test_moe_active_params():
    cfg = get("qwen3_moe_235b_a22b")
    assert abs(cfg.param_count(active_only=True) - 22.2e9) / 22.2e9 < 0.05


def test_train_loss_decreases_tiny_run():
    """3-step sanity: loss strictly decreases on learnable synthetic data."""
    from repro.data import SyntheticTextDataset
    from repro.optim import adamw
    from repro.optim.adamw import AdamWConfig

    cfg = dataclasses.replace(get_smoke("qwen3_0_6b"), vocab_size=64)
    params = M.init_params(cfg, KEY)
    opt_cfg = AdamWConfig(lr_peak=1e-2, warmup_steps=1, total_steps=20)
    opt = adamw.init_opt_state(params, opt_cfg)
    ds = SyntheticTextDataset(cfg.vocab_size, 32, 4, seed=0)

    @jax.jit
    def step(params, opt, batch):
        loss, g = jax.value_and_grad(
            lambda p: M.train_loss(p, batch, cfg))(params)
        params, opt, _ = adamw.adamw_update(g, opt, params, opt_cfg)
        return params, opt, loss

    losses = []
    for i in range(8):
        b = {k: jnp.asarray(v) for k, v in ds.batch_at(i).items()}
        params, opt, loss = step(params, opt, b)
        losses.append(float(loss))
    assert losses[-1] < losses[0]
