"""Benchmark persistence: BENCH_<tag>.json must accumulate run history
(append-safe), survive the legacy single-run layout, and tolerate a
corrupt file instead of losing the new rows."""
import json

from benchmarks import common


def _fresh(monkeypatch, tmp_path):
    monkeypatch.setenv("BENCH_OUT", str(tmp_path))
    monkeypatch.setattr(common, "RESULTS", [])


def test_write_json_appends_runs(tmp_path, monkeypatch):
    _fresh(monkeypatch, tmp_path)
    common.emit("row_a", 1.25, "first")
    path = common.write_json("unittest")
    common.emit("row_b", 2.5)
    path2 = common.write_json("unittest")
    assert path2 == path
    with open(path) as f:
        data = json.load(f)
    assert data["tag"] == "unittest"
    assert [len(r["rows"]) for r in data["runs"]] == [1, 2]
    assert data["runs"][0]["rows"][0]["name"] == "row_a"
    assert data["runs"][1]["rows"][1]["us_per_call"] == 2.5
    assert all("ts" in r for r in data["runs"])


def test_write_json_explicit_rows_subset(tmp_path, monkeypatch):
    _fresh(monkeypatch, tmp_path)
    common.emit("early", 1.0)
    start = len(common.RESULTS)
    common.emit("mine", 3.0)
    path = common.write_json("subset", rows=common.RESULTS[start:])
    with open(path) as f:
        data = json.load(f)
    assert [r["name"] for r in data["runs"][-1]["rows"]] == ["mine"]


def test_write_json_migrates_legacy_layout(tmp_path, monkeypatch):
    _fresh(monkeypatch, tmp_path)
    legacy = {"tag": "unittest", "rows": [{"name": "old", "us_per_call": 9}]}
    with open(tmp_path / "BENCH_unittest.json", "w") as f:
        json.dump(legacy, f)
    common.emit("new", 1.0)
    path = common.write_json("unittest")
    with open(path) as f:
        data = json.load(f)
    assert len(data["runs"]) == 2
    assert data["runs"][0]["rows"][0]["name"] == "old"
    assert data["runs"][1]["rows"][0]["name"] == "new"


def test_write_json_survives_corrupt_history(tmp_path, monkeypatch):
    _fresh(monkeypatch, tmp_path)
    (tmp_path / "BENCH_unittest.json").write_text("{not json")
    common.emit("fresh", 4.0)
    path = common.write_json("unittest")
    with open(path) as f:
        data = json.load(f)
    assert [r["name"] for r in data["runs"][-1]["rows"]] == ["fresh"]
