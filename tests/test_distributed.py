"""Distributed pieces that need >1 device run in subprocesses with
xla_force_host_platform_device_count (the main test process keeps the real
1-device platform per the assignment)."""
import json
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from jax.sharding import PartitionSpec as P

from repro.distributed.sharding import (DEFAULT_RULES, logical_to_pspec,
                                        mesh_context, constrain)


def _run_sub(code: str, devices: int = 8) -> str:
    prog = (f"import os\n"
            f"os.environ['XLA_FLAGS'] = "
            f"'--xla_force_host_platform_device_count={devices}'\n"
            + textwrap.dedent(code))
    out = subprocess.run([sys.executable, "-c", prog], capture_output=True,
                         text=True, timeout=600,
                         env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
                              "HOME": "/root",
                              # force the host platform: without this, jax
                              # backend discovery can block for minutes
                              # probing accelerators from the clean env
                              "JAX_PLATFORMS": "cpu"}, cwd="/root/repo")
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


# ----------------------- sharding rules (no mesh needed) --------------- #
def test_pspec_no_mesh_is_empty():
    assert logical_to_pspec((4, 4), ("batch", "embed"), None) == P()


def test_constrain_noop_without_mesh():
    x = jnp.ones((4, 4))
    assert constrain(x, "batch", None) is x


def test_pspec_rules_subprocess():
    out = _run_sub("""
    import jax, json
    from jax.sharding import PartitionSpec as P
    from repro.distributed.sharding import logical_to_pspec, DEFAULT_RULES
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    checks = []
    # normal weight: embed->data, mlp->model
    s = logical_to_pspec((8, 16), ("embed", "mlp"), mesh)
    checks.append(s == P("data", "model"))
    # non-divisible but >= axis size: uneven sharding kept (GSPMD pads)
    s = logical_to_pspec((6, 16), ("embed", "mlp"), mesh)
    checks.append(s == P("data", "model"))
    # dim smaller than the axis: replicate (GQA kv heads case)
    s = logical_to_pspec((1, 16), ("embed", "mlp"), mesh)
    checks.append(s == P(None, "model"))
    # tuple with missing axis filtered ("pod" absent)
    s = logical_to_pspec((8, 4), ("batch", None), mesh)
    checks.append(s == P("data", None))
    # one mesh axis used once
    s = logical_to_pspec((8, 8), ("mlp", "heads"), mesh)
    checks.append(s == P("model", None))
    print(json.dumps(checks))
    """)
    assert all(json.loads(out.strip().splitlines()[-1]))


# ----------------------- distributed graph engine ---------------------- #
def test_engine_distributed_matches_reference():
    out = _run_sub("""
    from repro.algebra import ALGEBRAS
    from repro.graphs import make_road_network, reference
    from repro.core.engine import FlipEngine
    g = make_road_network(128, seed=3)
    for algo, src in [("bfs", 2), ("sssp", 2), ("wcc", 0),
                      ("widest", 2), ("reach", 2), ("pagerank", 0)]:
        eng = FlipEngine.build(g, algo, tile=32)
        got, steps = eng.run_distributed(src)    # (result, steps) like run
        assert steps >= 1, algo
        ref, _ = reference.run(algo, g, src)
        assert ALGEBRAS[algo].results_match(got, ref), algo
    print("OK")
    """)
    assert "OK" in out


def test_engine_distributed_batched_and_zero_block_devices():
    """Batched queries stay replicated while tiles shard; with ntiles <
    ndev some devices own only padded tiles and zero real blocks -- the
    degenerate all-identity slab must be an exact no-op, not a crash."""
    out = _run_sub("""
    import numpy as np
    from repro.algebra import ALGEBRAS
    from repro.graphs import make_road_network, reference
    from repro.core.engine import FlipEngine
    # ntiles = 2 over 8 devices: 6 devices own zero blocks
    g = make_road_network(48, seed=1)
    for algo in ("sssp", "pagerank"):
        eng = FlipEngine.build(g, algo, tile=32)
        srcs = np.array([5, 0, 17, 23])
        outs, steps = eng.run_distributed(srcs)
        assert outs.shape == (4, g.n) and steps.shape == (4,)
        for b, s in enumerate(srcs):
            ref, _ = reference.run(algo, g, int(s))
            assert ALGEBRAS[algo].results_match(outs[b], ref), (algo, b)
            solo, st = eng.run_distributed(int(s))
            assert np.array_equal(outs[b], solo), (algo, b)
            assert steps[b] == st, (algo, b)
    print("OK")
    """)
    assert "OK" in out


# ----------------------- MoE dispatch equivalence ---------------------- #
def test_moe_all_to_all_matches_gspmd():
    out = _run_sub("""
    import jax, jax.numpy as jnp
    from repro.configs import get_smoke
    from repro.distributed.sharding import mesh_context
    from repro.models import moe
    from repro.models.layers import init_tree
    cfg = get_smoke("granite_moe_3b_a800m")
    p = init_tree(jax.random.PRNGKey(0), moe.decls(cfg), jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, cfg.d_model))
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    with mesh_context(mesh):
        y1, a1 = jax.jit(lambda p, x: moe.apply(p, x, cfg, "gspmd"))(p, x)
        y2, a2 = jax.jit(lambda p, x: moe.apply(p, x, cfg,
                                                "all_to_all"))(p, x)
    assert float(jnp.abs(y1 - y2).max()) < 2e-5, float(jnp.abs(y1-y2).max())
    assert abs(float(a1) - float(a2)) < 1e-4
    print("OK")
    """)
    assert "OK" in out


# ----------------------- compressed psum over pods ---------------------- #
def test_compressed_psum_pod_axis():
    out = _run_sub("""
    import jax, jax.numpy as jnp, numpy as np
    from functools import partial
    from jax.sharding import PartitionSpec as P
    from jax.experimental.shard_map import shard_map
    from repro.distributed.compression import compressed_psum
    mesh = jax.make_mesh((4,), ("pod",))
    x = jnp.asarray(np.random.default_rng(0).normal(size=(4, 64)),
                    jnp.float32)
    @partial(shard_map, mesh=mesh, in_specs=P("pod"), out_specs=P("pod"),
             check_rep=False)
    def f(xs):
        mean, fb = compressed_psum(xs[0], "pod")
        return mean[None]
    got = f(x)[0]
    want = x.mean(axis=0)
    scale = float(jnp.abs(x).max()) / 127
    assert float(jnp.abs(got - want).max()) <= scale, "compression error"
    print("OK")
    """, devices=4)
    assert "OK" in out


# ----------------------- sharded train-step parity ---------------------- #
def test_sharded_train_step_matches_single_device():
    out = _run_sub("""
    import jax, jax.numpy as jnp, numpy as np
    from repro.configs import get_smoke
    from repro.distributed.sharding import mesh_context, DEFAULT_RULES
    from repro.launch import steps as S
    from repro.models import model as M
    from repro.optim import adamw
    from repro.optim.adamw import AdamWConfig
    cfg = get_smoke("qwen3_0_6b")
    opt_cfg = AdamWConfig()
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    state = {"params": params,
             "opt": adamw.init_opt_state(params, opt_cfg)}
    batch = {"tokens": jnp.zeros((4, 32), jnp.int32),
             "labels": jnp.ones((4, 32), jnp.int32)}
    step = S.make_train_step(cfg, opt_cfg, impl="plain")
    # single device
    s1, m1 = jax.jit(step)(jax.tree_util.tree_map(lambda x: x, state), batch)
    # 8-device mesh
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    with mesh_context(mesh, DEFAULT_RULES):
        sh = S.train_state_shardings(cfg, mesh, opt_cfg)
        bsh = S.batch_shardings(
            {k: jax.ShapeDtypeStruct(v.shape, v.dtype)
             for k, v in batch.items()}, mesh)
        s2, m2 = jax.jit(step, in_shardings=(sh, bsh),
                         out_shardings=(sh, None))(state, batch)
    d = abs(float(m1["loss"]) - float(m2["loss"]))
    assert d < 1e-3, d
    for a, b in zip(jax.tree_util.tree_leaves(s1["params"]),
                    jax.tree_util.tree_leaves(s2["params"])):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   atol=5e-3)
    print("OK")
    """)
    assert "OK" in out
