"""Optimizer, data pipeline, checkpointing, compression, health."""
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager, save_pytree, load_pytree
from repro.data import SyntheticTextDataset, make_batches
from repro.distributed.compression import compress_grads, init_feedback
from repro.distributed.health import HeartbeatMonitor, StepFailure, step_guard
from repro.optim import adamw
from repro.optim.adamw import AdamWConfig


# ----------------------------- optimizer ------------------------------ #
def test_adamw_minimizes_quadratic():
    cfg = AdamWConfig(lr_peak=0.1, warmup_steps=1, total_steps=200,
                      weight_decay=0.0)
    params = {"w": jnp.asarray([5.0, -3.0])}
    opt = adamw.init_opt_state(params, cfg)
    loss = lambda p: jnp.sum(p["w"] ** 2)
    for _ in range(150):
        g = jax.grad(loss)(params)
        params, opt, _ = adamw.adamw_update(g, opt, params, cfg)
    assert float(loss(params)) < 1e-2


def test_cosine_schedule_shape():
    cfg = AdamWConfig(lr_peak=1e-3, warmup_steps=10, total_steps=100)
    lrs = [float(adamw.cosine_schedule(s, cfg)) for s in range(101)]
    assert lrs[0] < lrs[10]                       # warmup rises
    assert abs(lrs[10] - 1e-3) < 1e-9             # peak
    assert lrs[100] == pytest.approx(1e-4, rel=0.01)   # min ratio


def test_grad_clipping_caps_update_norm():
    cfg = AdamWConfig(clip_norm=1.0, lr_peak=1.0, warmup_steps=1,
                      weight_decay=0.0)
    params = {"w": jnp.zeros(3)}
    opt = adamw.init_opt_state(params, cfg)
    g = {"w": jnp.asarray([1e6, 0.0, 0.0])}
    _, _, stats = adamw.adamw_update(g, opt, params, cfg)
    assert float(stats["grad_norm"]) > 1e5        # raw norm reported


def test_bf16_moments_dtype():
    cfg = AdamWConfig(moment_dtype="bfloat16")
    params = {"w": jnp.zeros(4, jnp.bfloat16)}
    opt = adamw.init_opt_state(params, cfg)
    assert opt["mu"]["w"].dtype == jnp.bfloat16


# ----------------------------- data ----------------------------------- #
def test_data_deterministic_and_resumable():
    ds = SyntheticTextDataset(128, 16, 4, seed=7)
    a = ds.batch_at(5)
    b = ds.batch_at(5)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    # labels are next-token shifted
    np.testing.assert_array_equal(a["tokens"][:, 1:], a["labels"][:, :-1])


def test_data_learnable_structure():
    ds = SyntheticTextDataset(64, 256, 2, seed=0, noise=0.0)
    b = ds.batch_at(0)
    # zero noise -> labels fully determined by the bigram table
    succ = ds._succ
    np.testing.assert_array_equal(succ[b["tokens"]], b["labels"])


def test_prefetch_iterator_order():
    ds = SyntheticTextDataset(32, 8, 2, seed=1)
    steps = [s for s, _ in make_batches(ds, 3, 5)]
    assert steps == [3, 4, 5, 6, 7]


# ----------------------------- checkpoint ----------------------------- #
def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(6).reshape(2, 3),
            "b": {"c": jnp.ones(4, jnp.bfloat16)}}
    save_pytree(tree, str(tmp_path), 3, extras={"foo": 1})
    out, step, extras = load_pytree(tree, str(tmp_path))
    assert step == 3 and extras == {"foo": 1}
    np.testing.assert_array_equal(out["a"], tree["a"])
    assert out["b"]["c"].dtype == jnp.bfloat16


def test_checkpoint_async_and_retention(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    tree = {"w": jnp.zeros(8)}
    for s in (1, 2, 3, 4):
        mgr.save(jax.tree_util.tree_map(lambda x: x + s, tree), s)
    mgr.wait()
    from repro.checkpoint.manager import committed_steps
    assert committed_steps(str(tmp_path)) == [3, 4]
    out, step, _ = mgr.restore(tree)
    assert step == 4
    np.testing.assert_allclose(out["w"], 4.0)


def test_checkpoint_uncommitted_ignored(tmp_path):
    tree = {"w": jnp.zeros(2)}
    save_pytree(tree, str(tmp_path), 1)
    # fake a torn write at step 2
    os.makedirs(tmp_path / "step_00000002")
    out, step, _ = load_pytree(tree, str(tmp_path))
    assert step == 1


def test_checkpoint_structure_mismatch_raises(tmp_path):
    save_pytree({"a": jnp.zeros(2)}, str(tmp_path), 1)
    with pytest.raises(AssertionError):
        load_pytree({"b": jnp.zeros(2)}, str(tmp_path))


# ----------------------------- compression ---------------------------- #
def test_compression_error_bounded():
    g = {"w": jnp.asarray(np.random.default_rng(0).normal(size=1000),
                          jnp.float32)}
    cg, fb = compress_grads(g, None)
    scale = float(jnp.max(jnp.abs(g["w"]))) / 127
    assert float(jnp.abs(cg["w"] - g["w"]).max()) <= scale * 0.5 + 1e-6


def test_error_feedback_preserves_mean_signal():
    """Over many steps, quantization error doesn't accumulate (EF)."""
    rng = np.random.default_rng(1)
    g_true = jnp.asarray(rng.normal(size=64), jnp.float32) * 1e-3
    fb = None
    acc = jnp.zeros(64)
    for _ in range(200):
        cg, fb = compress_grads({"w": g_true}, fb if fb is None else fb)
        acc = acc + cg["w"]
    np.testing.assert_allclose(acc / 200, g_true, atol=2e-5)


# ----------------------------- health ---------------------------------- #
def test_heartbeat_detects_stall():
    fired = []
    hb = HeartbeatMonitor(timeout_s=0.2, on_stall=lambda: fired.append(1))
    hb.start()
    time.sleep(0.6)
    assert hb.stalled and fired
    hb.stop()


def test_heartbeat_no_false_positive():
    hb = HeartbeatMonitor(timeout_s=0.5)
    hb.start()
    for _ in range(4):
        time.sleep(0.1)
        hb.beat()
    assert not hb.stalled
    hb.stop()


def test_step_guard_wraps_failures():
    with pytest.raises(StepFailure) as e:
        step_guard(lambda: 1 / 0, step=17)
    assert e.value.step == 17
