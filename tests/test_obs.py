"""Telemetry subsystem tests: tracing exactness, oracle frontier replay,
server stats, exporters, and the metrics registry.

The two load-bearing contracts:

  * tracing is EXACT -- `query(trace=True)` returns bit-identical attrs
    and step counts to the untraced run for every algebra, relax mode,
    and batching shape (the stat buffers are write-only extra outputs);
  * the recorded per-step stats are TRUE -- on a 1k power-law graph the
    traced BFS active-vertex counts equal a numpy frontier replay of
    the algorithm exactly, per step, on both local fixpoints.
"""
import json
import types

import numpy as np
import pytest

from conftest import ALGOS, SRCS8
from repro import api as flip
from repro.graphs import make_power_law, make_road_network
from repro.obs import (Counter, Histogram, MetricsRegistry, QueryTelemetry,
                       chrome_trace_from_result, from_sim,
                       write_chrome_trace)


def _plan(relax_mode="jnp", **kw):
    kw.setdefault("tile", 64)
    return flip.ExecutionPlan(relax_mode=relax_mode, **kw)


# ---------------------------------------------------------------- #
# tracing exactness across the whole execution matrix
# ---------------------------------------------------------------- #
@pytest.mark.parametrize("relax_mode", ["jnp", "interpret"])
@pytest.mark.parametrize("algo", ALGOS)
def test_trace_bit_exact_solo(algo, relax_mode):
    g = make_road_network(160, seed=0)
    cq = flip.compile(g, algo, _plan(relax_mode))
    r = cq.query(3)
    rt = cq.query(3, trace=True)
    np.testing.assert_array_equal(np.asarray(r.attrs), np.asarray(rt.attrs))
    assert r.steps == rt.steps
    assert rt.telemetry is not None and r.telemetry is None
    d = rt.telemetry.dispatches[0]
    assert len(d.trace) == r.steps
    assert not d.truncated


@pytest.mark.parametrize("relax_mode", ["jnp", "interpret"])
@pytest.mark.parametrize("algo", ALGOS)
def test_trace_bit_exact_batched(algo, relax_mode):
    g = make_road_network(160, seed=0)
    srcs = SRCS8[:4]
    cq = flip.compile(g, algo, _plan(relax_mode))
    r = cq.query(srcs)
    rt = cq.query(srcs, trace=True)
    np.testing.assert_array_equal(np.asarray(r.attrs), np.asarray(rt.attrs))
    np.testing.assert_array_equal(np.asarray(r.steps),
                                  np.asarray(rt.steps))
    d = rt.telemetry.dispatches[0]
    assert len(d.trace) == int(np.asarray(r.steps).max())
    assert d.trace.active_vertices.shape == (len(d.trace), 4)


# ---------------------------------------------------------------- #
# per-step stats vs a numpy oracle frontier replay (BFS, 1k graph)
# ---------------------------------------------------------------- #
def _bfs_frontier_replay(g, src):
    """Replay BFS as the engine executes it: per step, relax every
    out-edge of the frontier; the improved destinations are the next
    frontier. Returns the per-step active-vertex counts (frontier size
    ENTERING each step) and the per-step frontier sets."""
    dist = np.full(g.n, np.inf)
    dist[src] = 0.0
    frontier = {src}
    counts, fronts = [], []
    while frontier:
        counts.append(len(frontier))
        fronts.append(set(frontier))
        nxt = set()
        for u in frontier:
            for v in g.indices[g.indptr[u]:g.indptr[u + 1]]:
                if dist[u] + 1.0 < dist[v]:
                    dist[v] = dist[u] + 1.0
                    nxt.add(int(v))
        frontier = nxt
    return counts, fronts, dist


@pytest.mark.parametrize("compact", [True, False])
def test_bfs_trace_matches_frontier_replay_1k(compact):
    g = make_power_law(1024, 4096, seed=0)
    src = 0
    counts, fronts, dist = _bfs_frontier_replay(g, src)

    cq = flip.compile(g, "bfs", flip.ExecutionPlan(compact=compact))
    r = cq.query(src)
    rt = cq.query(src, trace=True)
    # exactness first: tracing changes nothing
    np.testing.assert_array_equal(np.asarray(r.attrs), np.asarray(rt.attrs))
    assert r.steps == rt.steps == len(counts)

    tr = rt.telemetry.dispatches[0].trace
    np.testing.assert_array_equal(tr.active_vertices[:, 0],
                                  np.asarray(counts, np.int32))
    np.testing.assert_array_equal((~tr.converged[:, 0]),
                                  np.ones(len(counts), bool))

    # active tiles and fetched blocks follow from the frontier sets via
    # the engine's own placement (perm -> tile) and block list (bsrc)
    bg = cq.engine.bg
    perm = np.asarray(bg.perm)
    bsrc = np.asarray(bg.bsrc)
    nb = bsrc.shape[0]
    for t, front in enumerate(fronts):
        tiles = {int(perm[v]) // bg.tile for v in front}
        assert int(tr.active_tiles[t]) == len(tiles), t
        if compact:
            fetched = int(sum(int(b) in tiles for b in bsrc))
        else:
            fetched = nb
        assert int(tr.blocks_fetched[t]) == fetched, t
        assert int(tr.blocks_skipped[t]) == nb - fetched, t


def test_trace_identical_across_fixpoints():
    """The host-driven and while_loop fixpoints must record the same
    stats row for row (only step_wall_s is host-exclusive)."""
    g = make_power_law(512, 1536, seed=1)
    srcs = [0, 7]
    traces = {}
    for compact in (True, False):
        cq = flip.compile(g, "bfs", flip.ExecutionPlan(compact=compact))
        traces[compact] = cq.query(srcs, trace=True)
    th = traces[True].telemetry.dispatches[0].trace
    tw = traces[False].telemetry.dispatches[0].trace
    np.testing.assert_array_equal(th.active_vertices, tw.active_vertices)
    np.testing.assert_array_equal(th.active_tiles, tw.active_tiles)
    np.testing.assert_array_equal(th.converged, tw.converged)
    assert th.step_wall_s is not None and len(th.step_wall_s) == len(th)
    assert (th.step_wall_s > 0).all()
    assert tw.step_wall_s is None        # while_loop has no per-step clock


def test_converged_mask_two_depths():
    """Batch of two sources with different convergence depths: the
    converged mask records exactly when each query froze, and its
    frontier stays empty afterwards."""
    g = make_power_law(512, 1536, seed=1)
    cq = flip.compile(g, "bfs", flip.ExecutionPlan())
    rt = cq.query([0, 5], trace=True)
    steps = np.asarray(rt.steps)
    tr = rt.telemetry.dispatches[0].trace
    assert len(tr) == steps.max()
    for t in range(len(tr)):
        for b in range(2):
            assert bool(tr.converged[t, b]) == (t >= steps[b]), (t, b)
            if t >= steps[b]:
                assert tr.active_vertices[t, b] == 0


def test_truncation_flag():
    g = make_power_law(512, 1536, seed=1)
    for compact in (True, False):
        cq = flip.compile(g, "bfs", flip.ExecutionPlan(compact=compact))
        r = cq.query(0)
        rt = cq.query(0, trace=2)
        assert r.steps > 2
        d = rt.telemetry.dispatches[0]
        assert d.truncated and len(d.trace) == 2
        assert r.steps == rt.steps       # execution itself is not cut


def test_trace_distributed_raises():
    g = make_road_network(96, seed=0)
    cq = flip.compile(g, "bfs", flip.ExecutionPlan(distributed=True))
    with pytest.raises(ValueError, match="distributed"):
        cq.query(0, trace=True)


# ---------------------------------------------------------------- #
# compile-time attribution
# ---------------------------------------------------------------- #
def test_compile_s_first_dispatch_only():
    g = make_road_network(160, seed=0)
    cq = flip.compile(g, "bfs", _plan())
    r1 = cq.query(3)
    r2 = cq.query(5)
    assert 0.0 < r1.compile_s <= r1.wall_s
    assert r1.compile_s == pytest.approx(r1.wall_s, rel=0.05)
    assert r2.compile_s == 0.0 and r2.wall_s > 0.0
    # tracing compiles its own executable (extended carry) -> first
    # traced dispatch is compile-attributed again; the second is not
    t1 = cq.query(3, trace=True)
    t2 = cq.query(3, trace=True)
    assert t1.compile_s > 0.0 and t2.compile_s == 0.0


def test_compile_s_bucketed():
    g = make_road_network(160, seed=0)
    cq = flip.compile(g, "bfs", _plan(batch=4))
    srcs = list(range(10))
    r1 = cq.query(srcs)
    r2 = cq.query(srcs)
    assert r1.dispatches == 3 and r2.dispatches == 3
    assert r1.compile_s > 0.0 and r2.compile_s == 0.0


def test_bucketed_trace_collects_all_dispatches():
    g = make_road_network(160, seed=0)
    cq = flip.compile(g, "bfs", _plan(batch=4))
    rt = cq.query(list(range(10)), trace=True)
    assert len(rt.telemetry.dispatches) == rt.dispatches == 3
    # per-query step counts across dispatches match the solo runs
    solo = flip.compile(g, "bfs", _plan())
    for s in (0, 4, 9):
        assert int(np.asarray(rt.steps)[s]) == solo.query(s).steps
    hist = rt.telemetry.steps_histogram()
    assert sum(hist.values()) == 12      # 3 padded buckets of B=4


# ---------------------------------------------------------------- #
# server stats
# ---------------------------------------------------------------- #
def test_server_stats_shape_and_monotonicity():
    from repro.launch.serve_graph import GraphServer
    g = make_power_law(256, 768, seed=0)
    srv = GraphServer(g, batch=4, tile=64)
    rng = np.random.default_rng(0)
    stream = [(a, int(rng.integers(g.n)))
              for a in ["bfs", "sssp"] * 6]
    srv.serve(stream)
    s1 = srv.stats()
    json.dumps(s1)                       # JSON-ready all the way down
    assert s1["queue_depth"] == 0
    assert s1["completed"] == 12
    assert s1["sessions_cached"] == 2
    assert s1["session_cache"]["misses"] == 2
    assert s1["session_cache"]["hits"] >= 2
    h = s1["metrics"]["histograms"]
    for algo in ("bfs", "sssp"):
        for kind in ("latency_s", "queue_wait_s", "service_s", "steps"):
            hh = h[f"{kind}.{algo}"]
            assert hh["count"] == 6, (kind, algo)
        assert h[f"latency_s.{algo}"]["sum"] > 0.0
        assert h[f"latency_s.{algo}"]["p95"] >= h[f"latency_s.{algo}"]["p50"]
    assert h["compile_s"]["count"] >= 2   # one first dispatch per algebra

    # more traffic plus an update: counters only move up
    srv.serve([("bfs", 1), ("bfs", 2), ("update", [(0, 1, 0.5)]),
               ("sssp", 3)])
    s2 = srv.stats()
    assert s2["completed"] == 15
    assert s2["updates_applied"] == 1
    assert s2["metrics"]["counters"]["requests.completed"] == 15
    assert s2["session_cache"]["hits"] > s1["session_cache"]["hits"]
    assert s2["metrics"]["histograms"]["update_s"]["count"] == 1
    assert s2["metrics"]["histograms"]["rebuild_s"]["count"] == 2
    for k, v in s1["metrics"]["counters"].items():
        assert s2["metrics"]["counters"][k] >= v, k


# ---------------------------------------------------------------- #
# exporters
# ---------------------------------------------------------------- #
def test_chrome_trace_roundtrip(tmp_path):
    g = make_power_law(256, 768, seed=0)
    cq = flip.compile(g, "bfs", flip.ExecutionPlan())
    rt = cq.query([0, 5], trace=True)
    path = str(tmp_path / "trace.json")
    write_chrome_trace(path, rt)
    with open(path) as f:
        doc = json.load(f)
    assert doc == chrome_trace_from_result(rt)
    evs = doc["traceEvents"]
    steps = [e for e in evs if e["ph"] == "X"
             and e["name"].startswith("step ")]
    assert len(steps) == int(np.asarray(rt.steps).max())
    assert all(e["dur"] >= 0 and "args" in e for e in steps)
    assert {"active_vertices", "active_tiles", "blocks_fetched",
            "blocks_skipped", "live_queries"} <= set(steps[0]["args"])
    assert any(e["ph"] == "C" and e["name"] == "frontier" for e in evs)

    with pytest.raises(ValueError, match="trace=True"):
        chrome_trace_from_result(cq.query(0))


def test_telemetry_to_json_roundtrip():
    g = make_road_network(160, seed=0)
    rt = flip.compile(g, "bfs", _plan()).query(SRCS8[:4], trace=True)
    doc = json.loads(json.dumps(rt.telemetry.to_json()))
    assert doc["summary"]["traced_steps"] == \
        len(rt.telemetry.dispatches[0].trace)
    assert len(doc["dispatches"]) == 1
    tr = doc["dispatches"][0]["trace"]
    assert len(tr["active_vertices"]) == doc["summary"]["traced_steps"]


def test_from_sim_schema():
    sim = types.SimpleNamespace(
        parallelism_trace=[1, 3, 2, 0], cycles=4,
        attrs=np.zeros(16, np.float32), packets_delivered=9,
        edges_relaxed=6, avg_parallelism=1.5, max_parallelism=3, swaps=1)
    tele = from_sim(sim, freq_mhz=100.0)
    assert isinstance(tele, QueryTelemetry)
    d = tele.dispatches[0]
    assert d.backend == "sim" and d.batch == 1
    assert len(d.trace) == 4
    np.testing.assert_array_equal(d.trace.active_vertices[:, 0],
                                  [1, 3, 2, 0])
    assert d.trace.step_wall_s is not None
    assert tele.wall_s == pytest.approx(4 * 1e-6 / 100.0)
    assert d.meta["packets_delivered"] == 9
    json.dumps(tele.to_json())           # whole schema is JSON-clean


# ---------------------------------------------------------------- #
# metrics registry
# ---------------------------------------------------------------- #
def test_counter_monotone():
    c = Counter("x")
    c.inc()
    c.inc(4)
    assert c.snapshot() == 5
    with pytest.raises(ValueError):
        c.inc(-1)


def test_histogram_quantiles_exact_below_capacity():
    h = Histogram("lat", capacity=256)
    for v in range(100):                 # 0..99, exact (under capacity)
        h.observe(float(v))
    s = h.snapshot()
    assert s["count"] == 100 and s["min"] == 0.0 and s["max"] == 99.0
    assert s["mean"] == pytest.approx(49.5)
    assert abs(s["p50"] - 49.5) <= 1.0
    assert s["p95"] >= 93.0 and s["p99"] >= 97.0


def test_histogram_reservoir_bounded():
    h = Histogram("lat", capacity=64)
    for v in range(10_000):
        h.observe(float(v % 100))
    assert len(h._reservoir) == 64
    assert h.count == 10_000
    assert 0.0 <= h.quantile(0.5) <= 99.0


def test_registry_snapshot_and_exports(tmp_path):
    m = MetricsRegistry()
    m.counter("req").inc(3)
    m.gauge("depth").set(7)
    m.histogram("lat").observe(0.25)
    m.emit("dispatch", algo="bfs", batch=4)
    snap = m.snapshot()
    assert snap["counters"]["req"] == 3
    assert snap["gauges"]["depth"] == 7.0
    assert snap["histograms"]["lat"]["count"] == 1
    p = m.write_snapshot_json(str(tmp_path / "snap.json"))
    with open(p) as f:
        assert json.load(f) == snap
    p = m.write_events_jsonl(str(tmp_path / "events.jsonl"))
    with open(p) as f:
        lines = [json.loads(ln) for ln in f]
    assert len(lines) == 1
    assert lines[0]["kind"] == "dispatch" and lines[0]["algo"] == "bfs"
    assert m.counter("req") is m.counter("req")   # get-or-create
