"""Frontier-compacted block streaming: exactness and layout.

Compaction must be invisible to results: a compacted relax step streams
only blocks with an active source tile (inactive slots point at one
all-identity sentinel block), and because the ⊕-identity annihilates ⊗
the outcome is bit-for-bit the dense-streaming result -- across every
registered algebra, on the jnp fallback and the Pallas-interpret kernel,
solo and batched, including the all-inactive and all-active frontier edge
cases and destinations kept alive only by their carry.
"""
import jax.numpy as jnp
import numpy as np
import pytest
from conftest import (ALGOS, cpu_only, masked_src_vals as _src_vals,
                      tiled_state)

from repro.algebra import ALGEBRAS, get_algebra
from repro.core.engine import FlipEngine
from repro.graphs import Graph, make_power_law, make_synthetic, reference
from repro.kernels.frontier import (build_blocks, compact_block_stream,
                                    frontier_relax, tile_activity)

# named frontier densities; "edge" cases required by the compaction
# contract: all-inactive (everything sentinel) and all-active (compaction
# degenerates to the dense stream)
DENSITIES = ("none", "tile0", 0.5, "all")


def _state(bg, rng, batch):
    return tiled_state(bg, rng, batch)


@pytest.mark.parametrize("batch", [0, 32], ids=["solo", "b32"])
@pytest.mark.parametrize("algo", ALGOS)
def test_compact_bitexact_vs_dense_jnp(algo, batch):
    g = make_power_law(96, 280, seed=7)
    bg = build_blocks(g, algo, tile=16)
    rng = np.random.default_rng(0)
    attrs = _state(bg, rng, batch)
    for density in DENSITIES:
        sv = _src_vals(bg, attrs, rng, density)
        dense = frontier_relax(sv, attrs, bg, mode="jnp", compact=False)
        comp = frontier_relax(sv, attrs, bg, mode="jnp", compact=True)
        np.testing.assert_array_equal(np.asarray(dense), np.asarray(comp),
                                      err_msg=f"{algo} density={density}")


@pytest.mark.parametrize("batch", [0, 32], ids=["solo", "b32"])
@pytest.mark.parametrize("algo", ALGOS)
def test_compact_bitexact_vs_dense_interpret(algo, batch):
    """Same contract through the Pallas kernel body (interpret mode):
    the sentinel-indexed block stream and the compacted bsrc/bdst scalar
    prefetch must reproduce the dense grid bit-for-bit."""
    g = make_synthetic(24, 70, seed=2)
    bg = build_blocks(g, algo, tile=8)
    rng = np.random.default_rng(1)
    attrs = _state(bg, rng, batch)
    for density in DENSITIES:
        sv = _src_vals(bg, attrs, rng, density)
        dense = frontier_relax(sv, attrs, bg, mode="interpret",
                               compact=False)
        comp = frontier_relax(sv, attrs, bg, mode="interpret",
                              compact=True)
        np.testing.assert_array_equal(np.asarray(dense), np.asarray(comp),
                                      err_msg=f"{algo} density={density}")


@pytest.mark.parametrize("mode", ["jnp", "interpret"])
def test_compact_carry_only_destination(mode):
    """A destination tile whose only incident block has an inactive source
    is fully compacted out of the stream; its output must be the carry,
    bit-for-bit (kernel: input_output_aliases; jnp: segment-⊕ identity)."""
    # tile 1 (vertices 8..15) receives edges only from tile 2; activate
    # only tile 0, so every block writing tile 1 is inactive
    edges = [(0, 1), (1, 2), (2, 3), (16, 8), (17, 9), (0, 17)]
    g = Graph.from_edges(24, edges,
                         weights=[2.0] * len(edges), directed=True)
    bg = build_blocks(g, "sssp", tile=8)
    rng = np.random.default_rng(3)
    attrs = _state(bg, rng, 0)
    mask = np.zeros(attrs.shape, dtype=bool)
    mask[0, :] = True
    sv = jnp.where(jnp.asarray(mask), attrs, np.float32(np.inf))
    dense = np.asarray(frontier_relax(sv, attrs, bg, mode=mode,
                                      compact=False))
    comp = np.asarray(frontier_relax(sv, attrs, bg, mode=mode,
                                     compact=True))
    np.testing.assert_array_equal(dense, comp)
    # the carry-only tile came back untouched
    np.testing.assert_array_equal(comp[1], np.asarray(attrs)[1])
    # and the relax really did something elsewhere (tile 0 improved)
    assert (comp[0] <= np.asarray(attrs)[0]).all()
    assert (comp[0] < np.asarray(attrs)[0]).any()


def test_compact_block_stream_layout():
    """Masked-cumsum compaction: stable (bdst order preserved), active
    prefix exact, inactive tail = sentinel index repeating the last
    active block's tile pair (so consecutive index maps are equal and the
    pipeline skips the re-fetch)."""
    bsrc = jnp.asarray([0, 1, 2, 0, 1], jnp.int32)
    bdst = jnp.asarray([0, 0, 0, 1, 2], jnp.int32)   # (bdst, bsrc)-sorted
    nb = 5
    act = jnp.asarray([True, False, True])
    bsel, bsrc_c, bdst_c, na = compact_block_stream(act, bsrc, bdst)
    assert int(na) == 3
    np.testing.assert_array_equal(np.asarray(bsel), [0, 2, 3, nb, nb])
    np.testing.assert_array_equal(np.asarray(bsrc_c), [0, 2, 0, 0, 0])
    np.testing.assert_array_equal(np.asarray(bdst_c), [0, 0, 1, 1, 1])
    assert (np.diff(np.asarray(bdst_c)) >= 0).all()   # still bdst-sorted

    # all-inactive: every slot is the sentinel, tile pair = last block's
    bsel, bsrc_c, bdst_c, na = compact_block_stream(
        jnp.zeros(3, bool), bsrc, bdst)
    assert int(na) == 0
    np.testing.assert_array_equal(np.asarray(bsel), [nb] * nb)
    np.testing.assert_array_equal(np.asarray(bsrc_c), [1] * nb)
    np.testing.assert_array_equal(np.asarray(bdst_c), [2] * nb)

    # all-active: identity selection
    bsel, bsrc_c, bdst_c, na = compact_block_stream(
        jnp.ones(3, bool), bsrc, bdst)
    assert int(na) == nb
    np.testing.assert_array_equal(np.asarray(bsel), np.arange(nb))
    np.testing.assert_array_equal(np.asarray(bsrc_c), np.asarray(bsrc))
    np.testing.assert_array_equal(np.asarray(bdst_c), np.asarray(bdst))


def test_tile_activity_matches_trigger():
    g = make_synthetic(40, 110, seed=4)
    bg = build_blocks(g, "sssp", tile=8)
    rng = np.random.default_rng(0)
    attrs = _state(bg, rng, 4)                       # batched
    mask = rng.random(attrs.shape) < 0.1
    sv = jnp.where(jnp.asarray(mask), attrs, np.float32(np.inf))
    act = np.asarray(tile_activity(sv, bg.semiring))
    want = np.asarray(sv != np.inf).any(axis=(0, 2))
    np.testing.assert_array_equal(act, want)


@pytest.mark.parametrize("algo", ALGOS)
def test_engine_compact_fixpoint_bitexact(algo):
    """End-to-end: the host-driven bucketed fixpoint (compact, jnp) is
    bit-for-bit the dense while_loop fixpoint -- results and per-query
    step counts -- and matches the oracle."""
    g = make_power_law(64, 190, seed=3)
    srcs = np.array([3, 11, 0, 27, 42, 8, 19, 33]) % g.n
    dense = FlipEngine.build(g, algo, tile=16, relax_mode="jnp",
                             compact=False)
    comp = FlipEngine.build(g, algo, tile=16, relax_mode="jnp",
                            compact=True)
    o1, s1 = dense.run_batch(srcs)
    o2, s2 = comp.run_batch(srcs)
    np.testing.assert_array_equal(o1, o2)
    np.testing.assert_array_equal(s1, s2)
    solo, st = comp.run(int(srcs[0]))
    np.testing.assert_array_equal(o2[0], solo)
    assert s2[0] == st
    ref, _ = reference.run(algo, g, int(srcs[0]))
    assert ALGEBRAS[algo].results_match(o2[0], ref)


def test_compact_auto_resolution():
    g = make_synthetic(20, 50, seed=0)
    assert FlipEngine.build(g, "bfs", tile=8, mode="data")._use_compact
    assert not FlipEngine.build(g, "bfs", tile=8, mode="op")._use_compact
    assert FlipEngine.build(g, "bfs", tile=8, mode="op",
                            compact=True)._use_compact
    assert not FlipEngine.build(g, "bfs", tile=8, mode="data",
                                compact=False)._use_compact


@cpu_only
def test_pallas_mode_off_tpu_raises_clear_error():
    g = make_synthetic(20, 50, seed=0)
    bg = build_blocks(g, "bfs", tile=8)
    attrs = _state(bg, np.random.default_rng(0), 0)
    with pytest.raises(ValueError, match="needs a TPU backend"):
        frontier_relax(attrs, attrs, bg, mode="pallas")


# ------------------------------------------------------------------ #
# vectorized build_blocks: exact vs the per-edge reference algorithm
# ------------------------------------------------------------------ #
def _build_blocks_ref(graph, algo, tile, order=None):
    """The pre-vectorization per-edge/dict algorithm, kept as the oracle
    for the numpy key-sort + ufunc.at scatter build."""
    alg = get_algebra(algo)
    sr = alg.semiring
    n = graph.n
    if order is None:
        order = np.arange(n)
    perm = np.empty(n, dtype=np.int64)
    perm[order] = np.arange(n)
    ntiles = max(1, -(-n // tile))
    outdeg = graph.out_degree()
    edges = []
    for u, v, w in graph.edge_list():
        wval = alg.edge_value(u, v, w, outdeg)
        edges.append((perm[u], perm[v], wval))
        if alg.undirected:
            edges.append((perm[v], perm[u], wval))
    by_block = {}
    for pu, pv, w in edges:
        by_block.setdefault((pv // tile, pu // tile), []).append(
            (pu % tile, pv % tile, w))
    for d in range(ntiles):
        by_block.setdefault((d, d), [])
    keys = sorted(by_block)
    blocks = np.full((len(keys), tile, tile), np.float32(sr.zero),
                     dtype=np.float32)
    bsrc = np.empty(len(keys), np.int32)
    bdst = np.empty(len(keys), np.int32)
    for i, (d, s) in enumerate(keys):
        bdst[i], bsrc[i] = d, s
        for su, dv, w in by_block[(d, s)]:
            blocks[i, su, dv] = sr.add_np(blocks[i, su, dv], np.float32(w))
    return blocks, bsrc, bdst


@pytest.mark.parametrize("algo", ALGOS)
def test_build_blocks_matches_python_reference(algo):
    g = make_synthetic(37, 120, seed=2)              # ragged: 37 = 2*16+5
    rng = np.random.default_rng(5)
    for order in (None, rng.permutation(g.n)):
        bg = build_blocks(g, algo, tile=16, order=order)
        blocks, bsrc, bdst = _build_blocks_ref(g, algo, 16, order)
        np.testing.assert_array_equal(np.asarray(bg.bsrc), bsrc)
        np.testing.assert_array_equal(np.asarray(bg.bdst), bdst)
        np.testing.assert_array_equal(np.asarray(bg.blocks), blocks)


def test_blocked_graph_layout_helpers():
    g = make_power_law(96, 280, seed=7)
    bg = build_blocks(g, "sssp", tile=16)
    nb = bg.blocks.shape[0]
    # sentinel extension: one extra all-⊕-identity block at index nb
    ext = np.asarray(bg.blocks_ext)
    assert ext.shape == (nb + 1, bg.tile, bg.tile)
    np.testing.assert_array_equal(ext[:nb], np.asarray(bg.blocks))
    assert (ext[nb] == np.float32(bg.semiring.zero)).all()
    # per-destination segment layout covers the sorted list exactly
    ds = np.asarray(bg.dst_start)
    bdst = np.asarray(bg.bdst)
    assert ds[0] == 0 and ds[-1] == nb
    for d in range(bg.ntiles):
        seg = bdst[ds[d]:ds[d + 1]]
        assert (seg == d).all() and len(seg) >= 1   # diag guarantees >=1
