"""The unified query API (`repro.api` / `import flip`).

The redesign's contract, proven here:

  * `flip.compile(graph, program, plan).query(srcs)` is bit-exact vs
    every legacy `FlipEngine.run*` entry point -- solo, batched,
    distributed, and incremental-recompute -- across all registered
    algebras x {jnp, interpret} relax modes;
  * the legacy `run*` methods are deprecated shims (DeprecationWarning)
    over the same executor;
  * `ExecutionPlan` validation rejects inconsistent knob combinations
    at compile time;
  * a `Program`-defined custom algorithm (algebra + oracle registered
    atomically in one call) round-trips through the engine, the
    `reference.run` dispatch, and `QueryResult.check`.
"""
import warnings

import numpy as np
import pytest
from conftest import ALGOS, assert_close, oracle

import flip
from repro.algebra import ALGEBRAS, Semiring, VertexAlgebra
from repro.core.engine import FlipEngine, WarmStart
from repro.graphs import make_power_law, make_synthetic, reference


def _legacy(eng, method, *args, **kw):
    """Call a deprecated shim with its warning silenced (the warning
    itself is asserted once in test_legacy_shims_warn)."""
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        return getattr(eng, method)(*args, **kw)


def _monotone_batch(g):
    """⊕-improving reweights: halve the first three edge weights."""
    eu = g.edge_sources()
    return [(int(eu[i]), int(g.indices[i]), float(g.weights[i]) * 0.5)
            for i in range(3)]


# --------------------------------------------------------------------- #
# bit-exact parity: new surface vs legacy run* paths
# --------------------------------------------------------------------- #
@pytest.mark.parametrize("algo", ALGOS)
@pytest.mark.parametrize("relax", ["jnp", "interpret"])
def test_query_parity_solo_and_batch(algo, relax):
    """query(src) == run(src) and query(srcs) == run_batch(srcs),
    bit-for-bit, for every algebra on both CPU kernel paths."""
    g = make_synthetic(40, 110, seed=3)
    cq = flip.compile(g, algo,
                      flip.ExecutionPlan(tile=16, relax_mode=relax))
    r = cq.query(2)
    out, steps = _legacy(cq.engine, "run", 2)
    np.testing.assert_array_equal(r.attrs, out)
    assert r.steps == steps
    assert_close(r.attrs, oracle(algo, g, 2), algo, "solo")

    srcs = np.array([2, 7, 19])
    rb = cq.query(srcs)
    outs, steps = _legacy(cq.engine, "run_batch", srcs)
    np.testing.assert_array_equal(rb.attrs, outs)
    np.testing.assert_array_equal(rb.steps, steps)
    assert rb.check()


@pytest.mark.parametrize("algo", ["sssp", "pagerank"])
def test_query_parity_distributed(algo):
    """A distributed plan routes through the shard_map fixpoint and is
    bit-exact vs run_distributed and vs the local path."""
    g = make_synthetic(48, 140, seed=5)
    plan = flip.ExecutionPlan(tile=16, relax_mode="jnp",
                              distributed=True)
    cq = flip.compile(g, algo, plan)
    assert cq.plan.distributed
    r = cq.query(3)
    out, steps = _legacy(cq.engine, "run_distributed", 3)
    np.testing.assert_array_equal(r.attrs, out)
    assert r.steps == steps
    local = flip.compile(
        g, algo, flip.ExecutionPlan(tile=16, relax_mode="jnp")).query(3)
    np.testing.assert_array_equal(r.attrs, local.attrs)


@pytest.mark.parametrize("algo", ["sssp", "bfs", "widest"])
def test_query_parity_incremental(algo):
    """session.update + query(warm=prev) == run_updated == scratch,
    bit-for-bit (the incremental-recompute leg of the old surface)."""
    g = make_power_law(48, 140, seed=7)
    cq = flip.compile(g, algo,
                      flip.ExecutionPlan(tile=16, relax_mode="jnp"))
    prev = cq.query(3)
    batch = _monotone_batch(g)
    cq2, delta = cq.update(batch)
    warm = cq2.query(3, warm=prev)
    legacy_out, legacy_steps = _legacy(cq2.engine, "run_updated", 3,
                                       prev.attrs, delta)
    np.testing.assert_array_equal(warm.attrs, legacy_out)
    assert warm.steps == legacy_steps
    scratch = cq2.query(3)
    np.testing.assert_array_equal(warm.attrs, scratch.attrs)
    if delta.monotone and ALGEBRAS[algo].kind == "monotone":
        assert warm.steps <= scratch.steps
    assert_close(warm.attrs, oracle(algo, cq2.graph, 3), algo, "incr")


def test_query_nonmonotone_update_falls_back_to_scratch():
    """warm='auto' on a delete (non-monotone delta): query(warm=...)
    silently recomputes from scratch, exactly like run_updated did."""
    g = make_power_law(48, 140, seed=2)
    cq = flip.compile(g, "sssp",
                      flip.ExecutionPlan(tile=16, relax_mode="jnp"))
    prev = cq.query(1)
    eu = g.edge_sources()
    cq2, delta = cq.update([(int(eu[0]), int(g.indices[0]), None)])
    assert not delta.monotone
    warm = cq2.query(1, warm=prev)
    scratch = cq2.query(1)
    np.testing.assert_array_equal(warm.attrs, scratch.attrs)
    assert warm.steps == scratch.steps          # no resume happened


def test_bucketed_dispatch_is_bitexact():
    """plan.batch > 0: padded fixed-size buckets return exactly the
    solo-run rows (the serving policy, now a plan knob) -- including a
    short sequence, which pads to one full bucket rather than tracing a
    tail-sized executable."""
    g = make_synthetic(40, 110, seed=9)
    cq = flip.compile(g, "bfs",
                      flip.ExecutionPlan(tile=16, relax_mode="jnp",
                                         batch=4))
    srcs = np.array([3, 11, 0, 27, 5, 19])     # 6 queries -> 2 dispatches
    r = cq.query(srcs)
    assert r.dispatches == 2
    assert r.attrs.shape == (6, g.n)
    solo = flip.compile(g, "bfs",
                        flip.ExecutionPlan(tile=16, relax_mode="jnp"))
    for b, s in enumerate(srcs):
        np.testing.assert_array_equal(r.attrs[b],
                                      solo.query(int(s)).attrs)
    short = cq.query(np.array([3, 11]))        # < B: one padded bucket
    assert short.dispatches == 1
    assert short.attrs.shape == (2, g.n)
    np.testing.assert_array_equal(short.attrs, r.attrs[:2])
    empty = cq.query(np.array([], dtype=np.int64))   # degenerate batch
    assert empty.attrs.shape == (0, g.n)
    assert empty.steps.shape == (0,)


# --------------------------------------------------------------------- #
# deprecated shims
# --------------------------------------------------------------------- #
def test_legacy_shims_warn():
    g = make_synthetic(40, 110, seed=0)
    eng = FlipEngine.build(g, "sssp", tile=16, relax_mode="jnp")
    with pytest.warns(DeprecationWarning, match="run is deprecated"):
        eng.run(0)
    with pytest.warns(DeprecationWarning, match="run_batch"):
        eng.run_batch([0, 1])
    with pytest.warns(DeprecationWarning, match="run_distributed"):
        eng.run_distributed(0)
    prev, _ = _legacy(eng, "run", 0)
    batch = _monotone_batch(g)
    eng2, delta = eng.apply_updates(g.apply_updates(batch), batch)
    with pytest.warns(DeprecationWarning, match="run_updated"):
        eng2.run_updated(0, prev, delta)


# --------------------------------------------------------------------- #
# ExecutionPlan validation
# --------------------------------------------------------------------- #
def test_plan_rejects_bad_combos():
    with pytest.raises(ValueError, match="compact=True is inconsistent"):
        flip.ExecutionPlan(mode="op", compact=True).resolve()
    with pytest.raises(ValueError, match="plan.mode"):
        flip.ExecutionPlan(mode="dataa").resolve()
    with pytest.raises(ValueError, match="plan.relax_mode"):
        flip.ExecutionPlan(relax_mode="cuda").resolve()
    with pytest.raises(ValueError, match="plan.batch"):
        flip.ExecutionPlan(batch=-1).resolve()
    with pytest.raises(ValueError, match="plan.tile"):
        flip.ExecutionPlan(tile=0).resolve()
    with pytest.raises(ValueError, match="plan.warm"):
        flip.ExecutionPlan(warm="maybe").resolve()
    with pytest.raises(ValueError, match="plan.max_steps"):
        flip.ExecutionPlan(max_steps=0).resolve()
    # warm='always' is unsound for residual algebras
    with pytest.raises(ValueError, match="monotone algebra"):
        flip.ExecutionPlan(warm="always").resolve(ALGEBRAS["pagerank"])


def test_plan_resolution_collapses_auto():
    plan = flip.ExecutionPlan().resolve()
    assert plan.relax_mode in ("jnp", "pallas")     # backend-concrete
    assert plan.compact is True                     # data mode default
    assert flip.ExecutionPlan(mode="op").resolve().compact is False
    # a mesh implies distributed execution
    import jax
    from jax.sharding import Mesh
    mesh = Mesh(np.array(jax.devices()), ("data",))
    assert flip.ExecutionPlan(mesh=mesh).resolve().distributed
    # resolution is idempotent
    assert plan.resolve() == plan


def test_plan_warm_never_forbids_warm_queries():
    g = make_synthetic(40, 110, seed=1)
    cq = flip.compile(g, "sssp",
                      flip.ExecutionPlan(tile=16, relax_mode="jnp",
                                         warm="never"))
    prev = cq.query(0)
    cq2, _ = cq.update(_monotone_batch(g))
    with pytest.raises(ValueError, match="warm='never'"):
        cq2.query(0, warm=prev)


def test_plan_warm_always_rejects_unsound_resume():
    g = make_synthetic(40, 110, seed=1)
    cq = flip.compile(g, "sssp",
                      flip.ExecutionPlan(tile=16, relax_mode="jnp",
                                         warm="always"))
    prev = cq.query(0)
    eu = g.edge_sources()
    cq2, delta = cq.update([(int(eu[0]), int(g.indices[0]), None)])
    assert not delta.monotone
    with pytest.raises(ValueError, match="unsound"):
        cq2.query(0, warm=prev)


def test_warm_from_stale_graph_version_rejected():
    """A warm result older than the session's last update carries
    improvements the delta's seeds cannot re-derive: resuming from it
    must error, not silently return wrong attrs."""
    g = make_power_law(48, 140, seed=7)
    cq = flip.compile(g, "sssp",
                      flip.ExecutionPlan(tile=16, relax_mode="jnp"))
    prev = cq.query(3)
    eu = g.edge_sources()
    b1 = _monotone_batch(g)
    b2 = [(int(eu[9]), int(g.indices[9]), float(g.weights[9]) * 0.5)]
    cq2, _ = cq.update(b1)
    cq3, _ = cq2.update(b2)
    with pytest.raises(ValueError, match="pre-update graph version"):
        cq3.query(3, warm=prev)                # two updates stale
    mid = cq2.query(3, warm=prev)              # one update: fine
    fresh = cq3.query(3, warm=mid)             # stepwise: fine
    np.testing.assert_array_equal(fresh.attrs, cq3.query(3).attrs)
    # a warm result resumes only its own sources
    with pytest.raises(ValueError, match="same sources"):
        cq3.query(7, warm=mid)
    fan = cq3.query([3, 3], warm=mid)          # scalar fan-out: fine
    np.testing.assert_array_equal(fan.attrs[0], fresh.attrs)
    # (1, n) batched results fan out exactly like scalar ones
    mid_b = cq2.query([3], warm=None)
    fan_b = cq3.query([3, 3], warm=mid_b)
    np.testing.assert_array_equal(fan_b.attrs, fan.attrs)


def test_warm_without_update_delta_rejected():
    g = make_synthetic(40, 110, seed=1)
    cq = flip.compile(g, "sssp",
                      flip.ExecutionPlan(tile=16, relax_mode="jnp"))
    prev = cq.query(0)
    with pytest.raises(ValueError, match="no update delta"):
        cq.query(0, warm=prev)
    # ... but an explicit WarmStart resumes from arbitrary state
    r = cq.query(0, warm=WarmStart(prev.attrs, np.array([], np.int64)))
    np.testing.assert_array_equal(r.attrs, prev.attrs)
    assert r.steps == 0


def test_cli_alias_resolution():
    """--engine op folds into --engine jax --mode op with one warning;
    canonical spellings pass through silently."""
    with pytest.warns(DeprecationWarning, match="--engine op"):
        assert flip.resolve_cli_engine("op", "data") == ("jax", "op")
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        assert flip.resolve_cli_engine("jax", "op") == ("jax", "op")
        assert flip.resolve_cli_engine("dist", "data") == ("dist", "data")
    plan = flip.plan_from_cli("dist", "data")
    assert plan.distributed
    with pytest.raises(ValueError, match="no ExecutionPlan"):
        flip.plan_from_cli("sim", "data")


# --------------------------------------------------------------------- #
# Program: one-call algorithm registration
# --------------------------------------------------------------------- #
def test_program_round_trip_engine_and_oracle():
    """One Program.define call registers algebra + oracle atomically:
    the engine runs it, reference.run dispatches to the user's oracle,
    and QueryResult.check closes the loop."""
    import heapq

    import jax
    import jax.numpy as jnp

    min_max = Semiring(
        name="min_max_api", zero=float("inf"), one=float("-inf"),
        add_np=np.minimum, mul_np=np.maximum,
        add_jnp=jnp.minimum, mul_jnp=jnp.maximum,
        add_reduce_jnp=jnp.min,
        segment_reduce_jnp=lambda x, s, n: jax.ops.segment_min(
            x, s, num_segments=n),
        idempotent=True,
    )

    @flip.Program.define("minimax_api", min_max, weight_rule="graph")
    def minimax_oracle(g, src):
        best = np.full(g.n, np.inf, dtype=np.float32)
        best[src] = -np.inf
        heap = [(-np.inf, src)]
        while heap:
            d, u = heapq.heappop(heap)
            if d > best[u]:
                continue
            for k in range(g.indptr[u], g.indptr[u + 1]):
                v = int(g.indices[k])
                cand = max(d, float(g.weights[k]))
                if cand < best[v]:
                    best[v] = np.float32(cand)
                    heapq.heappush(heap, (cand, v))
        return best

    prog = minimax_oracle                  # the decorator returns Program
    assert isinstance(prog, flip.Program)
    try:
        assert "minimax_api" in ALGEBRAS               # engine registry
        g = make_synthetic(40, 120, seed=9)
        ref, stats = reference.run("minimax_api", g, 2)  # oracle registry
        assert stats == {}
        # compile by name, by algebra, and by Program: all equivalent
        for spec in ("minimax_api", prog.algebra, prog):
            r = flip.compile(
                g, spec,
                flip.ExecutionPlan(tile=16, relax_mode="jnp")).query(2)
            assert_close(r.attrs, ref, "minimax_api", "round-trip")
            assert r.check()
    finally:
        prog.unregister()
    assert "minimax_api" not in ALGEBRAS
    assert reference.get_oracle("minimax_api") is None
    with pytest.raises(ValueError, match="unknown algorithm"):
        reference.run("minimax_api", g, 2)


def test_program_define_without_register():
    """register=False compiles locally without touching the registries."""
    alg = VertexAlgebra("local_bfs", ALGEBRAS["bfs"].semiring,
                        weight_rule="hop")
    prog = flip.Program.define(algebra=alg,
                               oracle=lambda g, src: reference.bfs(g, src),
                               register=False)
    assert "local_bfs" not in ALGEBRAS
    g = make_synthetic(40, 110, seed=4)
    r = flip.compile(g, prog,
                     flip.ExecutionPlan(tile=16, relax_mode="jnp")).query(3)
    assert r.check()
    assert "local_bfs" not in ALGEBRAS


def test_program_get_wraps_builtins():
    prog = flip.Program.get("sssp")
    assert prog.name == "sssp" and prog.oracle is not None
    g = make_synthetic(30, 80, seed=0)
    np.testing.assert_array_equal(prog.reference(g, 1),
                                  oracle("sssp", g, 1))
    with pytest.raises(ValueError, match="unknown algorithm"):
        flip.Program.get("nope")
    with pytest.raises(TypeError, match="program must be"):
        flip.Program.of(42)


# --------------------------------------------------------------------- #
# serving: sessions cached by fingerprint + plan
# --------------------------------------------------------------------- #
def test_server_caches_sessions_by_fingerprint_and_plan():
    from repro.launch.serve_graph import GraphServer
    g = make_synthetic(40, 110, seed=5)
    srv = GraphServer(g, batch=2, tile=16, relax_mode="jnp")
    s1 = srv.session("sssp")
    assert srv.session("sssp") is s1               # cache hit
    srv.update(_monotone_batch(srv.graph))
    s2 = srv.session("sssp")
    assert s2 is not s1                            # new graph version
    assert s2.graph.fingerprint() == srv.graph.fingerprint()
    r = srv.serve([("sssp", 3)])[0]
    assert ALGEBRAS["sssp"].results_match(
        r.result, oracle("sssp", srv.graph, 3))
    # wholesale graph swaps supersede, not accumulate: one session per
    # algebra survives no matter how many versions were served
    for seed in (11, 12, 13):
        srv.graph = make_synthetic(40, 110, seed=seed)
        srv.serve([("sssp", 1)])
    assert len([k for k in srv._sessions if k[0] == "sssp"]) == 1
