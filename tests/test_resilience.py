"""Serving-layer resilience: taxonomy, budgets, ladder, shedding, chaos.

The contract under test (docs/RESILIENCE.md):

  * every failure is TYPED (`FlipError` subclass) and attached to the
    request that caused it -- a failing request can never take down its
    bucket, its stream, or the server;
  * budget-stopped fixpoints (`max_steps` / `deadline_s`) come back as
    FLAGGED partials (`converged=False` + typed error), never silent
    truncations -- across both the host-driven and the jitted
    while_loop fixpoint;
  * the degradation ladder (pallas->jnp, compact->dense) is EXACT:
    a degraded response is bit-for-bit the primary response;
  * admission control sheds the newest request with `CapacityExceeded`;
  * the chaos replay: a seeded fault schedule (backend raise, NaN
    poison, step stall, plus deadline/budget pressure) over a mixed
    query+update stream loses zero requests and keeps every success
    oracle-exact.
"""
import time

import numpy as np
import pytest
from conftest import oracle

import flip
from repro.algebra import ALGEBRAS
from repro.distributed.health import HeartbeatMonitor
from repro.graphs import make_power_law
from repro.launch.serve_graph import GraphServer
from repro.resilience import (BackendFailure, CapacityExceeded,
                              ConvergenceFailure, DeadlineExceeded,
                              FaultInjector, FaultSpec, FlipError,
                              InjectedFault, InvalidRequest, classify,
                              fallback_chain, finite_guard)

TILE = 16


@pytest.fixture(scope="module")
def g():
    return make_power_law(60, 180, seed=3)


# ------------------------------------------------------------------ #
# taxonomy
# ------------------------------------------------------------------ #
def test_error_taxonomy_shape():
    cases = [
        (InvalidRequest("bad", value=-1), "invalid_request", False),
        (CapacityExceeded("full", depth=3, limit=3),
         "capacity_exceeded", False),
        (DeadlineExceeded("late", deadline_s=1.0, elapsed_s=2.0),
         "deadline_exceeded", False),
        (ConvergenceFailure("partial", steps=5, max_steps=5),
         "convergence_failure", False),
        (BackendFailure("boom", rung=0), "backend_failure", True),
    ]
    codes = set()
    for err, code, retryable in cases:
        assert isinstance(err, FlipError)
        assert err.code == code
        assert err.retryable is retryable
        d = err.describe()
        assert d["code"] == code and d["type"] == type(err).__name__
        codes.add(code)
    assert len(codes) == 5          # codes are distinct machine ids
    # pre-taxonomy `except ValueError` call sites keep working
    assert isinstance(InvalidRequest("x"), ValueError)
    assert not isinstance(CapacityExceeded("x"), ValueError)


def test_classify_passthrough_and_wrap():
    e = InvalidRequest("bad")
    assert classify(e, rung=1) is e
    wrapped = classify(RuntimeError("xla died"), rung=2)
    assert isinstance(wrapped, BackendFailure) and wrapped.retryable
    assert wrapped.rung == 2
    assert isinstance(wrapped.cause, RuntimeError)


def test_finite_guard():
    finite_guard(np.array([1.0, np.inf, -np.inf]))   # ±inf legitimate
    with pytest.raises(BackendFailure):
        finite_guard(np.array([[1.0, np.nan], [2.0, 3.0]]))


# ------------------------------------------------------------------ #
# degradation ladder
# ------------------------------------------------------------------ #
def test_fallback_chain_is_validated_and_terminates():
    alg = ALGEBRAS["sssp"]
    chain = fallback_chain(flip.ExecutionPlan(mode="data", tile=TILE), alg)
    assert len(chain) >= 1
    keys = [p.key() for p in chain]
    assert len(keys) == len(set(keys))          # no duplicate rungs
    assert chain[-1].relax_mode == "jnp" and chain[-1].compact is False
    # a plan already at the bottom gets a one-rung chain
    bottom = flip.ExecutionPlan(mode="op", relax_mode="jnp",
                                compact=False, tile=TILE)
    assert len(fallback_chain(bottom, alg)) == 1


def test_degraded_rungs_bit_exact(g):
    """Every ladder rung returns bit-for-bit the primary result."""
    plan = flip.ExecutionPlan(mode="data", tile=TILE)
    chain = fallback_chain(plan, ALGEBRAS["sssp"])
    assert len(chain) >= 2
    srcs = [0, 7, 13, 21]
    ref = flip.compile(g, "sssp", chain[0]).query(srcs)
    for rung in chain[1:]:
        got = flip.compile(g, "sssp", rung).query(srcs)
        np.testing.assert_array_equal(got.attrs, ref.attrs)
        np.testing.assert_array_equal(got.steps, ref.steps)


def test_server_ladder_result_bit_exact_with_primary(g):
    """A fault-degraded server response equals the no-fault response."""
    srcs = list(range(8))
    clean = GraphServer(g, batch=4, tile=TILE)
    ok = [clean.submit("sssp", s) for s in srcs]
    clean.drain()
    inj = FaultInjector(specs=[FaultSpec(kind="raise", dispatch=d, rung=0)
                               for d in range(2)])
    srv = GraphServer(g, batch=4, tile=TILE, fault_injector=inj)
    degraded = [srv.submit("sssp", s) for s in srcs]
    srv.drain()
    assert all(r.ok and r.rung == 1 for r in degraded)
    assert len(inj.fired) == 2
    for a, b in zip(ok, degraded):
        np.testing.assert_array_equal(a.result, b.result)
        assert a.steps == b.steps
    assert srv.metrics.sum_counters("fallback.") == 2
    assert srv.stats()["resilience"]["fallbacks"] == 2


# ------------------------------------------------------------------ #
# truncated fixpoints: host-driven AND jitted while_loop
# ------------------------------------------------------------------ #
TRUNC_PLANS = [
    # compact+jnp routes through the host-driven fixpoint
    pytest.param(dict(mode="data", compact=True, relax_mode="jnp"),
                 id="host-compact"),
    # dense data mode runs the jitted while_loop fixpoint
    pytest.param(dict(mode="data", compact=False, relax_mode="jnp"),
                 id="jit-dense"),
    # op mode: full-sweep jitted while_loop
    pytest.param(dict(mode="op", relax_mode="jnp"), id="jit-op"),
]


@pytest.mark.parametrize("knobs", TRUNC_PLANS)
def test_truncated_fixpoint_flagged_not_silent(g, knobs):
    cq = flip.compile(g, "sssp", flip.ExecutionPlan(tile=TILE, **knobs))
    srcs = [0, 7, 13, 21]
    base = cq.query(srcs)
    assert base.all_converged
    base.check()                       # oracle-exact when converged
    steps = np.atleast_1d(base.steps)
    cap = int(steps.max()) - 1
    assert cap >= 1, "fixture graph must need >= 2 steps"
    part = cq.query(srcs, max_steps=cap)
    want_conv = steps <= cap
    np.testing.assert_array_equal(np.atleast_1d(part.converged),
                                  want_conv)
    assert not part.all_converged
    # converged rows are bit-exact; the partial is flagged, and check()
    # refuses to certify it
    for b, conv in enumerate(want_conv):
        if conv:
            np.testing.assert_array_equal(part.attrs[b], base.attrs[b])
    with pytest.raises(ConvergenceFailure) as ei:
        part.check()
    assert "converge" in str(ei.value)
    # a budget >= the true step count changes nothing, bit-for-bit
    full = cq.query(srcs, max_steps=int(steps.max()))
    assert full.all_converged
    np.testing.assert_array_equal(full.attrs, base.attrs)


def test_per_query_budget_vector(g):
    cq = flip.compile(g, "sssp",
                      flip.ExecutionPlan(mode="data", tile=TILE))
    srcs = [0, 7, 13, 21]
    steps = np.atleast_1d(cq.query(srcs).steps)
    cap = int(steps.max()) - 1
    mixed = cq.query(srcs, max_steps=[cap, 10_000, cap, 10_000])
    conv = np.atleast_1d(mixed.converged)
    assert conv[1] and conv[3]
    np.testing.assert_array_equal(
        conv, [steps[0] <= cap, True, steps[2] <= cap, True])
    # None entries in a budget vector mean "plan default", same as the
    # scalar form -- never a cast error
    part = cq.query(srcs, max_steps=[cap, None, cap, None])
    np.testing.assert_array_equal(np.atleast_1d(part.converged), conv)
    full = cq.query(srcs)
    assert np.array_equal(part.attrs[1], full.attrs[1])
    assert np.array_equal(part.attrs[3], full.attrs[3])


def test_deadline_expiry_flagged(g):
    cq = flip.compile(g, "sssp",
                      flip.ExecutionPlan(mode="data", tile=TILE))
    srcs = [0, 7, 13, 21]
    base = cq.query(srcs)
    tight = cq.query(srcs, deadline_s=1e-9)
    assert np.any(np.atleast_1d(tight.deadline_expired))
    assert not tight.all_converged
    with pytest.raises(ConvergenceFailure):
        tight.check()
    generous = cq.query(srcs, deadline_s=120.0)
    assert generous.all_converged
    assert not np.any(np.atleast_1d(generous.deadline_expired))
    np.testing.assert_array_equal(generous.attrs, base.attrs)


def test_plan_deadline_default_and_validation(g):
    plan = flip.ExecutionPlan(mode="data", tile=TILE, deadline_s=120.0)
    r = flip.compile(g, "bfs", plan).query([0, 5])
    assert r.all_converged
    assert plan.key() != flip.ExecutionPlan(mode="data", tile=TILE).key()
    with pytest.raises(ValueError):
        flip.ExecutionPlan(deadline_s=0.0).validate()
    with pytest.raises(ValueError):
        flip.ExecutionPlan(deadline_s=5.0, distributed=True).validate()


# ------------------------------------------------------------------ #
# request validation
# ------------------------------------------------------------------ #
def test_session_rejects_bad_sources(g):
    cq = flip.compile(g, "bfs", flip.ExecutionPlan(tile=TILE))
    for bad in (-1, g.n, [2, g.n + 7], [0, -3]):
        with pytest.raises(InvalidRequest) as ei:
            cq.query(bad)
        msg = str(ei.value)
        assert str(g.n) in msg          # names the valid range
    with pytest.raises(InvalidRequest):
        cq.query([0.5, 1])
    with pytest.raises(InvalidRequest):
        cq.query([0, 1], max_steps=0)
    with pytest.raises(InvalidRequest):
        cq.query([0, 1], deadline_s=-1.0)


def test_server_rejects_bad_requests_synchronously(g):
    srv = GraphServer(g, batch=4, tile=TILE)
    for bad in (-1, g.n, "seven"):
        with pytest.raises(InvalidRequest):
            srv.submit("bfs", bad)
    with pytest.raises(InvalidRequest):
        srv.submit("not_an_algo", 0)
    with pytest.raises(InvalidRequest):
        srv.submit("bfs", 0, max_steps=-5)
    with pytest.raises(InvalidRequest):
        srv.submit("bfs", 0, deadline_s=0.0)
    # nothing was enqueued by the rejected submissions
    assert srv.stats()["queue_depth"] == 0


# ------------------------------------------------------------------ #
# per-request failure isolation (the request-loss fix)
# ------------------------------------------------------------------ #
def test_no_request_loss_when_every_rung_fails(g):
    """All rungs poisoned: the bucket's requests each carry the typed
    error (never vanish), and the server keeps serving afterwards."""
    inj = FaultInjector(specs=[FaultSpec(kind="nan", dispatch=0, rung=r)
                               for r in range(4)])
    srv = GraphServer(g, batch=4, tile=TILE, fault_injector=inj)
    reqs = [srv.submit("bfs", i) for i in range(4)]
    assert all(r.done for r in reqs)
    assert all(isinstance(r.error, BackendFailure) for r in reqs)
    assert all(r.result is None for r in reqs)
    assert srv.failed == 4 and srv.stats()["failed"] == 4
    assert srv.stats()["queue_depth"] == 0       # bucket not stuck
    after = [srv.submit("bfs", i) for i in range(4)]
    assert all(r.ok for r in after)
    for r in after:
        assert ALGEBRAS["bfs"].results_match(r.result,
                                             oracle("bfs", g, r.src))


def test_failed_bucket_does_not_poison_other_algebras(g):
    inj = FaultInjector(specs=[FaultSpec(kind="nan", dispatch=0, rung=r,
                                         algo="bfs") for r in range(4)])
    srv = GraphServer(g, batch=2, tile=TILE, fault_injector=inj)
    bfs = [srv.submit("bfs", i) for i in range(2)]        # dispatch 0
    sssp = [srv.submit("sssp", i) for i in range(2)]      # dispatch 1
    assert all(isinstance(r.error, BackendFailure) for r in bfs)
    assert all(r.ok for r in sssp)


# ------------------------------------------------------------------ #
# admission control
# ------------------------------------------------------------------ #
def test_admission_sheds_newest_with_typed_error(g):
    srv = GraphServer(g, batch=8, tile=TILE, max_queue_depth=2)
    a = srv.submit("bfs", 0)
    b = srv.submit("bfs", 1)
    c = srv.submit("bfs", 2)             # newest -> shed
    assert isinstance(c.error, CapacityExceeded)
    assert c.error.depth == 2 and c.error.limit == 2
    assert c.done and not c.ok and c.result is None
    assert a.error is None and b.error is None
    srv.drain()
    assert a.ok and b.ok                 # accepted requests unharmed
    st = srv.stats()
    assert st["shed"] == 1 and st["resilience"]["shed"] == 1
    assert st["completed"] == 2


def test_admission_quota_is_per_algo(g):
    srv = GraphServer(g, batch=8, tile=TILE, quotas={"bfs": 1})
    srv.submit("bfs", 0)
    shed = srv.submit("bfs", 1)
    assert isinstance(shed.error, CapacityExceeded)
    other = srv.submit("sssp", 1)        # no quota -> accepted
    assert other.error is None
    srv.drain()


def test_resilience_off_disables_admission_and_ladder(g):
    srv = GraphServer(g, batch=4, tile=TILE, resilience=False,
                      max_queue_depth=1)
    reqs = [srv.submit("bfs", i) for i in range(4)]   # depth cap ignored
    assert all(r.ok for r in reqs)
    assert srv.shed == 0


# ------------------------------------------------------------------ #
# heartbeat monitor
# ------------------------------------------------------------------ #
def test_heartbeat_rearms_after_each_stall():
    hits = []
    hb = HeartbeatMonitor(timeout_s=0.08, poll_s=0.02,
                          on_stall=lambda: hits.append(1)).start()
    try:
        deadline = time.monotonic() + 5.0
        while hb.stall_count < 1 and time.monotonic() < deadline:
            time.sleep(0.01)
        assert hb.stalled and hb.stall_count == 1 and len(hits) == 1
        hb.beat()                         # re-arm
        assert not hb.stalled
        while hb.stall_count < 2 and time.monotonic() < deadline:
            time.sleep(0.01)              # second stall episode
        assert hb.stall_count == 2 and len(hits) == 2
    finally:
        hb.stop()


def test_heartbeat_stop_joins_and_silences_callback():
    hits = []
    hb = HeartbeatMonitor(timeout_s=0.05, poll_s=0.01,
                          on_stall=lambda: hits.append(1)).start()
    deadline = time.monotonic() + 5.0
    while not hits and time.monotonic() < deadline:
        time.sleep(0.01)
    hb.stop()                             # synchronous: joins the thread
    assert hb._thread is None
    n = len(hits)
    time.sleep(0.1)                       # several poll intervals
    assert len(hits) == n                 # no callback after stop()
    hb.stop()                             # idempotent


def test_stall_fault_trips_wired_heartbeat(g):
    hits = []
    hb = HeartbeatMonitor(timeout_s=0.1, poll_s=0.02,
                          on_stall=lambda: hits.append(1)).start()
    inj = FaultInjector(specs=[FaultSpec(kind="stall", dispatch=0,
                                         rung=0, stall_s=0.3)])
    srv = GraphServer(g, batch=2, tile=TILE, fault_injector=inj,
                      heartbeat=hb)
    try:
        reqs = [srv.submit("bfs", i) for i in range(2)]
        assert all(r.ok for r in reqs)    # the stall only delays
        assert hb.stall_count >= 1 and hits
        assert not hb.stalled             # post-dispatch beat re-armed
        assert srv.stats()["resilience"]["heartbeat_stalls"] >= 1
    finally:
        hb.stop()


# ------------------------------------------------------------------ #
# budgets through the server
# ------------------------------------------------------------------ #
def test_server_step_budget_partial_with_typed_error(g):
    srv = GraphServer(g, batch=4, tile=TILE)
    base = [srv.submit("sssp", i) for i in range(4)]
    srv.drain()
    cap = max(r.steps for r in base) - 1
    assert cap >= 1
    part = [srv.submit("sssp", i, max_steps=cap) for i in range(4)]
    srv.drain()
    hit = [r for r in part if not r.converged]
    assert hit
    for r in hit:
        assert isinstance(r.error, ConvergenceFailure)
        assert r.result is not None       # flagged partial attached
    for r in part:
        if r.converged:
            assert r.error is None
            np.testing.assert_array_equal(r.result, base[r.src].result)


def test_server_deadline_counts_queue_wait(g):
    srv = GraphServer(g, batch=4, tile=TILE)
    reqs = [srv.submit("sssp", i, deadline_s=1e-6) for i in range(4)]
    srv.drain()
    for r in reqs:
        assert r.deadline_expired
        assert isinstance(r.error, DeadlineExceeded)
        assert r.error.code == "deadline_exceeded"


# ------------------------------------------------------------------ #
# the chaos replay
# ------------------------------------------------------------------ #
def _chaos_stream(g0, algos, n_requests, n_updates, seed):
    """Deterministic mixed stream + the graph snapshot each query will
    be served against (submission order is graph-version order)."""
    rng = np.random.default_rng(seed)
    update_at = set(np.linspace(1, n_requests - 1, n_updates,
                                dtype=int).tolist())
    stream, snaps, g_cur = [], [], g0
    for i in range(n_requests):
        if i in update_at:
            eu = g_cur.edge_sources()
            k = int(rng.integers(1, 4))
            idx = rng.choice(g_cur.m, size=min(k, g_cur.m), replace=False)
            batch = [(int(eu[j]), int(g_cur.indices[j]),
                      float(g_cur.weights[j]) * 0.5) for j in idx]
            batch.append((int(rng.integers(g_cur.n)),
                          int(rng.integers(g_cur.n)),
                          float(rng.integers(1, 9))))
            stream.append(("update", batch))
            g_cur = g_cur.apply_updates(batch)
        stream.append((algos[int(rng.integers(len(algos)))],
                       int(rng.integers(g0.n))))
        snaps.append(g_cur)
    return stream, snaps


def test_chaos_replay_zero_loss_typed_errors_exact_successes(g):
    """>= 64 requests over 3 algebras with interleaved updates, under a
    seeded schedule spanning >= 4 failure modes: injected backend
    raises, NaN-poisoned results, a step stall (tripping the wired
    heartbeat), plus deadline and step-budget pressure. Invariants:
    zero lost requests, a typed error on every failure, and bit-exact
    oracle agreement on every success."""
    algos = ["bfs", "sssp", "pagerank"]
    n_req = 72
    stream, snaps = _chaos_stream(g, algos, n_req, n_updates=3, seed=11)

    specs = FaultInjector.random(seed=13, dispatches=40, algos=None,
                                 rate=0.3).specs
    # a nan fault pinned to every rung of one dispatch: guaranteed
    # ladder exhaustion -> per-request typed BackendFailure
    specs += [FaultSpec(kind="nan", dispatch=5, rung=r)
              for r in range(4)]
    # one stall long enough to trip the heartbeat
    specs += [FaultSpec(kind="stall", dispatch=8, rung=0, stall_s=0.3)]
    inj = FaultInjector(specs=specs, seed=13)
    hits = []
    hb = HeartbeatMonitor(timeout_s=0.1, poll_s=0.02,
                          on_stall=lambda: hits.append(1)).start()
    srv = GraphServer(g, batch=4, tile=TILE, fault_injector=inj,
                      heartbeat=hb)
    rng = np.random.default_rng(17)
    reqs = []
    try:
        qi = 0
        for algo, arg in stream:
            if algo == "update":
                srv.update(arg)
                continue
            kw = {}
            roll = rng.random()
            if roll < 0.08:
                kw["max_steps"] = 1          # step-budget pressure
            elif roll < 0.16:
                kw["deadline_s"] = 1e-6      # deadline pressure
            reqs.append(srv.submit(algo, arg, **kw))
            qi += 1
        srv.drain()
    finally:
        hb.stop()

    assert len(reqs) == n_req
    # --- zero lost requests: every submission reached an outcome ---
    assert all(r.done for r in reqs)
    # --- every failure is typed, every success oracle-exact ---
    n_ok = n_failed = 0
    kinds = {f["kind"] for f in inj.fired}
    for r, g_snap in zip(reqs, snaps):
        if r.error is not None:
            n_failed += 1
            assert isinstance(r.error, FlipError), r.error
            assert r.error.code in {
                "backend_failure", "deadline_exceeded",
                "convergence_failure", "capacity_exceeded"}
            if isinstance(r.error, (DeadlineExceeded,
                                    ConvergenceFailure)):
                assert r.result is not None    # flagged partial
        if r.ok:
            n_ok += 1
            assert ALGEBRAS[r.algo].results_match(
                r.result, oracle(r.algo, g_snap, r.src)), \
                (r.req_id, r.algo, r.src, r.rung)
    assert n_ok + n_failed == n_req
    assert n_ok > 0 and n_failed > 0
    # --- the schedule really exercised >= 4 failure modes ---
    error_codes = {r.error.code for r in reqs if r.error is not None}
    assert kinds >= {"raise", "nan", "stall"}, kinds
    assert len(error_codes) + len(kinds) >= 4
    assert hb.stall_count >= 1 and hits
    # --- counters line up: nothing double-counted, nothing dropped ---
    st = srv.stats()
    assert st["completed"] + srv.failed - sum(
        1 for r in reqs if r.error is not None and r.result is not None
    ) == n_req - st["shed"]
    assert st["resilience"]["faults_fired"] == len(inj.fired) > 0
    assert st["queue_depth"] == 0


def test_chaos_replay_is_deterministic(g):
    """Same seeds -> same fault schedule -> identical outcome vector."""
    def run():
        stream, _ = _chaos_stream(g, ["bfs", "sssp"], 16, 1, seed=23)
        inj = FaultInjector.random(seed=29, dispatches=10, rate=0.5)
        srv = GraphServer(g, batch=4, tile=TILE, fault_injector=inj)
        out = []
        for algo, arg in stream:
            if algo == "update":
                srv.update(arg)
            else:
                out.append(srv.submit(algo, arg))
        srv.drain()
        return ([None if r.error is None else r.error.code
                 for r in out],
                [r.rung for r in out], inj.fired)
    a, b = run(), run()
    assert a == b


def test_injected_fault_is_not_a_flip_error():
    """The injector's exception must look foreign to the taxonomy, so
    classify() exercises the real wrap path."""
    assert not isinstance(InjectedFault("x"), FlipError)
    wrapped = classify(InjectedFault("x"), rung=0)
    assert isinstance(wrapped, BackendFailure)
