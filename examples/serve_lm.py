"""Continuous-batching serving example (FLIP frontier semantics over
requests: slots activate on admission, retire at EOS).

  PYTHONPATH=src python examples/serve_lm.py
"""
import subprocess
import sys

subprocess.run(
    [sys.executable, "-m", "repro.launch.serve",
     "--arch", "qwen3_0_6b", "--preset", "tiny",
     "--slots", "8", "--requests", "24", "--max-new", "24"],
    check=True)
