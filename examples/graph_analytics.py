"""Graph analytics on the distributed JAX engine: every registered
vertex algebra on every local device (shard_map over destination tiles).

  PYTHONPATH=src python examples/graph_analytics.py
"""
from repro.algebra import ALGEBRAS
from repro.core import compile_mapping
from repro.core.engine import FlipEngine
from repro.graphs import make_road_network, reference

g = make_road_network(512, seed=1)
mapping = compile_mapping(g, effort=0, seed=0)
print(f"|V|={g.n} |E|={g.m} slices={mapping.num_copies()}")
srcs = [0, 17, 255, 64]          # batched: 4 queries per fixpoint
for algo in sorted(ALGEBRAS):
    eng = FlipEngine.build(g, algo, mapping=mapping, tile=64)
    outs, steps = eng.run_distributed(srcs)
    ok = all(ALGEBRAS[algo].results_match(outs[b],
                                          reference.run(algo, g, s)[0])
             for b, s in enumerate(srcs))
    sem = ALGEBRAS[algo].semiring.name
    print(f"{algo:9s} ({sem:10s}): distributed batch of {len(srcs)} "
          f"correct={ok} steps={steps.tolist()}")
