"""Graph analytics on the distributed JAX engine: every registered
vertex algebra on every local device (shard_map over destination tiles),
through the unified query API -- one distributed ExecutionPlan, one
compiled session per algebra.

  PYTHONPATH=src python examples/graph_analytics.py
"""
import flip
from repro.algebra import ALGEBRAS
from repro.core import compile_mapping
from repro.graphs import make_road_network

g = make_road_network(512, seed=1)
mapping = compile_mapping(g, effort=0, seed=0)
print(f"|V|={g.n} |E|={g.m} slices={mapping.num_copies()}")
srcs = [0, 17, 255, 64]          # batched: 4 queries per fixpoint
plan = flip.ExecutionPlan(tile=64, distributed=True)
for algo in sorted(ALGEBRAS):
    res = flip.compile(g, algo, plan, mapping=mapping).query(srcs)
    sem = ALGEBRAS[algo].semiring.name
    ok = res.check()
    print(f"{algo:9s} ({sem:10s}): distributed batch of {len(srcs)} "
          f"correct={ok} steps={res.steps.tolist()}")
    assert ok, f"{algo} diverged from its oracle"
