"""Graph analytics on the distributed JAX engine: all three paper
workloads on every local device (shard_map over destination tiles).

  PYTHONPATH=src python examples/graph_analytics.py
"""
import numpy as np

from repro.core import compile_mapping
from repro.core.engine import FlipEngine
from repro.graphs import make_road_network, reference

g = make_road_network(512, seed=1)
mapping = compile_mapping(g, effort=0, seed=0)
print(f"|V|={g.n} |E|={g.m} slices={mapping.num_copies()}")
for algo in ("bfs", "sssp", "wcc"):
    eng = FlipEngine.build(g, algo, mapping=mapping, tile=64)
    got = eng.run_distributed(0)
    ref, _ = reference.run(algo, g, 0)
    ok = np.allclose(np.where(np.isinf(got), -1, got),
                     np.where(np.isinf(ref), -1, ref))
    print(f"{algo}: distributed fixpoint correct={ok}")
