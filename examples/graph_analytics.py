"""Graph analytics on the distributed JAX engine: every registered
vertex algebra on every local device (shard_map over destination tiles).

  PYTHONPATH=src python examples/graph_analytics.py
"""
from repro.algebra import ALGEBRAS
from repro.core import compile_mapping
from repro.core.engine import FlipEngine
from repro.graphs import make_road_network, reference

g = make_road_network(512, seed=1)
mapping = compile_mapping(g, effort=0, seed=0)
print(f"|V|={g.n} |E|={g.m} slices={mapping.num_copies()}")
for algo in sorted(ALGEBRAS):
    eng = FlipEngine.build(g, algo, mapping=mapping, tile=64)
    got = eng.run_distributed(0)
    ref, _ = reference.run(algo, g, 0)
    sem = ALGEBRAS[algo].semiring.name
    ok = ALGEBRAS[algo].results_match(got, ref)
    print(f"{algo:9s} ({sem:10s}): distributed fixpoint correct={ok}")
