"""Graph analytics on the distributed JAX engine: every registered
vertex algebra on every local device (shard_map over destination tiles),
through the unified query API -- one distributed ExecutionPlan, one
compiled session per algebra. Vector programs (feature_dim > 1) return
(n, d) feature blocks from the same sessions; the label-propagation demo
at the end turns one of them into community labels.

  PYTHONPATH=src python examples/graph_analytics.py
"""
import numpy as np

import flip
from repro.algebra import ALGEBRAS, landmarks
from repro.core import compile_mapping
from repro.graphs import make_road_network

g = make_road_network(512, seed=1)
mapping = compile_mapping(g, effort=0, seed=0)
print(f"|V|={g.n} |E|={g.m} slices={mapping.num_copies()}")
srcs = [0, 17, 255, 64]          # batched: 4 queries per fixpoint
plan = flip.ExecutionPlan(tile=64, distributed=True)
for algo in sorted(ALGEBRAS):
    res = flip.compile(g, algo, plan, mapping=mapping).query(srcs)
    alg = ALGEBRAS[algo]
    ok = res.check()
    shape = "x".join(map(str, res.attrs.shape))
    print(f"{algo:9s} ({alg.semiring.name:10s}): distributed batch of "
          f"{len(srcs)} correct={ok} steps={res.steps.tolist()} "
          f"attrs={shape}")
    assert ok, f"{algo} diverged from its oracle"

# ------------------------------------------------------------------ #
# label propagation: one vector-state fixpoint diffuses 8 seeded label
# masses through the damped-walk (+, x) operator -- each weight block
# streamed once feeds all 8 lanes as a (T, T) x (T, 8) matmul -- and
# argmax over the feature axis assigns every vertex its community
# ------------------------------------------------------------------ #
src = 0
res = flip.compile(g, "labelprop", flip.ExecutionPlan(tile=64)).query(src)
assert res.check(), "labelprop diverged from its (n, d) oracle"
lm = landmarks(g.n, src, 8)
labels = np.argmax(res.attrs, axis=1)
assert (labels[lm] == np.arange(8)).all(), \
    "every landmark must claim its own label"
sizes = np.bincount(labels, minlength=8)
print(f"labelprop communities from landmarks {lm.tolist()}: "
      f"sizes={sizes.tolist()} ({res.steps} steps, one (n, 8) fixpoint)")
