"""Quickstart: the paper's pipeline in 30 lines.

Builds a road network, compiles the vertex->PE mapping with the FLIP
compiler, runs SSSP three ways (cycle-accurate simulator, TPU-native JAX
frontier engine, classic op-centric mode), and verifies against Dijkstra.

The engine runs go through the unified query API: compile a
(graph, program, plan) session once, then query it.

  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

import flip
from repro.core import SSSP, compile_mapping, simulate, baselines
from repro.graphs import make_road_network, reference

g = make_road_network(256, seed=0)                   # Table-4 "LRN" graph
print(f"graph: |V|={g.n} |E|={g.m}")

mapping = compile_mapping(g, program=SSSP, seed=0)   # Algorithm 1 + 2
print(f"mapping: avg routing length {mapping.avg_routing_length():.2f} "
      f"(paper Table 8: 0.76 for LRN)")

# 1. cycle-accurate FLIP simulator (the paper's evaluation vehicle)
r = simulate(mapping, SSSP, src=5)
t_us = r.cycles / mapping.arch.freq_mhz
print(f"simulator: {r.cycles} cycles = {t_us:.1f}us @100MHz, "
      f"parallelism {r.avg_parallelism:.1f} avg / {r.max_parallelism} max")
print(f"speedup: {baselines.mcu_cycles('sssp', g, 5).time_us / t_us:.0f}x "
      f"vs MCU, {baselines.cgra_cycles('sssp', g, 5).time_us / t_us:.0f}x "
      f"vs op-centric CGRA")

# 2. TPU-native frontier engine (data-centric mode, the default plan)
res = flip.compile(g, "sssp", mapping=mapping).query(5)
print(f"jax engine (data-centric): fixpoint in {res.steps} steps")

# 3. classic op-centric mode (one plan knob, Sec. 3.4)
res_op = flip.compile(g, "sssp", flip.ExecutionPlan(mode="op"),
                      mapping=mapping).query(5)

ref, _ = reference.sssp(g, 5)
for name, a in [("sim", r.attrs), ("data", res.attrs),
                ("op", res_op.attrs)]:
    ok = np.allclose(np.where(np.isinf(a), -1, a),
                     np.where(np.isinf(ref), -1, ref))
    print(f"correct ({name} vs Dijkstra): {ok}")
    assert ok, f"{name} diverged from Dijkstra"
