"""End-to-end LM training driver (deliverable b).

Trains a reduced qwen3-family model on the deterministic synthetic corpus
for a few hundred steps with checkpointing; demonstrates the full substrate
(data pipeline -> sharded train step -> AdamW -> async checkpoints).

CPU (default, ~15M params):
  PYTHONPATH=src python examples/train_lm.py
Real hardware (full 0.6B config, add a mesh):
  PYTHONPATH=src python -m repro.launch.train --arch qwen3_0_6b \
      --preset full --steps 300 --mesh auto
"""
import subprocess
import sys

subprocess.run(
    [sys.executable, "-m", "repro.launch.train",
     "--arch", "qwen3_0_6b", "--preset", "tiny",
     "--steps", "200", "--seq", "128", "--batch", "8",
     "--ckpt-dir", "checkpoints/example_lm", "--ckpt-every", "100",
     "--log-every", "20"],
    check=True)
