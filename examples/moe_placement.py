"""The paper-technique bridge: FLIP's mapping compiler placing MoE experts.

Collects router co-activation statistics from a (smoke-size) MoE model on
synthetic data, compiles an expert->device placement with the FLIP mapping
compiler (affinity-weighted routing length), and reports the traffic
reduction vs the identity layout.

  PYTHONPATH=src python examples/moe_placement.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke
from repro.core.placement import expert_affinity, place_experts
from repro.models import model as M, moe

cfg = get_smoke("qwen3_moe_235b_a22b")
params = M.init_params(cfg, jax.random.PRNGKey(0))
rng = np.random.default_rng(0)

# run the router over a few synthetic batches, collect top-k decisions
p0 = params["blocks"]["block0"]["ffn"]
router = jax.tree_util.tree_map(lambda x: x[0], p0)["router"]
topks = []
for i in range(16):
    toks = rng.integers(0, cfg.vocab_size, (4, 32))
    x = jnp.take(params["embed"], jnp.asarray(toks), axis=0)
    logits = jnp.einsum("bsd,de->bse", x.astype(jnp.float32),
                        router.astype(jnp.float32))
    _, ids = jax.lax.top_k(logits, cfg.top_k)
    topks.append(np.asarray(ids).reshape(-1, cfg.top_k))
aff = expert_affinity(np.concatenate(topks), cfg.num_experts)

pl = place_experts(aff, num_devices=4, seed=0)
print(f"experts: {cfg.num_experts}, top-{cfg.top_k}, 4 devices")
print(f"affinity-weighted routing cost: identity={pl.baseline_cost:.0f} "
      f"FLIP-placed={pl.est_cost:.0f} "
      f"({100 * (1 - pl.est_cost / max(pl.baseline_cost, 1e-9)):.0f}% less"
      f" expected cross-device traffic)")
print(f"expert order: {pl.perm.tolist()}")
